//! Model converter showcase (paper §2.2.3 + the size columns of Tables
//! 1–2): build REAL-width ResNet-18 checkpoints in memory, convert, and
//! verify the paper's 29× compression and the Table 2 size ladder exactly.
//!
//!     cargo run --release --example convert_and_compare
//!
//! Also proves converted models still run: output equality between the
//! f32-weights engine path and the packed path is asserted for LeNet.

use anyhow::Result;
use repro::bench::harness::BenchTable;
use repro::data::Rng;
use repro::model::bmx::convert;
use repro::model::ckpt::Checkpoint;
use repro::model::inventory::{self, Inventory, Stem};
use repro::nn::Engine;
use repro::runtime::Manifest;
use repro::tensor::Tensor;

const MB: f64 = 1024.0 * 1024.0;

/// Materialize a random checkpoint matching an inventory.
fn random_ckpt(inv: &Inventory, seed: u64) -> Checkpoint {
    let mut rng = Rng::new(seed);
    let mut ck = Checkpoint::new();
    for p in &inv.params {
        let name = if p.name.starts_with("state.") {
            p.name.clone()
        } else {
            format!("params.{}", p.name)
        };
        let data: Vec<f32> = (0..p.numel())
            .map(|_| {
                let v = rng.normal() * 0.1;
                if name.contains(".var") {
                    v.abs() + 0.5
                } else {
                    v
                }
            })
            .collect();
        ck.push_f32(&name, p.shape.clone(), data);
    }
    ck
}

fn main() -> Result<()> {
    // --- Table 1: CIFAR ResNet-18, real width ---------------------------
    let inv_fp = inventory::resnet18(64, 10, Stem::Cifar, &[1, 2, 3, 4]);
    let inv_bin = inventory::resnet18(64, 10, Stem::Cifar, &[]);
    let ck = random_ckpt(&inv_bin, 1);
    let meta = r#"{"arch": "resnet18", "classes": 10, "fp_stages": []}"#;
    let bmx = convert(&ck, &inv_bin.binary_names(), meta)?;
    println!(
        "ResNet-18 (CIFAR): f32 {:.1} MB -> .bmx {:.1} MB = {:.1}x   (paper: 44.7 -> 1.5 MB, 29x)",
        inv_fp.fp32_bytes() as f64 / MB,
        bmx.payload_bytes() as f64 / MB,
        inv_fp.fp32_bytes() as f64 / bmx.payload_bytes() as f64
    );
    assert_eq!(bmx.payload_bytes(), inv_bin.bmx_bytes(), "accounting mismatch");

    // the converted real-width model actually runs
    let engine = Engine::from_bmx(&bmx)?;
    let logits = engine.forward(&Tensor::full(vec![1, 3, 32, 32], 0.2))?;
    println!("real-width binary ResNet-18 forward OK: {:?} logits", logits.shape());

    // --- Table 2: ImageNet ResNet-18 size ladder ------------------------
    let mut table = BenchTable::new(
        "Table 2 size ladder (ImageNet ResNet-18)",
        &["fp stage", "ours", "paper"],
    );
    for (label, fp_stages, paper) in [
        ("none", vec![], "3.6MB"),
        ("1st", vec![1], "4.1MB"),
        ("2nd", vec![2], "5.6MB"),
        ("3rd", vec![3], "11.3MB"),
        ("4th", vec![4], "36MB"),
        ("1st,2nd", vec![1, 2], "6.2MB"),
        ("all", vec![1, 2, 3, 4], "47MB"),
    ] {
        let inv = inventory::resnet18(64, 1000, Stem::Imagenet, &fp_stages);
        table.row(vec![
            label.into(),
            format!("{:.1} MB", inv.bmx_bytes() as f64 / MB),
            paper.into(),
        ]);
    }
    table.print();

    // --- LeNet: converted model == PJRT-shaped init model ---------------
    if let Ok(manifest) = Manifest::load(repro::ARTIFACTS_DIR) {
        let entry = manifest.model("lenet_bin")?;
        let ck = Checkpoint::load(manifest.path(&entry.init_ckpt))?;
        let bmx = convert(&ck, &inventory::lenet(true).binary_names(), &entry.bmx_meta())?;
        let engine = Engine::from_bmx(&bmx)?;
        let x = Tensor::full(vec![1, 1, 28, 28], 0.1);
        let y = engine.forward(&x)?;
        println!(
            "LeNet conversion: {:.0} kB packed, logits[0]={:.3} (finite: {})",
            bmx.payload_bytes() as f64 / 1024.0,
            y.data()[0],
            y.data().iter().all(|v| v.is_finite())
        );
    } else {
        println!("(artifacts not built; LeNet demo skipped)");
    }
    Ok(())
}
