//! Quickstart: the whole stack in ~40 lines.
//!
//!     make artifacts                       # once (python AOT path)
//!     cargo run --release --example quickstart
//!
//! Loads the binary-LeNet init checkpoint, converts it to the packed
//! `.bmx` deployment format (paper §2.2.3), builds the Rust xnor inference
//! engine and classifies a batch of synthetic digits.

use anyhow::Result;
use repro::data::Kind;
use repro::model::bmx::convert;
use repro::model::ckpt::Checkpoint;
use repro::model::inventory;
use repro::nn::Engine;
use repro::runtime::Manifest;

fn main() -> Result<()> {
    // 1. The manifest describes every AOT artifact python emitted.
    let manifest = Manifest::load(repro::ARTIFACTS_DIR)?;
    let entry = manifest.model("lenet_bin")?;

    // 2. Convert the f32 checkpoint: Q-layer weights -> 1 bit each.
    let ckpt = Checkpoint::load(manifest.path(&entry.init_ckpt))?;
    let binary_names = inventory::lenet(true).binary_names();
    let bmx = convert(&ckpt, &binary_names, &entry.bmx_meta())?;
    println!(
        "converted: {} tensors, packed payload {:.1} kB",
        bmx.tensors.len(),
        bmx.payload_bytes() as f64 / 1024.0
    );

    // 3. Build the xnor inference engine and classify some digits.
    let engine = Engine::from_bmx(&bmx)?;
    let ds = Kind::Digits.generate(8, 1);
    let preds = engine.classify(&ds.images, 8)?;
    for (i, (class, score)) in preds.iter().enumerate() {
        println!(
            "image {i}: label={} pred={class} (logit {score:.2})",
            ds.labels[i]
        );
    }
    println!("note: untrained weights — run --example train_binary_lenet for real accuracy");
    Ok(())
}
