//! Serving demo — the role of the paper's Android/iOS apps (§4.2), as an
//! inference server: load a (trained if available, else init) binary LeNet
//! `.bmx`, start the coordinator, fire concurrent requests, report
//! latency percentiles and throughput.
//!
//!     cargo run --release --example serve_classifier [requests] [producers]

use anyhow::Result;
use std::sync::Arc;
use std::time::{Duration, Instant};

use repro::coordinator::{BatchPolicy, Server, ServerConfig};
use repro::data::Kind;
use repro::model::bmx::{convert, BmxModel};
use repro::model::ckpt::Checkpoint;
use repro::model::inventory;
use repro::nn::Engine;
use repro::runtime::Manifest;

fn load_model(manifest: &Manifest) -> Result<BmxModel> {
    // prefer the checkpoint the e2e example writes
    let trained = std::path::Path::new("target/e2e/lenet_bin.bmx");
    if trained.exists() {
        println!("using trained model {trained:?}");
        return BmxModel::load(trained);
    }
    println!("using init checkpoint (run --example train_binary_lenet for a trained one)");
    let entry = manifest.model("lenet_bin")?;
    let ck = Checkpoint::load(manifest.path(&entry.init_ckpt))?;
    convert(&ck, &inventory::lenet(true).binary_names(), &entry.bmx_meta())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(512);
    let producers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    let manifest = Manifest::load(repro::ARTIFACTS_DIR)?;
    let engine = Arc::new(Engine::from_bmx(&load_model(&manifest)?)?);
    let ds = Kind::Digits.generate(requests, 23);

    let server = Server::start(
        engine,
        ServerConfig {
            policy: BatchPolicy { max_batch: 32, window: Duration::from_millis(2) },
            queue_cap: 4096,
        },
    );

    println!("== {requests} requests from {producers} concurrent producers ==");
    let t0 = Instant::now();
    let correct: usize = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for p in 0..producers {
            let client = server.client();
            let ds = &ds;
            handles.push(s.spawn(move || {
                let mut ok = 0usize;
                for i in (p..requests).step_by(producers) {
                    let resp = client.classify(ds.image(i).to_vec()).unwrap();
                    if resp.class == ds.labels[i] as usize {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    let wall = t0.elapsed();
    let snap = server.shutdown();

    println!(
        "throughput: {:.0} req/s  |  accuracy {:.3}",
        requests as f64 / wall.as_secs_f64(),
        correct as f64 / requests as f64
    );
    println!("{}", snap.summary());
    Ok(())
}
