//! End-to-end driver (DESIGN.md §End-to-end): train binary LeNet on
//! synth-MNIST through the AOT train_step (PJRT, float dots on ±1 values),
//! log the loss curve, evaluate with BOTH the PJRT graph and the Rust xnor
//! engine, convert to `.bmx`, and report the compression ratio.
//!
//!     cargo run --release --example train_binary_lenet [steps] [examples]
//!
//! Defaults: 300 steps, 4096 train / 1024 test examples.  Results recorded
//! in EXPERIMENTS.md §End-to-end.

use anyhow::Result;
use repro::data::Kind;
use repro::model::bmx::convert;
use repro::model::inventory;
use repro::nn::Engine;
use repro::runtime::{Manifest, Runtime};
use repro::train::{train, TrainConfig};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let train_examples: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4096);

    let manifest = Manifest::load(repro::ARTIFACTS_DIR)?;
    let rt = Runtime::cpu()?;
    println!("platform: {}", rt.platform());

    let out_dir = std::path::PathBuf::from("target/e2e");
    std::fs::create_dir_all(&out_dir)?;
    let cfg = TrainConfig {
        model: "lenet_bin".into(),
        dataset: Kind::Digits,
        steps,
        lr: 0.05,
        lr_decay_steps: steps / 3,
        lr_decay: 0.5,
        train_examples,
        test_examples: train_examples / 4,
        seed: 42,
        log_every: 20,
        eval_every: (steps / 4).max(1),
        out_ckpt: Some(out_dir.join("lenet_bin_trained.bmxc")),
        metrics_csv: Some(out_dir.join("lenet_bin_loss_curve.csv")),
    };
    println!("== training binary LeNet: {steps} steps, batch 64 ==");
    let report = train(&rt, &manifest, &cfg)?;
    println!(
        "loss: {:.4} (first 5 avg) -> {:.4} (last 5 avg) | {:.2} steps/s | {:.0}ms/step",
        report.metrics.mean_loss_head(5),
        report.metrics.mean_loss_tail(5),
        report.steps_per_sec,
        report.metrics.mean_step_ms(),
    );
    println!("PJRT eval accuracy: {:.4}", report.final_eval_acc);

    // Deploy: convert the trained checkpoint and evaluate on the Rust
    // xnor engine — the Eq. 2 equivalence means accuracy must match the
    // PJRT number (same logits, same argmax).
    let entry = manifest.model("lenet_bin")?;
    let ckpt = repro::model::ckpt::Checkpoint::load(out_dir.join("lenet_bin_trained.bmxc"))?;
    let names = inventory::lenet(true).binary_names();
    let bmx = convert(&ckpt, &names, &entry.bmx_meta())?;
    let bmx_path = out_dir.join("lenet_bin.bmx");
    bmx.save(&bmx_path)?;

    let fp_bytes: usize = ckpt
        .tensors
        .iter()
        .map(|(_, s, _)| 4 * s.iter().product::<usize>())
        .sum();
    println!(
        "converter: f32 {:.2} MB -> .bmx {:.0} kB ({:.1}x compression; paper LeNet: 4.6MB -> 206kB)",
        fp_bytes as f64 / (1024.0 * 1024.0),
        bmx.payload_bytes() as f64 / 1024.0,
        fp_bytes as f64 / bmx.payload_bytes() as f64,
    );

    let engine = Engine::from_bmx(&bmx)?;
    let test = Kind::Digits.generate(cfg.test_examples, 777);
    let t0 = std::time::Instant::now();
    let rust_acc = engine.accuracy(&test.images, &test.labels, 32)?;
    let wall = t0.elapsed();
    println!(
        "rust xnor engine: accuracy {:.4} on {} fresh images ({:.0} img/s)",
        rust_acc,
        test.len(),
        test.len() as f64 / wall.as_secs_f64()
    );
    println!("loss curve -> {:?}", out_dir.join("lenet_bin_loss_curve.csv"));

    anyhow::ensure!(
        report.metrics.mean_loss_tail(5) < report.metrics.mean_loss_head(5),
        "training did not reduce the loss"
    );
    Ok(())
}
