//! Bit-width ablation (paper §2.1): train LeNet at act_bit ∈ {1, 2, 4, 32}
//! on synth-MNIST and compare accuracy + deployed size — the trade-off the
//! Q-layers' `act_bit` parameter exposes.
//!
//!     cargo run --release --example quantization_sweep [steps]
//!
//! Expected shape: accuracy rises (or saturates) with bit width while the
//! deployable size grows 32× from 1-bit to full precision.

use anyhow::Result;
use repro::bench::harness::BenchTable;
use repro::data::Kind;
use repro::model::bmx::{convert, convert_kbit};
use repro::model::ckpt::Checkpoint;
use repro::model::inventory;
use repro::nn::Engine;
use repro::runtime::{Manifest, Runtime};
use repro::train::{train, TrainConfig};

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    let man = Manifest::load(repro::ARTIFACTS_DIR)?;
    let rt = Runtime::cpu()?;

    let mut table = BenchTable::new(
        "act_bit sweep: LeNet on synth-MNIST",
        &["act_bit", "train acc (PJRT)", "engine acc", "deployed size"],
    );
    for (model, act_bit) in [
        ("lenet_bin", 1u32),
        ("lenet_q2", 2),
        ("lenet_q4", 4),
        ("lenet_fp", 32),
    ] {
        if man.model(model).is_err() {
            println!("({model} artifacts missing, skipped)");
            continue;
        }
        println!("-- {model} (act_bit={act_bit}, {steps} steps) --");
        let mut cfg = TrainConfig::quick(model, Kind::Digits, steps);
        cfg.log_every = 50;
        cfg.lr_decay_steps = steps / 3;
        let report = train(&rt, &man, &cfg)?;

        // deploy through the right converter and evaluate on the engine
        let entry = man.model(model)?;
        let mut ck = Checkpoint::new();
        for (spec, data) in entry.params.iter().zip(&report.params) {
            ck.push_f32(&format!("params.{}", spec.name), spec.shape.clone(), data.clone());
        }
        for (spec, data) in entry.state.iter().zip(&report.state) {
            ck.push_f32(&format!("state.{}", spec.name), spec.shape.clone(), data.clone());
        }
        let names = if act_bit == 32 {
            vec![]
        } else {
            inventory::lenet(true).binary_names()
        };
        let bmx = match act_bit {
            1 | 32 => convert(&ck, &names, &entry.bmx_meta())?,
            k => convert_kbit(&ck, &names, k, &entry.bmx_meta())?,
        };
        let engine = Engine::from_bmx(&bmx)?;
        let test = Kind::Digits.generate(512, 909);
        let engine_acc = engine.accuracy(&test.images, &test.labels, 32)?;
        table.row(vec![
            act_bit.to_string(),
            format!("{:.3}", report.final_eval_acc),
            format!("{engine_acc:.3}"),
            format!("{:.0} kB", bmx.payload_bytes() as f64 / 1024.0),
        ]);
    }
    table.print();
    Ok(())
}
