//! Accuracy columns for Tables 1 and 2: train each model variant on its
//! synthetic dataset and report test accuracy + converted size.
//!
//!     cargo run --release --example table_accuracy [steps] [--table2]
//!
//! Defaults to 150 steps per model (enough for the *ordering* the paper's
//! tables show; raise for tighter numbers).  Without --table2 only the
//! Table 1 pairs run (binary vs fp LeNet and mini-ResNet); with --table2
//! the 7 partial-binarization configs train as well (slow on one core).

use anyhow::Result;
use repro::bench::harness::BenchTable;
use repro::data::Kind;
use repro::model::bmx::convert;
use repro::model::ckpt::Checkpoint;
use repro::model::inventory::{self, Stem};
use repro::runtime::{Manifest, Runtime};
use repro::train::{train, TrainConfig};

fn run_one(
    rt: &Runtime,
    man: &Manifest,
    model: &str,
    dataset: Kind,
    steps: usize,
) -> Result<(f64, usize)> {
    println!("-- training {model} ({steps} steps) --");
    let mut cfg = TrainConfig::quick(model, dataset, steps);
    cfg.log_every = 50;
    cfg.lr = if model.starts_with("lenet") { 0.05 } else { 0.02 };
    cfg.lr_decay_steps = steps / 3;
    let report = train(rt, man, &cfg)?;

    // converted size of the trained model
    let entry = man.model(model)?;
    let mut ck = Checkpoint::new();
    for (spec, data) in entry.params.iter().zip(&report.params) {
        ck.push_f32(&format!("params.{}", spec.name), spec.shape.clone(), data.clone());
    }
    for (spec, data) in entry.state.iter().zip(&report.state) {
        ck.push_f32(&format!("state.{}", spec.name), spec.shape.clone(), data.clone());
    }
    let names = match entry.arch.as_str() {
        "lenet" => {
            if matches!(entry.raw.get("binary"), Some(repro::model::json::Value::Bool(true))) {
                inventory::lenet(true).binary_names()
            } else {
                vec![]
            }
        }
        _ => {
            let width = entry.raw.get("width").and_then(|v| v.as_usize()).unwrap_or(64);
            inventory::resnet18(width, entry.classes, Stem::Cifar, &entry.fp_stages())
                .binary_names()
        }
    };
    let bmx = convert(&ck, &names, &entry.bmx_meta())?;
    Ok((report.final_eval_acc, bmx.payload_bytes()))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args
        .iter()
        .find_map(|a| a.parse().ok())
        .unwrap_or(150);
    let table2 = args.iter().any(|a| a == "--table2");

    let man = Manifest::load(repro::ARTIFACTS_DIR)?;
    let rt = Runtime::cpu()?;

    let mut t1 = BenchTable::new(
        "Table 1 (synthetic stand-ins): accuracy + size",
        &["dataset", "model", "acc", "size", "paper acc", "paper size"],
    );
    for (model, dataset, label, pacc, psize) in [
        ("lenet_bin", Kind::Digits, "synth-MNIST", "0.97", "206kB"),
        ("lenet_fp", Kind::Digits, "synth-MNIST", "0.99", "4.6MB"),
        ("resnet_mini_bin", Kind::Cifar, "synth-CIFAR", "0.86", "1.5MB"),
        ("resnet_mini_fp", Kind::Cifar, "synth-CIFAR", "0.90", "44.7MB"),
    ] {
        let (acc, bytes) = run_one(&rt, &man, model, dataset, steps)?;
        t1.row(vec![
            label.into(),
            model.into(),
            format!("{acc:.3}"),
            format!("{:.0} kB", bytes as f64 / 1024.0),
            pacc.into(),
            psize.into(),
        ]);
    }
    t1.print();

    if table2 {
        let mut t2 = BenchTable::new(
            "Table 2 (synth-ImageNet-100, mini width): accuracy + size",
            &["fp stage", "acc", "size kB", "paper acc", "paper size"],
        );
        for (cfg, label, pacc, psize) in [
            ("none", "none", "0.42", "3.6MB"),
            ("fp1", "1st", "0.48", "4.1MB"),
            ("fp2", "2nd", "0.44", "5.6MB"),
            ("fp3", "3rd", "0.49", "11.3MB"),
            ("fp4", "4th", "0.47", "36MB"),
            ("fp12", "1st,2nd", "0.49", "6.2MB"),
            ("all", "all", "0.61", "47MB"),
        ] {
            let model = format!("resnet_mini_img_{cfg}");
            let (acc, bytes) = run_one(&rt, &man, &model, Kind::Imagenet, steps)?;
            t2.row(vec![
                label.into(),
                format!("{acc:.3}"),
                format!("{:.0}", bytes as f64 / 1024.0),
                pacc.into(),
                psize.into(),
            ]);
        }
        t2.print();
    } else {
        println!("(pass --table2 to also train the 7 partial-binarization configs)");
    }
    Ok(())
}
