//! End-to-end tests of the perf-regression gate: `bmxnet bench-suite`
//! writing schema-2 records and `bmxnet bench-compare` judging them,
//! including the non-zero exit path CI depends on.
//!
//! Runs the real binary (`CARGO_BIN_EXE_bmxnet`); the suite invocation
//! uses `--filter tables` (byte-exact, deterministic, no timing) so the
//! test is fast and flake-free.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

use repro::bench::harness::Stats;
use repro::bench::{PerfRecord, Provenance, Unit};

fn tmp_dir(case: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bench_compare_{}_{case}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn bmxnet(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_bmxnet"))
        .args(args)
        .output()
        .expect("run bmxnet")
}

fn write_record(path: &Path, bench: &str, cells: &[(&str, Unit, f64, f64)]) {
    let mut rec = PerfRecord::new(bench, Provenance::capture("bench_compare test"));
    for &(id, unit, median, mad) in cells {
        rec.push(id, unit, Stats { median, min: median, mad, reps: 3 });
    }
    rec.write(path).unwrap();
}

#[test]
fn suite_quick_emits_schema_valid_records_and_self_compares_clean() {
    let dir = tmp_dir("suite");
    let out = bmxnet(&[
        "bench-suite",
        "--quick",
        "--filter",
        "tables",
        "--json",
        dir.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "bench-suite failed: {}", String::from_utf8_lossy(&out.stderr));
    let path = dir.join("BENCH_tables.json");
    let rec = PerfRecord::load(&path).expect("schema-valid record on disk");
    assert_eq!(rec.bench, "tables");
    assert!(!rec.cells.is_empty());
    // provenance is populated, not defaulted
    assert_eq!(rec.provenance.tool, "bmxnet bench-suite");
    assert!(!rec.provenance.git.is_empty());
    assert!(!rec.provenance.rustc.is_empty());
    assert!(rec.provenance.dispatch.contains("kernel"));
    assert!(rec.provenance.quick);

    // self-compare (dir vs dir) must pass with zero regressions
    let out = bmxnet(&["bench-compare", dir.to_str().unwrap(), dir.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stdout));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("bench-compare: OK"), "{stdout}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn injected_regression_exits_nonzero() {
    let dir = tmp_dir("inject");
    let base = dir.join("base.json");
    let new = dir.join("new.json");
    write_record(&base, "gemm", &[("fig1/C=64/naive", Unit::Ms, 10.0, 0.0)]);
    write_record(&new, "gemm", &[("fig1/C=64/naive", Unit::Ms, 15.0, 0.0)]);
    let out = bmxnet(&["bench-compare", base.to_str().unwrap(), new.to_str().unwrap()]);
    assert!(!out.status.success(), "a 50% regression must exit non-zero");
    let all = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(all.contains("REGRESSED"), "{all}");

    // raising --fail-on above the delta turns the gate green
    let out = bmxnet(&[
        "bench-compare",
        base.to_str().unwrap(),
        new.to_str().unwrap(),
        "--fail-on",
        "60",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn noisy_delta_is_suppressed_until_min_effect_shrinks() {
    let dir = tmp_dir("noise");
    let base = dir.join("base.json");
    let new = dir.join("new.json");
    // +40% but the MAD floor (3 × 0.2 = 0.6) swallows the 0.4ms delta
    write_record(&base, "gemm", &[("a/b/c", Unit::Ms, 1.0, 0.2)]);
    write_record(&new, "gemm", &[("a/b/c", Unit::Ms, 1.4, 0.2)]);
    let out = bmxnet(&["bench-compare", base.to_str().unwrap(), new.to_str().unwrap()]);
    assert!(out.status.success(), "within-noise delta must pass");
    // shrink the floor below the delta -> regression
    let out = bmxnet(&[
        "bench-compare",
        base.to_str().unwrap(),
        new.to_str().unwrap(),
        "--min-effect",
        "1",
    ]);
    assert!(!out.status.success(), "1xMAD floor (0.2) < 0.4 delta must fail");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mismatched_schema_and_families_are_loud_errors() {
    let dir = tmp_dir("mismatch");
    let base = dir.join("base.json");
    let new = dir.join("new.json");
    write_record(&base, "gemm", &[("a", Unit::Ms, 1.0, 0.0)]);
    write_record(&new, "serve", &[("a", Unit::Ms, 1.0, 0.0)]);
    let out = bmxnet(&["bench-compare", base.to_str().unwrap(), new.to_str().unwrap()]);
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("different bench families"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );

    // old/foreign schema version: refuse, never mis-align
    std::fs::write(&new, "{\"schema\": 1, \"bench\": \"gemm\", \"cells\": []}").unwrap();
    let out = bmxnet(&["bench-compare", base.to_str().unwrap(), new.to_str().unwrap()]);
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("schema"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn missing_cells_warn_but_pass_and_json_verdict_reports_them() {
    let dir = tmp_dir("missing");
    let base = dir.join("base.json");
    let new = dir.join("new.json");
    write_record(
        &base,
        "gemm",
        &[("keep", Unit::Ms, 1.0, 0.0), ("gone", Unit::Ms, 2.0, 0.0)],
    );
    write_record(
        &new,
        "gemm",
        &[("keep", Unit::Ms, 1.0, 0.0), ("added", Unit::Ms, 3.0, 0.0)],
    );
    let out = bmxnet(&[
        "bench-compare",
        base.to_str().unwrap(),
        new.to_str().unwrap(),
        "--json",
    ]);
    assert!(out.status.success(), "missing cells alone must not fail the gate");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("\"failed\": false"), "{stdout}");
    assert!(stdout.contains("\"missing\": 2"), "{stdout}");
    assert!(stdout.contains("\"verdict\": \"removed\""), "{stdout}");
    assert!(stdout.contains("\"verdict\": \"new cell\""), "{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn record_round_trips_through_disk_and_reqs_direction() {
    let dir = tmp_dir("roundtrip");
    let path = dir.join("rec.json");
    let mut rec = PerfRecord::new("serve", Provenance::capture("roundtrip"));
    rec.provenance.reps = 5;
    rec.provenance.quick = true;
    rec.provenance.note = "unit \"quoted\" note".into();
    rec.push("w=1,p=4/req_s", Unit::ReqPerSec, Stats { median: 812.5, min: 800.0, mad: 6.25, reps: 5 });
    rec.push("w=1,p=4/p95", Unit::Ms, Stats::exact(3.0));
    rec.write(&path).unwrap();
    let back = PerfRecord::load(&path).unwrap();
    assert_eq!(back, rec);
    assert!(!back.cell("w=1,p=4/req_s").unwrap().unit.lower_is_better());

    // throughput drop regresses end-to-end through the binary
    let worse = dir.join("worse.json");
    let mut w = back.clone();
    w.cells[0].stats = Stats { median: 500.0, min: 500.0, mad: 6.25, reps: 5 };
    w.write(&worse).unwrap();
    let out = bmxnet(&["bench-compare", path.to_str().unwrap(), worse.to_str().unwrap()]);
    assert!(!out.status.success(), "req/s drop must regress");
    let _ = std::fs::remove_dir_all(&dir);
}
