//! Model converter integration (paper §2.2.3): convert the real init
//! checkpoints, verify bit-exactness, file roundtrips and the size
//! accounting against the inventory predictions.

use repro::model::bmx::{convert, BmxModel};
use repro::model::ckpt::Checkpoint;
use repro::model::inventory::{self, Stem};
use repro::quant::sign_binarize;
use repro::runtime::Manifest;

fn manifest() -> Option<Manifest> {
    match Manifest::load(repro::ARTIFACTS_DIR) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (artifacts not built): {e:#}");
            None
        }
    }
}

#[test]
fn lenet_bin_conversion_bit_exact_and_compresses() {
    let Some(man) = manifest() else { return };
    let entry = man.model("lenet_bin").unwrap();
    let ck = Checkpoint::load(man.path(&entry.init_ckpt)).unwrap();
    let names = inventory::lenet(true).binary_names();
    let bmx = convert(&ck, &names, &entry.bmx_meta()).unwrap();

    // every packed bit equals the sign of the original f32 weight
    for name in &names {
        let (_, packed) = bmx.get_packed(name).unwrap();
        let (_, orig) = ck.get_f32(&format!("params.{name}")).unwrap();
        let unpacked = packed.unpack();
        assert_eq!(unpacked.len(), orig.len(), "{name}");
        for (u, o) in unpacked.iter().zip(orig) {
            assert_eq!(*u, sign_binarize(*o), "{name}");
        }
    }

    // size accounting matches the inventory prediction exactly
    let inv = inventory::lenet(true);
    assert_eq!(bmx.payload_bytes(), inv.bmx_bytes(), "payload bytes");
    let fp_bytes: usize = ck
        .tensors
        .iter()
        .map(|(_, s, _)| 4 * s.iter().product::<usize>())
        .sum();
    assert_eq!(fp_bytes, inv.fp32_bytes(), "fp bytes");
    let ratio = fp_bytes as f64 / bmx.payload_bytes() as f64;
    assert!(ratio > 3.0, "LeNet compression only {ratio:.1}x");
}

#[test]
fn bmx_file_roundtrip_preserves_everything() {
    let Some(man) = manifest() else { return };
    let entry = man.model("lenet_bin").unwrap();
    let ck = Checkpoint::load(man.path(&entry.init_ckpt)).unwrap();
    let names = inventory::lenet(true).binary_names();
    let bmx = convert(&ck, &names, &entry.bmx_meta()).unwrap();

    let path = std::env::temp_dir().join(format!("it_lenet_{}.bmx", std::process::id()));
    bmx.save(&path).unwrap();
    let back = BmxModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(back.meta, bmx.meta);
    assert_eq!(back.tensors.len(), bmx.tensors.len());
    for ((n1, t1), (n2, t2)) in bmx.tensors.iter().zip(&back.tensors) {
        assert_eq!(n1, n2);
        assert_eq!(t1.shape(), t2.shape());
        assert_eq!(t1.payload_bytes(), t2.payload_bytes());
    }
    let (_, p1) = bmx.get_packed("fc1.w").unwrap();
    let (_, p2) = back.get_packed("fc1.w").unwrap();
    assert_eq!(p1.words, p2.words);
}

#[test]
fn resnet_mini_partial_conversions_order_by_size() {
    let Some(man) = manifest() else { return };
    // Table 2 ordering on the *trained-size* axis, via the real artifacts
    let configs = ["none", "fp1", "fp2", "fp3", "fp4", "fp12", "all"];
    let mut sizes = Vec::new();
    for cfg in configs {
        let name = format!("resnet_mini_img_{cfg}");
        let entry = man.model(&name).unwrap();
        let ck = Checkpoint::load(man.path(&entry.init_ckpt)).unwrap();
        let width = entry.raw.get("width").and_then(|v| v.as_usize()).unwrap();
        let names = inventory::resnet18(width, entry.classes, Stem::Cifar, &entry.fp_stages())
            .binary_names();
        let bmx = convert(&ck, &names, &entry.bmx_meta()).unwrap();
        sizes.push(bmx.payload_bytes());
    }
    // none < fp1 < fp2 < fp3 < fp4 < all ; fp12 between fp2 and fp4
    assert!(sizes[0] < sizes[1], "{sizes:?}");
    assert!(sizes[1] < sizes[2], "{sizes:?}");
    assert!(sizes[2] < sizes[3], "{sizes:?}");
    assert!(sizes[3] < sizes[4], "{sizes:?}");
    assert!(sizes[4] < sizes[6], "{sizes:?}");
    assert!(sizes[5] > sizes[2] && sizes[5] < sizes[4], "{sizes:?}");
}

#[test]
fn resnet18_real_inventory_reproduces_paper_sizes() {
    // Table 1: 44.7 MB -> 1.5 MB (29x); Table 2: 3.6 .. 47 MB — exact
    // accounting, no artifacts needed (pure inventory).
    const MB: f64 = 1024.0 * 1024.0;
    let fp = inventory::resnet18(64, 10, Stem::Cifar, &[1, 2, 3, 4]);
    let bin = inventory::resnet18(64, 10, Stem::Cifar, &[]);
    let fp_mb = fp.fp32_bytes() as f64 / MB;
    let bin_mb = bin.bmx_bytes() as f64 / MB;
    assert!((40.0..47.0).contains(&fp_mb), "cifar fp {fp_mb:.1} MB");
    assert!((1.0..2.2).contains(&bin_mb), "cifar binary {bin_mb:.1} MB");
    let ratio = fp.fp32_bytes() as f64 / bin.bmx_bytes() as f64;
    assert!((20.0..32.0).contains(&ratio), "compression {ratio:.1}x (paper: 29x)");
}
