//! PJRT runtime integration: load every artifact kind, execute, and check
//! numerics against the Rust substrates.  Requires `make artifacts`.

use repro::data::Rng;
use repro::gemm::{PackedMatrix, Side};
use repro::runtime::client::{lit_f32, lit_u32, scalar_f32, to_f32_vec, to_i32_vec};
use repro::runtime::{Manifest, Runtime};

/// Artifacts + the real PJRT backend, or a clean skip: these tests must
/// pass (as no-ops) when `artifacts/` is absent or the build is the
/// default pjrt-less stub (DESIGN.md §PJRT runtime gating).
fn manifest() -> Option<Manifest> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("SKIP (built without the `pjrt` feature; PJRT runtime stubbed)");
        return None;
    }
    match Manifest::load(repro::ARTIFACTS_DIR) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (artifacts not built): {e:#}");
            None
        }
    }
}

#[test]
fn kernel_xnor_gemm_artifact_matches_rust_gemm() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let (file, entry) = &man.kernels["xnor_gemm"];
    let (m, n, w) = (
        entry.get("m").and_then(|v| v.as_usize()).unwrap(),
        entry.get("n").and_then(|v| v.as_usize()).unwrap(),
        entry.get("words").and_then(|v| v.as_usize()).unwrap(),
    );
    let exe = rt.load_hlo_text(man.path(file)).unwrap();

    let mut rng = Rng::new(3);
    let aw: Vec<u32> = (0..m * w).map(|_| rng.next_u64() as u32).collect();
    let bw: Vec<u32> = (0..n * w).map(|_| rng.next_u64() as u32).collect();
    let out = exe
        .run(&[lit_u32(&aw, &[m, w]).unwrap(), lit_u32(&bw, &[n, w]).unwrap()])
        .unwrap();
    let pjrt_pops = to_i32_vec(&out[0]).unwrap();

    // direct popcount reference over the same u32 words
    let mut expect = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0u32;
            for k in 0..w {
                acc += (!(aw[i * w + k] ^ bw[j * w + k])).count_ones();
            }
            expect[i * n + j] = acc as i32;
        }
    }
    assert_eq!(pjrt_pops, expect, "Pallas xnor GEMM != Rust popcount");
}

#[test]
fn kernel_pack_artifact_matches_rust_pack() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let (file, entry) = &man.kernels["pack"];
    let (m, k) = (
        entry.get("m").and_then(|v| v.as_usize()).unwrap(),
        entry.get("k").and_then(|v| v.as_usize()).unwrap(),
    );
    let exe = rt.load_hlo_text(man.path(file)).unwrap();
    let mut rng = Rng::new(9);
    let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let out = exe.run(&[lit_f32(&x, &[m, k]).unwrap()]).unwrap();
    let packed: Vec<u32> = out[0].to_vec::<u32>().unwrap();

    // Rust pack (u64 words) -> u32 lanes, same LSB-first convention.
    let p = PackedMatrix::pack_rows(&x, m, k, Side::B);
    let rust_u32 = p.words_u32();
    let lanes = k / 32;
    for r in 0..m {
        for l in 0..lanes {
            assert_eq!(
                packed[r * lanes + l],
                rust_u32[r * p.words_per_row * 2 + l],
                "row {r} lane {l}"
            );
        }
    }
}

#[test]
fn kernel_quantize_artifact_matches_rust_quant() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let (file, entry) = &man.kernels["quantize_k4"];
    let (m, n) = (
        entry.get("m").and_then(|v| v.as_usize()).unwrap(),
        entry.get("n").and_then(|v| v.as_usize()).unwrap(),
    );
    let exe = rt.load_hlo_text(man.path(file)).unwrap();
    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
    let out = exe.run(&[lit_f32(&x, &[m, n]).unwrap()]).unwrap();
    let got = to_f32_vec(&out[0]).unwrap();
    for (g, x) in got.iter().zip(&x) {
        let expect = repro::quant::clip_quantize(*x, 4);
        assert!((g - expect).abs() < 1e-6, "{x} -> {g} vs {expect}");
    }
}

#[test]
fn lenet_train_step_decreases_loss() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut trainer = repro::train::Trainer::new(&rt, &man, "lenet_bin").unwrap();
    let exe = rt.load_cached(man.path(&trainer.entry.train_file)).unwrap();
    let b = trainer.entry.train_batch;
    let ds = repro::data::Kind::Digits.generate(b * 4, 5);
    let mut first = None;
    let mut last = 0.0f32;
    for step in 0..12 {
        let batch =
            ds.gather(&(0..b).map(|i| (step * 7 + i) % ds.len()).collect::<Vec<_>>());
        let (loss, _acc) = trainer.step(&exe, &batch.images, &batch.labels, 0.05).unwrap();
        assert!(loss.is_finite(), "loss diverged at step {step}");
        first.get_or_insert(loss);
        last = loss;
    }
    assert!(last < first.unwrap(), "loss did not decrease: {first:?} -> {last}");
}

#[test]
fn lenet_infer_artifacts_consistent_across_batch_sizes() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let trainer = repro::train::Trainer::new(&rt, &man, "lenet_bin").unwrap();
    let entry = &trainer.entry;
    let per: usize = entry.input_shape.iter().product();
    let mut rng = Rng::new(21);
    let img: Vec<f32> = (0..per).map(|_| rng.normal() * 0.3).collect();

    let mut logits_by_batch = Vec::new();
    for inf in &entry.infer {
        let exe = rt.load_cached(man.path(&inf.file)).unwrap();
        let mut inputs = Vec::new();
        for (spec, data) in entry.params.iter().zip(&trainer.params) {
            inputs.push(lit_f32(data, &spec.shape).unwrap());
        }
        for (spec, data) in entry.state.iter().zip(&trainer.state) {
            inputs.push(lit_f32(data, &spec.shape).unwrap());
        }
        let mut x = Vec::with_capacity(inf.batch * per);
        for _ in 0..inf.batch {
            x.extend_from_slice(&img);
        }
        let mut dims = vec![inf.batch];
        dims.extend(&entry.input_shape);
        inputs.push(lit_f32(&x, &dims).unwrap());
        let out = exe.run(&inputs).unwrap();
        let logits = to_f32_vec(&out[0]).unwrap();
        logits_by_batch.push((inf.batch, logits[..entry.classes].to_vec()));
    }
    let (b0, base) = &logits_by_batch[0];
    for (b, logits) in &logits_by_batch[1..] {
        for (l, r) in base.iter().zip(logits) {
            assert!(
                (l - r).abs() < 1e-4,
                "logits differ between batch {b0} and {b}: {l} vs {r}"
            );
        }
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let entry = man.model("lenet_bin").unwrap();
    let p = man.path(&entry.infer[0].file);
    let a = rt.load_cached(&p).unwrap();
    let b = rt.load_cached(&p).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b), "cache miss on identical path");
}

#[test]
fn scalar_lr_literal_roundtrip() {
    // guards the lr input convention of train_step
    let l = repro::runtime::client::lit_scalar_f32(0.025);
    assert_eq!(scalar_f32(&l).unwrap(), 0.025);
}
