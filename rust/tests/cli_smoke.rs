//! CLI-surface smoke tests (no artifacts needed): `Engine::load` must turn
//! every bad-input path into a clean `Err` — never a panic — because the
//! serving coordinator and the `bmxnet predict/serve` commands feed it
//! user-supplied paths.  Also pins the `Method` label round-trip, the
//! stable-string API contract documented on [`repro::gemm::Method::label`].

use repro::gemm::Method;
use repro::model::bmx::convert;
use repro::model::ckpt::Checkpoint;
use repro::model::inventory;
use repro::nn::Engine;

fn tmp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cli_smoke_{}_{name}", std::process::id()))
}

#[test]
fn engine_load_missing_path_is_clean_error() {
    let err = Engine::load("definitely/not/here.bmx");
    assert!(err.is_err(), "missing file must be an Err, not a panic");
    let msg = format!("{:#}", err.err().expect("expected an Err"));
    assert!(msg.contains("here.bmx"), "error does not name the path: {msg}");
}

#[test]
fn engine_load_garbage_file_is_clean_error() {
    let path = tmp_path("garbage.bmx");
    std::fs::write(&path, b"this is not a bmx model at all, not even close")
        .unwrap();
    let err = Engine::load(&path);
    std::fs::remove_file(&path).ok();
    assert!(err.is_err(), "garbage bytes must be an Err, not a panic");
    let msg = format!("{:#}", err.err().expect("expected an Err"));
    assert!(msg.contains("magic"), "expected a bad-magic parse error: {msg}");
}

#[test]
fn engine_load_truncated_model_is_clean_error() {
    // Build a real, loadable binary-LeNet .bmx, then cut it short.
    let inv = inventory::lenet(true);
    let mut ck = Checkpoint::new();
    for p in &inv.params {
        let name = if p.name.starts_with("state.") {
            p.name.clone()
        } else {
            format!("params.{}", p.name)
        };
        let data = vec![if name.contains(".var") { 1.0 } else { 0.25 }; p.numel()];
        ck.push_f32(&name, p.shape.clone(), data);
    }
    let bmx = convert(&ck, &inv.binary_names(), r#"{"arch": "lenet", "binary": true}"#)
        .unwrap();
    let bytes = bmx.to_bytes();

    let path = tmp_path("truncated.bmx");
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    let err = Engine::load(&path);
    std::fs::remove_file(&path).ok();
    assert!(err.is_err(), "truncated model must be an Err, not a panic");
    let msg = format!("{:#}", err.err().expect("expected an Err"));
    assert!(msg.contains("truncated"), "expected a truncation error: {msg}");

    // sanity: the untruncated bytes do load
    let path = tmp_path("whole.bmx");
    std::fs::write(&path, &bytes).unwrap();
    let ok = Engine::load(&path);
    std::fs::remove_file(&path).ok();
    ok.expect("untruncated model must load");
}

#[test]
fn engine_load_metadata_without_arch_is_clean_error() {
    let mut ck = Checkpoint::new();
    ck.push_f32("params.w", vec![2, 2], vec![0.0; 4]);
    let bmx = convert(&ck, &[], "{}").unwrap();
    let path = tmp_path("noarch.bmx");
    bmx.save(&path).unwrap();
    let err = Engine::load(&path);
    std::fs::remove_file(&path).ok();
    let msg = format!("{:#}", err.err().expect("expected an Err"));
    assert!(msg.contains("arch"), "expected a missing-arch error: {msg}");
}

#[test]
fn method_labels_roundtrip_for_all_variants() {
    for m in Method::all() {
        assert_eq!(
            Method::from_label(m.label()),
            Some(*m),
            "label round-trip broken for {m:?}"
        );
    }
    assert_eq!(Method::from_label("not-a-method"), None);
}

#[test]
fn method_labels_are_the_pinned_strings() {
    // The exact strings are an API contract: they key BENCH_*.json
    // records and bench-table columns (see Method::label docs and
    // EXPERIMENTS.md §Perf).  Changing one must fail a test, not slip by.
    let labels: Vec<&str> = Method::all().iter().map(|m| m.label()).collect();
    assert_eq!(
        labels,
        [
            "naive",
            "cblas",
            "xnor_32",
            "xnor_64",
            "xnor_64_blk",
            "xnor_64_omp",
            "xnor_64_avx2",
            "xnor_64_avx512",
            "xnor_64_neon",
            "xnor_fused",
            "xnor_fused_thr",
        ]
    );
}

#[test]
fn available_methods_are_a_stable_subset() {
    // `available()` filters `all()` without reordering, always keeps the
    // portable variants, and labels stay round-trippable even for
    // variants this machine cannot run (the catalog is cross-arch).
    let all: Vec<Method> = Method::all().to_vec();
    let avail = Method::available();
    let mut last_idx = 0;
    for m in &avail {
        let idx = all.iter().position(|x| x == m).expect("available ⊆ all");
        assert!(idx >= last_idx, "available() must preserve catalog order");
        last_idx = idx;
    }
    for label in [
        "naive",
        "cblas",
        "xnor_32",
        "xnor_64",
        "xnor_64_blk",
        "xnor_64_omp",
        "xnor_fused",
        "xnor_fused_thr",
    ] {
        let m = Method::from_label(label).unwrap();
        assert!(avail.contains(&m), "{label} must always be available");
    }
}
