//! End-to-end tests of the integer threshold epilogue: folded engines
//! must produce bit-identical logits to the float BN+sign reference,
//! pre-folded `.bmx` v2 files must round-trip smaller and load back into
//! the same rules, and the `BMXNET_NO_FOLD` escape hatch must flip the
//! epilogue label on a real process (env reads are per-load, so the env
//! leg runs the installed binary rather than racing this test's threads).

use std::process::Command;

use repro::gemm::{fold_bn_sign, ChannelRule};
use repro::model::bmx::{fold_thresholds, synth_lenet, BmxModel};
use repro::nn::lenet::Lenet;
use repro::nn::Engine;
use repro::tensor::Tensor;

fn tmp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("threshold_fold_{}_{name}", std::process::id()))
}

fn varied_batch(n: usize) -> Tensor {
    let data: Vec<f32> =
        (0..n * 28 * 28).map(|i| ((i * 31 + 7) % 113) as f32 / 56.5 - 1.0).collect();
    Tensor::new(vec![n, 1, 28, 28], data)
}

#[test]
fn folded_engine_logits_equal_unfolded_bit_for_bit() {
    let m = synth_lenet(11, 1).unwrap();
    let folded = Lenet::from_bmx_with_fold(&m, true, 1, true).unwrap();
    let unfolded = Lenet::from_bmx_with_fold(&m, true, 1, false).unwrap();
    let x = varied_batch(3);
    assert_eq!(folded.forward(&x).unwrap().data(), unfolded.forward(&x).unwrap().data());
}

#[test]
fn folded_file_roundtrips_smaller_and_matches() {
    let m = synth_lenet(12, 1).unwrap();
    let unfolded = Lenet::from_bmx_with_fold(&m, true, 1, false).unwrap();
    let mut mf = m.clone();
    let folded_count = fold_thresholds(&mut mf).unwrap();
    assert_eq!(folded_count, 1); // lenet: conv2 → bn2 → sign
    // Thresholds (5 B/channel) replace BN (16 B/channel): smaller file.
    let (plain, packed) = (m.to_bytes(), mf.to_bytes());
    assert!(
        packed.len() < plain.len(),
        "folded file must shrink: {} vs {}",
        packed.len(),
        plain.len()
    );
    let path = tmp_path("v2.bmx");
    std::fs::write(&path, &packed).unwrap();
    let engine = Engine::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(engine.epilogue(), "thr");
    let x = varied_batch(2);
    assert_eq!(engine.forward(&x).unwrap().data(), unfolded.forward(&x).unwrap().data());
}

#[test]
fn version1_bytes_still_load_and_fold_at_engine_load() {
    let m = synth_lenet(13, 1).unwrap();
    let mut bytes = m.to_bytes();
    bytes[4..8].copy_from_slice(&1u32.to_le_bytes()); // rewrite header to v1
    let back = BmxModel::from_bytes(&bytes).unwrap();
    let unfolded = Lenet::from_bmx_with_fold(&back, true, 1, false).unwrap();
    let folded = Lenet::from_bmx_with_fold(&back, true, 1, true).unwrap();
    let x = varied_batch(2);
    assert_eq!(folded.forward(&x).unwrap().data(), unfolded.forward(&x).unwrap().data());
}

#[test]
fn fold_edge_cases_pin_rule_shapes() {
    let k = 800; // the LeNet conv2 im2col K (32*5*5)
    // Always-fire / never-fire shifts saturate at the popcount extremes.
    assert_eq!(fold_bn_sign(1.0, 1e12, k), ChannelRule::Ge(0));
    assert_eq!(fold_bn_sign(1.0, -1e12, k), ChannelRule::Ge(k as i32 + 1));
    assert_eq!(fold_bn_sign(-1.0, 1e12, k), ChannelRule::Le(k as i32));
    assert_eq!(fold_bn_sign(-1.0, -1e12, k), ChannelRule::Le(-1));
    // Zero scale degenerates to a constant decision on the shift sign.
    assert_eq!(fold_bn_sign(0.0, 0.5, k), ChannelRule::Const(true));
    assert_eq!(fold_bn_sign(0.0, -0.5, k), ChannelRule::Const(false));
    // A negative gamma flips the comparison direction.
    assert!(matches!(fold_bn_sign(-0.004, 1.5, k), ChannelRule::Le(_)));
    assert!(matches!(fold_bn_sign(0.004, 1.5, k), ChannelRule::Ge(_)));
}

/// `BMXNET_NO_FOLD=1` must flip the profile's dispatch line to the float
/// epilogue; unset, folding is the default. Runs the real binary so the
/// env var cannot race other tests in this process.
#[test]
fn no_fold_env_flips_epilogue_label_in_profile() {
    let path = tmp_path("env.bmx");
    synth_lenet(14, 1).unwrap().save(&path).unwrap();
    let run = |no_fold: bool| {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_bmxnet"));
        cmd.args(["profile", "--bmx", path.to_str().unwrap(), "--batch", "1", "--reps", "1"]);
        if no_fold {
            cmd.env("BMXNET_NO_FOLD", "1");
        } else {
            cmd.env_remove("BMXNET_NO_FOLD");
        }
        let out = cmd.output().expect("run bmxnet profile");
        assert!(out.status.success(), "profile failed: {}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout).into_owned()
    };
    let folded = run(false);
    let unfolded = run(true);
    std::fs::remove_file(&path).ok();
    assert!(folded.contains("epilogue thr"), "default must fold: {folded}");
    assert!(unfolded.contains("epilogue f32bn"), "NO_FOLD must not fold: {unfolded}");
}
