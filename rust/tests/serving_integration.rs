//! Serving integration: the coordinator over the real binary engine,
//! under concurrent load, answers exactly what the engine answers directly.

use std::sync::Arc;
use std::time::Duration;

use repro::coordinator::{BatchPolicy, Server, ServerConfig};
use repro::data::Kind;
use repro::model::bmx::convert;
use repro::model::ckpt::Checkpoint;
use repro::model::inventory;
use repro::nn::Engine;
use repro::runtime::Manifest;

fn engine() -> Option<Arc<Engine>> {
    let man = match Manifest::load(repro::ARTIFACTS_DIR) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP (artifacts not built): {e:#}");
            return None;
        }
    };
    let entry = man.model("lenet_bin").unwrap();
    let ck = Checkpoint::load(man.path(&entry.init_ckpt)).unwrap();
    let names = inventory::lenet(true).binary_names();
    let bmx = convert(&ck, &names, &entry.bmx_meta()).unwrap();
    Some(Arc::new(Engine::from_bmx(&bmx).unwrap()))
}

#[test]
fn served_answers_equal_direct_engine_calls() {
    let Some(eng) = engine() else { return };
    let ds = Kind::Digits.generate(24, 17);
    // ground truth: direct engine classification one-by-one
    let direct: Vec<usize> = (0..ds.len())
        .map(|i| eng.classify(ds.image(i), 1).unwrap()[0].0)
        .collect();

    let server = Server::start(
        eng.clone(),
        ServerConfig {
            policy: BatchPolicy { max_batch: 8, window: Duration::from_millis(4) },
            queue_cap: 64,
        },
    );
    let client = server.client();
    let handles: Vec<_> = (0..ds.len())
        .map(|i| {
            let c = client.clone();
            let img = ds.image(i).to_vec();
            std::thread::spawn(move || c.classify(img).unwrap().class)
        })
        .collect();
    let served: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    drop(client);
    let snap = server.shutdown();

    assert_eq!(served, direct, "served classes differ from direct engine");
    assert_eq!(snap.requests, ds.len() as u64);
    assert!(snap.p50 > Duration::ZERO);
}

#[test]
fn batching_reduces_batch_count_under_load() {
    let Some(eng) = engine() else { return };
    let ds = Kind::Digits.generate(32, 3);
    let server = Server::start(
        eng,
        ServerConfig {
            policy: BatchPolicy { max_batch: 16, window: Duration::from_millis(10) },
            queue_cap: 64,
        },
    );
    let client = server.client();
    // submit all requests asynchronously, then collect
    let pending: Vec<_> = (0..ds.len())
        .map(|i| client.submit(ds.image(i).to_vec()).unwrap())
        .collect();
    let mut max_batch_seen = 0;
    for rx in pending {
        let resp = rx.recv().unwrap();
        max_batch_seen = max_batch_seen.max(resp.batch_size);
    }
    drop(client);
    let snap = server.shutdown();
    assert!(
        snap.batches < snap.requests,
        "no batching: {} batches for {} requests",
        snap.batches,
        snap.requests
    );
    assert!(max_batch_seen > 1, "never saw a batched response");
    assert!(max_batch_seen <= 16, "exceeded max_batch");
}

#[test]
fn backpressure_rejects_when_queue_full() {
    let Some(eng) = engine() else { return };
    let server = Server::start(
        eng,
        ServerConfig {
            // tiny queue + long window: the queue must overflow
            policy: BatchPolicy { max_batch: 4, window: Duration::from_millis(50) },
            queue_cap: 2,
        },
    );
    let client = server.client();
    let img = vec![0.0f32; 784];
    let mut rejected = 0;
    let mut receivers = Vec::new();
    for _ in 0..64 {
        match client.submit(img.clone()) {
            Ok(rx) => receivers.push(rx),
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "queue_cap=2 never rejected under burst of 64");
    // accepted requests still complete
    for rx in receivers {
        rx.recv().unwrap();
    }
    drop(client);
    server.shutdown();
}
