//! Differential kernel-correctness harness (ISSUE 6's headline test).
//!
//! Every GEMM variant the running CPU can execute — scalar, unrolled,
//! blocked, parallel, each SIMD kernel, and the fused path — is compared
//! **bit-exactly** against the naive float reference on binarized
//! operands, over randomized shapes plus the edge classes where tail-word
//! masking bugs live: K not a multiple of 64, single-row/column matrices,
//! and all-ones/all-zeros inputs.
//!
//! The suite is dispatch-aware: run it plain to exercise the SIMD kernels
//! the CPU supports, and with `BMXNET_FORCE_SCALAR=1` to pin the scalar
//! fallback (the CI matrix runs both legs).  A mismatch panics with the
//! method, shape, and seed so the failing case replays deterministically.

use repro::data::Rng;
use repro::gemm::{
    binary_gemm_f32, binary_gemm_packed_b, gemm_fused, naive, simd, xnor_gemm_prepacked,
    Method, PackedMatrix, Side,
};
use repro::quant::{sign_binarize, xnor_to_dot};

/// Shape classes where off-by-one / tail-masking bugs concentrate.
const EDGE_SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),    // minimal everything
    (1, 1, 63),   // single cell, one partial word
    (1, 1, 64),   // single cell, exact word
    (1, 1, 65),   // single cell, word + 1-bit tail
    (1, 5, 127),  // single row, tail one bit short
    (5, 1, 128),  // single column, two exact words
    (3, 3, 129),  // two words + 1-bit tail
    (2, 2, 191),  // three words minus one
    (3, 3, 192),  // three exact words
    (7, 3, 1000), // deep K, 15 words + 40-bit tail
    (1, 64, 256), // one row against a full B tile (JB = 64)
    (9, 65, 64),  // B one past the tile boundary
    (8, 8, 4096), // 64 exact words: exercises the full AVX2 CSA block
    (8, 8, 4097), // CSA block + 1-bit tail
];

fn reference(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let ab: Vec<f32> = a.iter().map(|&x| sign_binarize(x)).collect();
    let bb: Vec<f32> = b.iter().map(|&x| sign_binarize(x)).collect();
    naive::gemm_f32(&ab, &bb, m, n, k)
}

fn assert_all_methods_match(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, tag: &str) {
    let expect = reference(a, b, m, n, k);
    for method in Method::available() {
        let got = binary_gemm_f32(method, a, b, m, n, k);
        assert_eq!(got, expect, "{tag}: method {method:?} m={m} n={n} k={k}");
    }
}

#[test]
fn edge_shapes_all_methods_bit_exact() {
    for &(m, n, k) in EDGE_SHAPES {
        let mut rng = Rng::new((m * 1_000_000 + n * 1_000 + k) as u64);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        assert_all_methods_match(&a, &b, m, n, k, "edge");
    }
}

#[test]
fn randomized_shapes_all_methods_bit_exact() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed * 6151 + 7);
        let m = 1 + rng.below(24);
        let n = 1 + rng.below(80);
        // Bias K toward word-boundary neighborhoods where masking bugs live.
        let k = match seed % 4 {
            0 => 1 + rng.below(63),             // sub-word
            1 => 64 * (1 + rng.below(8)),       // exact words
            2 => 64 * (1 + rng.below(8)) + 1 + rng.below(63), // words + tail
            _ => 1 + rng.below(2000),           // anything
        };
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        assert_all_methods_match(&a, &b, m, n, k, &format!("seed={seed}"));
    }
}

#[test]
fn constant_inputs_hit_popcount_extremes() {
    // All-plus vs all-plus: every lane matches -> pop = k, dot = +k.
    // All-plus vs all-minus: no lane matches -> pop = 0, dot = -k.
    // All-zeros binarize to +1 (sign convention: x >= 0 -> +1).
    for k in [1usize, 63, 64, 65, 129, 1000] {
        let plus = vec![1.0f32; k];
        let minus = vec![-1.0f32; k];
        let zeros = vec![0.0f32; k];
        for method in Method::available() {
            let same = binary_gemm_f32(method, &plus, &plus, 1, 1, k);
            assert_eq!(same, vec![k as f32], "{method:?} k={k} all-match");
            let opposite = binary_gemm_f32(method, &plus, &minus, 1, 1, k);
            assert_eq!(opposite, vec![-(k as f32)], "{method:?} k={k} all-mismatch");
            let zero_case = binary_gemm_f32(method, &zeros, &plus, 1, 1, k);
            assert_eq!(zero_case, vec![k as f32], "{method:?} k={k} zeros-as-plus");
        }
    }
}

#[test]
fn row_kernels_match_scalar_reference_directly() {
    // Below the Method layer: every dispatchable row kernel against the
    // scalar reduction on raw word arrays, across vector-width boundaries
    // (AVX2 consumes 64 words/iter then 4, AVX-512 8, NEON 2 — cover
    // every remainder class around each).
    let mut rng = Rng::new(99);
    for words in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 63, 64, 65, 67, 127, 128, 200]
    {
        let a: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
        let b: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
        let expect = simd::scalar_row(&a, &b);
        for kernel in simd::available_kernels() {
            let got = simd::row_fn(kernel)(&a, &b);
            assert_eq!(got, expect, "kernel {kernel:?} words={words}");
        }
    }
}

#[test]
fn prepacked_agrees_with_f32_entry_for_all_methods() {
    let (m, n, k) = (6, 10, 197);
    let mut rng = Rng::new(5);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let pa = PackedMatrix::pack_rows(&a, m, k, Side::A);
    let pb = PackedMatrix::pack_cols(&b, k, n);
    for method in Method::available().into_iter().filter(|m| m.is_binary()) {
        let via_prepacked: Vec<f32> = xnor_gemm_prepacked(method, &pa, &pb)
            .into_iter()
            .map(|p| xnor_to_dot(p, k))
            .collect();
        let via_f32 = binary_gemm_f32(method, &a, &b, m, n, k);
        assert_eq!(via_prepacked, via_f32, "{method:?}");
    }
}

#[test]
fn fused_entry_agrees_with_unfused_and_reference() {
    for &(m, n, k) in EDGE_SHAPES {
        let mut rng = Rng::new((k * 31 + n) as u64);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let pb = PackedMatrix::pack_cols(&b, k, n);
        let fused: Vec<f32> = gemm_fused(&a, m, k, &pb)
            .into_iter()
            .map(|p| xnor_to_dot(p, k))
            .collect();
        assert_eq!(fused, reference(&a, &b, m, n, k), "fused m={m} n={n} k={k}");
        // And through the layer-forward entry point with every binary method.
        for method in Method::available().into_iter().filter(|m| m.is_binary()) {
            let via_packed_b: Vec<f32> = binary_gemm_packed_b(method, &a, m, k, &pb)
                .into_iter()
                .map(|p| xnor_to_dot(p, k))
                .collect();
            assert_eq!(via_packed_b, fused, "packed_b {method:?} m={m} n={n} k={k}");
        }
    }
}

#[test]
fn dispatch_respects_force_scalar_override() {
    // Env-dependent assertions only; the CI matrix provides the env legs.
    if simd::force_scalar() {
        assert_eq!(simd::best_kernel(), simd::Kernel::Scalar);
        assert_eq!(simd::available_kernels(), vec![simd::Kernel::Scalar]);
        // Pinned-SIMD methods disappear from the available set...
        for m in Method::available() {
            assert!(
                !matches!(
                    m,
                    Method::Xnor64Avx2 | Method::Xnor64Avx512 | Method::Xnor64Neon
                ),
                "{m:?} must not be available under BMXNET_FORCE_SCALAR"
            );
        }
        // ...but the delegating methods keep working (on the scalar path).
        let a = vec![1.0f32; 2 * 100];
        let b = vec![-1.0f32; 100 * 3];
        assert_eq!(
            binary_gemm_f32(Method::XnorFused, &a, &b, 2, 3, 100),
            vec![-100.0; 6]
        );
    } else {
        // Without the override the scalar kernel is still always present.
        assert!(simd::available_kernels().contains(&simd::Kernel::Scalar));
    }
}

#[test]
fn available_methods_cover_every_catalog_entry_or_are_justified() {
    // Every catalog variant is either available or pinned to a kernel the
    // CPU genuinely lacks — there is no third state where a runnable
    // variant silently drops out of the differential net.
    for m in Method::all() {
        if !m.is_available() {
            assert!(
                matches!(
                    m,
                    Method::Xnor64Avx2 | Method::Xnor64Avx512 | Method::Xnor64Neon
                ),
                "{m:?} unavailable but not a pinned-SIMD variant"
            );
        }
    }
}
