//! End-to-end observability over real loopback TCP: classify requests
//! must leave complete stage traces in `/v1/debug/trace`, per-stage
//! histograms and kernel-call counters in `/metrics`, dispatch info in
//! `/v1/models`, and a per-layer profile at `/v1/models/{name}/profile`.
//!
//! Needs no artifacts: the model is a synthetic packed LeNet written to a
//! temp models dir (same idiom as `tests/serve_gateway.rs`).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use repro::coordinator::BatchPolicy;
use repro::data::Kind;
use repro::model::bmx::synth_lenet;
use repro::model::json;
use repro::obs::Stage;
use repro::serve::{Gateway, ModelRegistry, PoolConfig, RegistryConfig};

fn temp_models_dir(case: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("obs_gateway_{}_{case}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Tiny HTTP/1.1 client: one request, `connection: close`, parsed reply.
fn http_request(addr: &str, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect to gateway");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut req = format!("{method} {path} HTTP/1.1\r\nhost: test\r\nconnection: close\r\n");
    if let Some(b) = body {
        req.push_str(&format!(
            "content-type: application/json\r\ncontent-length: {}\r\n",
            b.len()
        ));
    }
    req.push_str("\r\n");
    if let Some(b) = body {
        req.push_str(b);
    }
    stream.write_all(req.as_bytes()).unwrap();
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("bad status line {status_line:?}"))
        .parse()
        .unwrap();
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().unwrap();
            }
        }
    }
    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

fn classify_body(img: &[f32]) -> String {
    let nums: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
    format!("{{\"image\": [{}]}}", nums.join(","))
}

#[test]
fn traces_metrics_dispatch_and_profile_end_to_end() {
    let dir = temp_models_dir("e2e");
    synth_lenet(11, 1).unwrap().save(dir.join("lenet_bin.bmx")).unwrap();
    let registry = Arc::new(ModelRegistry::new(RegistryConfig {
        pool: PoolConfig {
            workers: 1,
            policy: BatchPolicy { max_batch: 4, window: Duration::from_millis(1) },
            queue_cap: 64,
            ..Default::default()
        },
        ..RegistryConfig::new(dir.clone())
    }));
    let gateway = Gateway::start(registry, "127.0.0.1:0").unwrap();
    let addr = gateway.addr().to_string();
    let ds = Kind::Digits.generate(4, 5);

    for i in 0..4 {
        let (status, resp) = http_request(
            &addr,
            "POST",
            "/v1/models/lenet_bin:classify",
            Some(&classify_body(ds.image(i))),
        );
        assert_eq!(status, 200, "classify {i} failed: {resp}");
    }
    // an invalid request must also leave a (400) trace
    let (status, _) =
        http_request(&addr, "POST", "/v1/models/lenet_bin:classify", Some("not json"));
    assert_eq!(status, 400);

    // --- /v1/debug/trace: 5 requests, newest-first, named monotone stages
    let (status, text) = http_request(&addr, "GET", "/v1/debug/trace?n=8", None);
    assert_eq!(status, 200, "{text}");
    let v = json::parse(&text).unwrap();
    assert!(v.get("total").and_then(|t| t.as_usize()).unwrap() >= 5, "{text}");
    let traces = v.get("traces").and_then(|t| t.as_array()).unwrap();
    assert!(traces.len() >= 5, "want >=5 traces, got {}: {text}", traces.len());
    // newest first: the 400 request is trace[0]
    assert_eq!(traces[0].get("status").and_then(|s| s.as_usize()), Some(400));
    let ok_trace = traces
        .iter()
        .find(|t| t.get("status").and_then(|s| s.as_usize()) == Some(200))
        .unwrap_or_else(|| panic!("no 200 trace in {text}"));
    assert_eq!(ok_trace.get("model").and_then(|m| m.as_str()), Some("lenet_bin"));
    assert!(ok_trace.get("batch_size").and_then(|b| b.as_usize()).unwrap() >= 1);
    let stages = ok_trace
        .get("stages_us")
        .and_then(|s| s.as_object())
        .unwrap_or_else(|| panic!("no stages_us object in {text}"));
    assert!(
        stages.len() >= 5,
        "a served request must reach >=5 named stages, got {}: {text}",
        stages.len()
    );
    // offsets are monotone in stage order
    let mut prev = 0u64;
    for s in Stage::all() {
        if let Some(off) = stages.get(s.label()).and_then(|v| v.as_f64()) {
            let off = off as u64;
            assert!(off >= prev, "stage {} offset {off} < {prev}: {text}", s.label());
            prev = off;
        }
    }
    let total = ok_trace.get("total_us").and_then(|t| t.as_usize()).unwrap() as u64;
    assert!(total >= prev, "total_us {total} below last stage offset {prev}");

    // --- /metrics: new families present and consistent
    let mut metrics = String::new();
    for _ in 0..50 {
        let (status, text) = http_request(&addr, "GET", "/metrics", None);
        assert_eq!(status, 200);
        metrics = text;
        if metrics.contains("bmxnet_requests_total{model=\"lenet_bin\"} 4") {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    for family in [
        "# TYPE bmxnet_stage_latency_us histogram",
        "bmxnet_stage_latency_us_bucket{stage=\"parse\",le=\"+Inf\"}",
        "bmxnet_stage_latency_us_bucket{stage=\"forward\",le=\"+Inf\"}",
        "bmxnet_stage_latency_us_sum{stage=\"respond\"}",
        "# TYPE bmxnet_kernel_calls_total counter",
        "bmxnet_queue_depth{model=\"lenet_bin\",shard=\"0\"}",
        "bmxnet_latency_us_count{model=\"lenet_bin\"}",
        "bmxnet_latency_us_sum{model=\"lenet_bin\"}",
        "bmxnet_trace_total",
    ] {
        assert!(metrics.contains(family), "missing {family} in:\n{metrics}");
    }
    // the binary layers ran, so a kernel counter line must be nonzero
    let kernel_line = metrics
        .lines()
        .find(|l| l.starts_with("bmxnet_kernel_calls_total{"))
        .unwrap_or_else(|| panic!("no kernel call samples in:\n{metrics}"));
    let calls: u64 = kernel_line.rsplit(' ').next().unwrap().parse().unwrap();
    assert!(calls > 0, "kernel counter is zero: {kernel_line}");
    assert!(kernel_line.contains("method=\"") && kernel_line.contains("kernel=\""));

    // build identity rides along with every scrape
    assert!(metrics.contains("bmxnet_build_info{version=\""), "{metrics}");
    assert!(metrics.contains("git_sha=\""), "{metrics}");

    // --- /v1/models: per-model dispatch + process force_scalar state
    let (status, list) = http_request(&addr, "GET", "/v1/models", None);
    assert_eq!(status, 200);
    let v = json::parse(&list).unwrap();
    assert!(v.get("gemm_dispatch").and_then(|d| d.as_str()).unwrap().contains("method"));
    let build = v.get("build_info").expect("build_info object in /v1/models");
    assert!(build.get("version").and_then(|x| x.as_str()).is_some(), "{list}");
    assert!(build.get("git").and_then(|x| x.as_str()).is_some(), "{list}");
    assert!(
        matches!(build.get("force_scalar"), Some(json::Value::Bool(_))),
        "build_info.force_scalar missing: {list}"
    );
    assert!(
        matches!(v.get("force_scalar"), Some(json::Value::Bool(_))),
        "force_scalar missing: {list}"
    );
    let models = v.get("models").and_then(|m| m.as_array()).unwrap();
    let entry = models
        .iter()
        .find(|m| m.get("name").and_then(|n| n.as_str()) == Some("lenet_bin"))
        .unwrap();
    let dispatch = entry.get("dispatch").and_then(|d| d.as_str()).unwrap();
    assert!(dispatch.contains("method"), "dispatch line malformed: {dispatch}");

    // --- /v1/models/{name}/profile: a schema-2 perf record with
    // per-layer cells (metadata in the cell notes) + convenience extras
    let (status, prof) =
        http_request(&addr, "GET", "/v1/models/lenet_bin/profile?batch=2&reps=2", None);
    assert_eq!(status, 200, "{prof}");
    let v = json::parse(&prof).unwrap();
    assert_eq!(v.get("schema").and_then(|s| s.as_usize()), Some(2), "{prof}");
    assert_eq!(v.get("bench").and_then(|b| b.as_str()), Some("profile"));
    assert_eq!(v.get("model").and_then(|m| m.as_str()), Some("lenet_bin"));
    assert_eq!(v.get("batch").and_then(|b| b.as_usize()), Some(2));
    let rec = repro::bench::PerfRecord::parse(&prof).expect("profile parses as perf record");
    assert!(rec.cells.len() >= 11, "total + >=10 layer cells: {prof}");
    assert!(rec.cell("forward/total").is_some(), "{prof}");
    let conv2 = rec.cell("layer/conv2").unwrap_or_else(|| panic!("no conv2 cell in {prof}"));
    assert!(conv2.note.contains("method="), "{}", conv2.note);
    assert!(conv2.note.contains("kernel="), "{}", conv2.note);
    assert_eq!(conv2.stats.reps, 2);
    // unknown model 404s
    let (status, _) = http_request(&addr, "GET", "/v1/models/nope/profile", None);
    assert_eq!(status, 404);

    gateway.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
