//! End-to-end serving-gateway integration over real loopback TCP: two
//! different models behind one gateway, ≥64 in-flight requests, per-model
//! routing correctness, the bounded-queue 429 path, and `/metrics`
//! consistency (per-model request counts; batch-size histogram whose
//! `sum(size*count)` equals the requests sent).
//!
//! Needs no artifacts: models are built from synthetic checkpoints
//! (`Inventory::synthetic_checkpoint`) and written to a temp models dir.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use repro::coordinator::BatchPolicy;
use repro::data::Kind;
use repro::model::bmx::{synth_lenet, BmxModel, BmxTensor};
use repro::model::json;
use repro::nn::Engine;
use repro::serve::{Gateway, GatewayConfig, ModelRegistry, PoolConfig, RegistryConfig};

fn temp_models_dir(case: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("serve_gateway_{}_{case}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Pin a synthetic model's answers by dominating one output bias, so the
/// two test models provably disagree and misrouting cannot hide.
/// (fc2.b stays f32 in both converter modes, so mutating the converted
/// model is equivalent to mutating the checkpoint.)
fn bias_toward_class(m: &mut BmxModel, class: usize) {
    for (name, t) in &mut m.tensors {
        if name == "params.fc2.b" {
            if let BmxTensor::F32 { data, .. } = t {
                data[class] = 1000.0;
            }
        }
    }
}

/// Write two *different* models (1-bit packed vs 4-bit quantized LeNet,
/// different weights, different pinned answers) and return direct engines
/// as the ground truth.
fn write_two_models(dir: &Path) -> (Engine, Engine) {
    let mut bin = synth_lenet(101, 1).unwrap();
    bias_toward_class(&mut bin, 2);
    bin.save(dir.join("lenet_bin.bmx")).unwrap();
    let mut q4 = synth_lenet(202, 4).unwrap();
    bias_toward_class(&mut q4, 7);
    q4.save(dir.join("lenet_q4.bmx")).unwrap();
    (Engine::from_bmx(&bin).unwrap(), Engine::from_bmx(&q4).unwrap())
}

/// Tiny HTTP/1.1 client: one request, `connection: close`, parsed reply.
fn http_request(addr: &str, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect to gateway");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut req = format!("{method} {path} HTTP/1.1\r\nhost: test\r\nconnection: close\r\n");
    if let Some(b) = body {
        req.push_str(&format!(
            "content-type: application/json\r\ncontent-length: {}\r\n",
            b.len()
        ));
    }
    req.push_str("\r\n");
    if let Some(b) = body {
        req.push_str(b);
    }
    stream.write_all(req.as_bytes()).unwrap();
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("bad status line {status_line:?}"))
        .parse()
        .unwrap();
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().unwrap();
            }
        }
    }
    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

fn classify_body(img: &[f32]) -> String {
    let nums: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
    format!("{{\"image\": [{}]}}", nums.join(","))
}

/// `name{model="m"} V` → V, from the Prometheus text.
fn metric_value(text: &str, name: &str, model: &str) -> Option<u64> {
    let prefix = format!("{name}{{model=\"{model}\"}} ");
    text.lines().find_map(|l| l.strip_prefix(&prefix).and_then(|v| v.trim().parse().ok()))
}

/// Sum of size*count over the model's batch-size histogram lines.
fn batch_hist_weighted_sum(text: &str, model: &str) -> u64 {
    let prefix = format!("bmxnet_batch_size_total{{model=\"{model}\",size=\"");
    text.lines()
        .filter_map(|l| l.strip_prefix(&prefix))
        .map(|rest| {
            let (size, tail) = rest.split_once("\"}").expect("histogram line shape");
            size.parse::<u64>().unwrap() * tail.trim().parse::<u64>().unwrap()
        })
        .sum()
}

#[test]
fn two_models_64_inflight_routing_and_metrics() {
    let dir = temp_models_dir("two_models");
    let (bin_eng, q4_eng) = write_two_models(&dir);
    let n = 64usize;
    let ds = Kind::Digits.generate(n, 9);
    // ground truth: even requests -> lenet_bin, odd -> lenet_q4
    let expected: Vec<usize> = (0..n)
        .map(|i| {
            let eng = if i % 2 == 0 { &bin_eng } else { &q4_eng };
            eng.classify(ds.image(i), 1).unwrap()[0].0
        })
        .collect();
    // the two models genuinely disagree somewhere, else routing is untested
    let disagree = (0..n).any(|i| {
        bin_eng.classify(ds.image(i), 1).unwrap()[0].0
            != q4_eng.classify(ds.image(i), 1).unwrap()[0].0
    });
    assert!(disagree, "synthetic models agree everywhere; routing test is vacuous");

    let registry = Arc::new(ModelRegistry::new(RegistryConfig {
        pool: PoolConfig {
            workers: 2,
            policy: BatchPolicy { max_batch: 8, window: Duration::from_millis(2) },
            queue_cap: 128,
            ..Default::default()
        },
        ..RegistryConfig::new(dir.clone())
    }));
    let gateway = Gateway::start(registry, "127.0.0.1:0").unwrap();
    let addr = gateway.addr().to_string();

    // 64 in-flight requests on 64 concurrent connections, across both models
    let served: Vec<(usize, u16, String)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let addr = addr.clone();
                let body = classify_body(ds.image(i));
                let model = if i % 2 == 0 { "lenet_bin" } else { "lenet_q4" };
                s.spawn(move || {
                    let path = format!("/v1/models/{model}:classify");
                    let (status, resp) = http_request(&addr, "POST", &path, Some(&body));
                    (i, status, resp)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (i, status, resp) in &served {
        assert_eq!(*status, 200, "request {i} failed: {resp}");
        let v = json::parse(resp).unwrap();
        let class = v.get("class").and_then(|c| c.as_usize()).unwrap();
        let model = v.get("model").and_then(|m| m.as_str()).unwrap();
        let want_model = if i % 2 == 0 { "lenet_bin" } else { "lenet_q4" };
        assert_eq!(model, want_model, "request {i} answered by the wrong model");
        assert_eq!(class, expected[*i], "request {i} routed to the wrong engine");
        assert!(v.get("batch_size").and_then(|b| b.as_usize()).unwrap() >= 1);
    }

    // model listing shows both resident
    let (status, list) = http_request(&addr, "GET", "/v1/models", None);
    assert_eq!(status, 200);
    let v = json::parse(&list).unwrap();
    let models = v.get("models").and_then(|m| m.as_array()).unwrap();
    for name in ["lenet_bin", "lenet_q4"] {
        let entry = models
            .iter()
            .find(|m| m.get("name").and_then(|n| n.as_str()) == Some(name))
            .unwrap_or_else(|| panic!("{name} missing from /v1/models: {list}"));
        assert_eq!(entry.get("loaded"), Some(&json::Value::Bool(true)));
    }

    // /metrics: per-model request counts and histogram consistency.
    // Counters are recorded just *after* replies are sent, so poll briefly
    // instead of racing the last batch's bookkeeping.
    let mut metrics = String::new();
    for _ in 0..50 {
        let (status, text) = http_request(&addr, "GET", "/metrics", None);
        assert_eq!(status, 200);
        metrics = text;
        let done = ["lenet_bin", "lenet_q4"].iter().all(|m| {
            metric_value(&metrics, "bmxnet_requests_total", m) == Some((n / 2) as u64)
        });
        if done {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    for model in ["lenet_bin", "lenet_q4"] {
        let requests = metric_value(&metrics, "bmxnet_requests_total", model)
            .unwrap_or_else(|| panic!("no request counter for {model} in:\n{metrics}"));
        assert_eq!(requests, (n / 2) as u64, "{model} request count");
        assert_eq!(
            batch_hist_weighted_sum(&metrics, model),
            requests,
            "{model}: batch-size histogram does not sum to the requests sent"
        );
        assert_eq!(metric_value(&metrics, "bmxnet_rejected_total", model), Some(0));
    }

    gateway.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bounded_queue_rejects_with_429_under_burst() {
    let dir = temp_models_dir("burst");
    let (bin_eng, _) = write_two_models(&dir);
    let registry = Arc::new(ModelRegistry::new(RegistryConfig {
        // one shard, queue of 1, no batching: a burst must overflow
        pool: PoolConfig {
            workers: 1,
            policy: BatchPolicy { max_batch: 1, window: Duration::ZERO },
            queue_cap: 1,
            ..Default::default()
        },
        ..RegistryConfig::new(dir.clone())
    }));
    let gateway = Gateway::start(registry, "127.0.0.1:0").unwrap();
    let addr = gateway.addr().to_string();
    let ds = Kind::Digits.generate(32, 3);

    // warm the model so the burst hits a loaded pool, not the loader
    let (status, _) = http_request(
        &addr,
        "POST",
        "/v1/models/lenet_bin:classify",
        Some(&classify_body(ds.image(0))),
    );
    assert_eq!(status, 200);

    let results: Vec<(usize, u16, String)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..32)
            .map(|i| {
                let addr = addr.clone();
                let body = classify_body(ds.image(i));
                s.spawn(move || {
                    let (status, resp) =
                        http_request(&addr, "POST", "/v1/models/lenet_bin:classify", Some(&body));
                    (i, status, resp)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let oks = results.iter().filter(|(_, s, _)| *s == 200).count();
    let rejects = results.iter().filter(|(_, s, _)| *s == 429).count();
    assert!(rejects > 0, "queue_cap=1 under a 32-burst never returned 429");
    assert!(oks > 0, "admission control rejected the entire burst");
    assert_eq!(oks + rejects, 32, "unexpected statuses: {results:?}");
    // accepted answers are still correct
    for (i, status, resp) in &results {
        if *status == 200 {
            let class = json::parse(resp).unwrap().get("class").and_then(|c| c.as_usize());
            assert_eq!(class, Some(bin_eng.classify(ds.image(*i), 1).unwrap()[0].0));
        }
    }
    // rejections are visible in /metrics
    let (_, metrics) = http_request(&addr, "GET", "/metrics", None);
    let rejected = metric_value(&metrics, "bmxnet_rejected_total", "lenet_bin").unwrap();
    assert!(rejected >= rejects as u64, "429s not counted: {rejected} < {rejects}");

    gateway.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unknown_model_and_bad_bodies_are_clean_http_errors() {
    let dir = temp_models_dir("errors");
    write_two_models(&dir);
    let registry = Arc::new(ModelRegistry::new(RegistryConfig {
        pool: PoolConfig { workers: 1, ..Default::default() },
        ..RegistryConfig::new(dir.clone())
    }));
    let gateway = Gateway::start(registry, "127.0.0.1:0").unwrap();
    let addr = gateway.addr().to_string();

    let (status, body) =
        http_request(&addr, "POST", "/v1/models/nope:classify", Some("{\"image\": [0]}"));
    assert_eq!(status, 404, "{body}");
    let (status, _) =
        http_request(&addr, "POST", "/v1/models/lenet_bin:classify", Some("not json"));
    assert_eq!(status, 400);
    let (status, body) = http_request(
        &addr,
        "POST",
        "/v1/models/lenet_bin:classify",
        Some("{\"image\": [1, 2, 3]}"),
    );
    assert_eq!(status, 400, "wrong image length must be 400, got: {body}");
    let (status, _) = http_request(&addr, "GET", "/definitely/not/here", None);
    assert_eq!(status, 404);
    let (status, body) = http_request(&addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    assert!(body.contains("ok"));

    gateway.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Count this process's OS threads (Linux: one dir per thread).
fn thread_count() -> usize {
    std::fs::read_dir("/proc/self/task").map(|d| d.count()).unwrap_or(0)
}

/// The reactor's headline capability: 1024 concurrent keep-alive
/// connections on a bounded set of worker threads — 4× the old
/// thread-per-connection gateway's hard 256-connection cap. Every
/// connection answers two rounds of classify requests (round 2 proves
/// keep-alive reuse), and answers match a direct engine.
#[test]
fn serves_1024_keepalive_connections_with_bounded_threads() {
    let n = 1024usize;
    let dir = temp_models_dir("kilo");
    let (bin_eng, _) = write_two_models(&dir);
    let registry = Arc::new(ModelRegistry::new(RegistryConfig {
        pool: PoolConfig {
            workers: 2,
            policy: BatchPolicy { max_batch: 64, window: Duration::from_millis(1) },
            queue_cap: 2 * n,
            ..Default::default()
        },
        ..RegistryConfig::new(dir.clone())
    }));
    let gateway = Gateway::start_with(
        registry,
        "127.0.0.1:0",
        GatewayConfig {
            io_workers: 2,
            max_conns: n + 64,
            idle_timeout: Duration::from_secs(120),
            request_timeout: Duration::from_secs(60),
        },
    )
    .unwrap();
    let addr = gateway.addr();
    let threads_before = thread_count();

    // a handful of distinct images with known answers, cycled across conns
    let ds = Kind::Digits.generate(8, 21);
    let expected: Vec<usize> =
        (0..8).map(|i| bin_eng.classify(ds.image(i), 1).unwrap()[0].0).collect();
    let bodies: Vec<String> = (0..8).map(|i| classify_body(ds.image(i))).collect();

    let mut conns: Vec<TcpStream> = (0..n)
        .map(|i| {
            let s = TcpStream::connect(addr)
                .unwrap_or_else(|e| panic!("connect {i} of {n} failed: {e}"));
            s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
            s
        })
        .collect();

    // opening 1024 connections must not spawn threads per connection
    let threads_during = thread_count();
    assert!(
        threads_during < threads_before + 64,
        "thread count grew from {threads_before} to {threads_during} with {n} conns open"
    );

    for round in 0..2 {
        // write all requests first (keep-alive, no connection: close) …
        for (i, s) in conns.iter_mut().enumerate() {
            let body = &bodies[i % 8];
            let req = format!(
                "POST /v1/models/lenet_bin:classify HTTP/1.1\r\nhost: t\r\n\
                 content-type: application/json\r\ncontent-length: {}\r\n\r\n{body}",
                body.len()
            );
            s.write_all(req.as_bytes())
                .unwrap_or_else(|e| panic!("round {round} write {i}: {e}"));
        }
        // … then read every response; the gateway must hold all of them
        // open and in flight at once
        for (i, s) in conns.iter_mut().enumerate() {
            let mut reader = BufReader::new(s);
            let mut status_line = String::new();
            reader
                .read_line(&mut status_line)
                .unwrap_or_else(|e| panic!("round {round} read {i}: {e}"));
            assert!(
                status_line.contains(" 200 "),
                "round {round} conn {i}: {status_line:?}"
            );
            let mut content_len = 0usize;
            loop {
                let mut h = String::new();
                reader.read_line(&mut h).unwrap();
                let h = h.trim_end();
                if h.is_empty() {
                    break;
                }
                if let Some((k, v)) = h.split_once(':') {
                    if k.trim().eq_ignore_ascii_case("content-length") {
                        content_len = v.trim().parse().unwrap();
                    }
                }
            }
            let mut body = vec![0u8; content_len];
            reader.read_exact(&mut body).unwrap();
            let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
            let class = v.get("class").and_then(|c| c.as_usize()).unwrap();
            assert_eq!(
                class,
                expected[i % 8],
                "round {round} conn {i} answered the wrong class"
            );
        }
    }

    // the reactor saw all of them concurrently
    let (_, metrics) = http_request(&addr.to_string(), "GET", "/metrics", None);
    let active: usize = metrics
        .lines()
        .find_map(|l| l.strip_prefix("bmxnet_active_connections "))
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or_else(|| panic!("no active-connections gauge in:\n{metrics}"));
    assert!(active >= n, "gauge shows {active} active, want >= {n}");

    drop(conns);
    gateway.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn manifest_backed_models_serve_from_artifacts() {
    // Mirrors the other artifact-driven integration tests: skip cleanly
    // when `make artifacts` has not run in this checkout.
    let dir = PathBuf::from(repro::ARTIFACTS_DIR);
    if !dir.join("manifest.json").is_file() {
        eprintln!("SKIP (artifacts not built): no {:?}", dir.join("manifest.json"));
        return;
    }
    let registry = ModelRegistry::new(RegistryConfig {
        pool: PoolConfig { workers: 1, ..Default::default() },
        ..RegistryConfig::new(dir)
    });
    // both acceptance models resolve through the manifest → convert path
    for name in ["lenet_bin", "lenet_q4"] {
        let model = registry.get(name).unwrap();
        assert_eq!(model.info.arch, "lenet");
        let resp = model.pool.classify(vec![0.1f32; 784]).unwrap();
        assert!(resp.class < 10, "{name} class out of range");
    }
    assert!(registry.list().iter().any(|m| m.name == "lenet_bin" && m.loaded));
}
