//! The paper's central equivalence (§2.2.2): the Rust xnor inference
//! engine must produce the same logits as the float-dot AOT graphs, for
//! LeNet and for (partially binarized) ResNet-18 — and the Pallas-composed
//! inference artifact must agree with both.

use repro::model::bmx::convert;
use repro::model::ckpt::Checkpoint;
use repro::nn::Engine;
use repro::runtime::client::{lit_f32, to_f32_vec};
use repro::runtime::{Manifest, ModelEntry, Runtime};
use repro::tensor::Tensor;

/// Artifacts + the real PJRT backend, or a clean skip: these tests must
/// pass (as no-ops) when `artifacts/` is absent or the build is the
/// default pjrt-less stub (DESIGN.md §PJRT runtime gating).
fn manifest() -> Option<Manifest> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("SKIP (built without the `pjrt` feature; PJRT runtime stubbed)");
        return None;
    }
    match Manifest::load(repro::ARTIFACTS_DIR) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (artifacts not built): {e:#}");
            None
        }
    }
}

/// Binary weight names for a model entry (mirrors the CLI's logic).
fn binary_names(entry: &ModelEntry) -> Vec<String> {
    use repro::model::inventory::{self, Stem};
    match entry.arch.as_str() {
        "lenet" => {
            if matches!(entry.raw.get("binary"), Some(repro::model::json::Value::Bool(true))) {
                inventory::lenet(true).binary_names()
            } else {
                vec![]
            }
        }
        "resnet18" => {
            let width = entry.raw.get("width").and_then(|v| v.as_usize()).unwrap_or(64);
            inventory::resnet18(width, entry.classes, Stem::Cifar, &entry.fp_stages())
                .binary_names()
        }
        _ => vec![],
    }
}

/// Run a PJRT inference artifact on a batch with the init-ckpt params.
fn pjrt_logits(
    rt: &Runtime,
    man: &Manifest,
    entry: &ModelEntry,
    file: &str,
    batch: usize,
    x: &[f32],
) -> Vec<f32> {
    let ck = Checkpoint::load(man.path(&entry.init_ckpt)).unwrap();
    let exe = rt.load_cached(man.path(file)).unwrap();
    let mut inputs = Vec::new();
    for spec in &entry.params {
        let (_, data) = ck.get_f32(&format!("params.{}", spec.name)).unwrap();
        inputs.push(lit_f32(data, &spec.shape).unwrap());
    }
    for spec in &entry.state {
        let (_, data) = ck.get_f32(&format!("state.{}", spec.name)).unwrap();
        inputs.push(lit_f32(data, &spec.shape).unwrap());
    }
    let mut dims = vec![batch];
    dims.extend(&entry.input_shape);
    inputs.push(lit_f32(x, &dims).unwrap());
    let out = exe.run(&inputs).unwrap();
    to_f32_vec(&out[0]).unwrap()
}

/// Build the Rust engine from the same init checkpoint.
fn rust_engine(man: &Manifest, entry: &ModelEntry) -> Engine {
    let ck = Checkpoint::load(man.path(&entry.init_ckpt)).unwrap();
    let bmx = convert(&ck, &binary_names(entry), &entry.bmx_meta()).unwrap();
    Engine::from_bmx(&bmx).unwrap()
}

fn test_batch(entry: &ModelEntry, batch: usize, seed: u64) -> Vec<f32> {
    let per: usize = entry.input_shape.iter().product();
    let mut rng = repro::data::Rng::new(seed);
    (0..batch * per).map(|_| rng.normal() * 0.5).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{what}: logit {i} differs: {x} vs {y}"
        );
    }
}

#[test]
fn lenet_bin_engine_matches_pjrt_infer() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let entry = man.model("lenet_bin").unwrap();
    let batch = 8;
    let x = test_batch(entry, batch, 31);
    let inf = entry.infer_for_batch(batch).unwrap();
    let expect = pjrt_logits(&rt, &man, entry, &inf.file, batch, &x);

    let engine = rust_engine(&man, entry);
    let t = Tensor::new(
        {
            let mut d = vec![batch];
            d.extend(&entry.input_shape);
            d
        },
        x,
    );
    let got = engine.forward(&t).unwrap();
    assert_close(got.data(), &expect, 2e-4, "lenet_bin rust-engine vs PJRT");
}

#[test]
fn lenet_bin_pallas_artifact_matches_engine_and_plain() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let entry = man.model("lenet_bin").unwrap();
    let pallas = entry.infer_pallas.as_ref().expect("pallas artifact missing");
    let batch = pallas.batch;
    let x = test_batch(entry, batch, 77);

    let plain = pjrt_logits(
        &rt,
        &man,
        entry,
        &entry.infer_for_batch(batch).unwrap().file,
        batch,
        &x,
    );
    let via_pallas = pjrt_logits(&rt, &man, entry, &pallas.file, batch, &x);
    assert_close(&via_pallas, &plain, 2e-4, "pallas-composed vs plain HLO");

    let engine = rust_engine(&man, entry);
    let t = Tensor::new(
        {
            let mut d = vec![batch];
            d.extend(&entry.input_shape);
            d
        },
        x,
    );
    let got = engine.forward(&t).unwrap();
    assert_close(got.data(), &via_pallas, 2e-4, "rust engine vs pallas artifact");
}

#[test]
fn lenet_fp_engine_matches_pjrt_infer() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let entry = man.model("lenet_fp").unwrap();
    let batch = entry.infer[0].batch;
    let x = test_batch(entry, batch, 13);
    let expect = pjrt_logits(&rt, &man, entry, &entry.infer[0].file, batch, &x);
    let engine = rust_engine(&man, entry);
    let t = Tensor::new(
        {
            let mut d = vec![batch];
            d.extend(&entry.input_shape);
            d
        },
        x,
    );
    let got = engine.forward(&t).unwrap();
    // fp path has more float accumulation divergence than the binary path
    assert_close(got.data(), &expect, 1e-3, "lenet_fp rust-engine vs PJRT");
}

#[test]
fn lenet_q2_kbit_engine_matches_pjrt_infer() {
    // paper §2.1: act_bit = 2 — quantized f32 weights, standard dots.
    let Some(man) = manifest() else { return };
    let Ok(entry) = man.model("lenet_q2") else {
        eprintln!("SKIP (lenet_q2 artifacts not built)");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let batch = entry.infer[0].batch;
    let x = test_batch(entry, batch, 41);
    let expect = pjrt_logits(&rt, &man, entry, &entry.infer[0].file, batch, &x);

    let ck = Checkpoint::load(man.path(&entry.init_ckpt)).unwrap();
    let names = repro::model::inventory::lenet(true).binary_names();
    let bmx =
        repro::model::bmx::convert_kbit(&ck, &names, entry.act_bit(), &entry.bmx_meta())
            .unwrap();
    let engine = Engine::from_bmx(&bmx).unwrap();
    let t = Tensor::new(
        {
            let mut d = vec![batch];
            d.extend(&entry.input_shape);
            d
        },
        x,
    );
    let got = engine.forward(&t).unwrap();
    assert_close(got.data(), &expect, 1e-3, "lenet_q2 rust-engine vs PJRT");
}

#[test]
fn resnet_mini_bin_engine_matches_pjrt_infer() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let entry = man.model("resnet_mini_bin").unwrap();
    let batch = entry.infer[0].batch;
    let x = test_batch(entry, batch, 99);
    let expect = pjrt_logits(&rt, &man, entry, &entry.infer[0].file, batch, &x);
    let engine = rust_engine(&man, entry);
    let t = Tensor::new(
        {
            let mut d = vec![batch];
            d.extend(&entry.input_shape);
            d
        },
        x,
    );
    let got = engine.forward(&t).unwrap();
    assert_close(got.data(), &expect, 1e-3, "resnet_mini_bin vs PJRT");
}

#[test]
fn resnet_mini_partial_engine_matches_pjrt_infer() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    // fp12: stages 1-2 full precision, 3-4 binary — exercises both paths
    let entry = man.model("resnet_mini_img_fp12").unwrap();
    let batch = entry.infer[0].batch;
    let x = test_batch(entry, batch, 55);
    let expect = pjrt_logits(&rt, &man, entry, &entry.infer[0].file, batch, &x);
    let engine = rust_engine(&man, entry);
    let t = Tensor::new(
        {
            let mut d = vec![batch];
            d.extend(&entry.input_shape);
            d
        },
        x,
    );
    let got = engine.forward(&t).unwrap();
    assert_close(got.data(), &expect, 1e-3, "resnet_mini_img_fp12 vs PJRT");
}
