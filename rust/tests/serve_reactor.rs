//! Reactor-gateway behaviors the JSON happy path doesn't cover: slowloris
//! clients hitting the timer-wheel timeout (not a hung worker), pipelined
//! keep-alive requests answered in order, the binary request formats
//! (`x-bmx-f32`, `x-bmx-packed`) agreeing bit-for-bit with their JSON
//! equivalents, and 503 connection shedding at `--max-conns`.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use repro::coordinator::BatchPolicy;
use repro::data::Kind;
use repro::model::bmx::synth_lenet;
use repro::model::json;
use repro::serve::{Gateway, GatewayConfig, ModelRegistry, PoolConfig, RegistryConfig};

fn temp_models_dir(case: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("serve_reactor_{}_{case}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn start_gateway(case: &str, cfg: GatewayConfig) -> (Gateway, PathBuf) {
    let dir = temp_models_dir(case);
    synth_lenet(31, 1).unwrap().save(dir.join("lenet_bin.bmx")).unwrap();
    let registry = Arc::new(ModelRegistry::new(RegistryConfig {
        pool: PoolConfig {
            workers: 1,
            policy: BatchPolicy { max_batch: 8, window: Duration::from_millis(1) },
            queue_cap: 64,
            ..Default::default()
        },
        ..RegistryConfig::new(dir.clone())
    }));
    (Gateway::start_with(registry, "127.0.0.1:0", cfg).unwrap(), dir)
}

/// Read everything until EOF or the read timeout, returning what arrived.
fn read_available(stream: &mut TcpStream, timeout: Duration) -> Vec<u8> {
    stream.set_read_timeout(Some(timeout)).unwrap();
    let mut acc = Vec::new();
    let mut buf = [0u8; 4096];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => acc.extend_from_slice(&buf[..n]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => break,
            Err(_) => break,
        }
    }
    acc
}

/// One request over a fresh connection; returns (status, body).
fn request(addr: &str, raw: &[u8]) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    s.write_all(raw).unwrap();
    let text = read_available(&mut s, Duration::from_secs(60));
    parse_response(&text).unwrap_or_else(|| panic!("no response to {raw:?}"))
}

/// Parse the first buffered response; `None` if the head is incomplete.
fn parse_response(acc: &[u8]) -> Option<(u16, String)> {
    let head_end = acc.windows(4).position(|w| w == b"\r\n\r\n")? + 4;
    let head = std::str::from_utf8(&acc[..head_end]).ok()?;
    let status: u16 = head.split_whitespace().nth(1)?.parse().ok()?;
    let content_len: usize = head.lines().find_map(|l| {
        let (k, v) = l.split_once(':')?;
        k.trim()
            .eq_ignore_ascii_case("content-length")
            .then(|| v.trim().parse().ok())?
    })?;
    let body = acc.get(head_end..head_end + content_len)?;
    Some((status, String::from_utf8_lossy(body).to_string()))
}

fn classify_raw(model: &str, content_type: &str, body: &[u8], keep_alive: bool) -> Vec<u8> {
    let mut req = format!(
        "POST /v1/models/{model}:classify HTTP/1.1\r\nhost: t\r\n\
         content-type: {content_type}\r\ncontent-length: {}\r\n{}\r\n",
        body.len(),
        if keep_alive { "" } else { "connection: close\r\n" },
    )
    .into_bytes();
    req.extend_from_slice(body);
    req
}

fn short_timeout_cfg() -> GatewayConfig {
    GatewayConfig {
        io_workers: 1,
        max_conns: 64,
        idle_timeout: Duration::from_millis(400),
        request_timeout: Duration::from_millis(400),
    }
}

/// A client that sends half a request head and then stalls must be
/// answered by the timeout path (408 or close) — not hold a worker
/// hostage. A healthy request afterwards proves the workers survived.
#[test]
fn slowloris_partial_header_times_out_not_hangs() {
    let (gateway, dir) = start_gateway("slow_head", short_timeout_cfg());
    let addr = gateway.addr().to_string();

    let mut slow = TcpStream::connect(&addr).unwrap();
    slow.write_all(b"GET /healthz HTT").unwrap();
    let t0 = Instant::now();
    // wait for the wheel: either a 408 arrives or the conn closes (EOF)
    let got = read_available(&mut slow, Duration::from_secs(6));
    let waited = t0.elapsed();
    assert!(
        waited < Duration::from_secs(6),
        "gateway neither answered nor closed a stalled half-request"
    );
    if !got.is_empty() {
        let (status, _) = parse_response(&got).expect("partial head answered with garbage");
        assert_eq!(status, 408, "stalled mid-request must time out");
    }

    // workers still serve fine after the slowloris
    let (status, body) = request(&addr, b"GET /healthz HTTP/1.1\r\nconnection: close\r\n\r\n");
    assert_eq!(status, 200, "{body}");
    gateway.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Same for a stalled *body*: complete head claiming 100 bytes, only a
/// few delivered.
#[test]
fn slowloris_partial_body_times_out_not_hangs() {
    let (gateway, dir) = start_gateway("slow_body", short_timeout_cfg());
    let addr = gateway.addr().to_string();

    let mut slow = TcpStream::connect(&addr).unwrap();
    slow.write_all(
        b"POST /v1/models/lenet_bin:classify HTTP/1.1\r\n\
          content-length: 100\r\n\r\n{\"image",
    )
    .unwrap();
    let got = read_available(&mut slow, Duration::from_secs(6));
    if !got.is_empty() {
        let (status, _) = parse_response(&got).expect("partial body answered with garbage");
        assert_eq!(status, 408, "stalled body must time out");
    }

    let (status, _) = request(&addr, b"GET /healthz HTTP/1.1\r\nconnection: close\r\n\r\n");
    assert_eq!(status, 200);
    gateway.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two requests written back-to-back in a single write (HTTP pipelining)
/// must produce two responses, in order, on the same connection.
#[test]
fn pipelined_keepalive_requests_answer_in_order() {
    let (gateway, dir) = start_gateway(
        "pipeline",
        GatewayConfig { io_workers: 1, ..GatewayConfig::default() },
    );
    let addr = gateway.addr().to_string();

    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s.write_all(
        b"GET /healthz HTTP/1.1\r\nhost: t\r\n\r\n\
          GET /v1/models HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n",
    )
    .unwrap();
    // connection: close on the second request delimits the stream
    let mut acc = Vec::new();
    let mut buf = [0u8; 4096];
    loop {
        match s.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => acc.extend_from_slice(&buf[..n]),
            Err(e) => panic!("read pipelined responses: {e}"),
        }
    }
    let (status1, body1) = parse_response(&acc).expect("first pipelined response");
    assert_eq!(status1, 200);
    assert!(body1.contains("ok"), "first response must be /healthz: {body1}");
    let first_len = acc.windows(4).position(|w| w == b"\r\n\r\n").unwrap() + 4 + body1.len();
    let (status2, body2) = parse_response(&acc[first_len..]).expect("second pipelined response");
    assert_eq!(status2, 200);
    assert!(body2.contains("models"), "second response must be /v1/models: {body2}");
    gateway.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// `application/x-bmx-f32` (raw LE floats) must classify bit-identically
/// to the JSON body carrying the same pixels.
#[test]
fn binary_f32_body_matches_json_bitwise() {
    let (gateway, dir) = start_gateway("binf32", GatewayConfig::default());
    let addr = gateway.addr().to_string();
    let ds = Kind::Digits.generate(3, 77);

    for i in 0..3 {
        let image = ds.image(i);
        let json_body: String = format!(
            "{{\"image\": [{}]}}",
            image.iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(",")
        );
        let (s1, r1) = request(
            &addr,
            &classify_raw("lenet_bin", "application/json", json_body.as_bytes(), false),
        );
        assert_eq!(s1, 200, "{r1}");

        let raw: Vec<u8> = image.iter().flat_map(|v| v.to_le_bytes()).collect();
        let (s2, r2) =
            request(&addr, &classify_raw("lenet_bin", "application/x-bmx-f32", &raw, false));
        assert_eq!(s2, 200, "{r2}");

        let (v1, v2) = (json::parse(&r1).unwrap(), json::parse(&r2).unwrap());
        assert_eq!(v1.get("class"), v2.get("class"), "class differs: {r1} vs {r2}");
        assert_eq!(v1.get("score"), v2.get("score"), "score differs: {r1} vs {r2}");
    }

    // a mis-sized raw body is a clean 400
    let (status, body) =
        request(&addr, &classify_raw("lenet_bin", "application/x-bmx-f32", &[0u8; 7], false));
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("raw f32 bytes"), "{body}");
    gateway.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// `application/x-bmx-packed` (LSB-first sign bits) must agree with the
/// JSON body carrying the equivalent ±1.0 floats.
#[test]
fn packed_body_matches_json_of_signs_bitwise() {
    let (gateway, dir) = start_gateway("packed", GatewayConfig::default());
    let addr = gateway.addr().to_string();
    let ds = Kind::Digits.generate(2, 55);

    for i in 0..2 {
        // ±1.0 image from the sample's signs — exactly representable both ways
        let signs: Vec<f32> =
            ds.image(i).iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
        let json_body = format!(
            "{{\"image\": [{}]}}",
            signs.iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(",")
        );
        let (s1, r1) = request(
            &addr,
            &classify_raw("lenet_bin", "application/json", json_body.as_bytes(), false),
        );
        assert_eq!(s1, 200, "{r1}");

        let mut packed = vec![0u8; signs.len().div_ceil(8)];
        for (j, &v) in signs.iter().enumerate() {
            if v > 0.0 {
                packed[j / 8] |= 1 << (j % 8);
            }
        }
        let (s2, r2) =
            request(&addr, &classify_raw("lenet_bin", "application/x-bmx-packed", &packed, false));
        assert_eq!(s2, 200, "{r2}");

        let (v1, v2) = (json::parse(&r1).unwrap(), json::parse(&r2).unwrap());
        assert_eq!(v1.get("class"), v2.get("class"), "class differs: {r1} vs {r2}");
        assert_eq!(v1.get("score"), v2.get("score"), "score differs: {r1} vs {r2}");
    }

    // 784 bits: no padding in the last byte, but a wrong byte count is 400
    let (status, body) =
        request(&addr, &classify_raw("lenet_bin", "application/x-bmx-packed", &[0u8; 3], false));
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("packed bytes"), "{body}");
    gateway.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Past `max_conns` open connections the acceptor sheds immediately with
/// a 503 instead of queueing or crashing, and the shed counter shows it.
#[test]
fn sheds_connections_past_max_conns_with_503() {
    let (gateway, dir) = start_gateway(
        "shed",
        GatewayConfig {
            io_workers: 1,
            max_conns: 2,
            idle_timeout: Duration::from_secs(30),
            request_timeout: Duration::from_secs(10),
        },
    );
    let addr = gateway.addr().to_string();

    // hold the only two allowed slots open and idle
    let held: Vec<TcpStream> = (0..2).map(|_| TcpStream::connect(&addr).unwrap()).collect();
    // the acceptor counts at accept; give it a beat to adopt both
    std::thread::sleep(Duration::from_millis(100));

    let mut third = TcpStream::connect(&addr).unwrap();
    let got = read_available(&mut third, Duration::from_secs(5));
    let (status, body) = parse_response(&got).expect("shed connection got no 503");
    assert_eq!(status, 503, "{body}");
    assert!(body.contains("connection limit"), "{body}");

    // free the slots; the shed counter must be visible on /metrics
    drop(held);
    let mut shed_total = 0u64;
    for _ in 0..50 {
        let mut s = match TcpStream::connect(&addr) {
            Ok(s) => s,
            Err(_) => continue,
        };
        s.write_all(b"GET /metrics HTTP/1.1\r\nconnection: close\r\n\r\n").unwrap();
        let got = read_available(&mut s, Duration::from_secs(5));
        if let Some((200, text)) = parse_response(&got) {
            shed_total = text
                .lines()
                .find_map(|l| l.strip_prefix("bmxnet_conns_shed_total "))
                .and_then(|v| v.trim().parse().ok())
                .unwrap_or(0);
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(shed_total >= 1, "shed counter never reached 1");
    gateway.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
