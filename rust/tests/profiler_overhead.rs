//! Observability overhead budget: the instrumented hot path must not
//! allocate (DESIGN.md §Observability).
//!
//! A counting global allocator wraps `System` and tallies every
//! `alloc`/`alloc_zeroed`/`realloc` in the process; each assertion warms
//! its path first (lazy statics, CPU-feature detection), then measures
//! the allocation-count delta across many iterations and requires it to
//! be zero at least once out of several attempts (other test threads in
//! the same binary may allocate concurrently, so a single noisy run must
//! not flake the suite — this file has exactly one #[test] to keep the
//! binary single-threaded anyway).
//!
//! The `unsafe` here is confined to forwarding the `GlobalAlloc` trait to
//! `System`; library code stays safe (`gemm/simd.rs` is the one unsafe
//! library module).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use repro::obs::{journal::Journal, layer, BatchTiming, Obs, Stage, StageStats, Trace};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Run `f` repeatedly; pass if any attempt saw zero allocations.
fn assert_alloc_free(what: &str, mut f: impl FnMut()) {
    let mut best = u64::MAX;
    for _ in 0..5 {
        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..100 {
            f();
        }
        let delta = ALLOCS.load(Ordering::Relaxed) - before;
        best = best.min(delta);
        if best == 0 {
            return;
        }
    }
    panic!("{what}: allocated {best} times in 100 iterations (want 0)");
}

#[test]
fn disabled_observability_does_not_allocate() {
    // Warm every lazy path outside the measured windows: journal + stats
    // construction, CPU feature detection behind kernel dispatch, the
    // env read in from_env, and one full trace publish.
    let obs = Obs::with_slots(64);
    let journal = Journal::new(64);
    let stats = StageStats::new();
    let _ = repro::gemm::simd::best_kernel();
    let timing = BatchTiming { queue_us: 3, window_us: 2, forward_us: 40 };
    let mut warm = Trace::begin();
    warm.mark(Stage::Parse);
    warm.absorb_batch_timing(&timing);
    let rec = warm.finish("warmup", 200, 0, 1);
    obs.complete(&rec);
    journal.publish(&rec);
    stats.observe_record(&rec);

    // 1. The layer() hook with no profiler: one branch, no name string.
    assert_alloc_free("layer(None)", || {
        let v = layer(
            None,
            || unreachable!("name closure must not run when disabled"),
            "tanh",
            None,
            4096,
            || 7u64,
        );
        assert_eq!(v, 7);
    });

    // 2. A full trace lifecycle: begin, marks, batch fold, finish.
    assert_alloc_free("trace lifecycle", || {
        let mut t = Trace::begin();
        t.mark(Stage::Parse);
        t.mark(Stage::Admission);
        t.absorb_batch_timing(&timing);
        t.mark(Stage::Respond);
        let r = t.finish("lenet_bin", 200, 1, 8);
        assert_eq!(r.status, 200);
    });

    // 3. Stage histogram observation.
    assert_alloc_free("StageStats::observe_record", || {
        stats.observe_record(&rec);
    });

    // 4. Journal publish (seqlock slot write).
    assert_alloc_free("Journal::publish", || {
        journal.publish(&rec);
    });

    // 5. The whole per-request completion path (no slow log configured).
    assert!(obs.slow_req_us.is_none() || std::env::var_os("BMXNET_SLOW_REQ_US").is_some());
    if obs.slow_req_us.is_none() {
        assert_alloc_free("Obs::complete", || {
            obs.complete(&rec);
        });
    }
}
