//! Property-based tests (hand-rolled; the proptest crate is unavailable
//! offline).  Each property runs over a few hundred randomized cases from
//! a seeded splitmix64 generator, with the failing seed printed on panic.

use repro::coordinator::BatchPolicy;
use repro::data::Rng;
use repro::gemm::{binary_gemm_f32, naive, Method, PackedMatrix, Side};
use repro::model::ckpt::Checkpoint;
use repro::model::json;
use repro::quant::{dot_to_xnor, quantize_k, sign_binarize, xnor_to_dot};
use std::time::{Duration, Instant};

fn cases(n: usize) -> impl Iterator<Item = (u64, Rng)> {
    (0..n as u64).map(|seed| (seed, Rng::new(seed * 7919 + 13)))
}

// ---------------------------------------------------------------------------
// GEMM family equivalence  (the paper's Eq. 2 contract, ∀ shapes)
// ---------------------------------------------------------------------------

#[test]
fn prop_all_gemm_variants_agree() {
    for (seed, mut rng) in cases(150) {
        let m = 1 + rng.below(12);
        let n = 1 + rng.below(20);
        let k = 1 + rng.below(300);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let ab: Vec<f32> = a.iter().map(|&x| sign_binarize(x)).collect();
        let bb: Vec<f32> = b.iter().map(|&x| sign_binarize(x)).collect();
        let expect = naive::gemm_f32(&ab, &bb, m, n, k);
        for method in Method::available() {
            let got = binary_gemm_f32(method, &a, &b, m, n, k);
            assert_eq!(got, expect, "seed={seed} method={method:?} m={m} n={n} k={k}");
        }
    }
}

#[test]
fn prop_xnor_popcount_in_range_and_parity() {
    // pop in [0, k]; dot = 2*pop - k has the same parity as k
    for (seed, mut rng) in cases(100) {
        let m = 1 + rng.below(6);
        let n = 1 + rng.below(6);
        let k = 1 + rng.below(200);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let pa = PackedMatrix::pack_rows(&a, m, k, Side::A);
        let pb = PackedMatrix::pack_cols(&b, k, n);
        for pop in repro::gemm::xnor_gemm_prepacked(Method::Xnor64, &pa, &pb) {
            assert!((0..=k as i32).contains(&pop), "seed={seed} pop={pop} k={k}");
            let dot = xnor_to_dot(pop, k) as i64;
            assert_eq!((dot + k as i64) % 2, 0, "seed={seed} parity");
        }
    }
}

#[test]
fn prop_pack_unpack_roundtrip() {
    for (seed, mut rng) in cases(200) {
        let rows = 1 + rng.below(8);
        let k = 1 + rng.below(260);
        let data: Vec<f32> = (0..rows * k).map(|_| rng.normal()).collect();
        let side = if rng.below(2) == 0 { Side::A } else { Side::B };
        let p = PackedMatrix::pack_rows(&data, rows, k, side);
        let back = p.unpack();
        for (u, o) in back.iter().zip(&data) {
            assert_eq!(*u, sign_binarize(*o), "seed={seed}");
        }
    }
}

// ---------------------------------------------------------------------------
// Pack-layer invariants the SIMD kernels rely on (kernels never mask tail
// words; correctness hangs entirely on these pad-bit properties)
// ---------------------------------------------------------------------------

#[test]
fn prop_pack_pad_bits_follow_side_convention() {
    // A-side pad bits are all 1, B-side pad bits are all 0, in the last
    // word of every packed row — for pack_rows on both sides and for
    // pack_cols (which is B-side by definition).
    for (seed, mut rng) in cases(200) {
        let rows = 1 + rng.below(8);
        let k = 1 + rng.below(260);
        if k % 64 == 0 {
            continue; // no pad bits to check
        }
        let pad_mask = !0u64 << (k % 64);
        let data: Vec<f32> = (0..rows * k).map(|_| rng.normal()).collect();
        let pa = PackedMatrix::pack_rows(&data, rows, k, Side::A);
        let pb = PackedMatrix::pack_rows(&data, rows, k, Side::B);
        for r in 0..rows {
            let a_last = *pa.row(r).last().unwrap();
            let b_last = *pb.row(r).last().unwrap();
            assert_eq!(a_last & pad_mask, pad_mask, "seed={seed} r={r}: A pads must be 1s");
            assert_eq!(b_last & pad_mask, 0, "seed={seed} r={r}: B pads must be 0s");
        }
        let n = 1 + rng.below(6);
        let bdata: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let pc = PackedMatrix::pack_cols(&bdata, k, n);
        for j in 0..n {
            let last = *pc.row(j).last().unwrap();
            assert_eq!(last & pad_mask, 0, "seed={seed} j={j}: pack_cols pads must be 0s");
        }
    }
}

#[test]
fn prop_corrupted_pad_bit_shifts_popcount_by_one() {
    // The negative control for the property above: flipping a single
    // B-side pad bit to 1 makes it xnor-match the A-side 1-pad, inflating
    // exactly the affected column's popcounts by exactly one.  If this
    // test ever passes with a diff of 0, the kernels started masking
    // tails and the pad convention is dead weight; if the diff exceeds 1,
    // packing leaked real bits into the pad region.
    for (seed, mut rng) in cases(60) {
        let m = 1 + rng.below(5);
        let n = 1 + rng.below(5);
        let k = 1 + rng.below(200);
        if k % 64 == 0 {
            continue;
        }
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let pa = PackedMatrix::pack_rows(&a, m, k, Side::A);
        let pb = PackedMatrix::pack_cols(&b, k, n);
        let clean = repro::gemm::xnor_gemm_prepacked(Method::Xnor64Blocked, &pa, &pb);
        let victim = rng.below(n);
        let pad_bit = k % 64 + rng.below(64 - k % 64); // any bit in the pad region
        let mut corrupt = pb.clone();
        let wpr = corrupt.words_per_row;
        corrupt.words[victim * wpr + wpr - 1] |= 1u64 << pad_bit;
        let dirty = repro::gemm::xnor_gemm_prepacked(Method::Xnor64Blocked, &pa, &corrupt);
        for i in 0..m {
            for j in 0..n {
                let (c, d) = (clean[i * n + j], dirty[i * n + j]);
                if j == victim {
                    assert_eq!(d, c + 1, "seed={seed} ({i},{j}): corrupt pad must add exactly 1");
                } else {
                    assert_eq!(d, c, "seed={seed} ({i},{j}): other columns must be untouched");
                }
            }
        }
    }
}

#[test]
fn prop_prepacked_agrees_with_f32_entry() {
    // xnor_gemm_prepacked (popcount domain) and binary_gemm_f32 (±1 dot
    // domain) must describe the same logical matrix for every available
    // binary method — the Eq. 2 bridge, per method.
    for (seed, mut rng) in cases(40) {
        let m = 1 + rng.below(8);
        let n = 1 + rng.below(12);
        let k = 1 + rng.below(300);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let pa = PackedMatrix::pack_rows(&a, m, k, Side::A);
        let pb = PackedMatrix::pack_cols(&b, k, n);
        for method in Method::available().into_iter().filter(|m| m.is_binary()) {
            let via_pop: Vec<f32> = repro::gemm::xnor_gemm_prepacked(method, &pa, &pb)
                .into_iter()
                .map(|p| xnor_to_dot(p, k))
                .collect();
            let via_f32 = binary_gemm_f32(method, &a, &b, m, n, k);
            assert_eq!(via_pop, via_f32, "seed={seed} method={method:?} m={m} n={n} k={k}");
        }
    }
}

// ---------------------------------------------------------------------------
// BN+sign threshold folding (integer epilogue ≡ float reference, ∀ channels)
// ---------------------------------------------------------------------------

#[test]
fn prop_folded_thresholds_match_f32_bn_sign() {
    use repro::gemm::{binary_gemm_packed_b, binary_gemm_packed_b_threshold, fold_bn_sign_all};
    for (seed, mut rng) in cases(60) {
        let m = 1 + rng.below(6);
        let n = 1 + rng.below(12);
        let k = 1 + rng.below(200);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        // Mixed-sign scales with occasional exact zeros; shifts spanning
        // magnitudes so some channels saturate at the popcount extremes.
        let scale: Vec<f32> = (0..n)
            .map(|j| if j % 5 == 4 { 0.0 } else { rng.normal() * 10f32.powi(rng.below(5) as i32 - 2) })
            .collect();
        let shift: Vec<f32> = (0..n)
            .map(|_| rng.normal() * 10f32.powi(rng.below(7) as i32 - 3))
            .collect();
        let rules = fold_bn_sign_all(&scale, &shift, k);
        let pb = PackedMatrix::pack_cols(&b, k, n);
        let pops = binary_gemm_packed_b(Method::XnorFused, &a, m, k, &pb);
        let bits = binary_gemm_packed_b_threshold(&a, m, k, &pb, &rules);
        for i in 0..m {
            for j in 0..n {
                let dot = xnor_to_dot(pops[i * n + j], k);
                let reference = scale[j] * dot + shift[j] >= 0.0;
                assert_eq!(
                    bits.get_bit(i, j),
                    reference,
                    "seed={seed} ({i},{j}) scale={} shift={} pop={} k={k}",
                    scale[j],
                    shift[j],
                    pops[i * n + j],
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Eq. 1 / Eq. 2 quantization properties
// ---------------------------------------------------------------------------

#[test]
fn prop_quantize_idempotent_monotone_bounded() {
    for (seed, mut rng) in cases(200) {
        let k = 1 + rng.below(31) as u32;
        let x1 = rng.uniform();
        let x2 = rng.uniform();
        let (lo, hi) = if x1 <= x2 { (x1, x2) } else { (x2, x1) };
        let (qlo, qhi) = (quantize_k(lo, k), quantize_k(hi, k));
        assert!(qlo <= qhi, "seed={seed} monotonicity k={k}");
        assert!((0.0..=1.0).contains(&qlo), "seed={seed} bounds");
        assert_eq!(quantize_k(qlo, k), qlo, "seed={seed} idempotence");
        // quantization error bounded by half a level
        let levels = ((1u64 << k) - 1) as f32;
        assert!((qlo - lo).abs() <= 0.5 / levels + 1e-6, "seed={seed} error bound");
    }
}

#[test]
fn prop_eq2_maps_are_inverse_bijections() {
    for (seed, mut rng) in cases(300) {
        let n = 1 + rng.below(20_000);
        let matches = rng.below(n + 1);
        let dot = (2 * matches) as f32 - n as f32;
        let pop = dot_to_xnor(dot, n);
        assert_eq!(pop, matches as f32, "seed={seed}");
        assert_eq!(xnor_to_dot(matches as i32, n), dot, "seed={seed}");
    }
}

// ---------------------------------------------------------------------------
// Checkpoint + JSON formats
// ---------------------------------------------------------------------------

#[test]
fn prop_ckpt_roundtrip_random_tensors() {
    for (seed, mut rng) in cases(60) {
        let mut ck = Checkpoint::new();
        let count = 1 + rng.below(6);
        for t in 0..count {
            let ndim = rng.below(4);
            let shape: Vec<usize> = (0..ndim).map(|_| 1 + rng.below(5)).collect();
            let n: usize = shape.iter().product();
            if rng.below(2) == 0 {
                ck.push_f32(
                    &format!("t{t}.x"),
                    shape,
                    (0..n).map(|_| rng.normal()).collect(),
                );
            } else {
                ck.push_u32(
                    &format!("t{t}.x"),
                    shape,
                    (0..n).map(|_| rng.next_u64() as u32).collect(),
                );
            }
        }
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.len(), ck.len(), "seed={seed}");
        for ((n1, s1, d1), (n2, s2, d2)) in ck.tensors.iter().zip(&back.tensors) {
            assert_eq!(n1, n2, "seed={seed}");
            assert_eq!(s1, s2, "seed={seed}");
            assert_eq!(d1, d2, "seed={seed}");
        }
    }
}

#[test]
fn prop_json_parses_generated_numbers() {
    for (seed, mut rng) in cases(300) {
        let v = (rng.normal() as f64) * 10f64.powi(rng.below(7) as i32 - 3);
        let text = format!("{v}");
        let parsed = json::parse(&text).unwrap_or_else(|e| panic!("seed={seed} {text}: {e}"));
        let got = parsed.as_f64().unwrap();
        assert!(
            (got - v).abs() <= 1e-9 * v.abs().max(1.0),
            "seed={seed}: {text} -> {got}"
        );
    }
}

#[test]
fn prop_json_string_escaping_roundtrip() {
    for (seed, mut rng) in cases(100) {
        let len = rng.below(20);
        let s: String = (0..len)
            .map(|_| {
                let c = rng.below(96) as u8 + 32;
                c as char
            })
            .collect();
        let escaped = s.replace('\\', "\\\\").replace('"', "\\\"");
        let parsed = json::parse(&format!("\"{escaped}\""))
            .unwrap_or_else(|e| panic!("seed={seed} {escaped:?}: {e}"));
        assert_eq!(parsed.as_str(), Some(s.as_str()), "seed={seed}");
    }
}

// ---------------------------------------------------------------------------
// Batching policy invariants (routing/batching/state per DESIGN.md)
// ---------------------------------------------------------------------------

#[test]
fn prop_batch_policy_never_exceeds_max_and_never_starves() {
    let t0 = Instant::now();
    for (seed, mut rng) in cases(200) {
        let policy = BatchPolicy {
            max_batch: 1 + rng.below(64),
            window: Duration::from_micros(1 + rng.below(5000) as u64),
        };
        let queued = rng.below(200);
        let age = Duration::from_micros(rng.below(10_000) as u64);
        let now = t0 + age;
        let dispatch = policy.should_dispatch(queued, t0, now);
        if queued >= policy.max_batch {
            assert!(dispatch, "seed={seed}: full batch must dispatch");
        }
        if age >= policy.window && queued > 0 {
            assert!(dispatch, "seed={seed}: expired window must dispatch (no starvation)");
        }
        if !dispatch {
            assert!(
                queued < policy.max_batch && age < policy.window,
                "seed={seed}: held batch must be under both limits"
            );
        }
        // remaining() is consistent with should_dispatch on the time axis
        if policy.remaining(t0, now) == Duration::ZERO && queued > 0 {
            assert!(dispatch, "seed={seed}: zero budget but no dispatch");
        }
    }
}

#[test]
fn prop_dataset_epochs_partition_examples() {
    for (seed, mut rng) in cases(40) {
        let n = 4 + rng.below(60);
        let batch = 1 + rng.below(8);
        let ds = repro::data::Kind::Digits.generate(n, seed);
        let epochs = ds.epoch(batch, seed);
        // every batch full-sized; total coverage >= n
        let mut count = 0;
        for b in &epochs {
            assert_eq!(b.labels.len(), batch, "seed={seed}");
            count += batch;
        }
        assert!(count >= n, "seed={seed}");
        assert!(count < n + batch, "seed={seed}: over-padded");
        let _ = rng.next_u64();
    }
}
