//! Reusable buffer pools for the reactor gateway.
//!
//! Two pools with different ownership rules (DESIGN.md §Gateway reactor):
//!
//! * [`BytePool`] — request/response byte buffers. Owned by exactly one
//!   event-loop worker, so it is plain `&mut self` with no locking: a
//!   connection checks buffers out when it is accepted and the worker
//!   puts them back when the connection closes. Oversized buffers (a
//!   client that once sent a near-`MAX_BODY` request) are dropped rather
//!   than retained, so one abusive request cannot pin megabytes forever.
//! * [`FloatPool`] — decoded image tensors (`Vec<f32>`) that leave the
//!   gateway thread entirely: they ride a [`crate::coordinator::ImageBuf`]
//!   through the pool shard's queue into the batcher, which copies the
//!   pixels into its contiguous batch and recycles the buffer from *its*
//!   thread. The return path is therefore a `Mutex`-guarded free list
//!   behind an `Arc` closure ([`ImageBuf::pooled`]'s `home` hook); the
//!   lock is held for a push/pop only and the drop guarantee on
//!   `ImageBuf` means every exit path (queue-full give-back, engine
//!   failure, shutdown drain) still returns the storage.

use std::sync::{Arc, Mutex};

use crate::coordinator::ImageBuf;

/// Cap on the *capacity* of a byte buffer worth keeping. Buffers that
/// grew past this (large request bodies) are freed instead of pooled.
pub const BYTE_RETAIN_CAP: usize = 256 << 10;

/// Per-worker stack of reusable byte buffers. Not `Sync` on purpose —
/// each event-loop worker owns its own.
pub struct BytePool {
    free: Vec<Vec<u8>>,
    max_pooled: usize,
}

impl BytePool {
    pub fn new(max_pooled: usize) -> BytePool {
        BytePool { free: Vec::new(), max_pooled }
    }

    /// Check out an empty buffer (reused capacity when available).
    pub fn get(&mut self) -> Vec<u8> {
        self.free.pop().unwrap_or_default()
    }

    /// Return a buffer. Cleared; dropped instead of pooled when the pool
    /// is full or the buffer's capacity exceeds [`BYTE_RETAIN_CAP`].
    pub fn put(&mut self, mut buf: Vec<u8>) {
        if self.free.len() < self.max_pooled && buf.capacity() <= BYTE_RETAIN_CAP {
            buf.clear();
            self.free.push(buf);
        }
    }

    /// Buffers currently pooled (tests/metrics).
    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

/// Shared free list of decoded image tensors. Cheap to clone (two Arcs);
/// the gateway keeps one per `Gateway`, shared by all workers, because
/// buffers are returned from the batcher thread, not the worker that
/// checked them out.
#[derive(Clone)]
pub struct FloatPool {
    free: Arc<Mutex<Vec<Vec<f32>>>>,
    home: Arc<dyn Fn(Vec<f32>) + Send + Sync>,
}

impl FloatPool {
    pub fn new(max_pooled: usize) -> FloatPool {
        let free: Arc<Mutex<Vec<Vec<f32>>>> = Arc::new(Mutex::new(Vec::new()));
        let slot = free.clone();
        let home = Arc::new(move |mut v: Vec<f32>| {
            v.clear();
            if let Ok(mut g) = slot.lock() {
                if g.len() < max_pooled {
                    g.push(v);
                }
            }
        });
        FloatPool { free, home }
    }

    /// Check out an empty tensor with at least `cap` capacity, wrapped so
    /// that recycling/dropping it anywhere returns the storage here.
    pub fn checkout(&self, cap: usize) -> ImageBuf {
        let mut v = self
            .free
            .lock()
            .map(|mut g| g.pop())
            .ok()
            .flatten()
            .unwrap_or_default();
        v.clear();
        v.reserve(cap);
        ImageBuf::pooled(v, self.home.clone())
    }

    /// Tensors currently pooled (tests/metrics).
    pub fn pooled(&self) -> usize {
        self.free.lock().map(|g| g.len()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_pool_reuses_capacity_and_caps_retention() {
        let mut p = BytePool::new(2);
        let mut a = p.get();
        a.extend_from_slice(b"hello");
        let cap_a = a.capacity();
        p.put(a);
        assert_eq!(p.pooled(), 1);
        let b = p.get();
        assert!(b.is_empty(), "returned buffer must come back cleared");
        assert_eq!(b.capacity(), cap_a, "capacity must be reused");
        assert_eq!(p.pooled(), 0);

        // pool size cap
        p.put(vec![1; 8]);
        p.put(vec![2; 8]);
        p.put(vec![3; 8]);
        assert_eq!(p.pooled(), 2);

        // oversized buffers are dropped, not retained
        let mut q = BytePool::new(4);
        q.put(Vec::with_capacity(BYTE_RETAIN_CAP + 1));
        assert_eq!(q.pooled(), 0);
    }

    #[test]
    fn float_pool_round_trips_through_imagebuf_recycle_and_drop() {
        let pool = FloatPool::new(4);
        let mut buf = pool.checkout(16);
        assert!(buf.is_empty());
        for i in 0..16 {
            buf.push(i as f32);
        }
        assert_eq!(buf.len(), 16);
        assert_eq!(pool.pooled(), 0);
        buf.recycle();
        assert_eq!(buf.len(), 0, "recycled buffer reads empty");
        assert_eq!(pool.pooled(), 1, "explicit recycle returns storage");

        let again = pool.checkout(4);
        assert_eq!(pool.pooled(), 0);
        drop(again);
        assert_eq!(pool.pooled(), 1, "drop also returns storage");
    }

    #[test]
    fn float_pool_return_crosses_threads() {
        let pool = FloatPool::new(4);
        let mut buf = pool.checkout(8);
        buf.extend_from_slice(&[1.0, 2.0]);
        std::thread::spawn(move || drop(buf)).join().unwrap();
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn float_pool_caps_pooled_count() {
        let pool = FloatPool::new(1);
        let a = pool.checkout(4);
        let b = pool.checkout(4);
        drop(a);
        drop(b);
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn unpooled_imagebuf_from_vec_still_works() {
        let mut buf = ImageBuf::from(vec![0.5f32; 3]);
        assert_eq!(&buf[..], &[0.5, 0.5, 0.5]);
        buf.recycle();
        assert!(buf.is_empty());
    }
}
