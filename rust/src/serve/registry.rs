//! Multi-model registry: name → sharded pool, loaded lazily from a
//! models directory.
//!
//! Two source kinds resolve a model name, in order:
//!
//! 1. `<models_dir>/<name>.bmx` — a packed deployment model (what
//!    `bmxnet convert` writes);
//! 2. a `manifest.json` entry — the BMXC init/trained checkpoint named by
//!    the artifact manifest, converted on first request (the same
//!    arch-driven packing as `bmxnet convert`).
//!
//! Residency policy: models load on first request; a byte budget evicts
//! the least-recently-used pool when exceeded (in-flight requests keep
//! the evicted pool alive through its `Arc` until they drain).  Hot swap:
//! every lookup fingerprints the source file (mtime + length), so
//! overwriting a `.bmx` swaps the model in on the next request with no
//! gateway restart.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant, SystemTime};

use super::pool::{ModelPool, PoolConfig};
use crate::model::bmx::{convert, convert_kbit, BmxModel};
use crate::model::ckpt::Checkpoint;
use crate::model::inventory::{self, Stem};
use crate::model::json::{self, Value};
use crate::nn::Engine;
use crate::runtime::Manifest;

/// Binary weight names + embedded `.bmx` metadata for a manifest model
/// (arch + metadata driven).  Shared by `bmxnet convert` and the
/// registry's manifest-backed loading path.
pub fn binary_names_for(manifest: &Manifest, model: &str) -> Result<(Vec<String>, String)> {
    let entry = manifest.model(model)?;
    let meta = entry.bmx_meta();
    let names = match entry.arch.as_str() {
        "lenet" => {
            let binary = matches!(entry.raw.get("binary"), Some(Value::Bool(true)));
            if binary {
                inventory::lenet(true).binary_names()
            } else {
                vec![]
            }
        }
        "resnet18" => {
            let width = entry.raw.get("width").and_then(|v| v.as_usize()).unwrap_or(64);
            let fp = entry.fp_stages();
            inventory::resnet18(width, entry.classes, Stem::Cifar, &fp).binary_names()
        }
        other => bail!("unknown arch {other}"),
    };
    Ok((names, meta))
}

/// Registry construction parameters.
#[derive(Debug, Clone)]
pub struct RegistryConfig {
    /// Directory holding `<name>.bmx` files and/or an artifact manifest.
    pub models_dir: PathBuf,
    /// Pool shape applied to every model.
    pub pool: PoolConfig,
    /// LRU eviction budget over resident packed payload bytes; 0 = no cap.
    pub max_resident_bytes: usize,
    /// How stale a hot-swap fingerprint check may be: the source file is
    /// re-stat'ed at most this often (the stat runs under the registry
    /// lock, so per-request stats would serialize all models on one
    /// syscall).  `Duration::ZERO` re-checks on every lookup.
    pub fingerprint_ttl: Duration,
}

impl RegistryConfig {
    pub fn new(models_dir: impl Into<PathBuf>) -> Self {
        Self {
            models_dir: models_dir.into(),
            pool: PoolConfig::default(),
            max_resident_bytes: 0,
            fingerprint_ttl: Duration::from_secs(2),
        }
    }
}

/// Static facts about a resident model.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub arch: String,
    pub input_shape: [usize; 3],
    pub classes: usize,
    /// Packed payload bytes (the LRU accounting unit).
    pub resident_bytes: usize,
}

/// Identity of the bytes a model was loaded from (hot-swap detection).
#[derive(Debug, Clone, PartialEq, Eq)]
struct Fingerprint {
    path: PathBuf,
    mtime: Option<SystemTime>,
    len: u64,
}

fn fingerprint_of(path: &Path) -> Option<Fingerprint> {
    let meta = std::fs::metadata(path).ok()?;
    Some(Fingerprint { path: path.to_path_buf(), mtime: meta.modified().ok(), len: meta.len() })
}

/// A resident model: its pool plus the source identity.
pub struct LoadedModel {
    pub info: ModelInfo,
    pub pool: ModelPool,
    /// The engine the pool shards share — exposed so diagnostics
    /// (`GET /v1/models/{name}/profile`, `dispatch_summary`) can run
    /// against the exact loaded weights without a second load.
    pub engine: Arc<Engine>,
    fingerprint: Fingerprint,
}

/// One row of [`ModelRegistry::list`].
#[derive(Debug, Clone)]
pub struct ModelStatus {
    pub name: String,
    /// "bmx" (a `<name>.bmx` file) or "manifest" (BMXC checkpoint entry).
    pub source: &'static str,
    pub loaded: bool,
    pub resident_bytes: usize,
    /// [`Engine::dispatch_summary`] for resident models; `None` until
    /// the model is loaded.
    pub dispatch: Option<String>,
}

struct Entry {
    model: Arc<LoadedModel>,
    last_used: u64,
    /// When the source fingerprint was last verified against disk.
    checked_at: Instant,
}

struct Inner {
    loaded: HashMap<String, Entry>,
    /// Names with a load in flight (cold-start herd dedup).
    loading: HashSet<String>,
    clock: u64,
}

/// The serving gateway's model table.
pub struct ModelRegistry {
    cfg: RegistryConfig,
    inner: Mutex<Inner>,
    /// Signalled whenever a load finishes (success or failure).
    load_done: Condvar,
}

fn validate_name(name: &str) -> Result<()> {
    let ok = !name.is_empty()
        && name.len() <= 128
        && !name.contains("..")
        && name.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.'));
    anyhow::ensure!(ok, "invalid model name {name:?}");
    Ok(())
}

impl ModelRegistry {
    pub fn new(cfg: RegistryConfig) -> Self {
        Self {
            cfg,
            inner: Mutex::new(Inner {
                loaded: HashMap::new(),
                loading: HashSet::new(),
                clock: 0,
            }),
            load_done: Condvar::new(),
        }
    }

    pub fn models_dir(&self) -> &Path {
        &self.cfg.models_dir
    }

    /// Resolve a model, loading (or hot-swapping) it if needed.
    ///
    /// The slow part (checkpoint read + conversion + engine build) runs
    /// **outside** the registry lock, so already-loaded models keep
    /// serving during a cold load.  A per-name `loading` marker dedupes
    /// cold-start herds: the first requester loads, the rest wait on a
    /// condvar and then hit the cache.
    pub fn get(&self, name: &str) -> Result<Arc<LoadedModel>> {
        validate_name(name)?;
        let mut g = self.inner.lock().unwrap();
        loop {
            g.clock += 1;
            let clock = g.clock;
            if let Some(e) = g.loaded.get_mut(name) {
                // hot-swap detection, rate-limited to one stat per TTL.
                // checked_at only advances when a stat actually ran, so
                // steady traffic cannot postpone the re-check forever.
                if e.checked_at.elapsed() < self.cfg.fingerprint_ttl {
                    e.last_used = clock;
                    return Ok(e.model.clone());
                }
                if fingerprint_of(&e.model.fingerprint.path).as_ref()
                    == Some(&e.model.fingerprint)
                {
                    e.checked_at = Instant::now();
                    e.last_used = clock;
                    return Ok(e.model.clone());
                }
                // source rewritten or deleted: drop the stale pool, reload
                g.loaded.remove(name);
            }
            if !g.loading.contains(name) {
                break; // this thread becomes the loader
            }
            // someone else is loading this model; wait and re-check
            g = self.load_done.wait(g).unwrap();
        }
        g.loading.insert(name.to_string());
        drop(g);

        let result = self.load_model(name);

        let mut g = self.inner.lock().unwrap();
        g.loading.remove(name);
        g.clock += 1;
        let clock = g.clock;
        let out = result.map(|m| {
            let loaded = Arc::new(m);
            evict_to_fit(&mut g, self.cfg.max_resident_bytes, loaded.info.resident_bytes, name);
            let entry =
                Entry { model: loaded.clone(), last_used: clock, checked_at: Instant::now() };
            g.loaded.insert(name.to_string(), entry);
            loaded
        });
        drop(g);
        self.load_done.notify_all();
        out
    }

    /// All available model names (dir scan + manifest), with residency.
    pub fn list(&self) -> Vec<ModelStatus> {
        let mut names: BTreeMap<String, &'static str> = BTreeMap::new();
        if let Ok(rd) = std::fs::read_dir(&self.cfg.models_dir) {
            for entry in rd.flatten() {
                let p = entry.path();
                if p.extension().and_then(|s| s.to_str()) == Some("bmx") {
                    if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
                        names.insert(stem.to_string(), "bmx");
                    }
                }
            }
        }
        if let Ok(man) = Manifest::load(&self.cfg.models_dir) {
            for name in man.models.keys() {
                names.entry(name.clone()).or_insert("manifest");
            }
        }
        let g = self.inner.lock().unwrap();
        names
            .into_iter()
            .map(|(name, source)| {
                let entry = g.loaded.get(&name);
                ModelStatus {
                    loaded: entry.is_some(),
                    resident_bytes: entry.map_or(0, |e| e.model.info.resident_bytes),
                    dispatch: entry.map(|e| e.model.engine.dispatch_summary()),
                    name,
                    source,
                }
            })
            .collect()
    }

    /// Resident models, sorted by name (the `/metrics` iteration order).
    pub fn loaded_models(&self) -> Vec<Arc<LoadedModel>> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<_> = g.loaded.values().map(|e| e.model.clone()).collect();
        v.sort_by(|a, b| a.info.name.cmp(&b.info.name));
        v
    }

    /// Total packed bytes currently resident.
    pub fn resident_bytes(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.loaded.values().map(|e| e.model.info.resident_bytes).sum()
    }

    fn load_model(&self, name: &str) -> Result<LoadedModel> {
        let dir = &self.cfg.models_dir;
        let bmx_path = dir.join(format!("{name}.bmx"));
        let (bmx, fingerprint) = if bmx_path.is_file() {
            let fp = fingerprint_of(&bmx_path)
                .ok_or_else(|| anyhow!("cannot stat {bmx_path:?}"))?;
            let bmx = BmxModel::load(&bmx_path).with_context(|| format!("load {bmx_path:?}"))?;
            (bmx, fp)
        } else {
            let manifest = Manifest::load(dir).with_context(|| {
                format!("model {name:?}: no {name}.bmx in {dir:?} and no usable manifest")
            })?;
            let entry = manifest.model(name)?;
            let ckpt_path = manifest.path(&entry.init_ckpt);
            let fp = fingerprint_of(&ckpt_path)
                .ok_or_else(|| anyhow!("cannot stat {ckpt_path:?}"))?;
            let ck = Checkpoint::load(&ckpt_path)
                .with_context(|| format!("load {ckpt_path:?}"))?;
            let (names, meta) = binary_names_for(&manifest, name)?;
            let act_bit = entry.act_bit();
            let bmx = if act_bit > 1 {
                convert_kbit(&ck, &names, act_bit, &meta)?
            } else {
                convert(&ck, &names, &meta)?
            };
            (bmx, fp)
        };
        let resident_bytes = bmx.payload_bytes();
        let arch = json::parse(&bmx.meta)
            .ok()
            .and_then(|v| v.get("arch").and_then(|a| a.as_str()).map(str::to_string))
            .unwrap_or_else(|| "?".to_string());
        let engine = Arc::new(Engine::from_bmx(&bmx).with_context(|| format!("model {name:?}"))?);
        let info = ModelInfo {
            name: name.to_string(),
            arch,
            input_shape: engine.input_shape(),
            classes: engine.classes(),
            resident_bytes,
        };
        let pool = ModelPool::start(engine.clone(), &self.cfg.pool);
        Ok(LoadedModel { info, pool, engine, fingerprint })
    }
}

/// Drop least-recently-used entries (never `keep`) until `incoming` fits
/// under `budget`.  Evicted pools die when their last `Arc` drops, so
/// requests already routed keep their answers.
fn evict_to_fit(g: &mut Inner, budget: usize, incoming: usize, keep: &str) {
    if budget == 0 {
        return;
    }
    loop {
        let resident: usize = g.loaded.values().map(|e| e.model.info.resident_bytes).sum();
        if resident + incoming <= budget {
            return;
        }
        let victim = g
            .loaded
            .iter()
            .filter(|(n, _)| n.as_str() != keep)
            .min_by_key(|(_, e)| e.last_used)
            .map(|(n, _)| n.clone());
        match victim {
            Some(n) => {
                g.loaded.remove(&n);
            }
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Write a loadable binary-LeNet `.bmx` (synthetic weights).
    fn write_bin_model(dir: &Path, name: &str, seed: u64) -> usize {
        let bmx = crate::model::bmx::synth_lenet(seed, 1).unwrap();
        bmx.save(dir.join(format!("{name}.bmx"))).unwrap();
        bmx.payload_bytes()
    }

    /// Write a loadable 4-bit LeNet `.bmx` (different payload size).
    fn write_q4_model(dir: &Path, name: &str, seed: u64) -> usize {
        let bmx = crate::model::bmx::synth_lenet(seed, 4).unwrap();
        bmx.save(dir.join(format!("{name}.bmx"))).unwrap();
        bmx.payload_bytes()
    }

    fn temp_dir(case: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("bmx_registry_{}_{case}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn small_pool() -> PoolConfig {
        PoolConfig { workers: 1, ..Default::default() }
    }

    /// One-worker pools, immediate fingerprint re-checks (the tests
    /// rewrite model files and expect the very next lookup to hot-swap).
    fn test_cfg(dir: &Path) -> RegistryConfig {
        RegistryConfig {
            pool: small_pool(),
            fingerprint_ttl: Duration::ZERO,
            ..RegistryConfig::new(dir)
        }
    }

    #[test]
    fn lazy_load_and_cached_lookup() {
        let dir = temp_dir("lazy");
        write_bin_model(&dir, "m1", 1);
        let reg = ModelRegistry::new(test_cfg(&dir));
        assert_eq!(reg.loaded_models().len(), 0, "must not load eagerly");
        let a = reg.get("m1").unwrap();
        assert_eq!(a.info.arch, "lenet");
        assert_eq!(a.info.input_shape, [1, 28, 28]);
        assert!(a.info.resident_bytes > 0);
        let b = reg.get("m1").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_and_invalid_names_are_clean_errors() {
        let dir = temp_dir("names");
        let reg = ModelRegistry::new(test_cfg(&dir));
        // (.err().expect: LoadedModel is not Debug, so no unwrap_err here)
        let err = format!("{:#}", reg.get("nope").err().expect("unknown model must fail"));
        assert!(err.contains("nope"), "error does not name the model: {err}");
        assert!(reg.get("../../etc/passwd").is_err());
        assert!(reg.get("").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hot_swap_on_source_change() {
        let dir = temp_dir("swap");
        let bin_bytes = write_bin_model(&dir, "m", 1);
        let reg = ModelRegistry::new(test_cfg(&dir));
        let a = reg.get("m").unwrap();
        assert_eq!(a.info.resident_bytes, bin_bytes);
        // overwrite with a different (larger, f32-stored) model file
        let q4_bytes = write_q4_model(&dir, "m", 2);
        assert_ne!(bin_bytes, q4_bytes);
        let b = reg.get("m").unwrap();
        assert!(!Arc::ptr_eq(&a, &b), "source changed but model not swapped");
        assert_eq!(b.info.resident_bytes, q4_bytes);
        // the old pool still answers for holders of the old Arc
        assert!(a.pool.classify(vec![0.1; 784]).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        let dir = temp_dir("lru");
        let b1 = write_bin_model(&dir, "m1", 1);
        let b2 = write_bin_model(&dir, "m2", 2);
        let b3 = write_bin_model(&dir, "m3", 3);
        // budget fits exactly two binary models
        let reg = ModelRegistry::new(RegistryConfig {
            max_resident_bytes: b1 + b2 + b3 / 2,
            ..test_cfg(&dir)
        });
        reg.get("m1").unwrap();
        reg.get("m2").unwrap();
        assert_eq!(reg.loaded_models().len(), 2);
        reg.get("m1").unwrap(); // refresh m1 so m2 is the LRU victim
        reg.get("m3").unwrap();
        let loaded: Vec<String> =
            reg.loaded_models().iter().map(|m| m.info.name.clone()).collect();
        assert_eq!(loaded, ["m1", "m3"], "LRU victim should have been m2");
        assert!(reg.resident_bytes() <= b1 + b2 + b3 / 2);
        // evicted model reloads on demand
        reg.get("m2").unwrap();
        assert!(reg.loaded_models().iter().any(|m| m.info.name == "m2"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn list_reports_dir_and_residency() {
        let dir = temp_dir("list");
        write_bin_model(&dir, "a", 1);
        write_q4_model(&dir, "b", 2);
        let reg = ModelRegistry::new(test_cfg(&dir));
        let before = reg.list();
        assert_eq!(before.len(), 2);
        assert!(before.iter().all(|m| !m.loaded && m.source == "bmx"));
        reg.get("b").unwrap();
        let after = reg.list();
        let b = after.iter().find(|m| m.name == "b").unwrap();
        assert!(b.loaded && b.resident_bytes > 0);
        let summary = b.dispatch.as_deref().expect("loaded model must report dispatch");
        assert!(summary.contains("method"), "dispatch summary malformed: {summary}");
        let a = after.iter().find(|m| m.name == "a").unwrap();
        assert!(!a.loaded && a.dispatch.is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
