//! Prometheus-style text rendering of per-model serving metrics.
//!
//! One sample family per line group, all labels `model="<name>"` (plus
//! `size=` for the batch histogram and `quantile=` for latencies).  The
//! invariant consumers can rely on: for every model,
//! `sum over size of (size * bmxnet_batch_size_total)` equals
//! `bmxnet_requests_total` — asserted by `tests/serve_gateway.rs`.
//!
//! Scrape cost: per-model snapshots come from
//! [`crate::serve::ModelPool::snapshot_cached`], so a scrape storm inside
//! the pool's `metrics_ttl` re-reads one cached merge instead of locking
//! every shard's ring each time.  Process-wide families (stage latency
//! histograms, kernel call counters, trace journal totals) read the
//! lock-free [`crate::obs`] state directly.

use crate::coordinator::MetricsSnapshot;
use crate::obs::{counters, Obs};

use super::reactor::ReactorStats;
use super::registry::{ModelInfo, ModelRegistry};

fn push_family(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

/// Escape a label value per the Prometheus text exposition format.
fn label_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render the whole registry: per-model counters, batch-size histogram
/// and latency quantiles, aggregated across each model's pool shards —
/// plus the process-wide observability families from `obs` and the
/// gateway reactor's connection gauges and loop histograms from `stats`.
pub fn render(registry: &ModelRegistry, obs: &Obs, stats: &ReactorStats) -> String {
    let loaded = registry.loaded_models();
    let rows: Vec<(ModelInfo, MetricsSnapshot, usize, Vec<usize>)> = loaded
        .iter()
        .map(|m| {
            (m.info.clone(), m.pool.snapshot_cached(), m.pool.workers(), m.pool.shard_depths())
        })
        .collect();

    let mut out = String::new();
    // Build identity first, so any scrape can be joined to the binary
    // that produced it (the same provenance block perf records carry).
    let prov = crate::bench::Provenance::capture("bmxnet serve");
    push_family(
        &mut out,
        "bmxnet_build_info",
        "gauge",
        "Build identity; value is constant 1, the labels carry the info.",
    );
    out.push_str(&format!(
        "bmxnet_build_info{{version=\"{}\",git_sha=\"{}\",features=\"{}\",force_scalar=\"{}\"}} 1\n",
        label_escape(&prov.version),
        label_escape(&prov.git),
        label_escape(&prov.features),
        prov.force_scalar,
    ));

    push_family(&mut out, "bmxnet_models_loaded", "gauge", "Resident models in the registry.");
    out.push_str(&format!("bmxnet_models_loaded {}\n", rows.len()));

    push_family(
        &mut out,
        "bmxnet_resident_bytes",
        "gauge",
        "Packed payload bytes of a resident model.",
    );
    for (info, _, _, _) in &rows {
        out.push_str(&format!(
            "bmxnet_resident_bytes{{model=\"{}\"}} {}\n",
            label_escape(&info.name),
            info.resident_bytes
        ));
    }

    push_family(&mut out, "bmxnet_pool_workers", "gauge", "Shards serving a model.");
    for (info, _, workers, _) in &rows {
        out.push_str(&format!(
            "bmxnet_pool_workers{{model=\"{}\"}} {}\n",
            label_escape(&info.name),
            workers
        ));
    }

    push_family(
        &mut out,
        "bmxnet_queue_depth",
        "gauge",
        "In-flight requests per pool shard at scrape time.",
    );
    for (info, _, _, depths) in &rows {
        for (shard, depth) in depths.iter().enumerate() {
            out.push_str(&format!(
                "bmxnet_queue_depth{{model=\"{}\",shard=\"{}\"}} {}\n",
                label_escape(&info.name),
                shard,
                depth
            ));
        }
    }

    push_family(&mut out, "bmxnet_requests_total", "counter", "Requests answered per model.");
    for (info, snap, _, _) in &rows {
        out.push_str(&format!(
            "bmxnet_requests_total{{model=\"{}\"}} {}\n",
            label_escape(&info.name),
            snap.requests
        ));
    }

    push_family(
        &mut out,
        "bmxnet_rejected_total",
        "counter",
        "Requests dropped by admission control or engine failure.",
    );
    for (info, snap, _, _) in &rows {
        out.push_str(&format!(
            "bmxnet_rejected_total{{model=\"{}\"}} {}\n",
            label_escape(&info.name),
            snap.rejected
        ));
    }

    push_family(&mut out, "bmxnet_batches_total", "counter", "Engine forward passes per model.");
    for (info, snap, _, _) in &rows {
        out.push_str(&format!(
            "bmxnet_batches_total{{model=\"{}\"}} {}\n",
            label_escape(&info.name),
            snap.batches
        ));
    }

    push_family(
        &mut out,
        "bmxnet_batch_size_total",
        "counter",
        "Batches dispatched at each batch size; sum(size*count) == requests.",
    );
    for (info, snap, _, _) in &rows {
        for &(size, count) in &snap.batch_hist {
            out.push_str(&format!(
                "bmxnet_batch_size_total{{model=\"{}\",size=\"{}\"}} {}\n",
                label_escape(&info.name),
                size,
                count
            ));
        }
    }

    push_family(
        &mut out,
        "bmxnet_latency_us",
        "summary",
        "Request latency quantiles in microseconds (queue + compute).",
    );
    for (info, snap, _, _) in &rows {
        for (q, v) in [(0.5, snap.p50), (0.95, snap.p95), (0.99, snap.p99)] {
            out.push_str(&format!(
                "bmxnet_latency_us{{model=\"{}\",quantile=\"{}\"}} {}\n",
                label_escape(&info.name),
                q,
                v.as_micros()
            ));
        }
        // _count/_sum are monotone (unlike the windowed quantile ring), so
        // rate(bmxnet_latency_us_sum[1m]) / rate(_count[1m]) works.
        out.push_str(&format!(
            "bmxnet_latency_us_count{{model=\"{}\"}} {}\n",
            label_escape(&info.name),
            snap.lat_count
        ));
        out.push_str(&format!(
            "bmxnet_latency_us_sum{{model=\"{}\"}} {}\n",
            label_escape(&info.name),
            snap.lat_sum_us
        ));
    }

    push_family(
        &mut out,
        "bmxnet_stage_latency_us",
        "histogram",
        "Per-stage request latency in microseconds \
         (read, parse, admission, queue_wait, batch_window, forward, respond, write).",
    );
    for h in obs.stages.snapshot() {
        let stage = h.stage;
        for (i, &le) in counters::STAGE_BUCKETS.iter().enumerate() {
            out.push_str(&format!(
                "bmxnet_stage_latency_us_bucket{{stage=\"{stage}\",le=\"{le}\"}} {}\n",
                h.buckets[i]
            ));
        }
        out.push_str(&format!(
            "bmxnet_stage_latency_us_bucket{{stage=\"{stage}\",le=\"+Inf\"}} {}\n",
            h.buckets[counters::STAGE_BUCKETS.len()]
        ));
        out.push_str(&format!("bmxnet_stage_latency_us_sum{{stage=\"{stage}\"}} {}\n", h.sum_us));
        out.push_str(&format!("bmxnet_stage_latency_us_count{{stage=\"{stage}\"}} {}\n", h.count));
    }

    push_family(
        &mut out,
        "bmxnet_kernel_calls_total",
        "counter",
        "GEMM entry calls by dispatch method and resolved kernel.",
    );
    for (method, kernel, calls) in counters::gemm_calls() {
        out.push_str(&format!(
            "bmxnet_kernel_calls_total{{method=\"{method}\",kernel=\"{kernel}\"}} {calls}\n"
        ));
    }

    push_family(
        &mut out,
        "bmxnet_trace_total",
        "counter",
        "Request traces published to the debug journal.",
    );
    out.push_str(&format!("bmxnet_trace_total {}\n", obs.journal.total()));
    push_family(
        &mut out,
        "bmxnet_trace_dropped_total",
        "counter",
        "Traces dropped on journal slot contention.",
    );
    out.push_str(&format!("bmxnet_trace_dropped_total {}\n", obs.journal.dropped()));

    push_family(
        &mut out,
        "bmxnet_active_connections",
        "gauge",
        "Connections currently open on the gateway reactor.",
    );
    out.push_str(&format!("bmxnet_active_connections {}\n", stats.active()));
    push_family(
        &mut out,
        "bmxnet_conns_shed_total",
        "counter",
        "Connections refused with 503 at accept (past --max-conns).",
    );
    out.push_str(&format!("bmxnet_conns_shed_total {}\n", stats.shed_total()));

    push_family(
        &mut out,
        "bmxnet_reactor_loop_us",
        "histogram",
        "Event-loop pass duration per reactor worker in microseconds \
         (active portion; backoff sleeps not counted).",
    );
    for h in stats.loop_snapshot() {
        let worker = h.worker;
        for (i, &le) in counters::STAGE_BUCKETS.iter().enumerate() {
            out.push_str(&format!(
                "bmxnet_reactor_loop_us_bucket{{worker=\"{worker}\",le=\"{le}\"}} {}\n",
                h.buckets[i]
            ));
        }
        out.push_str(&format!(
            "bmxnet_reactor_loop_us_bucket{{worker=\"{worker}\",le=\"+Inf\"}} {}\n",
            h.buckets[counters::STAGE_BUCKETS.len()]
        ));
        out.push_str(&format!("bmxnet_reactor_loop_us_sum{{worker=\"{worker}\"}} {}\n", h.sum_us));
        out.push_str(&format!(
            "bmxnet_reactor_loop_us_count{{worker=\"{worker}\"}} {}\n",
            h.count
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Stage, Trace};
    use crate::serve::registry::RegistryConfig;

    #[test]
    fn label_escaping() {
        assert_eq!(label_escape("plain"), "plain");
        assert_eq!(label_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn empty_registry_renders_zero_gauge() {
        let reg = ModelRegistry::new(RegistryConfig::new(std::env::temp_dir().join("nope")));
        let obs = Obs::with_slots(8);
        let stats = ReactorStats::new(2);
        let text = render(&reg, &obs, &stats);
        assert!(text.contains("# TYPE bmxnet_build_info gauge"), "{text}");
        assert!(text.contains("bmxnet_build_info{version=\""), "{text}");
        assert!(
            text.contains("git_sha=\"") && text.contains("force_scalar=\""),
            "{text}"
        );
        assert!(text.contains("} 1\n"), "build_info gauge value is 1: {text}");
        assert!(text.contains("bmxnet_models_loaded 0\n"), "{text}");
        assert!(text.contains("# TYPE bmxnet_requests_total counter"), "{text}");
        // process-wide families render even with no models
        assert!(text.contains("# TYPE bmxnet_stage_latency_us histogram"), "{text}");
        assert!(text.contains("# TYPE bmxnet_kernel_calls_total counter"), "{text}");
        assert!(text.contains("bmxnet_trace_total 0\n"), "{text}");
        // reactor families render even before any traffic
        assert!(text.contains("bmxnet_active_connections 0\n"), "{text}");
        assert!(text.contains("bmxnet_conns_shed_total 0\n"), "{text}");
        assert!(
            text.contains("bmxnet_reactor_loop_us_count{worker=\"1\"} 0\n"),
            "{text}"
        );
    }

    #[test]
    fn stage_histogram_counts_completed_traces() {
        let reg = ModelRegistry::new(RegistryConfig::new(std::env::temp_dir().join("nope")));
        let obs = Obs::with_slots(8);
        let mut t = Trace::begin();
        for s in Stage::all() {
            t.mark(s);
        }
        obs.complete(&t.finish("m", 200, 0, 1));
        let stats = ReactorStats::new(1);
        stats.record_loop(0, 7);
        let text = render(&reg, &obs, &stats);
        assert!(text.contains("bmxnet_trace_total 1\n"), "{text}");
        assert!(
            text.contains("bmxnet_stage_latency_us_count{stage=\"parse\"} 1\n"),
            "{text}"
        );
        assert!(
            text.contains("bmxnet_stage_latency_us_bucket{stage=\"forward\",le=\"+Inf\"} 1\n"),
            "{text}"
        );
        assert!(
            text.contains("bmxnet_reactor_loop_us_count{worker=\"0\"} 1\n"),
            "{text}"
        );
    }
}
