//! Prometheus-style text rendering of per-model serving metrics.
//!
//! One sample family per line group, all labels `model="<name>"` (plus
//! `size=` for the batch histogram and `quantile=` for latencies).  The
//! invariant consumers can rely on: for every model,
//! `sum over size of (size * bmxnet_batch_size_total)` equals
//! `bmxnet_requests_total` — asserted by `tests/serve_gateway.rs`.

use crate::coordinator::MetricsSnapshot;

use super::registry::{ModelInfo, ModelRegistry};

fn push_family(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

/// Escape a label value per the Prometheus text exposition format.
fn label_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render the whole registry: per-model counters, batch-size histogram
/// and latency quantiles, aggregated across each model's pool shards.
pub fn render(registry: &ModelRegistry) -> String {
    let loaded = registry.loaded_models();
    let rows: Vec<(ModelInfo, MetricsSnapshot, usize)> = loaded
        .iter()
        .map(|m| (m.info.clone(), m.pool.snapshot(), m.pool.workers()))
        .collect();

    let mut out = String::new();
    push_family(&mut out, "bmxnet_models_loaded", "gauge", "Resident models in the registry.");
    out.push_str(&format!("bmxnet_models_loaded {}\n", rows.len()));

    push_family(
        &mut out,
        "bmxnet_resident_bytes",
        "gauge",
        "Packed payload bytes of a resident model.",
    );
    for (info, _, _) in &rows {
        out.push_str(&format!(
            "bmxnet_resident_bytes{{model=\"{}\"}} {}\n",
            label_escape(&info.name),
            info.resident_bytes
        ));
    }

    push_family(&mut out, "bmxnet_pool_workers", "gauge", "Shards serving a model.");
    for (info, _, workers) in &rows {
        out.push_str(&format!(
            "bmxnet_pool_workers{{model=\"{}\"}} {}\n",
            label_escape(&info.name),
            workers
        ));
    }

    push_family(&mut out, "bmxnet_requests_total", "counter", "Requests answered per model.");
    for (info, snap, _) in &rows {
        out.push_str(&format!(
            "bmxnet_requests_total{{model=\"{}\"}} {}\n",
            label_escape(&info.name),
            snap.requests
        ));
    }

    push_family(
        &mut out,
        "bmxnet_rejected_total",
        "counter",
        "Requests dropped by admission control or engine failure.",
    );
    for (info, snap, _) in &rows {
        out.push_str(&format!(
            "bmxnet_rejected_total{{model=\"{}\"}} {}\n",
            label_escape(&info.name),
            snap.rejected
        ));
    }

    push_family(&mut out, "bmxnet_batches_total", "counter", "Engine forward passes per model.");
    for (info, snap, _) in &rows {
        out.push_str(&format!(
            "bmxnet_batches_total{{model=\"{}\"}} {}\n",
            label_escape(&info.name),
            snap.batches
        ));
    }

    push_family(
        &mut out,
        "bmxnet_batch_size_total",
        "counter",
        "Batches dispatched at each batch size; sum(size*count) == requests.",
    );
    for (info, snap, _) in &rows {
        for &(size, count) in &snap.batch_hist {
            out.push_str(&format!(
                "bmxnet_batch_size_total{{model=\"{}\",size=\"{}\"}} {}\n",
                label_escape(&info.name),
                size,
                count
            ));
        }
    }

    push_family(
        &mut out,
        "bmxnet_latency_us",
        "summary",
        "Request latency quantiles in microseconds (queue + compute).",
    );
    for (info, snap, _) in &rows {
        for (q, v) in [(0.5, snap.p50), (0.95, snap.p95), (0.99, snap.p99)] {
            out.push_str(&format!(
                "bmxnet_latency_us{{model=\"{}\",quantile=\"{}\"}} {}\n",
                label_escape(&info.name),
                q,
                v.as_micros()
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::registry::RegistryConfig;

    #[test]
    fn label_escaping() {
        assert_eq!(label_escape("plain"), "plain");
        assert_eq!(label_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn empty_registry_renders_zero_gauge() {
        let reg = ModelRegistry::new(RegistryConfig::new(std::env::temp_dir().join("nope")));
        let text = render(&reg);
        assert!(text.contains("bmxnet_models_loaded 0\n"), "{text}");
        assert!(text.contains("# TYPE bmxnet_requests_total counter"), "{text}");
    }
}
