//! Std-only readiness-polling reactor behind the HTTP gateway.
//!
//! No epoll/kqueue wrapper exists in std, so instead of a thread per
//! connection (the old gateway, capped at 256) this runs a small acceptor
//! plus N event-loop workers, each owning a slab of non-blocking
//! `TcpStream`s and driving them through a per-connection state machine:
//!
//! ```text
//! Read (headers → body) → Dispatch (poll batcher) → Write → Read …
//! ```
//!
//! Readiness is discovered by *attempting* the syscall and treating
//! `WouldBlock` as "not ready" (level-triggered polling). When a full
//! scan makes no progress the worker sleeps with exponential backoff
//! (100 µs doubling to 2 ms), so an idle gateway costs a few wakeups per
//! millisecond per worker and a busy one never sleeps. This trades a
//! bounded idle cost for zero dependencies — see DESIGN.md §Gateway
//! reactor for why this beats pulling in mio here.
//!
//! Timeouts come from a hashed [`TimerWheel`] with lazy revalidation:
//! every connection keeps exactly one wheel entry alive; when it fires
//! the worker re-checks the connection's *authoritative* deadline and
//! either closes it (408 mid-request, silent when idle) or reschedules.
//! Deadlines longer than one wheel revolution simply revalidate once per
//! revolution.
//!
//! Overload is shed at accept: past `max_conns` open connections the
//! acceptor writes a best-effort 503 and closes, instead of the old
//! "no thread available" cliff.

use anyhow::{Context, Result};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::bufpool::BytePool;
use super::http::{
    render_response, route_begin, ClassifyTail, GatewayConfig, GatewayCtx, HeadInfo, HeadParse,
    HttpResponse, RouteOutcome, MAX_HEAD,
};
use crate::obs::counters::STAGE_BUCKETS;
use crate::obs::{Stage, Trace};

/// Per-worker buffers kept for reuse (request + response per connection).
const BYTE_POOL_CAP: usize = 512;

/// Worker sleep bounds when a full scan makes no progress.
const MIN_BACKOFF: Duration = Duration::from_micros(100);
const MAX_BACKOFF: Duration = Duration::from_millis(2);

/// Timer wheel geometry: 256 slots × 5 ms ≈ 1.28 s per revolution.
const WHEEL_SLOTS: usize = 256;
const WHEEL_GRANULARITY: Duration = Duration::from_millis(5);

/// Stand-in deadline for states with no timeout (Dispatch: the batcher's
/// bounded queue guarantees an answer, matching the old blocking wait).
const NO_DEADLINE: Duration = Duration::from_secs(3600);

/// Bytes read from a socket per `read()` attempt.
const READ_CHUNK: usize = 16 << 10;

/// Gauges + counters the reactor exports on `/metrics`.
pub struct ReactorStats {
    /// Currently open connections (accepted, not yet closed).
    active: AtomicUsize,
    /// Connections refused with 503 at accept (`bmxnet_conns_shed_total`).
    shed: AtomicU64,
    /// Per-worker event-loop iteration histograms (µs, active portion of
    /// each pass — the backoff sleep is not counted).
    loops: Vec<LoopHist>,
}

struct LoopHist {
    buckets: [AtomicU64; STAGE_BUCKETS.len() + 1],
    sum_us: AtomicU64,
    count: AtomicU64,
}

/// One worker's loop histogram: cumulative counts aligned to
/// [`STAGE_BUCKETS`] plus a final +Inf entry (same shape as
/// `obs::counters::StageHist`).
pub struct LoopHistSnapshot {
    pub worker: usize,
    pub buckets: Vec<u64>,
    pub sum_us: u64,
    pub count: u64,
}

impl ReactorStats {
    pub fn new(workers: usize) -> ReactorStats {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        #[allow(clippy::declare_interior_mutable_const)]
        const ROW: [AtomicU64; STAGE_BUCKETS.len() + 1] = [ZERO; STAGE_BUCKETS.len() + 1];
        ReactorStats {
            active: AtomicUsize::new(0),
            shed: AtomicU64::new(0),
            loops: (0..workers.max(1))
                .map(|_| LoopHist { buckets: ROW, sum_us: ZERO, count: ZERO })
                .collect(),
        }
    }

    pub fn active(&self) -> usize {
        self.active.load(Ordering::Acquire)
    }

    pub fn shed_total(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn workers(&self) -> usize {
        self.loops.len()
    }

    fn conn_opened(&self) {
        self.active.fetch_add(1, Ordering::AcqRel);
    }

    fn conn_closed(&self) {
        self.active.fetch_sub(1, Ordering::AcqRel);
    }

    fn shed_one(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_loop(&self, worker: usize, us: u64) {
        let Some(h) = self.loops.get(worker) else { return };
        let bucket = STAGE_BUCKETS
            .iter()
            .position(|&le| us <= le)
            .unwrap_or(STAGE_BUCKETS.len());
        h.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        h.sum_us.fetch_add(us, Ordering::Relaxed);
        h.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn loop_snapshot(&self) -> Vec<LoopHistSnapshot> {
        self.loops
            .iter()
            .enumerate()
            .map(|(worker, h)| {
                let mut cum = 0u64;
                let buckets = h
                    .buckets
                    .iter()
                    .map(|c| {
                        cum += c.load(Ordering::Relaxed);
                        cum
                    })
                    .collect();
                LoopHistSnapshot {
                    worker,
                    buckets,
                    sum_us: h.sum_us.load(Ordering::Relaxed),
                    count: h.count.load(Ordering::Relaxed),
                }
            })
            .collect()
    }
}

/// Wheel entry: a slab index plus the generation it was armed for, so an
/// entry surviving past its connection (slot reused) is ignored.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimerEntry {
    pub idx: usize,
    pub gen: u64,
}

/// Hashed timer wheel. Entries land in the slot their deadline rounds up
/// to; deadlines past one revolution clamp to the farthest slot and fire
/// *early* — callers must revalidate against the real deadline and
/// reschedule (lazy revalidation). O(1) schedule, O(slots stepped) tick.
pub struct TimerWheel {
    slots: Vec<Vec<TimerEntry>>,
    granularity_us: u64,
    cursor: usize,
    last_tick: Instant,
}

impl TimerWheel {
    pub fn new(slots: usize, granularity: Duration, now: Instant) -> TimerWheel {
        TimerWheel {
            slots: (0..slots.max(2)).map(|_| Vec::new()).collect(),
            granularity_us: (granularity.as_micros() as u64).max(1),
            cursor: 0,
            last_tick: now,
        }
    }

    /// Arm `e` to fire no later than `deadline` (possibly earlier when
    /// the deadline exceeds one revolution).
    pub fn schedule(&mut self, now: Instant, deadline: Instant, e: TimerEntry) {
        let delta_us = deadline.saturating_duration_since(now).as_micros() as u64;
        let ticks = (delta_us / self.granularity_us + 1).min(self.slots.len() as u64 - 1) as usize;
        let slot = (self.cursor + ticks) % self.slots.len();
        self.slots[slot].push(e);
    }

    /// Advance to `now`, appending every entry whose slot has passed to
    /// `out`. A gap longer than one revolution drains the whole wheel.
    pub fn tick(&mut self, now: Instant, out: &mut Vec<TimerEntry>) {
        let elapsed_us = now.duration_since(self.last_tick).as_micros() as u64;
        let steps = elapsed_us / self.granularity_us;
        if steps == 0 {
            return;
        }
        if steps >= self.slots.len() as u64 {
            for slot in &mut self.slots {
                out.append(slot);
            }
            self.last_tick = now;
            return;
        }
        for _ in 0..steps {
            self.cursor = (self.cursor + 1) % self.slots.len();
            out.append(&mut self.slots[self.cursor]);
        }
        self.last_tick += Duration::from_micros(steps * self.granularity_us);
    }
}

/// Connection state machine position.
enum ConnState {
    /// Accumulating request bytes (head, then body).
    Read,
    /// Request handed to a pool shard; polling for the batcher's answer.
    Dispatch,
    /// Flushing the rendered response.
    Write,
}

/// Trace metadata carried to write-completion, where classify traces are
/// finished and published (`write` stage = full flush).
struct PublishMeta {
    name: String,
    status: u16,
    shard: u16,
    batch: u16,
}

struct Conn {
    stream: TcpStream,
    state: ConnState,
    /// Accumulated request bytes (pooled; pipelined requests queue here).
    req_buf: Vec<u8>,
    /// Rendered response bytes (pooled) + how many are already flushed.
    resp_buf: Vec<u8>,
    resp_written: usize,
    /// Parsed head while the body is still streaming in.
    head: Option<HeadInfo>,
    /// In-flight classify: the shard's response channel + model name.
    job: Option<ClassifyTail>,
    trace: Option<Trace>,
    publish: Option<PublishMeta>,
    keep_alive: bool,
    /// A request has started arriving and its response is not yet flushed.
    in_request: bool,
    /// Authoritative timeout; the wheel entry revalidates against this.
    deadline: Instant,
}

enum DriveVerdict {
    Keep,
    Close,
}

struct Worker {
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    /// Bumped on release; wheel entries from a prior tenant mismatch.
    gens: Vec<u64>,
    wheel: TimerWheel,
    bytes: BytePool,
}

impl Worker {
    fn adopt(&mut self, stream: TcpStream, now: Instant, cfg: &GatewayConfig) {
        let _ = stream.set_nodelay(true);
        let _ = stream.set_nonblocking(true);
        let idx = self.free.pop().unwrap_or_else(|| {
            self.conns.push(None);
            self.gens.push(0);
            self.conns.len() - 1
        });
        let deadline = now + cfg.idle_timeout;
        self.wheel.schedule(now, deadline, TimerEntry { idx, gen: self.gens[idx] });
        self.conns[idx] = Some(Conn {
            stream,
            state: ConnState::Read,
            req_buf: self.bytes.get(),
            resp_buf: self.bytes.get(),
            resp_written: 0,
            head: None,
            job: None,
            trace: None,
            publish: None,
            keep_alive: true,
            in_request: false,
            deadline,
        });
    }

    /// Close a connection: return its buffers to the pool, free the slab
    /// slot, invalidate outstanding wheel entries.
    fn release(&mut self, idx: usize, conn: Conn, stats: &ReactorStats) {
        self.bytes.put(conn.req_buf);
        self.bytes.put(conn.resp_buf);
        self.gens[idx] += 1;
        self.free.push(idx);
        stats.conn_closed();
        // conn.stream drops here → close
    }
}

fn would_block(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

enum ReadOutcome {
    Data,
    Blocked,
    Eof,
    Fatal,
}

fn read_some(c: &mut Conn) -> ReadOutcome {
    let old = c.req_buf.len();
    c.req_buf.resize(old + READ_CHUNK, 0);
    let r = c.stream.read(&mut c.req_buf[old..]);
    match r {
        Ok(0) => {
            c.req_buf.truncate(old);
            ReadOutcome::Eof
        }
        Ok(n) => {
            c.req_buf.truncate(old + n);
            ReadOutcome::Data
        }
        Err(e) if would_block(&e) || e.kind() == ErrorKind::Interrupted => {
            c.req_buf.truncate(old);
            ReadOutcome::Blocked
        }
        Err(_) => {
            c.req_buf.truncate(old);
            ReadOutcome::Fatal
        }
    }
}

/// Render `resp` and move the connection into the Write state.
fn start_write(c: &mut Conn, resp: &HttpResponse, keep_alive: bool, now: Instant, cfg: &GatewayConfig) {
    c.resp_buf.clear();
    render_response(resp, keep_alive, &mut c.resp_buf);
    c.resp_written = 0;
    c.keep_alive = keep_alive;
    if let Some(t) = c.trace.as_mut() {
        t.mark(Stage::Respond);
    }
    c.state = ConnState::Write;
    c.deadline = now + cfg.request_timeout;
}

/// Try to complete a buffered request: parse the head, wait for the full
/// body, route it. Returns true when the connection changed state (to
/// Write or Dispatch); false when more bytes are needed.
fn advance_request(c: &mut Conn, ctx: &GatewayCtx, cfg: &GatewayConfig, now: Instant) -> bool {
    if c.head.is_none() {
        match super::http::parse_head(&c.req_buf) {
            HeadParse::Incomplete => {
                if c.req_buf.len() > MAX_HEAD {
                    let resp =
                        HttpResponse::error(400, &format!("headers exceed cap {MAX_HEAD}"));
                    c.trace = None;
                    start_write(c, &resp, false, now, cfg);
                    return true;
                }
                return false;
            }
            HeadParse::Bad(msg) => {
                let resp = HttpResponse::error(400, &msg);
                c.trace = None;
                start_write(c, &resp, false, now, cfg);
                return true;
            }
            HeadParse::Parsed(h) => c.head = Some(h),
        }
    }
    let (head_len, content_length) = {
        let h = c.head.as_ref().expect("head parsed above");
        (h.head_len, h.content_length)
    };
    let total = head_len + content_length;
    if c.req_buf.len() < total {
        return false;
    }
    // full request buffered: stamp the read stage and route
    let head = c.head.take().expect("head parsed above");
    let mut trace = c.trace.take().unwrap_or_else(Trace::begin);
    trace.mark(Stage::Read);
    let keep_alive = head.keep_alive;
    let outcome = {
        let body = &c.req_buf[head_len..total];
        route_begin(ctx, &head, body, &mut trace)
    };
    c.req_buf.drain(..total); // keep pipelined leftovers
    match outcome {
        RouteOutcome::Plain(resp) => {
            c.trace = None;
            c.publish = None;
            start_write(c, &resp, keep_alive, now, cfg);
        }
        RouteOutcome::ClassifyDone { resp, name, shard, batch } => {
            c.publish = Some(PublishMeta { name, status: resp.status, shard, batch });
            c.trace = Some(trace);
            start_write(c, &resp, keep_alive, now, cfg);
        }
        RouteOutcome::ClassifyPending(tail) => {
            c.job = Some(tail);
            c.trace = Some(trace);
            c.keep_alive = keep_alive;
            c.state = ConnState::Dispatch;
            c.deadline = now + NO_DEADLINE;
        }
    }
    true
}

/// Drive one connection as far as it will go without blocking. Sets
/// `*progress` when any byte moved or any state advanced.
fn drive_conn(
    c: &mut Conn,
    ctx: &GatewayCtx,
    cfg: &GatewayConfig,
    now: Instant,
    progress: &mut bool,
) -> DriveVerdict {
    loop {
        match c.state {
            ConnState::Read => {
                // consume buffered bytes first (pipelining), then the socket
                loop {
                    if !c.req_buf.is_empty() && !c.in_request {
                        c.in_request = true;
                        c.trace = Some(Trace::begin());
                        c.deadline = now + cfg.request_timeout;
                    }
                    if advance_request(c, ctx, cfg, now) {
                        *progress = true;
                        break; // state changed; outer loop continues
                    }
                    match read_some(c) {
                        ReadOutcome::Data => *progress = true,
                        ReadOutcome::Blocked => return DriveVerdict::Keep,
                        ReadOutcome::Eof | ReadOutcome::Fatal => return DriveVerdict::Close,
                    }
                }
            }
            ConnState::Dispatch => {
                let tail = c.job.as_ref().expect("dispatch state has a job");
                let polled = tail.pending.poll();
                match polled {
                    Ok(None) => return DriveVerdict::Keep,
                    ready => {
                        let tail = c.job.take().expect("dispatch state has a job");
                        let trace = c.trace.as_mut().expect("classify carries a trace");
                        let result = ready.map(|r| r.expect("Ok(None) handled above"));
                        let (resp, shard, batch) =
                            super::http::classify_finish(&tail, result, trace);
                        c.publish = Some(PublishMeta {
                            name: tail.name,
                            status: resp.status,
                            shard,
                            batch,
                        });
                        let ka = c.keep_alive;
                        start_write(c, &resp, ka, now, cfg);
                        *progress = true;
                    }
                }
            }
            ConnState::Write => {
                while c.resp_written < c.resp_buf.len() {
                    match c.stream.write(&c.resp_buf[c.resp_written..]) {
                        Ok(0) => return DriveVerdict::Close,
                        Ok(n) => {
                            c.resp_written += n;
                            *progress = true;
                        }
                        Err(e) if would_block(&e) || e.kind() == ErrorKind::Interrupted => {
                            return DriveVerdict::Keep
                        }
                        Err(_) => return DriveVerdict::Close,
                    }
                }
                // fully flushed: finish + publish the classify trace
                if let (Some(t), Some(meta)) = (c.trace.as_mut(), c.publish.take()) {
                    t.mark(Stage::Write);
                    ctx.obs
                        .complete(&t.finish(&meta.name, meta.status, meta.shard, meta.batch));
                }
                c.trace = None;
                c.publish = None;
                c.resp_buf.clear();
                c.resp_written = 0;
                *progress = true;
                if !c.keep_alive {
                    return DriveVerdict::Close;
                }
                c.in_request = false;
                c.state = ConnState::Read;
                c.deadline = now + cfg.idle_timeout;
                // loop: pipelined bytes may already hold the next request
            }
        }
    }
}

fn worker_loop(
    id: usize,
    rx: mpsc::Receiver<TcpStream>,
    ctx: Arc<GatewayCtx>,
    cfg: GatewayConfig,
    stop: Arc<AtomicBool>,
) {
    let now = Instant::now();
    let mut w = Worker {
        conns: Vec::new(),
        free: Vec::new(),
        gens: Vec::new(),
        wheel: TimerWheel::new(WHEEL_SLOTS, WHEEL_GRANULARITY, now),
        bytes: BytePool::new(BYTE_POOL_CAP),
    };
    let mut backoff = MIN_BACKOFF;
    let mut fired: Vec<TimerEntry> = Vec::new();
    loop {
        let loop_start = Instant::now();
        let mut progress = false;
        // adopt new connections
        loop {
            match rx.try_recv() {
                Ok(s) => {
                    w.adopt(s, loop_start, &cfg);
                    progress = true;
                }
                Err(mpsc::TryRecvError::Empty) | Err(mpsc::TryRecvError::Disconnected) => break,
            }
        }
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // drive every live connection
        for idx in 0..w.conns.len() {
            let Some(mut c) = w.conns[idx].take() else { continue };
            match drive_conn(&mut c, &ctx, &cfg, loop_start, &mut progress) {
                DriveVerdict::Keep => w.conns[idx] = Some(c),
                DriveVerdict::Close => {
                    w.release(idx, c, &ctx.stats);
                    progress = true;
                }
            }
        }
        // expire / revalidate timers
        fired.clear();
        let tick_now = Instant::now();
        w.wheel.tick(tick_now, &mut fired);
        for e in fired.drain(..) {
            if w.gens.get(e.idx).copied() != Some(e.gen) {
                continue; // slot reused since this entry was armed
            }
            let due = match w.conns[e.idx].as_ref() {
                Some(c) => c.deadline <= tick_now,
                None => continue,
            };
            if !due {
                let d = w.conns[e.idx].as_ref().expect("checked above").deadline;
                w.wheel.schedule(tick_now, d, e);
                continue;
            }
            let mut c = w.conns[e.idx].take().expect("checked above");
            if c.in_request && matches!(c.state, ConnState::Read) {
                // slow client stalled mid-request: best-effort 408
                let resp = HttpResponse::error(408, "request timed out");
                let mut buf = Vec::new();
                render_response(&resp, false, &mut buf);
                let _ = c.stream.write(&buf);
            }
            w.release(e.idx, c, &ctx.stats);
            progress = true;
        }
        ctx.stats.record_loop(id, loop_start.elapsed().as_micros() as u64);
        if progress {
            backoff = MIN_BACKOFF;
        } else {
            std::thread::sleep(backoff);
            backoff = (backoff * 2).min(MAX_BACKOFF);
        }
    }
    // shutdown: close everything still open (and anything undrained)
    for idx in 0..w.conns.len() {
        if let Some(c) = w.conns[idx].take() {
            w.release(idx, c, &ctx.stats);
        }
    }
    while let Ok(s) = rx.try_recv() {
        drop(s);
        ctx.stats.conn_closed();
    }
}

fn shed(stream: TcpStream, stats: &ReactorStats) {
    stats.shed_one();
    let _ = stream.set_nonblocking(true);
    let resp = HttpResponse::error(503, "connection limit reached, retry");
    let mut buf = Vec::new();
    render_response(&resp, false, &mut buf);
    let mut s = stream;
    let _ = s.write(&buf); // single best-effort write; then close
}

fn acceptor_loop(
    listener: TcpListener,
    txs: Vec<mpsc::Sender<TcpStream>>,
    ctx: Arc<GatewayCtx>,
    cfg: GatewayConfig,
    stop: Arc<AtomicBool>,
) {
    let mut next = 0usize;
    for incoming in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let Ok(stream) = incoming else { continue };
        if ctx.stats.active() >= cfg.max_conns {
            shed(stream, &ctx.stats);
            continue;
        }
        ctx.stats.conn_opened();
        let mut s = stream;
        let mut placed = false;
        for _ in 0..txs.len() {
            let t = next % txs.len();
            next += 1;
            match txs[t].send(s) {
                Ok(()) => {
                    placed = true;
                    break;
                }
                Err(mpsc::SendError(back)) => s = back, // worker gone; try next
            }
        }
        if !placed {
            ctx.stats.conn_closed();
        }
    }
}

/// Spawn the acceptor + `cfg.io_workers` event-loop workers over a bound
/// listener. Returns the join handles (acceptor last).
pub(crate) fn spawn(
    listener: TcpListener,
    ctx: Arc<GatewayCtx>,
    cfg: GatewayConfig,
    stop: Arc<AtomicBool>,
) -> Result<Vec<JoinHandle<()>>> {
    let workers = ctx.stats.workers();
    let mut handles = Vec::with_capacity(workers + 1);
    let mut txs = Vec::with_capacity(workers);
    for id in 0..workers {
        let (tx, rx) = mpsc::channel();
        txs.push(tx);
        let ctx = ctx.clone();
        let cfg = cfg.clone();
        let stop = stop.clone();
        let h = std::thread::Builder::new()
            .name(format!("bmxnet-io-{id}"))
            .spawn(move || worker_loop(id, rx, ctx, cfg, stop))
            .context("spawn io worker")?;
        handles.push(h);
    }
    let h = std::thread::Builder::new()
        .name("bmxnet-accept".into())
        .spawn(move || acceptor_loop(listener, txs, ctx, cfg, stop))
        .context("spawn accept thread")?;
    handles.push(h);
    Ok(handles)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(idx: usize) -> TimerEntry {
        TimerEntry { idx, gen: 0 }
    }

    #[test]
    fn wheel_fires_at_or_after_deadline_slot() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(16, Duration::from_millis(10), t0);
        w.schedule(t0, t0 + Duration::from_millis(35), e(1));
        let mut out = Vec::new();
        w.tick(t0 + Duration::from_millis(20), &mut out);
        assert!(out.is_empty(), "fired {}ms early", 35 - 20);
        w.tick(t0 + Duration::from_millis(60), &mut out);
        assert_eq!(out, vec![e(1)]);
        // one-shot: nothing fires twice
        out.clear();
        w.tick(t0 + Duration::from_millis(500), &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn wheel_clamps_long_deadlines_to_one_revolution() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(8, Duration::from_millis(10), t0);
        // 8 slots × 10ms = 80ms revolution; a 10s deadline fires early
        w.schedule(t0, t0 + Duration::from_secs(10), e(7));
        let mut out = Vec::new();
        w.tick(t0 + Duration::from_millis(85), &mut out);
        assert_eq!(out, vec![e(7)], "long deadline must fire within one revolution");
    }

    #[test]
    fn wheel_gap_longer_than_revolution_drains_everything() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(8, Duration::from_millis(10), t0);
        w.schedule(t0, t0 + Duration::from_millis(15), e(1));
        w.schedule(t0, t0 + Duration::from_millis(75), e(2));
        let mut out = Vec::new();
        w.tick(t0 + Duration::from_secs(5), &mut out);
        assert_eq!(out.len(), 2);
        // wheel stays usable after catch-up
        let t1 = t0 + Duration::from_secs(5);
        w.schedule(t1, t1 + Duration::from_millis(15), e(3));
        out.clear();
        w.tick(t1 + Duration::from_millis(40), &mut out);
        assert_eq!(out, vec![e(3)]);
    }

    #[test]
    fn wheel_subgranularity_ticks_are_noops() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(8, Duration::from_millis(10), t0);
        w.schedule(t0, t0 + Duration::from_millis(5), e(1));
        let mut out = Vec::new();
        w.tick(t0 + Duration::from_millis(3), &mut out);
        assert!(out.is_empty());
        w.tick(t0 + Duration::from_millis(12), &mut out);
        assert_eq!(out, vec![e(1)], "sub-granularity deadline fires on the next slot");
    }

    #[test]
    fn stats_track_active_shed_and_loops() {
        let s = ReactorStats::new(2);
        s.conn_opened();
        s.conn_opened();
        s.conn_closed();
        assert_eq!(s.active(), 1);
        s.shed_one();
        assert_eq!(s.shed_total(), 1);
        s.record_loop(0, 3);
        s.record_loop(0, 100);
        s.record_loop(1, 5);
        let snap = s.loop_snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].count, 2);
        assert_eq!(snap[0].sum_us, 103);
        assert_eq!(*snap[0].buckets.last().unwrap(), 2, "+Inf bucket equals count");
        assert!(snap[0].buckets.windows(2).all(|w| w[0] <= w[1]), "cumulative buckets");
        assert_eq!(snap[1].count, 1);
        // out-of-range worker id is ignored, not a panic
        s.record_loop(9, 1);
    }
}
