//! Sharded engine pool: one model, N independent batcher workers.
//!
//! Every shard is a [`crate::coordinator::Server`] (its own bounded ingress
//! queue + batcher thread) over **one shared** `Arc<dyn Backend>` — the
//! engine is loaded once and referenced by all shards, which is exactly why
//! [`crate::coordinator::Backend`] is object-safe.  Routing is least-queue-
//! depth with a round-robin tiebreak; admission control is the per-shard
//! bounded queue: when every shard is full the pool rejects immediately
//! (the gateway turns that into HTTP 429) instead of queueing unboundedly.

use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{
    Backend, BatchPolicy, Client, ImageBuf, MetricsSnapshot, Response, Server, ServerConfig,
};

/// Pool construction parameters.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker shards (each owns a batcher thread); clamped to >= 1.
    pub workers: usize,
    /// Batch formation policy, applied per shard.
    pub policy: BatchPolicy,
    /// Ingress queue bound per shard (admission control).
    pub queue_cap: usize,
    /// How long [`ModelPool::snapshot_cached`] may serve a stale merged
    /// snapshot. Merging re-sorts the pooled latency window (up to
    /// `workers × LATENCY_WINDOW` samples), so uncached scrapes are the
    /// most expensive read in the gateway; `/metrics` uses the cache.
    /// `Duration::ZERO` disables caching.
    pub metrics_ttl: Duration,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            policy: BatchPolicy::default(),
            queue_cap: 256,
            metrics_ttl: Duration::from_millis(250),
        }
    }
}

struct Shard {
    server: Server,
    client: Client,
    /// Requests accepted by this shard and not yet delivered to a waiter.
    depth: Arc<AtomicUsize>,
}

/// A model sharded across N batcher workers.
pub struct ModelPool {
    shards: Vec<Shard>,
    cursor: AtomicUsize,
    image_len: usize,
    /// Requests refused at admission (every shard queue full).
    rejected: AtomicU64,
    metrics_ttl: Duration,
    /// Last merged snapshot + when it was computed (see `snapshot_cached`).
    snap_cache: Mutex<Option<(Instant, MetricsSnapshot)>>,
}

/// An accepted request: the response channel plus the shard bookkeeping.
/// Dropping it (with or without waiting) releases the queue-depth slot.
pub struct PendingResponse {
    rx: mpsc::Receiver<Response>,
    depth: Arc<AtomicUsize>,
    shard: usize,
}

impl PendingResponse {
    /// Which shard accepted the request (routing observability).
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Block until the response arrives.
    pub fn wait(self) -> Result<Response> {
        self.rx.recv().map_err(|_| anyhow!("server dropped the request"))
    }

    /// Non-blocking check for the response (the reactor's Dispatch state
    /// polls this each event-loop pass). `Ok(None)` = not ready yet;
    /// `Err` = the batcher dropped the request (gateway answers 500).
    pub fn poll(&self) -> Result<Option<Response>> {
        match self.rx.try_recv() {
            Ok(resp) => Ok(Some(resp)),
            Err(mpsc::TryRecvError::Empty) => Ok(None),
            Err(mpsc::TryRecvError::Disconnected) => {
                Err(anyhow!("server dropped the request"))
            }
        }
    }
}

impl Drop for PendingResponse {
    fn drop(&mut self) {
        self.depth.fetch_sub(1, Ordering::AcqRel);
    }
}

impl ModelPool {
    /// Start `cfg.workers` shards over one shared backend.
    pub fn start(backend: Arc<dyn Backend>, cfg: &PoolConfig) -> ModelPool {
        let workers = cfg.workers.max(1);
        let [c, h, w] = backend.input_shape();
        let image_len = c * h * w;
        let shards = (0..workers)
            .map(|_| {
                let server = Server::start(
                    backend.clone(),
                    ServerConfig { policy: cfg.policy, queue_cap: cfg.queue_cap.max(1) },
                );
                let client = server.client();
                Shard { server, client, depth: Arc::new(AtomicUsize::new(0)) }
            })
            .collect();
        ModelPool {
            shards,
            cursor: AtomicUsize::new(0),
            image_len,
            rejected: AtomicU64::new(0),
            metrics_ttl: cfg.metrics_ttl,
            snap_cache: Mutex::new(None),
        }
    }

    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    /// Expected flat image length (C*H*W).
    pub fn image_len(&self) -> usize {
        self.image_len
    }

    /// Requests currently accepted but not yet delivered, across shards.
    pub fn depth(&self) -> usize {
        self.shards.iter().map(|s| s.depth.load(Ordering::Relaxed)).sum()
    }

    /// Per-shard queue depths, in shard order (the
    /// `bmxnet_queue_depth{shard=...}` gauges).
    pub fn shard_depths(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.depth.load(Ordering::Relaxed)).collect()
    }

    /// Route a request: shards ordered by queue depth (round-robin cursor
    /// breaks ties), first shard with queue space wins.  Errs immediately
    /// when the image is malformed or every shard queue is full.  Takes
    /// anything convertible to [`ImageBuf`], so the gateway's pooled
    /// buffers and plain `Vec<f32>`s both flow through unchanged.
    pub fn submit(&self, image: impl Into<ImageBuf>) -> Result<PendingResponse> {
        let image = image.into();
        anyhow::ensure!(
            image.len() == self.image_len,
            "image must have {} floats, got {}",
            self.image_len,
            image.len()
        );
        let n = self.shards.len();
        let start = self.cursor.fetch_add(1, Ordering::Relaxed) % n;
        let mut order: Vec<usize> = (0..n).map(|i| (start + i) % n).collect();
        // stable sort: equal depths keep round-robin order
        order.sort_by_key(|&i| self.shards[i].depth.load(Ordering::Acquire));
        let mut img = image;
        for &idx in &order {
            let shard = &self.shards[idx];
            match shard.client.try_submit(img) {
                Ok(rx) => {
                    shard.depth.fetch_add(1, Ordering::AcqRel);
                    return Ok(PendingResponse { rx, depth: shard.depth.clone(), shard: idx });
                }
                Err((back, _why)) => img = back,
            }
        }
        self.rejected.fetch_add(1, Ordering::Relaxed);
        Err(anyhow!("model at capacity: all {n} shard queues full"))
    }

    /// Blocking classify through the router.
    pub fn classify(&self, image: impl Into<ImageBuf>) -> Result<Response> {
        self.submit(image)?.wait()
    }

    /// Aggregate metrics across shards (losslessly merged percentiles),
    /// with admission rejections folded into `rejected`.  Always fresh —
    /// scrape paths should prefer [`ModelPool::snapshot_cached`].
    pub fn snapshot(&self) -> MetricsSnapshot {
        let snaps: Vec<MetricsSnapshot> = self.shard_snapshots();
        let mut merged = MetricsSnapshot::merge(snaps.iter());
        merged.rejected += self.rejected.load(Ordering::Relaxed);
        merged
    }

    /// [`ModelPool::snapshot`] behind a `metrics_ttl` cache, so a scrape
    /// storm pays for one clone+sort of the pooled latency window per TTL
    /// instead of one per scrape.  Concurrent scrapes serialize on the
    /// cache lock: the first recomputes, the rest reuse its result.
    pub fn snapshot_cached(&self) -> MetricsSnapshot {
        let mut g = self.snap_cache.lock().unwrap();
        if let Some((at, snap)) = g.as_ref() {
            if at.elapsed() < self.metrics_ttl {
                return snap.clone();
            }
        }
        let snap = self.snapshot();
        *g = Some((Instant::now(), snap.clone()));
        snap
    }

    /// Per-shard metrics, in shard order.
    pub fn shard_snapshots(&self) -> Vec<MetricsSnapshot> {
        self.shards.iter().map(|s| s.server.metrics()).collect()
    }

    /// Stop every shard (each drains its queue first) and return the
    /// merged final metrics.
    pub fn shutdown(self) -> MetricsSnapshot {
        let rejected = self.rejected.load(Ordering::Relaxed);
        let snaps: Vec<MetricsSnapshot> = self
            .shards
            .into_iter()
            .map(|s| {
                let Shard { server, client, depth: _ } = s;
                drop(client);
                server.shutdown()
            })
            .collect();
        let mut merged = MetricsSnapshot::merge(snaps.iter());
        merged.rejected += rejected;
        merged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// Mock backend: class = index of max pixel value; counts forwards.
    struct Mock {
        delay: Duration,
        calls: AtomicUsize,
    }

    impl Mock {
        fn slow(ms: u64) -> Self {
            Mock { delay: Duration::from_millis(ms), calls: AtomicUsize::new(0) }
        }
    }

    impl Backend for Mock {
        fn input_shape(&self) -> [usize; 3] {
            [1, 2, 2]
        }

        fn classify_batch(&self, images: &[f32], batch: usize) -> Result<Vec<(usize, f32)>> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.delay);
            Ok(images
                .chunks(4)
                .take(batch)
                .map(|img| {
                    let (i, &v) = img
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .unwrap();
                    (i, v)
                })
                .collect())
        }
    }

    fn img(hot: usize) -> Vec<f32> {
        let mut v = vec![0.0; 4];
        v[hot] = 1.0;
        v
    }

    #[test]
    fn shards_share_one_backend_without_reloading() {
        let backend = Arc::new(Mock::slow(0));
        let before = Arc::strong_count(&backend);
        let cfg = PoolConfig { workers: 3, ..Default::default() };
        let pool = ModelPool::start(backend.clone(), &cfg);
        // 3 shards hold the same Arc — no per-shard copy of the engine
        assert_eq!(Arc::strong_count(&backend), before + 3);
        for i in 0..4 {
            assert_eq!(pool.classify(img(i % 4)).unwrap().class, i % 4);
        }
        assert!(backend.calls.load(Ordering::Relaxed) >= 1, "shared backend never invoked");
        let snap = pool.shutdown();
        assert_eq!(snap.requests, 4);
        assert_eq!(Arc::strong_count(&backend), before);
    }

    #[test]
    fn least_depth_routing_spreads_load() {
        let pool = ModelPool::start(
            Arc::new(Mock::slow(20)),
            &PoolConfig {
                workers: 2,
                policy: BatchPolicy { max_batch: 1, window: Duration::ZERO },
                queue_cap: 8,
                ..Default::default()
            },
        );
        let a = pool.submit(img(0)).unwrap();
        let b = pool.submit(img(1)).unwrap();
        // the second submit must route away from the busy shard
        assert_ne!(a.shard(), b.shard(), "least-depth routing sent both to one shard");
        assert_eq!(pool.depth(), 2);
        assert_eq!(a.wait().unwrap().class, 0);
        assert_eq!(b.wait().unwrap().class, 1);
        assert_eq!(pool.depth(), 0);
        pool.shutdown();
    }

    #[test]
    fn rejects_when_every_shard_queue_is_full() {
        let pool = ModelPool::start(
            Arc::new(Mock::slow(30)),
            &PoolConfig {
                workers: 2,
                policy: BatchPolicy { max_batch: 1, window: Duration::ZERO },
                queue_cap: 1,
                ..Default::default()
            },
        );
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for i in 0..16 {
            match pool.submit(img(i % 4)) {
                Ok(p) => accepted.push((i % 4, p)),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "16-burst over 2 shards with queue_cap=1 never rejected");
        assert!(accepted.len() >= 2, "admission rejected everything");
        // accepted requests still complete correctly
        let n_accepted = accepted.len();
        for (want, p) in accepted {
            assert_eq!(p.wait().unwrap().class, want);
        }
        let snap = pool.shutdown();
        assert_eq!(snap.rejected, rejected as u64, "admission rejects must be counted");
        assert_eq!(snap.requests, n_accepted as u64);
    }

    #[test]
    fn cached_snapshot_serves_stale_within_ttl_and_refreshes_after() {
        let pool = ModelPool::start(
            Arc::new(Mock::slow(0)),
            &PoolConfig {
                workers: 1,
                metrics_ttl: Duration::from_secs(3600),
                ..Default::default()
            },
        );
        pool.classify(img(0)).unwrap();
        assert_eq!(pool.snapshot_cached().requests, 1);
        pool.classify(img(1)).unwrap();
        // within the TTL the cache serves the stale merge...
        assert_eq!(pool.snapshot_cached().requests, 1, "cache recomputed inside TTL");
        // ...while the uncached path is always fresh
        assert_eq!(pool.snapshot().requests, 2);
        pool.shutdown();
    }

    #[test]
    fn zero_ttl_disables_the_snapshot_cache() {
        let pool = ModelPool::start(
            Arc::new(Mock::slow(0)),
            &PoolConfig { workers: 1, metrics_ttl: Duration::ZERO, ..Default::default() },
        );
        pool.classify(img(2)).unwrap();
        assert_eq!(pool.snapshot_cached().requests, 1);
        pool.classify(img(3)).unwrap();
        assert_eq!(pool.snapshot_cached().requests, 2);
        pool.shutdown();
    }

    #[test]
    fn shard_depths_tracks_in_flight_per_shard() {
        let pool = ModelPool::start(
            Arc::new(Mock::slow(20)),
            &PoolConfig {
                workers: 2,
                policy: BatchPolicy { max_batch: 1, window: Duration::ZERO },
                queue_cap: 8,
                ..Default::default()
            },
        );
        assert_eq!(pool.shard_depths(), vec![0, 0]);
        let a = pool.submit(img(0)).unwrap();
        let b = pool.submit(img(1)).unwrap();
        let depths = pool.shard_depths();
        assert_eq!(depths.len(), 2);
        assert_eq!(depths.iter().sum::<usize>(), 2);
        a.wait().unwrap();
        b.wait().unwrap();
        assert_eq!(pool.shard_depths().iter().sum::<usize>(), 0);
        pool.shutdown();
    }

    #[test]
    fn wrong_image_length_is_rejected_up_front() {
        let pool = ModelPool::start(Arc::new(Mock::slow(0)), &PoolConfig::default());
        assert!(pool.submit(vec![0.0; 3]).is_err());
        let snap = pool.shutdown();
        assert_eq!(snap.requests, 0);
    }

    #[test]
    fn snapshot_merges_across_shards() {
        let pool = ModelPool::start(
            Arc::new(Mock::slow(5)),
            &PoolConfig {
                workers: 2,
                policy: BatchPolicy { max_batch: 4, window: Duration::from_millis(2) },
                queue_cap: 64,
                ..Default::default()
            },
        );
        let pending: Vec<_> = (0..12).map(|i| pool.submit(img(i % 4)).unwrap()).collect();
        for p in pending {
            p.wait().unwrap();
        }
        let per_shard = pool.shard_snapshots();
        assert!(per_shard.iter().all(|s| s.requests > 0), "a shard sat idle: {per_shard:?}");
        let merged = pool.snapshot();
        assert_eq!(merged.requests, 12);
        let hist_total: u64 =
            merged.batch_hist.iter().map(|&(size, count)| size as u64 * count).sum();
        assert_eq!(hist_total, merged.requests);
        pool.shutdown();
    }
}
