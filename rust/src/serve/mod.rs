//! The network-facing serving gateway: multi-model, multi-worker, real TCP.
//!
//! Where [`crate::coordinator`] is one in-process batching loop over one
//! engine, this subsystem is the deployment story the paper motivates
//! (§4.2, binary models under real-world load on commodity CPUs):
//!
//! ```text
//!   readiness-polling reactor          [`http::Gateway`] / [`reactor`]
//!        │  acceptor + N event-loop workers, non-blocking conns,
//!        │  POST /v1/models/{name}:classify (JSON / x-bmx-f32 / x-bmx-packed)
//!        ▼
//!   name → model resolution            [`registry::ModelRegistry`]
//!        │  lazy load · LRU byte budget · hot-swap on file change
//!        ▼
//!   least-depth shard routing          [`pool::ModelPool`]
//!        │  bounded queues → fast 429 rejection
//!        ▼
//!   dynamic batcher × N shards         [`crate::coordinator::Server`]
//!        │  one shared Arc<Engine>
//!        ▼
//!   xnor/popcount engine forward       [`crate::nn::Engine`]
//! ```
//!
//! Everything is std-only (threads + non-blocking `TcpStream`s driven by
//! level-triggered readiness polling; no tokio/hyper/mio in the offline
//! environment).  Request/response byte buffers and decoded image tensors
//! are pooled ([`bufpool`]) so the steady state allocates nothing per
//! request.  `GET /metrics` exposes per-model request counts, batch-size
//! histograms, latency quantiles aggregated across shards, and the
//! reactor's connection gauges ([`prom`]); `GET /v1/models` lists what
//! the registry can serve.  Architecture rationale: DESIGN.md §Serving
//! architecture and §Gateway reactor.

pub mod bufpool;
pub mod http;
pub mod pool;
pub mod prom;
pub mod reactor;
pub mod registry;

pub use http::{Gateway, GatewayConfig};
pub use reactor::ReactorStats;
pub use pool::{ModelPool, PendingResponse, PoolConfig};
pub use registry::{
    binary_names_for, LoadedModel, ModelInfo, ModelRegistry, ModelStatus, RegistryConfig,
};
