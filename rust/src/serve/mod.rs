//! The network-facing serving gateway: multi-model, multi-worker, real TCP.
//!
//! Where [`crate::coordinator`] is one in-process batching loop over one
//! engine, this subsystem is the deployment story the paper motivates
//! (§4.2, binary models under real-world load on commodity CPUs):
//!
//! ```text
//!   HTTP/1.1 over TcpListener          [`http::Gateway`]
//!        │  POST /v1/models/{name}:classify
//!        ▼
//!   name → model resolution            [`registry::ModelRegistry`]
//!        │  lazy load · LRU byte budget · hot-swap on file change
//!        ▼
//!   least-depth shard routing          [`pool::ModelPool`]
//!        │  bounded queues → fast 429 rejection
//!        ▼
//!   dynamic batcher × N shards         [`crate::coordinator::Server`]
//!        │  one shared Arc<Engine>
//!        ▼
//!   xnor/popcount engine forward       [`crate::nn::Engine`]
//! ```
//!
//! Everything is std-only (threads + `TcpListener`; no tokio/hyper in the
//! offline environment).  `GET /metrics` exposes per-model request counts,
//! batch-size histograms and latency quantiles aggregated across shards
//! ([`prom`]); `GET /v1/models` lists what the registry can serve.
//! Architecture rationale: DESIGN.md §Serving architecture.

pub mod http;
pub mod pool;
pub mod prom;
pub mod registry;

pub use http::Gateway;
pub use pool::{ModelPool, PendingResponse, PoolConfig};
pub use registry::{
    binary_names_for, LoadedModel, ModelInfo, ModelRegistry, ModelStatus, RegistryConfig,
};
