//! Minimal HTTP/1.1 gateway over `std::net::TcpListener` (offline
//! environment: no hyper/tokio — hand-rolled request parsing, keep-alive,
//! thread-per-connection).
//!
//! Routes:
//!
//! * `POST /v1/models/{name}:classify` — body `{"image": [f32; C*H*W]}`;
//!   200 with `{"model", "class", "score", "latency_us", "batch_size",
//!   "shard"}`, 400 on malformed input, 404 on unknown model, **429 when
//!   every pool shard's bounded queue is full** (admission control).
//! * `GET /v1/models` — available + resident models, per-model GEMM
//!   dispatch, and the process `force_scalar` state.
//! * `GET /v1/models/{name}/profile?batch=N&reps=R` — per-layer wall
//!   time / bytes / dispatch labels from a synthetic profiled forward.
//! * `GET /v1/debug/trace?n=K` — the K most recent request traces from
//!   the lock-free journal (stage offsets in µs from request start).
//! * `GET /metrics` — Prometheus-style text (see [`super::prom`]).
//! * `GET /healthz` — liveness.
//!
//! Every classify request carries a [`Trace`]: the gateway stamps
//! parse/admission/respond, the pool batcher contributes
//! queue_wait/batch_window/forward via [`crate::coordinator::Response`]
//! timing, and the completed record feeds the journal, the per-stage
//! histograms and the slow-request log ([`Obs::complete`]).
//!
//! Limits: bodies over [`MAX_BODY`] are rejected, chunked transfer
//! encoding is not supported (501-adjacent 400), at most
//! [`MAX_CONNECTIONS`] handler threads run at once (then immediate 503),
//! and idle keep-alive connections are reaped on shutdown via a read
//! timeout + stop flag.

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::prom;
use super::registry::ModelRegistry;
use crate::model::json;
use crate::obs::{trace, Obs, Stage, Trace};

/// Request body cap (a 3×32×32 image in long-form JSON is ~40 kB).
pub const MAX_BODY: usize = 8 << 20;

/// Cap on one request-line or header line — without it a client
/// streaming newline-free bytes would grow the line buffer unboundedly.
pub const MAX_LINE: usize = 8 << 10;

/// How long a connection handler waits for the *first byte* of the next
/// request before re-checking the gateway stop flag (bounds shutdown
/// latency for idle keep-alive connections).
const IDLE_TIMEOUT: Duration = Duration::from_millis(200);

/// Read-timeout once a request has started arriving: a slow client may
/// stall this long between segments of the request line, headers or body
/// before the connection is dropped.
const REQUEST_TIMEOUT: Duration = Duration::from_secs(10);

/// Cap on concurrent connection-handler threads ("bounded everything":
/// past this, new connections get an immediate 503 instead of a thread).
pub const MAX_CONNECTIONS: usize = 256;

/// Decrements the live-connection gauge even if the handler panics.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// A running gateway: accept loop + per-connection handler threads.
pub struct Gateway {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    conn_handles: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Gateway {
    /// Bind and start serving.  `addr` is `host:port`; port 0 picks an
    /// ephemeral port — read the real one back from [`Gateway::addr`].
    /// Observability state (journal, stage histograms, slow-request
    /// threshold) is built from the environment ([`Obs::from_env`]).
    pub fn start(registry: Arc<ModelRegistry>, addr: &str) -> Result<Gateway> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conn_handles = Arc::new(Mutex::new(Vec::new()));
        let obs = Arc::new(Obs::from_env());
        let s = stop.clone();
        let ch = conn_handles.clone();
        let accept_handle = std::thread::Builder::new()
            .name("bmxnet-accept".into())
            .spawn(move || accept_loop(listener, registry, obs, s, ch))
            .context("spawn accept thread")?;
        Ok(Gateway { addr: local, stop, accept_handle: Some(accept_handle), conn_handles })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, wake the listener, join every handler thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake the blocking accept() with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *self.conn_handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        if self.accept_handle.is_some() {
            self.stop_and_join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    obs: Arc<Obs>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    let active = Arc::new(AtomicUsize::new(0));
    for incoming in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let Ok(stream) = incoming else { continue };
        // connection-level admission: shed load before spawning a thread
        if active.load(Ordering::Acquire) >= MAX_CONNECTIONS {
            let mut s = stream;
            let resp = HttpResponse::error(503, "connection limit reached, retry");
            let _ = write_response(&mut s, &resp, false);
            continue;
        }
        active.fetch_add(1, Ordering::AcqRel);
        let guard = ConnGuard(active.clone());
        let registry = registry.clone();
        let obs = obs.clone();
        let stop = stop.clone();
        let handle = std::thread::Builder::new()
            .name("bmxnet-conn".into())
            .spawn(move || {
                let _guard = guard;
                let _ = handle_connection(stream, &registry, &obs, &stop);
            });
        let mut g = conns.lock().unwrap();
        if let Ok(h) = handle {
            g.push(h);
        }
        // spawn failure: `guard` was moved into the closure only on
        // success; on Err the closure is dropped, releasing the slot.
        // reap finished handlers so the vec stays bounded under churn
        g.retain(|h| !h.is_finished());
    }
}

fn handle_connection(
    stream: TcpStream,
    registry: &ModelRegistry,
    obs: &Obs,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    let _ = stream.set_nodelay(true);
    // reader and writer are dup'd fds over one socket, so a timeout set on
    // `writer` governs `reader`'s reads too.
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    loop {
        // Idle gap between requests: short timeout, poll the stop flag.
        writer.set_read_timeout(Some(IDLE_TIMEOUT))?;
        match reader.fill_buf() {
            Ok(buf) if buf.is_empty() => return Ok(()), // clean EOF
            Ok(_) => {}
            Err(e) if is_timeout(&e) => {
                if stop.load(Ordering::SeqCst) {
                    return Ok(());
                }
                continue;
            }
            Err(_) => return Ok(()),
        }
        // A request has started: allow slow clients the full budget.
        writer.set_read_timeout(Some(REQUEST_TIMEOUT))?;
        match read_request(&mut reader) {
            Ok(Some(req)) => {
                let keep_alive = req.keep_alive;
                let resp = route(registry, obs, &req);
                write_response(&mut writer, &resp, keep_alive)?;
                if !keep_alive {
                    return Ok(());
                }
            }
            Ok(None) => return Ok(()), // clean EOF between requests
            Err(ReadError::Idle) => {
                if stop.load(Ordering::SeqCst) {
                    return Ok(());
                }
            }
            Err(ReadError::Bad(msg)) => {
                let _ = write_response(&mut writer, &HttpResponse::error(400, &msg), false);
                return Ok(());
            }
            Err(ReadError::Io(_)) => return Ok(()),
        }
    }
}

/// Why reading one request off the wire failed.
enum ReadError {
    /// Read timeout with no bytes consumed — poll the stop flag and retry.
    Idle,
    /// Client spoke malformed or unsupported HTTP (answer 400, close).
    Bad(String),
    /// Connection-level failure (close silently).
    Io(std::io::Error),
}

struct HttpRequest {
    method: String,
    /// Path with any query string stripped.
    path: String,
    /// Raw query string (after `?`, empty when absent).
    query: String,
    body: Vec<u8>,
    keep_alive: bool,
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// `Ok(None)` = clean EOF before a request; see [`ReadError`] otherwise.
type ReadResult = std::result::Result<Option<HttpRequest>, ReadError>;

/// `read_line` bounded by [`MAX_LINE`]: errors with `InvalidData` when a
/// line (sans terminator) would exceed the cap, instead of growing the
/// buffer for as long as the peer keeps sending newline-free bytes.
fn read_line_capped<R: BufRead>(reader: &mut R, line: &mut String) -> std::io::Result<usize> {
    let n = (&mut *reader).take((MAX_LINE + 2) as u64).read_line(line)?;
    if line.len() > MAX_LINE && !line.ends_with('\n') {
        return Err(std::io::Error::new(ErrorKind::InvalidData, "line exceeds MAX_LINE"));
    }
    Ok(n)
}

/// Parse one request (request line, headers, Content-Length body).
/// Generic over the reader so the parser is unit-testable off-socket.
fn read_request<R: BufRead>(reader: &mut R) -> ReadResult {
    let mut line = String::new();
    match read_line_capped(reader, &mut line) {
        Ok(0) => return Ok(None), // EOF before a request
        Ok(_) => {}
        Err(e) if e.kind() == ErrorKind::InvalidData => {
            return Err(ReadError::Bad("request line too long".to_string()))
        }
        Err(e) if is_timeout(&e) && line.is_empty() => return Err(ReadError::Idle),
        Err(e) => return Err(ReadError::Io(e)),
    }
    let line_t = line.trim_end();
    let mut parts = line_t.split_whitespace();
    let method = parts.next().unwrap_or("").to_ascii_uppercase();
    let target = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("HTTP/1.1").to_string();
    if method.is_empty() || target.is_empty() {
        return Err(ReadError::Bad(format!("malformed request line {line_t:?}")));
    }
    let mut headers: BTreeMap<String, String> = BTreeMap::new();
    loop {
        let mut h = String::new();
        match read_line_capped(reader, &mut h) {
            Ok(0) => return Err(ReadError::Bad("unexpected EOF in headers".to_string())),
            Ok(_) => {}
            Err(e) if e.kind() == ErrorKind::InvalidData => {
                return Err(ReadError::Bad("header line too long".to_string()))
            }
            Err(e) => return Err(ReadError::Io(e)),
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
        if headers.len() > 100 {
            return Err(ReadError::Bad("too many headers".to_string()));
        }
    }
    if headers
        .get("transfer-encoding")
        .is_some_and(|v| !v.eq_ignore_ascii_case("identity"))
    {
        return Err(ReadError::Bad("chunked transfer encoding not supported".to_string()));
    }
    let content_len: usize = match headers.get("content-length") {
        None => 0,
        Some(v) => v
            .parse()
            .map_err(|_| ReadError::Bad(format!("bad content-length {v:?}")))?,
    };
    if content_len > MAX_BODY {
        return Err(ReadError::Bad(format!("body of {content_len} bytes exceeds cap {MAX_BODY}")));
    }
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body).map_err(ReadError::Io)?;
    }
    let http10 = version.eq_ignore_ascii_case("HTTP/1.0");
    let keep_alive = match headers.get("connection").map(|s| s.to_ascii_lowercase()).as_deref() {
        Some("close") => false,
        Some("keep-alive") => true,
        _ => !http10,
    };
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    Ok(Some(HttpRequest { method, path, query, body, keep_alive }))
}

struct HttpResponse {
    status: u16,
    content_type: &'static str,
    body: Vec<u8>,
    retry_after: bool,
}

impl HttpResponse {
    fn json(status: u16, body: String) -> Self {
        Self {
            status,
            content_type: "application/json",
            body: body.into_bytes(),
            retry_after: false,
        }
    }

    fn text(status: u16, body: String) -> Self {
        Self {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into_bytes(),
            retry_after: false,
        }
    }

    fn error(status: u16, msg: &str) -> Self {
        Self::json(status, format!("{{\"error\": {}}}", json_string(msg)))
    }
}

fn status_reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Error",
    }
}

fn write_response(w: &mut TcpStream, r: &HttpResponse, keep_alive: bool) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: {}\r\n",
        r.status,
        status_reason(r.status),
        r.content_type,
        r.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    if r.retry_after {
        head.push_str("retry-after: 1\r\n");
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(&r.body)?;
    w.flush()
}

/// Serialize a string as a JSON string literal (quotes included).
fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

const CLASSIFY_PREFIX: &str = "/v1/models/";
const CLASSIFY_SUFFIX: &str = ":classify";
const PROFILE_SUFFIX: &str = "/profile";

/// First `key=` value in a query string, parsed as usize.
fn query_usize(query: &str, key: &str) -> Option<usize> {
    query
        .split('&')
        .find_map(|kv| kv.strip_prefix(key).and_then(|v| v.strip_prefix('=')))
        .and_then(|v| v.parse().ok())
}

fn route(registry: &ModelRegistry, obs: &Obs, req: &HttpRequest) -> HttpResponse {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/v1/models") => list_models(registry),
        ("GET", "/v1/debug/trace") => debug_trace(obs, &req.query),
        ("GET", "/metrics") => HttpResponse::text(200, prom::render(registry, obs)),
        ("GET", "/healthz") => HttpResponse::json(200, "{\"status\": \"ok\"}".to_string()),
        ("POST", path)
            if path.starts_with(CLASSIFY_PREFIX) && path.ends_with(CLASSIFY_SUFFIX) =>
        {
            let name = &path[CLASSIFY_PREFIX.len()..path.len() - CLASSIFY_SUFFIX.len()];
            classify(registry, obs, name, &req.body)
        }
        ("GET", path)
            if path.starts_with(CLASSIFY_PREFIX)
                && path.ends_with(PROFILE_SUFFIX)
                && path.len() > CLASSIFY_PREFIX.len() + PROFILE_SUFFIX.len() =>
        {
            let name = &path[CLASSIFY_PREFIX.len()..path.len() - PROFILE_SUFFIX.len()];
            model_profile(registry, name, &req.query)
        }
        ("GET" | "POST", _) => {
            HttpResponse::error(404, &format!("no route for {} {}", req.method, req.path))
        }
        _ => HttpResponse::error(405, &format!("method {} not allowed", req.method)),
    }
}

fn list_models(registry: &ModelRegistry) -> HttpResponse {
    let items: Vec<String> = registry
        .list()
        .iter()
        .map(|m| {
            let dispatch = match &m.dispatch {
                Some(d) => json_string(d),
                None => "null".to_string(),
            };
            format!(
                "{{\"name\": {}, \"source\": {}, \"loaded\": {}, \"resident_bytes\": {}, \
                 \"dispatch\": {}}}",
                json_string(&m.name),
                json_string(m.source),
                m.loaded,
                m.resident_bytes,
                dispatch,
            )
        })
        .collect();
    let prov = crate::bench::Provenance::capture("bmxnet serve");
    HttpResponse::json(
        200,
        format!(
            "{{\"models\": [{}], \"gemm_dispatch\": {}, \"force_scalar\": {}, \
             \"build_info\": {{\"version\": {}, \"git\": {}, \"rustc\": {}, \
             \"features\": {}, \"force_scalar\": {}}}}}",
            items.join(", "),
            json_string(&format!(
                "method {} · kernel {}",
                crate::gemm::Method::auto().label(),
                crate::gemm::simd::best_kernel().label()
            )),
            crate::gemm::simd::force_scalar(),
            json_string(&prov.version),
            json_string(&prov.git),
            json_string(&prov.rustc),
            json_string(&prov.features),
            prov.force_scalar,
        ),
    )
}

/// `GET /v1/debug/trace?n=K` — newest-first traces from the journal.
fn debug_trace(obs: &Obs, query: &str) -> HttpResponse {
    let n = query_usize(query, "n").unwrap_or(16).min(obs.journal.capacity());
    let mut items = Vec::new();
    for rec in obs.journal.recent(n) {
        let mut stages = String::new();
        for s in Stage::all() {
            if rec.stages[s.index()] != trace::UNSET {
                if !stages.is_empty() {
                    stages.push_str(", ");
                }
                stages.push_str(&format!("\"{}\": {}", s.label(), rec.stages[s.index()]));
            }
        }
        items.push(format!(
            "{{\"id\": {}, \"model\": {}, \"status\": {}, \"shard\": {}, \"batch_size\": {}, \
             \"start_unix_us\": {}, \"total_us\": {}, \"stages_us\": {{{}}}}}",
            rec.id,
            json_string(rec.model()),
            rec.status,
            rec.shard,
            rec.batch,
            rec.start_unix_us,
            rec.total_us,
            stages,
        ));
    }
    HttpResponse::json(
        200,
        format!(
            "{{\"total\": {}, \"dropped\": {}, \"traces\": [{}]}}",
            obs.journal.total(),
            obs.journal.dropped(),
            items.join(", "),
        ),
    )
}

/// `GET /v1/models/{name}/profile?batch=N&reps=R` — profiled synthetic
/// forward through the resident engine (loads the model if needed).
fn model_profile(registry: &ModelRegistry, name: &str, query: &str) -> HttpResponse {
    let batch = query_usize(query, "batch").unwrap_or(1).clamp(1, 64);
    let reps = query_usize(query, "reps").unwrap_or(3).clamp(1, 100);
    let model = match registry.get(name) {
        Ok(m) => m,
        Err(e) => {
            let known = registry.list().iter().any(|m| m.name == name);
            let status = if known { 500 } else { 404 };
            return HttpResponse::error(status, &format!("model {name:?} unavailable: {e:#}"));
        }
    };
    match model.engine.profile(batch, reps) {
        Ok(mut report) => {
            report.model = name.to_string();
            HttpResponse::json(200, report.render_json())
        }
        Err(e) => HttpResponse::error(500, &format!("profile failed: {e:#}")),
    }
}

fn classify(registry: &ModelRegistry, obs: &Obs, name: &str, body: &[u8]) -> HttpResponse {
    let mut trace = Trace::begin();
    let (resp, shard, batch) = classify_traced(registry, name, body, &mut trace);
    trace.mark(Stage::Respond);
    obs.complete(&trace.finish(name, resp.status, shard, batch));
    resp
}

/// Classify body with stage stamps; returns (response, shard, batch_size)
/// so the caller can finish and publish the trace on every exit path.
fn classify_traced(
    registry: &ModelRegistry,
    name: &str,
    body: &[u8],
    trace: &mut Trace,
) -> (HttpResponse, u16, u16) {
    let Ok(text) = std::str::from_utf8(body) else {
        return (HttpResponse::error(400, "body is not UTF-8"), 0, 0);
    };
    let parsed = match json::parse(text) {
        Ok(v) => v,
        Err(e) => return (HttpResponse::error(400, &format!("bad JSON body: {e}")), 0, 0),
    };
    let Some(image_v) = parsed.get("image").and_then(|v| v.as_array()) else {
        return (HttpResponse::error(400, "body must be {\"image\": [f32; C*H*W]}"), 0, 0);
    };
    let mut image = Vec::with_capacity(image_v.len());
    for v in image_v {
        match v.as_f64() {
            Some(f) => image.push(f as f32),
            None => {
                return (HttpResponse::error(400, "\"image\" must contain only numbers"), 0, 0)
            }
        }
    }
    trace.mark(Stage::Parse);
    let model = match registry.get(name) {
        Ok(m) => m,
        Err(e) => {
            // a name the registry could resolve but failed to load is a
            // server-side fault (500), not a client-side unknown (404)
            let known = registry.list().iter().any(|m| m.name == name);
            let status = if known { 500 } else { 404 };
            return (
                HttpResponse::error(status, &format!("model {name:?} unavailable: {e:#}")),
                0,
                0,
            );
        }
    };
    if image.len() != model.pool.image_len() {
        return (
            HttpResponse::error(
                400,
                &format!(
                    "model {name:?} expects {} floats, got {}",
                    model.pool.image_len(),
                    image.len()
                ),
            ),
            0,
            0,
        );
    }
    let pending = match model.pool.submit(image) {
        Ok(p) => p,
        Err(_) => {
            // every shard queue full: bounded-queue fast rejection
            let mut r = HttpResponse::error(429, &format!("model {name:?} at capacity, retry"));
            r.retry_after = true;
            return (r, 0, 0);
        }
    };
    trace.mark(Stage::Admission);
    let shard = pending.shard();
    match pending.wait() {
        Ok(resp) => {
            trace.absorb_batch_timing(&resp.timing);
            (
                HttpResponse::json(
                    200,
                    format!(
                        "{{\"model\": {}, \"class\": {}, \"score\": {:.6}, \"latency_us\": {}, \
                         \"batch_size\": {}, \"shard\": {}}}",
                        json_string(name),
                        resp.class,
                        resp.score,
                        resp.latency.as_micros(),
                        resp.batch_size,
                        shard,
                    ),
                ),
                shard as u16,
                resp.batch_size as u16,
            )
        }
        Err(e) => (
            HttpResponse::error(500, &format!("engine dropped the request: {e:#}")),
            shard as u16,
            0,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn req(raw: &str) -> ReadResult {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()))
    }

    #[test]
    fn parses_get_with_keepalive_default() {
        let r = req("GET /v1/models HTTP/1.1\r\nhost: x\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/v1/models");
        assert!(r.keep_alive);
        assert!(r.body.is_empty());
    }

    #[test]
    fn parses_post_body_and_connection_close() {
        let r = req(
            "POST /v1/models/m:classify HTTP/1.1\r\ncontent-length: 4\r\n\
             connection: close\r\n\r\nabcd",
        )
        .unwrap()
        .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.body, b"abcd");
        assert!(!r.keep_alive);
    }

    #[test]
    fn http10_defaults_to_close() {
        let r = req("GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!r.keep_alive);
    }

    #[test]
    fn query_string_is_stripped() {
        let r = req("GET /metrics?x=1 HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.path, "/metrics");
        assert_eq!(r.query, "x=1");
        let r = req("GET /metrics HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.query, "");
    }

    #[test]
    fn query_usize_parses_first_match() {
        assert_eq!(query_usize("n=5", "n"), Some(5));
        assert_eq!(query_usize("batch=8&reps=2", "reps"), Some(2));
        assert_eq!(query_usize("nn=9", "n"), None);
        assert_eq!(query_usize("n=x", "n"), None);
        assert_eq!(query_usize("", "n"), None);
    }

    #[test]
    fn eof_is_none_and_garbage_is_bad() {
        assert!(matches!(req(""), Ok(None)));
        assert!(matches!(req("\r\n\r\n"), Err(ReadError::Bad(_))));
        assert!(matches!(
            req("GET / HTTP/1.1\r\ncontent-length: nope\r\n\r\n"),
            Err(ReadError::Bad(_))
        ));
        assert!(matches!(
            req("GET / HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n"),
            Err(ReadError::Bad(_))
        ));
    }

    #[test]
    fn oversized_body_rejected_before_reading() {
        let r = req(&format!("POST /x HTTP/1.1\r\ncontent-length: {}\r\n\r\n", MAX_BODY + 1));
        assert!(matches!(r, Err(ReadError::Bad(_))));
    }

    #[test]
    fn overlong_lines_rejected() {
        let long_target = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_LINE));
        assert!(matches!(req(&long_target), Err(ReadError::Bad(_))));
        let long_header = format!("GET / HTTP/1.1\r\nx: {}\r\n\r\n", "b".repeat(MAX_LINE));
        assert!(matches!(req(&long_header), Err(ReadError::Bad(_))));
        // a line just under the cap still parses
        let ok_header = format!("GET / HTTP/1.1\r\nx: {}\r\n\r\n", "c".repeat(1024));
        assert!(req(&ok_header).unwrap().is_some());
    }

    #[test]
    fn json_string_escapes() {
        assert_eq!(json_string("plain"), "\"plain\"");
        assert_eq!(json_string("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(json_string("\u{1}"), "\"\\u0001\"");
    }

    #[test]
    fn classify_path_name_extraction() {
        let path = "/v1/models/lenet_bin:classify";
        assert!(path.starts_with(CLASSIFY_PREFIX) && path.ends_with(CLASSIFY_SUFFIX));
        let name = &path[CLASSIFY_PREFIX.len()..path.len() - CLASSIFY_SUFFIX.len()];
        assert_eq!(name, "lenet_bin");
    }
}
