//! HTTP/1.1 gateway over the std-only readiness-polling reactor
//! ([`super::reactor`]): a small acceptor plus N event-loop workers
//! drive non-blocking connections through a read → dispatch → write
//! state machine, so concurrency is bounded by fds (`max_conns`), not
//! threads — the old thread-per-connection gateway capped out at 256.
//!
//! Routes:
//!
//! * `POST /v1/models/{name}:classify` — 200 with `{"model", "class",
//!   "score", "latency_us", "batch_size", "shard"}`, 400 on malformed
//!   input, 404 on unknown model, **429 when every pool shard's bounded
//!   queue is full** (admission control). Three body formats, selected
//!   by `content-type`:
//!   - `application/json` (default): `{"image": [f32; C*H*W]}`
//!   - `application/x-bmx-f32`: raw little-endian f32 pixels, exactly
//!     `4*C*H*W` bytes — no JSON parse, decoded into a pooled buffer
//!   - `application/x-bmx-packed`: pre-packed sign bits, LSB-first
//!     (`(C*H*W+7)/8` bytes; bit set → +1.0, clear → −1.0; padding bits
//!     must be zero)
//! * `GET /v1/models` — available + resident models, per-model GEMM
//!   dispatch, and the process `force_scalar` state.
//! * `GET /v1/models/{name}/profile?batch=N&reps=R` — per-layer wall
//!   time / bytes / dispatch labels from a synthetic profiled forward
//!   (runs inline on the event-loop worker; it is a debug endpoint).
//! * `GET /v1/debug/trace?n=K` — the K most recent request traces from
//!   the lock-free journal (stage offsets in µs from request start).
//! * `GET /metrics` — Prometheus-style text (see [`super::prom`]),
//!   including the reactor's connection gauges and loop histograms.
//! * `GET /healthz` — liveness.
//!
//! Every classify request carries a [`Trace`]: the reactor stamps
//! read/respond/write, this module stamps parse/admission, the pool
//! batcher contributes queue_wait/batch_window/forward via
//! [`crate::coordinator::Response`] timing, and the completed record is
//! published when the response bytes finish flushing ([`Obs::complete`]).
//!
//! Limits: bodies over [`MAX_BODY`] and heads over [`MAX_HEAD`] are
//! rejected, chunked transfer encoding is not supported (400), past
//! `max_conns` open connections the acceptor sheds with an immediate
//! 503, and slow clients hit the timer-wheel idle/request timeouts.

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::bufpool::FloatPool;
use super::prom;
use super::reactor::{self, ReactorStats};
use super::registry::ModelRegistry;
use crate::coordinator::ImageBuf;
use crate::model::json;
use crate::obs::{trace, Obs, Stage, Trace};
use crate::serve::pool::PendingResponse;

/// Request body cap (a 3×32×32 image in long-form JSON is ~40 kB).
pub const MAX_BODY: usize = 8 << 20;

/// Cap on one request-line or header line — without it a client
/// streaming newline-free bytes would grow the line buffer unboundedly.
pub const MAX_LINE: usize = 8 << 10;

/// Cap on the whole head (request line + headers). A connection that
/// buffers this much without a blank line is answered 400 and closed.
pub const MAX_HEAD: usize = 16 << 10;

/// Default cap on concurrently open connections ("bounded everything":
/// past this, new connections get an immediate 503, not an fd).
pub const MAX_CONNECTIONS: usize = 256;

/// Default reap deadline for idle keep-alive connections. The old
/// thread-per-connection gateway's 200 ms "idle timeout" was a stop-flag
/// poll interval, not a reaping deadline — idle connections lived until
/// shutdown. Now that idleness actually closes connections, the default
/// is a conventional keep-alive horizon instead.
pub const DEFAULT_IDLE_TIMEOUT: Duration = Duration::from_secs(30);

/// Default budget for one whole request (first byte → response flushed,
/// excluding the batcher wait). Carried over from the old gateway's
/// per-request read timeout.
pub const DEFAULT_REQUEST_TIMEOUT: Duration = Duration::from_secs(10);

/// Decoded image tensors kept pooled for reuse across requests.
const FLOAT_POOL_CAP: usize = 1024;

/// Reactor sizing + timeout knobs (`cmd_serve` flags `--max-conns`,
/// `--idle-timeout-ms`, `--request-timeout-ms`).
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Event-loop worker threads; 0 = `min(available_parallelism, 4)`.
    pub io_workers: usize,
    /// Open-connection cap; the acceptor sheds with 503 past it.
    pub max_conns: usize,
    /// Reap a keep-alive connection idle this long between requests.
    pub idle_timeout: Duration,
    /// Budget for one request: covers reading it (408 on expiry) and
    /// writing the response (silent close). The batcher wait between the
    /// two is not counted — the bounded queue guarantees an answer.
    pub request_timeout: Duration,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            io_workers: 0,
            max_conns: MAX_CONNECTIONS,
            idle_timeout: DEFAULT_IDLE_TIMEOUT,
            request_timeout: DEFAULT_REQUEST_TIMEOUT,
        }
    }
}

/// Shared state every event-loop worker routes against.
pub(crate) struct GatewayCtx {
    pub registry: Arc<ModelRegistry>,
    pub obs: Arc<Obs>,
    pub floats: FloatPool,
    pub stats: Arc<ReactorStats>,
}

/// A running gateway: acceptor + event-loop worker threads.
pub struct Gateway {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handles: Vec<JoinHandle<()>>,
    stats: Arc<ReactorStats>,
}

impl Gateway {
    /// Bind and start serving with default reactor sizing.  `addr` is
    /// `host:port`; port 0 picks an ephemeral port — read the real one
    /// back from [`Gateway::addr`].  Observability state (journal, stage
    /// histograms, slow-request threshold) is built from the environment
    /// ([`Obs::from_env`]).
    pub fn start(registry: Arc<ModelRegistry>, addr: &str) -> Result<Gateway> {
        Self::start_with(registry, addr, GatewayConfig::default())
    }

    /// [`Gateway::start`] with explicit reactor sizing and timeouts.
    pub fn start_with(
        registry: Arc<ModelRegistry>,
        addr: &str,
        cfg: GatewayConfig,
    ) -> Result<Gateway> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        let workers = if cfg.io_workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4)
        } else {
            cfg.io_workers
        };
        let stats = Arc::new(ReactorStats::new(workers));
        let ctx = Arc::new(GatewayCtx {
            registry,
            obs: Arc::new(Obs::from_env()),
            floats: FloatPool::new(FLOAT_POOL_CAP),
            stats: stats.clone(),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let handles = reactor::spawn(listener, ctx, cfg, stop.clone())?;
        Ok(Gateway { addr: local, stop, handles, stats })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live reactor gauges (also on `/metrics`).
    pub fn stats(&self) -> &ReactorStats {
        &self.stats
    }

    /// Stop accepting, wake the listener, join every reactor thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if self.handles.is_empty() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // wake the blocking accept() with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Classify body encodings, selected by `content-type`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum BodyFormat {
    /// `{"image": [f32; C*H*W]}` (default for absent/other content types).
    Json,
    /// Raw little-endian f32 pixels (`application/x-bmx-f32`).
    F32,
    /// LSB-first packed sign bits (`application/x-bmx-packed`).
    Packed,
}

/// One parsed request head; body bytes follow at `head_len`.
#[derive(Debug)]
pub(crate) struct HeadInfo {
    pub method: String,
    /// Path with any query string stripped.
    pub path: String,
    /// Raw query string (after `?`, empty when absent).
    pub query: String,
    pub content_length: usize,
    pub format: BodyFormat,
    pub keep_alive: bool,
    /// Byte offset where the body starts (end of the blank line).
    pub head_len: usize,
}

/// Incremental head-parse result over the bytes buffered so far.
pub(crate) enum HeadParse {
    /// No blank line yet — read more (caller enforces [`MAX_HEAD`]).
    Incomplete,
    /// Malformed or unsupported HTTP — answer 400, close.
    Bad(String),
    Parsed(HeadInfo),
}

/// Find the end of the head: the byte offset just past the first blank
/// line (`\r\n\r\n` or `\n\n`).
fn find_head_end(buf: &[u8]) -> Option<usize> {
    let mut i = 0;
    while i < buf.len() {
        if buf[i] == b'\n' {
            match (buf.get(i + 1), buf.get(i + 2)) {
                (Some(b'\n'), _) => return Some(i + 2),
                (Some(b'\r'), Some(b'\n')) => return Some(i + 3),
                _ => {}
            }
        }
        i += 1;
    }
    None
}

/// Parse a complete head out of the buffered bytes, if one is there.
pub(crate) fn parse_head(buf: &[u8]) -> HeadParse {
    let Some(head_len) = find_head_end(buf) else {
        return HeadParse::Incomplete;
    };
    let Ok(text) = std::str::from_utf8(&buf[..head_len]) else {
        return HeadParse::Bad("head is not valid UTF-8".to_string());
    };
    let mut lines = text.split('\n').map(|l| l.strip_suffix('\r').unwrap_or(l));
    let req_line = lines.next().unwrap_or("");
    if req_line.len() > MAX_LINE {
        return HeadParse::Bad("request line too long".to_string());
    }
    let mut parts = req_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_ascii_uppercase();
    let target = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("HTTP/1.1").to_string();
    if method.is_empty() || target.is_empty() {
        return HeadParse::Bad(format!("malformed request line {req_line:?}"));
    }
    let mut headers: BTreeMap<String, String> = BTreeMap::new();
    for h in lines {
        if h.is_empty() {
            break;
        }
        if h.len() > MAX_LINE {
            return HeadParse::Bad("header line too long".to_string());
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
        if headers.len() > 100 {
            return HeadParse::Bad("too many headers".to_string());
        }
    }
    if headers
        .get("transfer-encoding")
        .is_some_and(|v| !v.eq_ignore_ascii_case("identity"))
    {
        return HeadParse::Bad("chunked transfer encoding not supported".to_string());
    }
    let content_length: usize = match headers.get("content-length") {
        None => 0,
        Some(v) => match v.parse() {
            Ok(n) => n,
            Err(_) => return HeadParse::Bad(format!("bad content-length {v:?}")),
        },
    };
    if content_length > MAX_BODY {
        return HeadParse::Bad(format!("body of {content_length} bytes exceeds cap {MAX_BODY}"));
    }
    let format = match headers.get("content-type") {
        Some(ct) => {
            let ct = ct.split(';').next().unwrap_or("").trim().to_ascii_lowercase();
            match ct.as_str() {
                "application/x-bmx-f32" => BodyFormat::F32,
                "application/x-bmx-packed" => BodyFormat::Packed,
                _ => BodyFormat::Json,
            }
        }
        None => BodyFormat::Json,
    };
    let http10 = version.eq_ignore_ascii_case("HTTP/1.0");
    let keep_alive = match headers.get("connection").map(|s| s.to_ascii_lowercase()).as_deref() {
        Some("close") => false,
        Some("keep-alive") => true,
        _ => !http10,
    };
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    HeadParse::Parsed(HeadInfo {
        method,
        path,
        query,
        content_length,
        format,
        keep_alive,
        head_len,
    })
}

pub(crate) struct HttpResponse {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    pub retry_after: bool,
}

impl HttpResponse {
    pub fn json(status: u16, body: String) -> Self {
        Self {
            status,
            content_type: "application/json",
            body: body.into_bytes(),
            retry_after: false,
        }
    }

    pub fn text(status: u16, body: String) -> Self {
        Self {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into_bytes(),
            retry_after: false,
        }
    }

    pub fn error(status: u16, msg: &str) -> Self {
        Self::json(status, format!("{{\"error\": {}}}", json_string(msg)))
    }
}

fn status_reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Error",
    }
}

/// Serialize a response into `out` (appended; caller clears). The
/// reactor flushes these bytes incrementally from its Write state.
pub(crate) fn render_response(r: &HttpResponse, keep_alive: bool, out: &mut Vec<u8>) {
    let head = format!(
        "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: {}\r\n",
        r.status,
        status_reason(r.status),
        r.content_type,
        r.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    out.extend_from_slice(head.as_bytes());
    if r.retry_after {
        out.extend_from_slice(b"retry-after: 1\r\n");
    }
    out.extend_from_slice(b"\r\n");
    out.extend_from_slice(&r.body);
}

/// Serialize a string as a JSON string literal (quotes included).
fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

const CLASSIFY_PREFIX: &str = "/v1/models/";
const CLASSIFY_SUFFIX: &str = ":classify";
const PROFILE_SUFFIX: &str = "/profile";

/// First `key=` value in a query string, parsed as usize.
fn query_usize(query: &str, key: &str) -> Option<usize> {
    query
        .split('&')
        .find_map(|kv| kv.strip_prefix(key).and_then(|v| v.strip_prefix('=')))
        .and_then(|v| v.parse().ok())
}

/// An accepted classify waiting on its pool shard: the reactor's
/// Dispatch state polls `pending` each pass.
pub(crate) struct ClassifyTail {
    pub pending: PendingResponse,
    pub name: String,
}

/// How one routed request resolves.
pub(crate) enum RouteOutcome {
    /// Non-classify route: respond, no trace publish.
    Plain(HttpResponse),
    /// Classify route that resolved synchronously (bad body, unknown
    /// model, 429 …): respond AND publish the trace with this metadata.
    ClassifyDone { resp: HttpResponse, name: String, shard: u16, batch: u16 },
    /// Classify accepted into a shard; poll the tail for the answer.
    ClassifyPending(ClassifyTail),
}

/// Route one complete request. Synchronous routes return `Plain`;
/// classify stamps parse/admission on `trace` and may go async.
pub(crate) fn route_begin(
    ctx: &GatewayCtx,
    head: &HeadInfo,
    body: &[u8],
    trace: &mut Trace,
) -> RouteOutcome {
    match (head.method.as_str(), head.path.as_str()) {
        ("GET", "/v1/models") => RouteOutcome::Plain(list_models(&ctx.registry)),
        ("GET", "/v1/debug/trace") => RouteOutcome::Plain(debug_trace(&ctx.obs, &head.query)),
        ("GET", "/metrics") => RouteOutcome::Plain(HttpResponse::text(
            200,
            prom::render(&ctx.registry, &ctx.obs, &ctx.stats),
        )),
        ("GET", "/healthz") => {
            RouteOutcome::Plain(HttpResponse::json(200, "{\"status\": \"ok\"}".to_string()))
        }
        ("POST", path)
            if path.starts_with(CLASSIFY_PREFIX) && path.ends_with(CLASSIFY_SUFFIX) =>
        {
            let name = &path[CLASSIFY_PREFIX.len()..path.len() - CLASSIFY_SUFFIX.len()];
            classify_begin(ctx, name, head.format, body, trace)
        }
        ("GET", path)
            if path.starts_with(CLASSIFY_PREFIX)
                && path.ends_with(PROFILE_SUFFIX)
                && path.len() > CLASSIFY_PREFIX.len() + PROFILE_SUFFIX.len() =>
        {
            let name = &path[CLASSIFY_PREFIX.len()..path.len() - PROFILE_SUFFIX.len()];
            RouteOutcome::Plain(model_profile(&ctx.registry, name, &head.query))
        }
        ("GET" | "POST", _) => RouteOutcome::Plain(HttpResponse::error(
            404,
            &format!("no route for {} {}", head.method, head.path),
        )),
        _ => RouteOutcome::Plain(HttpResponse::error(
            405,
            &format!("method {} not allowed", head.method),
        )),
    }
}

fn list_models(registry: &ModelRegistry) -> HttpResponse {
    let items: Vec<String> = registry
        .list()
        .iter()
        .map(|m| {
            let dispatch = match &m.dispatch {
                Some(d) => json_string(d),
                None => "null".to_string(),
            };
            format!(
                "{{\"name\": {}, \"source\": {}, \"loaded\": {}, \"resident_bytes\": {}, \
                 \"dispatch\": {}}}",
                json_string(&m.name),
                json_string(m.source),
                m.loaded,
                m.resident_bytes,
                dispatch,
            )
        })
        .collect();
    let prov = crate::bench::Provenance::capture("bmxnet serve");
    HttpResponse::json(
        200,
        format!(
            "{{\"models\": [{}], \"gemm_dispatch\": {}, \"force_scalar\": {}, \
             \"build_info\": {{\"version\": {}, \"git\": {}, \"rustc\": {}, \
             \"features\": {}, \"force_scalar\": {}}}}}",
            items.join(", "),
            json_string(&format!(
                "method {} · kernel {}",
                crate::gemm::Method::auto().label(),
                crate::gemm::simd::best_kernel().label()
            )),
            crate::gemm::simd::force_scalar(),
            json_string(&prov.version),
            json_string(&prov.git),
            json_string(&prov.rustc),
            json_string(&prov.features),
            prov.force_scalar,
        ),
    )
}

/// `GET /v1/debug/trace?n=K` — newest-first traces from the journal.
fn debug_trace(obs: &Obs, query: &str) -> HttpResponse {
    let n = query_usize(query, "n").unwrap_or(16).min(obs.journal.capacity());
    let mut items = Vec::new();
    for rec in obs.journal.recent(n) {
        let mut stages = String::new();
        for s in Stage::all() {
            if rec.stages[s.index()] != trace::UNSET {
                if !stages.is_empty() {
                    stages.push_str(", ");
                }
                stages.push_str(&format!("\"{}\": {}", s.label(), rec.stages[s.index()]));
            }
        }
        items.push(format!(
            "{{\"id\": {}, \"model\": {}, \"status\": {}, \"shard\": {}, \"batch_size\": {}, \
             \"start_unix_us\": {}, \"total_us\": {}, \"stages_us\": {{{}}}}}",
            rec.id,
            json_string(rec.model()),
            rec.status,
            rec.shard,
            rec.batch,
            rec.start_unix_us,
            rec.total_us,
            stages,
        ));
    }
    HttpResponse::json(
        200,
        format!(
            "{{\"total\": {}, \"dropped\": {}, \"traces\": [{}]}}",
            obs.journal.total(),
            obs.journal.dropped(),
            items.join(", "),
        ),
    )
}

/// `GET /v1/models/{name}/profile?batch=N&reps=R` — profiled synthetic
/// forward through the resident engine (loads the model if needed).
fn model_profile(registry: &ModelRegistry, name: &str, query: &str) -> HttpResponse {
    let batch = query_usize(query, "batch").unwrap_or(1).clamp(1, 64);
    let reps = query_usize(query, "reps").unwrap_or(3).clamp(1, 100);
    let model = match registry.get(name) {
        Ok(m) => m,
        Err(e) => {
            let known = registry.list().iter().any(|m| m.name == name);
            let status = if known { 500 } else { 404 };
            return HttpResponse::error(status, &format!("model {name:?} unavailable: {e:#}"));
        }
    };
    match model.engine.profile(batch, reps) {
        Ok(mut report) => {
            report.model = name.to_string();
            HttpResponse::json(200, report.render_json())
        }
        Err(e) => HttpResponse::error(500, &format!("profile failed: {e:#}")),
    }
}

/// Shorthand for a classify that resolved before reaching a shard.
fn classify_done(resp: HttpResponse, name: &str) -> RouteOutcome {
    RouteOutcome::ClassifyDone { resp, name: name.to_string(), shard: 0, batch: 0 }
}

/// Decode the body per its content type, resolve the model, and submit
/// into the pool. JSON keeps the old stage order (parse → resolve →
/// length check); the binary formats need the model first to know the
/// expected length, so they resolve → decode.
fn classify_begin(
    ctx: &GatewayCtx,
    name: &str,
    format: BodyFormat,
    body: &[u8],
    trace: &mut Trace,
) -> RouteOutcome {
    let lookup = |name: &str| match ctx.registry.get(name) {
        Ok(m) => Ok(m),
        Err(e) => {
            // a name the registry could resolve but failed to load is a
            // server-side fault (500), not a client-side unknown (404)
            let known = ctx.registry.list().iter().any(|m| m.name == name);
            let status = if known { 500 } else { 404 };
            Err(HttpResponse::error(status, &format!("model {name:?} unavailable: {e:#}")))
        }
    };
    let (model, image): (_, ImageBuf) = match format {
        BodyFormat::Json => {
            let Ok(text) = std::str::from_utf8(body) else {
                return classify_done(HttpResponse::error(400, "body is not UTF-8"), name);
            };
            let parsed = match json::parse(text) {
                Ok(v) => v,
                Err(e) => {
                    return classify_done(
                        HttpResponse::error(400, &format!("bad JSON body: {e}")),
                        name,
                    )
                }
            };
            let Some(image_v) = parsed.get("image").and_then(|v| v.as_array()) else {
                return classify_done(
                    HttpResponse::error(400, "body must be {\"image\": [f32; C*H*W]}"),
                    name,
                );
            };
            let mut image = ctx.floats.checkout(image_v.len());
            for v in image_v {
                match v.as_f64() {
                    Some(f) => image.push(f as f32),
                    None => {
                        return classify_done(
                            HttpResponse::error(400, "\"image\" must contain only numbers"),
                            name,
                        )
                    }
                }
            }
            trace.mark(Stage::Parse);
            let model = match lookup(name) {
                Ok(m) => m,
                Err(resp) => return classify_done(resp, name),
            };
            if image.len() != model.pool.image_len() {
                return classify_done(
                    HttpResponse::error(
                        400,
                        &format!(
                            "model {name:?} expects {} floats, got {}",
                            model.pool.image_len(),
                            image.len()
                        ),
                    ),
                    name,
                );
            }
            (model, image)
        }
        BodyFormat::F32 => {
            let model = match lookup(name) {
                Ok(m) => m,
                Err(resp) => return classify_done(resp, name),
            };
            let expect = model.pool.image_len();
            if body.len() != expect * 4 {
                return classify_done(
                    HttpResponse::error(
                        400,
                        &format!(
                            "model {name:?} expects {} raw f32 bytes, got {}",
                            expect * 4,
                            body.len()
                        ),
                    ),
                    name,
                );
            }
            let mut image = ctx.floats.checkout(expect);
            for ch in body.chunks_exact(4) {
                image.push(f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
            }
            trace.mark(Stage::Parse);
            (model, image)
        }
        BodyFormat::Packed => {
            let model = match lookup(name) {
                Ok(m) => m,
                Err(resp) => return classify_done(resp, name),
            };
            let expect = model.pool.image_len();
            let nbytes = expect.div_ceil(8);
            if body.len() != nbytes {
                return classify_done(
                    HttpResponse::error(
                        400,
                        &format!(
                            "model {name:?} expects {nbytes} packed bytes ({expect} bits), got {}",
                            body.len()
                        ),
                    ),
                    name,
                );
            }
            if expect % 8 != 0 {
                let pad_mask = !0u8 << (expect % 8);
                if body[nbytes - 1] & pad_mask != 0 {
                    return classify_done(
                        HttpResponse::error(400, "packed padding bits must be zero"),
                        name,
                    );
                }
            }
            let mut image = ctx.floats.checkout(expect);
            for i in 0..expect {
                let bit = (body[i / 8] >> (i % 8)) & 1;
                image.push(if bit == 1 { 1.0 } else { -1.0 });
            }
            trace.mark(Stage::Parse);
            (model, image)
        }
    };
    match model.pool.submit(image) {
        Ok(pending) => {
            trace.mark(Stage::Admission);
            RouteOutcome::ClassifyPending(ClassifyTail { pending, name: name.to_string() })
        }
        Err(_) => {
            // every shard queue full: bounded-queue fast rejection
            let mut r = HttpResponse::error(429, &format!("model {name:?} at capacity, retry"));
            r.retry_after = true;
            classify_done(r, name)
        }
    }
}

/// Turn the batcher's answer into the classify response; absorbs the
/// batcher's timing into the trace. Returns `(response, shard, batch)`.
pub(crate) fn classify_finish(
    tail: &ClassifyTail,
    result: Result<crate::coordinator::Response>,
    trace: &mut Trace,
) -> (HttpResponse, u16, u16) {
    let shard = tail.pending.shard();
    match result {
        Ok(resp) => {
            trace.absorb_batch_timing(&resp.timing);
            (
                HttpResponse::json(
                    200,
                    format!(
                        "{{\"model\": {}, \"class\": {}, \"score\": {:.6}, \"latency_us\": {}, \
                         \"batch_size\": {}, \"shard\": {}}}",
                        json_string(&tail.name),
                        resp.class,
                        resp.score,
                        resp.latency.as_micros(),
                        resp.batch_size,
                        shard,
                    ),
                ),
                shard as u16,
                resp.batch_size as u16,
            )
        }
        Err(e) => (
            HttpResponse::error(500, &format!("engine dropped the request: {e:#}")),
            shard as u16,
            0,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parsed(raw: &str) -> HeadInfo {
        match parse_head(raw.as_bytes()) {
            HeadParse::Parsed(h) => h,
            HeadParse::Incomplete => panic!("incomplete: {raw:?}"),
            HeadParse::Bad(m) => panic!("bad ({m}): {raw:?}"),
        }
    }

    fn bad(raw: &str) -> String {
        match parse_head(raw.as_bytes()) {
            HeadParse::Bad(m) => m,
            HeadParse::Parsed(_) => panic!("parsed: {raw:?}"),
            HeadParse::Incomplete => panic!("incomplete: {raw:?}"),
        }
    }

    #[test]
    fn parses_get_with_keepalive_default() {
        let h = parsed("GET /v1/models HTTP/1.1\r\nhost: x\r\n\r\n");
        assert_eq!(h.method, "GET");
        assert_eq!(h.path, "/v1/models");
        assert!(h.keep_alive);
        assert_eq!(h.content_length, 0);
        assert_eq!(h.format, BodyFormat::Json);
    }

    #[test]
    fn parses_post_body_offsets_and_connection_close() {
        let raw = "POST /v1/models/m:classify HTTP/1.1\r\ncontent-length: 4\r\n\
                   connection: close\r\n\r\nabcd";
        let h = parsed(raw);
        assert_eq!(h.method, "POST");
        assert_eq!(h.content_length, 4);
        assert!(!h.keep_alive);
        assert_eq!(&raw.as_bytes()[h.head_len..h.head_len + h.content_length], b"abcd");
    }

    #[test]
    fn http10_defaults_to_close() {
        assert!(!parsed("GET / HTTP/1.0\r\n\r\n").keep_alive);
        assert!(parsed("GET / HTTP/1.0\r\nconnection: keep-alive\r\n\r\n").keep_alive);
    }

    #[test]
    fn query_string_is_stripped() {
        let h = parsed("GET /metrics?x=1 HTTP/1.1\r\n\r\n");
        assert_eq!(h.path, "/metrics");
        assert_eq!(h.query, "x=1");
        assert_eq!(parsed("GET /metrics HTTP/1.1\r\n\r\n").query, "");
    }

    #[test]
    fn content_type_selects_body_format() {
        let f = |ct: &str| {
            parsed(&format!("POST /x HTTP/1.1\r\ncontent-type: {ct}\r\n\r\n")).format
        };
        assert_eq!(f("application/json"), BodyFormat::Json);
        assert_eq!(f("application/x-bmx-f32"), BodyFormat::F32);
        assert_eq!(f("application/x-bmx-packed"), BodyFormat::Packed);
        assert_eq!(f("Application/X-BMX-F32"), BodyFormat::F32);
        assert_eq!(f("application/x-bmx-packed; charset=binary"), BodyFormat::Packed);
        assert_eq!(f("text/plain"), BodyFormat::Json);
    }

    #[test]
    fn incomplete_and_garbage_heads() {
        assert!(matches!(parse_head(b""), HeadParse::Incomplete));
        assert!(matches!(parse_head(b"GET / HTTP/1.1\r\nhost: x\r\n"), HeadParse::Incomplete));
        bad("\r\n\r\n");
        bad("GET / HTTP/1.1\r\ncontent-length: nope\r\n\r\n");
        bad("GET / HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n");
    }

    #[test]
    fn bare_lf_terminator_accepted() {
        let h = parsed("GET /healthz HTTP/1.1\nhost: x\n\n");
        assert_eq!(h.path, "/healthz");
    }

    #[test]
    fn oversized_body_rejected_at_parse() {
        let msg = bad(&format!("POST /x HTTP/1.1\r\ncontent-length: {}\r\n\r\n", MAX_BODY + 1));
        assert!(msg.contains("exceeds cap"), "{msg}");
    }

    #[test]
    fn overlong_lines_rejected() {
        bad(&format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_LINE)));
        bad(&format!("GET / HTTP/1.1\r\nx: {}\r\n\r\n", "b".repeat(MAX_LINE)));
        // a line just under the cap still parses
        parsed(&format!("GET / HTTP/1.1\r\nx: {}\r\n\r\n", "c".repeat(1024)));
    }

    #[test]
    fn pipelined_bytes_stay_after_head_len() {
        let raw = "GET /healthz HTTP/1.1\r\n\r\nGET /metrics HTTP/1.1\r\n\r\n";
        let h = parsed(raw);
        assert_eq!(h.path, "/healthz");
        let rest = &raw.as_bytes()[h.head_len + h.content_length..];
        assert!(rest.starts_with(b"GET /metrics"), "second request must remain unconsumed");
    }

    #[test]
    fn render_response_wire_format() {
        let mut out = Vec::new();
        render_response(&HttpResponse::json(200, "{}".to_string()), true, &mut out);
        let text = String::from_utf8(out).unwrap();
        assert_eq!(
            text,
            "HTTP/1.1 200 OK\r\ncontent-type: application/json\r\ncontent-length: 2\r\n\
             connection: keep-alive\r\n\r\n{}"
        );
        let mut out = Vec::new();
        let mut resp = HttpResponse::error(429, "busy");
        resp.retry_after = true;
        render_response(&resp, false, &mut out);
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("connection: close\r\n"), "{text}");
        assert!(text.contains("retry-after: 1\r\n"), "{text}");
    }

    #[test]
    fn query_usize_parses_first_match() {
        assert_eq!(query_usize("n=5", "n"), Some(5));
        assert_eq!(query_usize("batch=8&reps=2", "reps"), Some(2));
        assert_eq!(query_usize("nn=9", "n"), None);
        assert_eq!(query_usize("n=x", "n"), None);
        assert_eq!(query_usize("", "n"), None);
    }

    #[test]
    fn json_string_escapes() {
        assert_eq!(json_string("plain"), "\"plain\"");
        assert_eq!(json_string("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(json_string("\u{1}"), "\"\\u0001\"");
    }

    #[test]
    fn classify_path_name_extraction() {
        let path = "/v1/models/lenet_bin:classify";
        assert!(path.starts_with(CLASSIFY_PREFIX) && path.ends_with(CLASSIFY_SUFFIX));
        let name = &path[CLASSIFY_PREFIX.len()..path.len() - CLASSIFY_SUFFIX.len()];
        assert_eq!(name, "lenet_bin");
    }
}
