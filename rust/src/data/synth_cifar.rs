//! synth-CIFAR: procedural 3×32×32 10-class images (DESIGN.md
//! §Substitutions).  Classes are distinct shape/texture programs —
//! stripes at two angles, checkerboards, discs, rings, gradients, crosses,
//! dots, triangles, bars — drawn in jittered colors over noisy backgrounds.
//! ResNet-style models separate these well, and binarization costs a few
//! points of accuracy, matching CIFAR-10's role in Table 1.

use super::loader::Dataset;
use super::rng::Rng;

pub const SIZE: usize = 32;
pub const CHANNELS: usize = 3;

/// Paint one 3×32×32 image of class `cls` (0..10).
pub fn render(cls: usize, rng: &mut Rng) -> Vec<f32> {
    assert!(cls < 10);
    let mut img = vec![0.0f32; CHANNELS * SIZE * SIZE];
    // jittered foreground/background colors
    let fg: [f32; 3] = [rng.range(0.5, 1.0), rng.range(0.1, 0.9), rng.range(0.1, 0.9)];
    let bg: [f32; 3] = [-fg[0] * 0.6, rng.range(-0.5, 0.1), rng.range(-0.5, 0.1)];
    let phase = rng.range(0.0, 8.0);
    let freq = rng.range(0.5, 0.9);
    let cx = rng.range(12.0, 20.0);
    let cy = rng.range(12.0, 20.0);
    let r = rng.range(6.0, 11.0);

    for y in 0..SIZE {
        for x in 0..SIZE {
            let (xf, yf) = (x as f32, y as f32);
            let on = match cls {
                0 => ((xf * freq + phase) as i32) % 2 == 0,                 // v-stripes
                1 => ((yf * freq + phase) as i32) % 2 == 0,                 // h-stripes
                2 => (((xf + yf) * freq * 0.7 + phase) as i32) % 2 == 0,    // diagonal
                3 => ((xf * 0.5) as i32 + (yf * 0.5) as i32) % 2 == 0,      // checker
                4 => (xf - cx).hypot(yf - cy) < r,                          // disc
                5 => {
                    let d = (xf - cx).hypot(yf - cy);                       // ring
                    d > r * 0.55 && d < r
                }
                6 => (xf - cx).abs() < 2.5 || (yf - cy).abs() < 2.5,        // cross
                7 => (xf % 6.0 < 2.0) && (yf % 6.0 < 2.0),                  // dots
                8 => yf - cy > (xf - cx).abs() - r * 0.8,                   // triangle-ish
                _ => (yf > cy - 3.0) && (yf < cy + 3.0),                    // h-bar
            };
            let color = if on { fg } else { bg };
            for (ch, &base) in color.iter().enumerate() {
                img[(ch * SIZE + y) * SIZE + x] = base;
            }
        }
    }
    for p in &mut img {
        *p += 0.10 * rng.normal();
        *p = p.clamp(-2.0, 2.0);
    }
    img
}

/// Generate n labelled images.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xC1FA);
    let mut images = Vec::with_capacity(n * CHANNELS * SIZE * SIZE);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let cls = rng.below(10);
        let mut img_rng = rng.fork(i as u64);
        images.extend(render(cls, &mut img_rng));
        labels.push(cls as i32);
    }
    Dataset { images, labels, shape: [CHANNELS, SIZE, SIZE], classes: 10 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_differ_in_texture() {
        let mut rng = Rng::new(5);
        let imgs: Vec<Vec<f32>> = (0..10).map(|c| render(c, &mut rng)).collect();
        for a in 0..10 {
            for b in (a + 1)..10 {
                let d: f32 = imgs[a]
                    .iter()
                    .zip(&imgs[b])
                    .map(|(x, y)| (x - y).abs())
                    .sum::<f32>()
                    / imgs[a].len() as f32;
                assert!(d > 0.05, "classes {a}/{b} mean abs diff {d}");
            }
        }
    }

    #[test]
    fn three_channels() {
        let ds = generate(3, 1);
        assert_eq!(ds.shape, [3, 32, 32]);
        assert_eq!(ds.images.len(), 3 * 3 * 32 * 32);
    }

    #[test]
    fn foreground_brighter_in_red() {
        // class 4 (disc): center red channel should exceed corner red
        let mut rng = Rng::new(9);
        let mut center = 0.0;
        let mut corner = 0.0;
        for _ in 0..20 {
            let img = render(4, &mut rng);
            center += img[16 * SIZE + 16];
            corner += img[1 * SIZE + 1];
        }
        assert!(center > corner, "disc not visible: {center} vs {corner}");
    }
}
