//! Synthetic dataset substrates.
//!
//! The paper evaluates on MNIST, CIFAR-10 and ImageNet; none are available
//! in this environment, so each is replaced by a *procedural* generator
//! that preserves the tensor shapes, class counts and "learnable but not
//! trivial" character of the original (DESIGN.md §Substitutions):
//!
//! * [`synth_digits`] — 1×28×28, 10 classes: bitmap digit glyphs with
//!   random placement, scale jitter and Gaussian noise (MNIST stand-in).
//! * [`synth_cifar`] — 3×32×32, 10 classes: procedural shape/texture
//!   classes with color jitter (CIFAR-10 stand-in).
//! * [`synth_imagenet`] — 3×32×32, 100 classes: shape × palette product
//!   classes (ImageNet stand-in for the Table 2 partial-binarization sweep).
//!
//! All generators are pure functions of a seed: the training orchestrator,
//! tests and the Python side can regenerate identical data.

pub mod loader;
pub mod rng;
pub mod synth_cifar;
pub mod synth_digits;
pub mod synth_imagenet;

pub use loader::{Batch, Dataset};
pub use rng::Rng;

/// Which generator to use (CLI-facing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Digits,
    Cifar,
    Imagenet,
}

impl Kind {
    pub fn from_name(s: &str) -> Option<Kind> {
        match s {
            "digits" | "mnist" => Some(Kind::Digits),
            "cifar" | "cifar10" => Some(Kind::Cifar),
            "imagenet" | "img" => Some(Kind::Imagenet),
            _ => None,
        }
    }

    pub fn generate(&self, n: usize, seed: u64) -> Dataset {
        match self {
            Kind::Digits => synth_digits::generate(n, seed),
            Kind::Cifar => synth_cifar::generate(n, seed),
            Kind::Imagenet => synth_imagenet::generate(n, seed),
        }
    }

    pub fn classes(&self) -> usize {
        match self {
            Kind::Digits | Kind::Cifar => 10,
            Kind::Imagenet => 100,
        }
    }

    pub fn input_shape(&self) -> [usize; 3] {
        match self {
            Kind::Digits => [1, 28, 28],
            Kind::Cifar | Kind::Imagenet => [3, 32, 32],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_from_name() {
        assert_eq!(Kind::from_name("mnist"), Some(Kind::Digits));
        assert_eq!(Kind::from_name("cifar10"), Some(Kind::Cifar));
        assert_eq!(Kind::from_name("imagenet"), Some(Kind::Imagenet));
        assert_eq!(Kind::from_name("svhn"), None);
    }

    #[test]
    fn generators_deterministic() {
        for kind in [Kind::Digits, Kind::Cifar, Kind::Imagenet] {
            let a = kind.generate(8, 123);
            let b = kind.generate(8, 123);
            assert_eq!(a.images, b.images, "{kind:?} not deterministic");
            assert_eq!(a.labels, b.labels);
            let c = kind.generate(8, 124);
            assert_ne!(a.images, c.images, "{kind:?} ignores seed");
        }
    }

    #[test]
    fn shapes_and_label_ranges() {
        for kind in [Kind::Digits, Kind::Cifar, Kind::Imagenet] {
            let ds = kind.generate(16, 7);
            let [c, h, w] = kind.input_shape();
            assert_eq!(ds.images.len(), 16 * c * h * w);
            assert!(ds.labels.iter().all(|&l| (l as usize) < kind.classes()));
            // a healthy majority of classes appears in a big enough sample
            let big = kind.generate(kind.classes() * 8, 9);
            let mut seen = vec![false; kind.classes()];
            for &l in &big.labels {
                seen[l as usize] = true;
            }
            assert!(seen.iter().filter(|&&s| s).count() > kind.classes() / 2);
        }
    }

    #[test]
    fn pixel_range_normalized() {
        for kind in [Kind::Digits, Kind::Cifar, Kind::Imagenet] {
            let ds = kind.generate(4, 5);
            for &p in &ds.images {
                assert!((-3.0..=3.0).contains(&p), "{kind:?} pixel {p} out of range");
            }
        }
    }
}
