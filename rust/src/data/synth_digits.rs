//! synth-MNIST: procedural 28×28 digit images (DESIGN.md §Substitutions).
//!
//! Each image renders a 5×7 bitmap glyph of its class digit, scaled ×3 with
//! bilinear-ish soft edges, placed at a jittered offset, with per-image
//! contrast jitter and additive Gaussian noise.  The task is learnable to
//! high accuracy by LeNet yet non-trivial under binarization — matching the
//! role MNIST plays in Table 1.

use super::loader::Dataset;
use super::rng::Rng;

pub const SIZE: usize = 28;

/// Classic 5×7 digit font, one row per digit, bit 4..0 = leftmost..rightmost.
const FONT: [[u8; 7]; 10] = [
    [0x0E, 0x11, 0x13, 0x15, 0x19, 0x11, 0x0E], // 0
    [0x04, 0x0C, 0x04, 0x04, 0x04, 0x04, 0x0E], // 1
    [0x0E, 0x11, 0x01, 0x02, 0x04, 0x08, 0x1F], // 2
    [0x1F, 0x02, 0x04, 0x02, 0x01, 0x11, 0x0E], // 3
    [0x02, 0x06, 0x0A, 0x12, 0x1F, 0x02, 0x02], // 4
    [0x1F, 0x10, 0x1E, 0x01, 0x01, 0x11, 0x0E], // 5
    [0x06, 0x08, 0x10, 0x1E, 0x11, 0x11, 0x0E], // 6
    [0x1F, 0x01, 0x02, 0x04, 0x08, 0x08, 0x08], // 7
    [0x0E, 0x11, 0x11, 0x0E, 0x11, 0x11, 0x0E], // 8
    [0x0E, 0x11, 0x11, 0x0F, 0x01, 0x02, 0x0C], // 9
];

/// Render one digit into a 28×28 buffer (values roughly in [-1, 2]).
pub fn render(digit: usize, rng: &mut Rng) -> Vec<f32> {
    assert!(digit < 10);
    let mut img = vec![0.0f32; SIZE * SIZE];
    let scale = rng.range(2.4, 3.4); // glyph cell size in pixels
    let glyph_w = 5.0 * scale;
    let glyph_h = 7.0 * scale;
    let ox = rng.range(1.0, (SIZE as f32 - glyph_w - 1.0).max(1.5));
    let oy = rng.range(1.0, (SIZE as f32 - glyph_h - 1.0).max(1.5));
    let ink = rng.range(0.8, 1.2);

    for y in 0..SIZE {
        for x in 0..SIZE {
            // map pixel center into glyph cell space
            let gx = (x as f32 + 0.5 - ox) / scale;
            let gy = (y as f32 + 0.5 - oy) / scale;
            if gx < 0.0 || gy < 0.0 || gx >= 5.0 || gy >= 7.0 {
                continue;
            }
            let (cx, cy) = (gx as usize, gy as usize);
            if (FONT[digit][cy] >> (4 - cx)) & 1 == 1 {
                // soft edge: fade near the cell border
                let fx = (gx - cx as f32 - 0.5).abs() * 2.0;
                let fy = (gy - cy as f32 - 0.5).abs() * 2.0;
                let soft = (1.0 - 0.3 * fx.max(fy)).max(0.0);
                img[y * SIZE + x] = ink * soft;
            }
        }
    }
    // additive noise + normalization to roughly zero-mean
    for p in &mut img {
        *p += 0.08 * rng.normal();
        *p = (*p - 0.13).clamp(-1.0, 2.0);
    }
    img
}

/// Generate n labelled images.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut images = Vec::with_capacity(n * SIZE * SIZE);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let digit = rng.below(10);
        let mut img_rng = rng.fork(i as u64);
        images.extend(render(digit, &mut img_rng));
        labels.push(digit as i32);
    }
    Dataset { images, labels, shape: [1, SIZE, SIZE], classes: 10 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glyphs_distinguishable() {
        // mean per-pixel ink differs across digits -> classes separable
        let mut rng = Rng::new(1);
        let imgs: Vec<Vec<f32>> = (0..10).map(|d| render(d, &mut rng)).collect();
        for a in 0..10 {
            for b in (a + 1)..10 {
                let d: f32 = imgs[a]
                    .iter()
                    .zip(&imgs[b])
                    .map(|(x, y)| (x - y).abs())
                    .sum();
                assert!(d > 5.0, "digits {a} and {b} nearly identical ({d})");
            }
        }
    }

    #[test]
    fn ink_present() {
        let mut rng = Rng::new(2);
        for d in 0..10 {
            let img = render(d, &mut rng);
            let ink = img.iter().filter(|&&p| p > 0.3).count();
            assert!(ink > 20, "digit {d} has only {ink} ink pixels");
        }
    }

    #[test]
    fn generate_counts() {
        let ds = generate(25, 3);
        assert_eq!(ds.len(), 25);
        assert_eq!(ds.images.len(), 25 * 28 * 28);
    }

    #[test]
    fn same_class_images_vary() {
        let ds = generate(200, 4);
        let first_of = |cls: i32| {
            ds.labels
                .iter()
                .enumerate()
                .filter(|(_, &l)| l == cls)
                .map(|(i, _)| i)
                .collect::<Vec<_>>()
        };
        let zeros = first_of(0);
        assert!(zeros.len() >= 2);
        assert_ne!(ds.image(zeros[0]), ds.image(zeros[1]), "no intra-class variation");
    }
}
