//! synth-ImageNet: 100-class 3×32×32 images for the Table 2 sweep
//! (DESIGN.md §Substitutions).
//!
//! Classes are a product code: class = 10 * shape + palette, where `shape`
//! reuses the 10 synth-CIFAR texture programs and `palette` selects one of
//! 10 distinct hue pairs.  Discriminating the full 100 classes requires
//! *both* texture and color features — coarse features that survive
//! binarization and finer color balance that benefits from full-precision
//! early stages, which is exactly the accuracy gradient Table 2 probes.

use super::loader::Dataset;
use super::rng::Rng;
use super::synth_cifar;

pub const SIZE: usize = 32;
pub const CHANNELS: usize = 3;
pub const CLASSES: usize = 100;

/// 10 palette (foreground hue) programs, index = class % 10.
fn palette(p: usize, rng: &mut Rng) -> ([f32; 3], [f32; 3]) {
    let j = |rng: &mut Rng| rng.range(-0.08, 0.08);
    let base: [[f32; 3]; 10] = [
        [1.0, 0.1, 0.1],
        [0.1, 1.0, 0.1],
        [0.1, 0.1, 1.0],
        [1.0, 1.0, 0.1],
        [1.0, 0.1, 1.0],
        [0.1, 1.0, 1.0],
        [0.9, 0.5, 0.1],
        [0.5, 0.1, 0.9],
        [0.7, 0.7, 0.7],
        [0.3, 0.9, 0.5],
    ];
    let fg = [
        base[p][0] + j(rng),
        base[p][1] + j(rng),
        base[p][2] + j(rng),
    ];
    let bg = [-fg[0] * 0.5 + j(rng), -fg[1] * 0.5 + j(rng), -fg[2] * 0.5 + j(rng)];
    (fg, bg)
}

/// Paint one image of class `cls` (0..100).
pub fn render(cls: usize, rng: &mut Rng) -> Vec<f32> {
    assert!(cls < CLASSES);
    let shape_cls = cls / 10;
    let pal_cls = cls % 10;
    // Render the shape program in grayscale via synth_cifar, then recolor.
    let proto = synth_cifar::render(shape_cls, rng);
    let (fg, bg) = palette(pal_cls, rng);
    let hw = SIZE * SIZE;
    let mut img = vec![0.0f32; CHANNELS * hw];
    for i in 0..hw {
        // proto red channel carries the shape mask polarity
        let mask = if proto[i] > 0.0 { 1.0 } else { 0.0 };
        for ch in 0..CHANNELS {
            let v = mask * fg[ch] + (1.0 - mask) * bg[ch];
            img[ch * hw + i] = v + 0.08 * rng.normal();
        }
    }
    img
}

/// Generate n labelled images.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x1A6E7);
    let mut images = Vec::with_capacity(n * CHANNELS * SIZE * SIZE);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let cls = rng.below(CLASSES);
        let mut img_rng = rng.fork(i as u64);
        images.extend(render(cls, &mut img_rng));
        labels.push(cls as i32);
    }
    Dataset { images, labels, shape: [CHANNELS, SIZE, SIZE], classes: CLASSES }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hundred_classes() {
        let ds = generate(4, 1);
        assert_eq!(ds.classes, 100);
    }

    #[test]
    fn same_shape_different_palette_differ() {
        let mut r1 = Rng::new(2);
        let mut r2 = Rng::new(2);
        let a = render(10, &mut r1); // shape 1, palette 0
        let b = render(13, &mut r2); // shape 1, palette 3
        let d: f32 =
            a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32;
        assert!(d > 0.05, "palettes indistinguishable: {d}");
    }

    #[test]
    fn same_palette_different_shape_differ() {
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let a = render(5, &mut r1); // shape 0, palette 5
        let b = render(45, &mut r2); // shape 4, palette 5
        let d: f32 =
            a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32;
        assert!(d > 0.05, "shapes indistinguishable: {d}");
    }
}
