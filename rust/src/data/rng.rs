//! Deterministic PRNG (splitmix64) — the repo is rand-free by design
//! (offline environment), and a fixed, documented generator keeps data
//! generation reproducible across Rust and test code.

/// splitmix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Derive an independent stream (for per-image generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(4);
        let mean: f32 = (0..50_000).map(|_| r.uniform()).sum::<f32>() / 50_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(6);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fork_streams_independent() {
        let mut r = Rng::new(7);
        let mut f1 = r.fork(1);
        let mut f2 = r.fork(1); // same tag, later state -> different stream
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
