//! Dataset container + batching/shuffling — the input pipeline feeding both
//! the PJRT training orchestrator and the Rust inference engine.

use super::rng::Rng;

/// In-memory dataset: NCHW images + integer labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Flattened images, n * c * h * w.
    pub images: Vec<f32>,
    /// One label per image, in [0, classes).
    pub labels: Vec<i32>,
    /// Per-image shape [c, h, w].
    pub shape: [usize; 3],
    /// Number of classes.
    pub classes: usize,
}

/// One minibatch view (owned copies — batches cross thread boundaries).
#[derive(Debug, Clone)]
pub struct Batch {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub batch: usize,
    pub shape: [usize; 3],
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    fn image_elems(&self) -> usize {
        self.shape.iter().product()
    }

    /// Copy out one image as a flat slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let e = self.image_elems();
        &self.images[i * e..(i + 1) * e]
    }

    /// Split into (train, test) by a test fraction; deterministic order.
    pub fn split(self, test_fraction: f32) -> (Dataset, Dataset) {
        let n_test = ((self.len() as f32) * test_fraction).round() as usize;
        let n_train = self.len() - n_test;
        let e = self.image_elems();
        let train = Dataset {
            images: self.images[..n_train * e].to_vec(),
            labels: self.labels[..n_train].to_vec(),
            shape: self.shape,
            classes: self.classes,
        };
        let test = Dataset {
            images: self.images[n_train * e..].to_vec(),
            labels: self.labels[n_train..].to_vec(),
            shape: self.shape,
            classes: self.classes,
        };
        (train, test)
    }

    /// Assemble a batch from explicit indices (wrapping around the end).
    pub fn gather(&self, indices: &[usize]) -> Batch {
        let e = self.image_elems();
        let mut images = Vec::with_capacity(indices.len() * e);
        let mut labels = Vec::with_capacity(indices.len());
        for &ix in indices {
            let i = ix % self.len();
            images.extend_from_slice(self.image(i));
            labels.push(self.labels[i]);
        }
        Batch { images, labels, batch: indices.len(), shape: self.shape }
    }

    /// Epoch iterator with Fisher-Yates shuffling; final short batch is
    /// wrapped to full size (PJRT executables have a fixed batch dim).
    pub fn epoch(&self, batch: usize, seed: u64) -> Vec<Batch> {
        let mut order: Vec<usize> = (0..self.len()).collect();
        let mut rng = Rng::new(seed);
        for i in (1..order.len()).rev() {
            order.swap(i, rng.below(i + 1));
        }
        order
            .chunks(batch)
            .map(|chunk| {
                let mut idx = chunk.to_vec();
                // wrap to full batch size for fixed-shape executables
                let mut fill = 0;
                while idx.len() < batch {
                    idx.push(order[fill % order.len()]);
                    fill += 1;
                }
                self.gather(&idx)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(n: usize) -> Dataset {
        Dataset {
            images: (0..n * 4).map(|i| i as f32).collect(),
            labels: (0..n).map(|i| (i % 3) as i32).collect(),
            shape: [1, 2, 2],
            classes: 3,
        }
    }

    #[test]
    fn split_fractions() {
        let (tr, te) = tiny(10).split(0.2);
        assert_eq!(tr.len(), 8);
        assert_eq!(te.len(), 2);
        assert_eq!(tr.image(0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(te.image(0), &[32.0, 33.0, 34.0, 35.0]);
    }

    #[test]
    fn gather_wraps_indices() {
        let ds = tiny(3);
        let b = ds.gather(&[0, 4]); // 4 % 3 == 1
        assert_eq!(b.labels, vec![0, 1]);
        assert_eq!(&b.images[4..8], ds.image(1));
    }

    #[test]
    fn epoch_covers_all_once() {
        let ds = tiny(12);
        let batches = ds.epoch(4, 99);
        assert_eq!(batches.len(), 3);
        let mut seen: Vec<f32> = batches
            .iter()
            .flat_map(|b| b.images.chunks(4).map(|img| img[0]))
            .collect();
        seen.sort_by(f32::total_cmp);
        let expect: Vec<f32> = (0..12).map(|i| (i * 4) as f32).collect();
        assert_eq!(seen, expect);
    }

    #[test]
    fn epoch_pads_final_batch() {
        let ds = tiny(10);
        let batches = ds.epoch(4, 1);
        assert_eq!(batches.len(), 3);
        for b in &batches {
            assert_eq!(b.batch, 4);
            assert_eq!(b.labels.len(), 4);
            assert_eq!(b.images.len(), 16);
        }
    }

    #[test]
    fn epoch_shuffles_by_seed() {
        let ds = tiny(32);
        let a: Vec<i32> = ds.epoch(8, 1).iter().flat_map(|b| b.labels.clone()).collect();
        let b: Vec<i32> = ds.epoch(8, 2).iter().flat_map(|b| b.labels.clone()).collect();
        assert_ne!(a, b);
        let c: Vec<i32> = ds.epoch(8, 1).iter().flat_map(|b| b.labels.clone()).collect();
        assert_eq!(a, c);
    }
}
