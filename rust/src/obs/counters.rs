//! Process-wide GEMM call counters and per-stage latency histograms.
//!
//! The GEMM counters are a static Method × Kernel grid of relaxed
//! atomics bumped once per GEMM *entry* (not per row) by
//! `gemm::dispatch`, so `/metrics` can answer "which kernel actually
//! ran" — the forced-scalar CI leg shows up as `kernel="scalar"` rows
//! where the SIMD leg shows `kernel="avx2"`. Float GEMMs count under the
//! pseudo-kernel column `"f32"` (they have no bit-kernel).
//!
//! [`StageStats`] is the Prometheus-histogram side of tracing: per-stage
//! log-spaced bucket counts plus sum/count, all relaxed atomics, zero
//! allocation on observe (asserted by `rust/tests/profiler_overhead.rs`).

use std::sync::atomic::{AtomicU64, Ordering};

use super::trace::{Stage, TraceRecord};
use crate::gemm::simd::Kernel;
use crate::gemm::Method;

#[allow(clippy::declare_interior_mutable_const)] // array-init pattern
const ZERO: AtomicU64 = AtomicU64::new(0);

const N_METHODS: usize = 11;
/// Kernel columns of the counter grid; the last is the float-GEMM
/// pseudo-kernel.
pub const KERNEL_COLUMNS: [&str; 5] = ["scalar", "avx2", "avx512", "neon", "f32"];

#[allow(clippy::declare_interior_mutable_const)]
const ROW: [AtomicU64; KERNEL_COLUMNS.len()] = [ZERO; KERNEL_COLUMNS.len()];
static GEMM_CALLS: [[AtomicU64; KERNEL_COLUMNS.len()]; N_METHODS] = [ROW; N_METHODS];

fn method_index(m: Method) -> usize {
    let i = Method::all().iter().position(|&x| x == m).unwrap_or(0);
    debug_assert!(Method::all().len() <= N_METHODS);
    i.min(N_METHODS - 1)
}

fn kernel_index(k: Kernel) -> usize {
    match k {
        Kernel::Scalar => 0,
        Kernel::Avx2 => 1,
        Kernel::Avx512 => 2,
        Kernel::Neon => 3,
    }
}

/// Count one binary GEMM entry under the kernel that ran its rows.
pub fn record_gemm(method: Method, kernel: Kernel) {
    GEMM_CALLS[method_index(method)][kernel_index(kernel)].fetch_add(1, Ordering::Relaxed);
}

/// Count one float GEMM entry (no bit kernel → `"f32"` column).
pub fn record_gemm_f32(method: Method) {
    GEMM_CALLS[method_index(method)][KERNEL_COLUMNS.len() - 1].fetch_add(1, Ordering::Relaxed);
}

/// Nonzero counter cells as `(method_label, kernel_label, count)`,
/// method-major — the `/metrics` `bmxnet_kernel_calls_total` rows.
pub fn gemm_calls() -> Vec<(&'static str, &'static str, u64)> {
    let mut out = Vec::new();
    for (mi, m) in Method::all().iter().enumerate() {
        for (ki, kernel) in KERNEL_COLUMNS.iter().enumerate() {
            let n = GEMM_CALLS[mi][ki].load(Ordering::Relaxed);
            if n > 0 {
                out.push((m.label(), *kernel, n));
            }
        }
    }
    out
}

/// Sum over the whole grid.
pub fn gemm_calls_total() -> u64 {
    GEMM_CALLS
        .iter()
        .flatten()
        .map(|c| c.load(Ordering::Relaxed))
        .sum()
}

/// Histogram bucket upper bounds in µs, log-spaced ×4 from 1 µs to ~1 s;
/// an implicit +Inf bucket follows.
pub const STAGE_BUCKETS: [u64; 11] =
    [1, 4, 16, 64, 256, 1024, 4096, 16_384, 65_536, 262_144, 1_048_576];

const N_BUCKETS: usize = STAGE_BUCKETS.len() + 1; // +Inf

/// Per-stage latency histograms (Prometheus `bmxnet_stage_latency_us`).
pub struct StageStats {
    counts: [[AtomicU64; N_BUCKETS]; Stage::COUNT],
    sum_us: [AtomicU64; Stage::COUNT],
    count: [AtomicU64; Stage::COUNT],
}

impl Default for StageStats {
    fn default() -> Self {
        Self::new()
    }
}

impl StageStats {
    pub fn new() -> StageStats {
        #[allow(clippy::declare_interior_mutable_const)]
        const BUCKET_ROW: [AtomicU64; N_BUCKETS] = [ZERO; N_BUCKETS];
        StageStats {
            counts: [BUCKET_ROW; Stage::COUNT],
            sum_us: [ZERO; Stage::COUNT],
            count: [ZERO; Stage::COUNT],
        }
    }

    /// Record one stage duration. Allocation-free.
    pub fn observe(&self, s: Stage, us: u64) {
        let bucket = STAGE_BUCKETS
            .iter()
            .position(|&le| us <= le)
            .unwrap_or(N_BUCKETS - 1);
        let i = s.index();
        self.counts[i][bucket].fetch_add(1, Ordering::Relaxed);
        self.sum_us[i].fetch_add(us, Ordering::Relaxed);
        self.count[i].fetch_add(1, Ordering::Relaxed);
    }

    /// Record every reached stage of a finished trace. Allocation-free.
    pub fn observe_record(&self, rec: &TraceRecord) {
        for s in Stage::all() {
            if let Some(us) = rec.stage_us(s) {
                self.observe(s, us);
            }
        }
    }

    /// Snapshot for rendering: per stage, *cumulative* bucket counts in
    /// `STAGE_BUCKETS` order (the +Inf count equals `count`), plus
    /// sum/count.
    pub fn snapshot(&self) -> Vec<StageHist> {
        Stage::all()
            .into_iter()
            .map(|s| {
                let i = s.index();
                let mut cum = 0u64;
                let buckets = self.counts[i]
                    .iter()
                    .map(|c| {
                        cum += c.load(Ordering::Relaxed);
                        cum
                    })
                    .collect();
                StageHist {
                    stage: s.label(),
                    buckets,
                    sum_us: self.sum_us[i].load(Ordering::Relaxed),
                    count: self.count[i].load(Ordering::Relaxed),
                }
            })
            .collect()
    }
}

/// One stage's histogram, cumulative counts aligned to `STAGE_BUCKETS`
/// plus a final +Inf entry.
pub struct StageHist {
    pub stage: &'static str,
    pub buckets: Vec<u64>,
    pub sum_us: u64,
    pub count: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{BatchTiming, Trace};

    #[test]
    fn gemm_counter_grid_accumulates_per_label() {
        let cell = |calls: &[(&str, &str, u64)]| {
            calls
                .iter()
                .filter(|(m, k, _)| *m == "xnor_64" && *k == "scalar")
                .map(|(_, _, n)| *n)
                .sum::<u64>()
        };
        let before = cell(&gemm_calls());
        record_gemm(Method::Xnor64, Kernel::Scalar);
        record_gemm(Method::Xnor64, Kernel::Scalar);
        let after = cell(&gemm_calls());
        assert_eq!(after - before, 2);
        assert!(gemm_calls_total() >= after);
    }

    #[test]
    fn f32_counts_land_in_the_f32_column() {
        let cell = |calls: &[(&str, &str, u64)]| {
            calls
                .iter()
                .find(|(m, k, _)| *m == "cblas" && *k == "f32")
                .map(|(_, _, n)| *n)
                .unwrap_or(0)
        };
        let before = cell(&gemm_calls());
        record_gemm_f32(Method::BlockedF32);
        assert_eq!(cell(&gemm_calls()) - before, 1);
    }

    #[test]
    fn stage_histogram_buckets_are_cumulative_and_sum_count_track() {
        let st = StageStats::new();
        st.observe(Stage::Forward, 0); // le="1"
        st.observe(Stage::Forward, 100); // le="256"
        st.observe(Stage::Forward, 2_000_000); // +Inf
        let snap = st.snapshot();
        let fwd = snap.iter().find(|h| h.stage == "forward").unwrap();
        assert_eq!(fwd.count, 3);
        assert_eq!(fwd.sum_us, 2_000_100);
        assert_eq!(fwd.buckets.len(), STAGE_BUCKETS.len() + 1);
        assert_eq!(fwd.buckets[0], 1); // ≤ 1µs
        assert_eq!(fwd.buckets[4], 2); // ≤ 256µs
        assert_eq!(*fwd.buckets.last().unwrap(), 3); // +Inf == count
        // monotone non-decreasing
        assert!(fwd.buckets.windows(2).all(|w| w[0] <= w[1]));
        // untouched stages stay empty
        let parse = snap.iter().find(|h| h.stage == "parse").unwrap();
        assert_eq!(parse.count, 0);
    }

    #[test]
    fn observe_record_covers_each_reached_stage_once() {
        let st = StageStats::new();
        let mut t = Trace::begin();
        t.mark(Stage::Read);
        t.mark(Stage::Parse);
        t.mark(Stage::Admission);
        t.absorb_batch_timing(&BatchTiming { queue_us: 5, window_us: 5, forward_us: 5 });
        t.mark(Stage::Respond);
        t.mark(Stage::Write);
        st.observe_record(&t.finish("m", 200, 0, 1));
        for h in st.snapshot() {
            assert_eq!(h.count, 1, "stage {} count", h.stage);
        }
    }
}
