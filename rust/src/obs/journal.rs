//! Lock-free bounded ring journal for completed traces.
//!
//! A fixed array of slots, each a bundle of plain atomics guarded by a
//! per-slot sequence counter with seqlock semantics:
//!
//! * writer: CAS the (even) sequence to odd → store fields (Relaxed) →
//!   store sequence+2 (Release). If the CAS fails another writer lapped
//!   the ring onto the same slot mid-write; the record is *dropped* and
//!   counted instead of blocking — publish never waits.
//! * reader: load sequence (Acquire); skip if odd or zero; read fields;
//!   `fence(Acquire)`; re-load sequence and discard the read if it moved.
//!
//! A textbook seqlock protects a plain (non-atomic) payload with an
//! `UnsafeCell`; `gemm/simd.rs` is deliberately this repo's only unsafe
//! module, so the payload here is itself atomics (word-packed name bytes
//! included) — torn reads are then merely *stale*, never UB, and the
//! sequence check discards them. Publish does zero allocation and takes
//! zero locks (asserted by `rust/tests/profiler_overhead.rs`); `recent`
//! (the `/v1/debug/trace` path) allocates freely — it is not hot.

use std::sync::atomic::{fence, AtomicU64, Ordering};

use super::trace::{Stage, TraceRecord, NAME_CAP};

/// Default ring capacity for the gateway journal (must be a power of two;
/// `new` rounds up). 512 × ~14 words ≈ 56 KiB resident.
pub const DEFAULT_SLOTS: usize = 512;

const NAME_WORDS: usize = NAME_CAP / 8;

#[allow(clippy::declare_interior_mutable_const)] // array-init pattern
const ZERO: AtomicU64 = AtomicU64::new(0);

struct Slot {
    /// Seqlock sequence: 0 = never written, odd = write in progress.
    seq: AtomicU64,
    id: AtomicU64,
    start_unix_us: AtomicU64,
    name: [AtomicU64; NAME_WORDS],
    stages: [AtomicU64; Stage::COUNT],
    total_us: AtomicU64,
    /// `status << 48 | shard << 32 | batch << 16 | name_len`.
    meta: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            seq: ZERO,
            id: ZERO,
            start_unix_us: ZERO,
            name: [ZERO; NAME_WORDS],
            stages: [ZERO; Stage::COUNT],
            total_us: ZERO,
            meta: ZERO,
        }
    }
}

pub struct Journal {
    slots: Vec<Slot>,
    /// Total publish attempts; `cursor % slots.len()` is the next slot,
    /// and the pre-increment value doubles as the record id.
    cursor: AtomicU64,
    dropped: AtomicU64,
}

impl Journal {
    /// `slots` is rounded up to a power of two (min 2) so the slot index
    /// is a mask, not a division.
    pub fn new(slots: usize) -> Journal {
        let n = slots.next_power_of_two().max(2);
        Journal {
            slots: (0..n).map(|_| Slot::new()).collect(),
            cursor: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total records ever published (including ones since overwritten
    /// or dropped).
    pub fn total(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Records dropped because a concurrent writer held the same slot.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Publish a record; returns its id. Lock-free, allocation-free.
    pub fn publish(&self, rec: &TraceRecord) -> u64 {
        let id = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[id as usize & (self.slots.len() - 1)];
        let seq = slot.seq.load(Ordering::Acquire);
        if seq & 1 == 1
            || slot
                .seq
                .compare_exchange(seq, seq + 1, Ordering::Acquire, Ordering::Relaxed)
                .is_err()
        {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return id;
        }
        slot.id.store(id, Ordering::Relaxed);
        slot.start_unix_us.store(rec.start_unix_us, Ordering::Relaxed);
        for (w, chunk) in slot.name.iter().zip(rec.name.chunks_exact(8)) {
            let mut bytes = [0u8; 8];
            bytes.copy_from_slice(chunk);
            w.store(u64::from_le_bytes(bytes), Ordering::Relaxed);
        }
        for (w, &v) in slot.stages.iter().zip(rec.stages.iter()) {
            w.store(v, Ordering::Relaxed);
        }
        slot.total_us.store(rec.total_us, Ordering::Relaxed);
        let meta = (rec.status as u64) << 48
            | (rec.shard as u64) << 32
            | (rec.batch as u64) << 16
            | rec.name_len as u64;
        slot.meta.store(meta, Ordering::Relaxed);
        slot.seq.store(seq + 2, Ordering::Release);
        id
    }

    /// The most recent `n` consistent records, newest first. Slots being
    /// rewritten concurrently, or already lapped past the id we walked
    /// to, are skipped rather than retried forever.
    pub fn recent(&self, n: usize) -> Vec<TraceRecord> {
        let end = self.cursor.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let mut out = Vec::with_capacity(n.min(self.slots.len()));
        let mut i = end;
        while i > 0 && out.len() < n && end - i < cap {
            i -= 1;
            let slot = &self.slots[i as usize & (self.slots.len() - 1)];
            if let Some(rec) = self.read_slot(slot) {
                if rec.id == i {
                    out.push(rec);
                }
            }
        }
        out
    }

    fn read_slot(&self, slot: &Slot) -> Option<TraceRecord> {
        // bounded retries: a slot under constant rewrite is not worth
        // spinning on — the walk just skips it
        for _ in 0..3 {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 & 1 == 1 {
                return None;
            }
            let id = slot.id.load(Ordering::Relaxed);
            let start_unix_us = slot.start_unix_us.load(Ordering::Relaxed);
            let mut name = [0u8; NAME_CAP];
            for (chunk, w) in name.chunks_exact_mut(8).zip(slot.name.iter()) {
                chunk.copy_from_slice(&w.load(Ordering::Relaxed).to_le_bytes());
            }
            let mut stages = [0u64; Stage::COUNT];
            for (v, w) in stages.iter_mut().zip(slot.stages.iter()) {
                *v = w.load(Ordering::Relaxed);
            }
            let total_us = slot.total_us.load(Ordering::Relaxed);
            let meta = slot.meta.load(Ordering::Relaxed);
            fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) != s1 {
                continue; // torn read: writer moved underneath us
            }
            return Some(TraceRecord {
                id,
                start_unix_us,
                name,
                name_len: (meta & 0xFF) as u8,
                stages,
                total_us,
                status: (meta >> 48) as u16,
                shard: (meta >> 32) as u16,
                batch: (meta >> 16) as u16,
            });
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{BatchTiming, Trace, UNSET};
    use std::sync::Arc;

    fn record(model: &str, status: u16, shard: u16, batch: u16) -> TraceRecord {
        let mut t = Trace::begin();
        t.mark(Stage::Parse);
        t.mark(Stage::Admission);
        t.absorb_batch_timing(&BatchTiming { queue_us: 3, window_us: 2, forward_us: 40 });
        t.mark(Stage::Respond);
        t.finish(model, status, shard, batch)
    }

    #[test]
    fn publish_then_recent_roundtrips_all_fields() {
        let j = Journal::new(8);
        let id = j.publish(&record("lenet_bin", 200, 3, 7));
        assert_eq!(id, 0);
        let recs = j.recent(4);
        assert_eq!(recs.len(), 1);
        let r = &recs[0];
        assert_eq!(r.id, 0);
        assert_eq!(r.model(), "lenet_bin");
        assert_eq!((r.status, r.shard, r.batch), (200, 3, 7));
        assert_eq!(r.stage_us(Stage::Forward), Some(40));
        assert!(r.total_us >= r.stages[Stage::Respond.index()].min(r.total_us));
    }

    #[test]
    fn recent_is_newest_first_and_bounded_by_capacity() {
        let j = Journal::new(8); // rounds to 8
        for i in 0..20u16 {
            j.publish(&record("m", 200, i, 1));
        }
        assert_eq!(j.total(), 20);
        let recs = j.recent(100);
        assert!(recs.len() <= j.capacity());
        assert!(!recs.is_empty());
        // newest first, ids strictly descending, all within the live window
        for pair in recs.windows(2) {
            assert!(pair[0].id > pair[1].id);
        }
        assert_eq!(recs[0].id, 19);
        assert!(recs.iter().all(|r| r.id >= 20 - j.capacity() as u64));
    }

    #[test]
    fn recent_zero_and_empty_journal() {
        let j = Journal::new(4);
        assert!(j.recent(10).is_empty());
        j.publish(&record("m", 200, 0, 1));
        assert!(j.recent(0).is_empty());
    }

    #[test]
    fn wraparound_under_concurrent_writers_yields_only_consistent_records() {
        let j = Arc::new(Journal::new(16));
        let writers = 8;
        let per = 500;
        let mut handles = Vec::new();
        for w in 0..writers {
            let j = Arc::clone(&j);
            handles.push(std::thread::spawn(move || {
                let name = format!("writer_{w}");
                for i in 0..per {
                    j.publish(&record(&name, 200, w as u16, (i % 7 + 1) as u16));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(j.total(), (writers * per) as u64);
        // every surviving record must be internally consistent: a valid
        // writer name and monotone stage offsets (a torn slot would mix
        // two records and violate one of these with high probability)
        let recs = j.recent(j.capacity());
        assert!(!recs.is_empty());
        for r in &recs {
            assert!(r.model().starts_with("writer_"), "corrupt name {:?}", r.model());
            let mut prev = 0u64;
            for s in Stage::all() {
                let off = r.stages[s.index()];
                if off != UNSET {
                    assert!(off >= prev, "non-monotone stages in {:?}", r);
                    prev = off;
                }
            }
            assert!(r.batch >= 1 && r.batch <= 7);
        }
        // drops only happen on same-slot contention; they must never
        // exceed the published total
        assert!(j.dropped() <= j.total());
    }
}
