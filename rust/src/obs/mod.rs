//! Observability: request tracing, per-layer profiling, kernel counters.
//!
//! std-only, three parts (DESIGN.md §Observability):
//!
//! * [`trace`] + [`journal`] — request-scoped stage spans written into a
//!   lock-free bounded ring; `GET /v1/debug/trace` reads it back.
//! * [`profiler`] — opt-in per-layer timing for engine forwards, behind
//!   `bmxnet profile` and `GET /v1/models/{name}/profile`.
//! * [`counters`] — process-wide GEMM Method×Kernel call counters and
//!   per-stage latency histograms, rendered by `serve::prom`.
//!
//! Overhead budget: with nothing enabled the per-request cost is eight
//! `Instant::now` stamps, ~20 relaxed atomic ops for the journal publish
//! and stage histograms, and zero heap allocation (enforced by
//! `rust/tests/profiler_overhead.rs`); the per-layer hook costs one
//! branch when no profiler is attached.

pub mod counters;
pub mod journal;
pub mod profiler;
pub mod trace;

pub use counters::StageStats;
pub use journal::Journal;
pub use profiler::{layer, LayerProfile, LayerRecord, ProfileReport, Profiler};
pub use trace::{BatchTiming, Stage, Trace, TraceRecord};

/// Environment variable holding the slow-request threshold in µs.
pub const SLOW_REQ_ENV: &str = "BMXNET_SLOW_REQ_US";

/// Shared observability state for one gateway: the trace journal, stage
/// histograms, and the slow-request log threshold.
pub struct Obs {
    pub journal: Journal,
    pub stages: StageStats,
    /// Requests totalling ≥ this many µs get one structured stderr line;
    /// `None` disables the slow log.
    pub slow_req_us: Option<u64>,
}

impl Obs {
    /// Default-sized journal; threshold from `BMXNET_SLOW_REQ_US`.
    pub fn from_env() -> Obs {
        Obs::with_slots(journal::DEFAULT_SLOTS)
    }

    pub fn with_slots(slots: usize) -> Obs {
        Obs {
            journal: Journal::new(slots),
            stages: StageStats::new(),
            slow_req_us: std::env::var(SLOW_REQ_ENV).ok().and_then(|v| v.parse().ok()),
        }
    }

    /// Finish one request: fold its stages into the histograms, publish
    /// it to the journal, and emit the slow-request line if it crossed
    /// the threshold. Returns the journal id. Allocation-free unless the
    /// request was slow.
    pub fn complete(&self, rec: &TraceRecord) -> u64 {
        self.stages.observe_record(rec);
        let id = self.journal.publish(rec);
        if let Some(t) = self.slow_req_us {
            if rec.total_us >= t {
                eprintln!("{}", slow_line(id, rec));
            }
        }
        id
    }
}

/// One `key=value` line for the slow-request log. Stage keys carry the
/// per-stage *duration*; unreached stages are omitted.
pub fn slow_line(id: u64, rec: &TraceRecord) -> String {
    let mut s = format!(
        "slow_request id={id} model={} status={} shard={} batch={} total_us={}",
        rec.model(),
        rec.status,
        rec.shard,
        rec.batch,
        rec.total_us,
    );
    for stage in Stage::all() {
        if let Some(us) = rec.stage_us(stage) {
            s.push_str(&format!(" {}_us={us}", stage.label()));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_publishes_and_observes() {
        let obs = Obs::with_slots(8);
        let mut t = Trace::begin();
        t.mark(Stage::Read);
        t.mark(Stage::Parse);
        t.mark(Stage::Admission);
        t.absorb_batch_timing(&BatchTiming { queue_us: 1, window_us: 1, forward_us: 10 });
        t.mark(Stage::Respond);
        t.mark(Stage::Write);
        let id = obs.complete(&t.finish("m", 200, 0, 2));
        assert_eq!(id, 0);
        assert_eq!(obs.journal.recent(1).len(), 1);
        let snap = obs.stages.snapshot();
        assert!(snap.iter().all(|h| h.count == 1));
    }

    #[test]
    fn slow_line_is_key_value_with_stage_durations() {
        let mut t = Trace::begin();
        t.mark(Stage::Parse);
        t.absorb_batch_timing(&BatchTiming { queue_us: 2, window_us: 3, forward_us: 4 });
        let line = slow_line(7, &t.finish("lenet_bin", 200, 1, 8));
        assert!(line.starts_with("slow_request id=7 model=lenet_bin status=200 shard=1 batch=8"));
        assert!(line.contains(" queue_wait_us=2"));
        assert!(line.contains(" batch_window_us=3"));
        assert!(line.contains(" forward_us=4"));
        assert!(!line.contains("respond_us="), "unreached stage must be omitted");
    }
}
