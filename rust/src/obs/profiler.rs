//! Opt-in per-layer profiler for the engine forward pass.
//!
//! Layer forwards are wrapped in [`layer`], which is the *only* hook:
//! with `prof == None` (every production forward) the cost is one branch
//! — the name closure is never called, nothing is timed, nothing
//! allocates (asserted by `rust/tests/profiler_overhead.rs` with a
//! counting allocator). With `Some(prof)` it times the closure, resolves
//! the GEMM Method×Kernel labels, and appends a [`LayerRecord`].
//!
//! [`ProfileReport`] aggregates records across repetitions and renders
//! the table behind `bmxnet profile` / `GET /v1/models/{name}/profile`,
//! plus a JSON document in the same hand-rolled self-parse-validated
//! style as `bench/record.rs` (shared `"schema": 1` + provenance keys,
//! so perf tooling can ingest both).

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::gemm::{dispatch, Method};

/// One timed layer execution (or the aggregate of several reps).
#[derive(Debug, Clone)]
pub struct LayerRecord {
    pub name: String,
    /// Layer kind, e.g. `conv_f32`, `qconv`, `batchnorm`, `tanh`.
    pub kind: &'static str,
    pub wall: Duration,
    /// Approximate bytes touched (activations + weights), for crude
    /// arithmetic-intensity eyeballing.
    pub bytes: usize,
    /// GEMM method label, for layers that run a GEMM.
    pub method: Option<&'static str>,
    /// Row kernel the method resolves to right now (None for float GEMM).
    pub kernel: Option<&'static str>,
}

/// Collects [`LayerRecord`]s from one or more profiled forwards.
/// A plain mutex: the profiled path is diagnostic, not hot.
#[derive(Debug, Default)]
pub struct Profiler {
    records: Mutex<Vec<LayerRecord>>,
}

impl Profiler {
    pub fn new() -> Profiler {
        Profiler::default()
    }

    pub fn record(&self, rec: LayerRecord) {
        self.records.lock().unwrap().push(rec);
    }

    /// Drain everything recorded so far, in execution order.
    pub fn take(&self) -> Vec<LayerRecord> {
        std::mem::take(&mut *self.records.lock().unwrap())
    }
}

/// The per-layer hook. `name` is a closure so the disabled path never
/// builds the string; `gemm` is a `Copy` method token so the disabled
/// path never resolves kernel labels either.
#[inline]
pub fn layer<T>(
    prof: Option<&Profiler>,
    name: impl FnOnce() -> String,
    kind: &'static str,
    gemm: Option<Method>,
    bytes: usize,
    f: impl FnOnce() -> T,
) -> T {
    match prof {
        None => f(),
        Some(p) => {
            let t0 = Instant::now();
            let out = f();
            let wall = t0.elapsed();
            p.record(LayerRecord {
                name: name(),
                kind,
                wall,
                bytes,
                method: gemm.map(|m| m.label()),
                kernel: gemm
                    .and_then(dispatch::effective_kernel)
                    .map(|k| k.label()),
            });
            out
        }
    }
}

/// Aggregated per-layer profile of one model.
#[derive(Debug)]
pub struct ProfileReport {
    /// Registry/file name of the model (callers set this; the engine
    /// only knows its architecture).
    pub model: String,
    pub arch: String,
    pub batch: usize,
    pub reps: usize,
    /// [`crate::nn::Engine::dispatch_summary`] at profile time.
    pub dispatch: String,
    pub force_scalar: bool,
    /// Mean wall time of one full forward.
    pub total: Duration,
    /// Per layer, forward order, wall = mean over reps.
    pub layers: Vec<LayerRecord>,
}

impl ProfileReport {
    /// Aggregate raw records (reps × layers, execution order) by layer
    /// name: wall times are summed then divided by `reps`.
    pub fn from_runs(
        arch: &str,
        batch: usize,
        reps: usize,
        dispatch: String,
        force_scalar: bool,
        total: Duration,
        records: Vec<LayerRecord>,
    ) -> ProfileReport {
        let reps = reps.max(1);
        let mut layers: Vec<LayerRecord> = Vec::new();
        for rec in records {
            match layers.iter_mut().find(|l| l.name == rec.name) {
                Some(l) => l.wall += rec.wall,
                None => layers.push(rec),
            }
        }
        for l in &mut layers {
            l.wall /= reps as u32;
        }
        ProfileReport {
            model: arch.to_string(),
            arch: arch.to_string(),
            batch,
            reps,
            dispatch,
            force_scalar,
            total: total / reps as u32,
            layers,
        }
    }

    fn layer_sum(&self) -> Duration {
        self.layers.iter().map(|l| l.wall).sum()
    }

    /// Human table: one row per layer plus a sum line.
    pub fn render_table(&self) -> String {
        let sum = self.layer_sum().max(Duration::from_nanos(1));
        let mut out = format!(
            "profile: {} (arch {}, batch {}, reps {})\ndispatch: {} (force_scalar={})\n\
             {:<14} {:>10} {:>6}  {:>10}  {:<12} {}\n",
            self.model,
            self.arch,
            self.batch,
            self.reps,
            self.dispatch,
            self.force_scalar,
            "layer",
            "ms",
            "pct",
            "kbytes",
            "method",
            "kernel",
        );
        for l in &self.layers {
            out.push_str(&format!(
                "{:<14} {:>10.3} {:>5.1}%  {:>10}  {:<12} {}\n",
                l.name,
                l.wall.as_secs_f64() * 1e3,
                100.0 * l.wall.as_secs_f64() / sum.as_secs_f64(),
                l.bytes / 1024,
                l.method.unwrap_or("-"),
                l.kernel.unwrap_or("-"),
            ));
        }
        out.push_str(&format!(
            "{:<14} {:>10.3}   (forward total {:.3} ms)\n",
            "sum",
            self.layer_sum().as_secs_f64() * 1e3,
            self.total.as_secs_f64() * 1e3,
        ));
        out
    }

    /// JSON document in the `bench/record.rs` family: same top-level
    /// provenance keys, layers as an array of objects. Optional GEMM
    /// labels are omitted (not null) for layers without a GEMM.
    pub fn render_json(&self) -> String {
        let sum = self.layer_sum().max(Duration::from_nanos(1));
        let mut s = String::with_capacity(1024);
        s.push_str("{\n");
        s.push_str("  \"schema\": 1,\n");
        s.push_str("  \"bench\": \"profile\",\n");
        s.push_str(&format!("  \"model\": {},\n", json_str(&self.model)));
        s.push_str(&format!("  \"arch\": {},\n", json_str(&self.arch)));
        s.push_str(&format!("  \"batch\": {},\n", self.batch));
        s.push_str(&format!("  \"reps\": {},\n", self.reps));
        s.push_str(&format!("  \"dispatch\": {},\n", json_str(&self.dispatch)));
        s.push_str(&format!("  \"force_scalar\": {},\n", self.force_scalar));
        s.push_str(&format!(
            "  \"total_ms\": {:.6},\n",
            self.total.as_secs_f64() * 1e3
        ));
        s.push_str("  \"layers\": [\n");
        for (i, l) in self.layers.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": {}, \"kind\": {}, \"ms\": {:.6}, \"pct\": {:.2}, \"bytes\": {}",
                json_str(&l.name),
                json_str(l.kind),
                l.wall.as_secs_f64() * 1e3,
                100.0 * l.wall.as_secs_f64() / sum.as_secs_f64(),
                l.bytes,
            ));
            if let Some(m) = l.method {
                s.push_str(&format!(", \"method\": {}", json_str(m)));
            }
            if let Some(k) = l.kernel {
                s.push_str(&format!(", \"kernel\": {}", json_str(k)));
            }
            s.push('}');
            s.push_str(if i + 1 < self.layers.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Minimal JSON string escaper (same contract as `serve::http`'s).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(name: &str, us: u64) -> LayerRecord {
        LayerRecord {
            name: name.to_string(),
            kind: "conv_f32",
            wall: Duration::from_micros(us),
            bytes: 4096,
            method: Some("xnor_fused"),
            kernel: Some("avx2"),
        }
    }

    #[test]
    fn disabled_hook_runs_the_closure_and_nothing_else() {
        let out = layer(
            None,
            || unreachable!("name closure must not run when disabled"),
            "k",
            Some(Method::XnorFused),
            0,
            || 41 + 1,
        );
        assert_eq!(out, 42);
    }

    #[test]
    fn enabled_hook_records_labels_and_time() {
        let p = Profiler::new();
        let out = layer(
            Some(&p),
            || "conv1".to_string(),
            "qconv",
            Some(Method::XnorFused),
            128,
            || 7,
        );
        assert_eq!(out, 7);
        let recs = p.take();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].name, "conv1");
        assert_eq!(recs[0].kind, "qconv");
        assert_eq!(recs[0].bytes, 128);
        assert_eq!(recs[0].method, Some("xnor_fused"));
        assert!(recs[0].kernel.is_some(), "binary gemm must resolve a kernel");
    }

    #[test]
    fn from_runs_aggregates_by_name_across_reps() {
        let records = vec![rec("a", 100), rec("b", 300), rec("a", 300), rec("b", 500)];
        let r = ProfileReport::from_runs(
            "lenet",
            4,
            2,
            "test".into(),
            false,
            Duration::from_micros(1300),
            records,
        );
        assert_eq!(r.layers.len(), 2);
        assert_eq!(r.layers[0].name, "a");
        assert_eq!(r.layers[0].wall, Duration::from_micros(200));
        assert_eq!(r.layers[1].wall, Duration::from_micros(400));
        assert_eq!(r.total, Duration::from_micros(650));
    }

    #[test]
    fn json_report_self_parses_with_expected_shape() {
        let r = ProfileReport::from_runs(
            "lenet",
            2,
            1,
            "x86_64 · method xnor_fused · kernel avx2".into(),
            false,
            Duration::from_micros(900),
            vec![
                rec("conv1", 600),
                LayerRecord {
                    name: "bn1".into(),
                    kind: "batchnorm",
                    wall: Duration::from_micros(50),
                    bytes: 256,
                    method: None,
                    kernel: None,
                },
            ],
        );
        let doc = crate::model::json::parse(&r.render_json()).unwrap();
        assert_eq!(doc.get("bench").and_then(|v| v.as_str()), Some("profile"));
        assert_eq!(doc.get("batch").and_then(|v| v.as_usize()), Some(2));
        let layers = doc.get("layers").and_then(|v| v.as_array()).unwrap();
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0].get("name").and_then(|v| v.as_str()), Some("conv1"));
        assert_eq!(layers[0].get("kernel").and_then(|v| v.as_str()), Some("avx2"));
        assert!(layers[1].get("kernel").is_none(), "non-gemm layer has no kernel key");
        assert!(doc.get("total_ms").and_then(|v| v.as_f64()).unwrap() > 0.0);
        let table = r.render_table();
        assert!(table.contains("conv1") && table.contains("xnor_fused"));
    }
}
