//! Opt-in per-layer profiler for the engine forward pass.
//!
//! Layer forwards are wrapped in [`layer`], which is the *only* hook:
//! with `prof == None` (every production forward) the cost is one branch
//! — the name closure is never called, nothing is timed, nothing
//! allocates (asserted by `rust/tests/profiler_overhead.rs` with a
//! counting allocator). With `Some(prof)` it times the closure, resolves
//! the GEMM Method×Kernel labels, and appends a [`LayerRecord`].
//!
//! [`ProfileReport`] aggregates records across repetitions into
//! per-layer [`Stats`] and renders the table behind `bmxnet profile` /
//! `GET /v1/models/{name}/profile`.  Its JSON *is* a schema-2
//! [`PerfRecord`] (bench `profile`, one `layer/<name>` cell per layer
//! plus `forward/total`, per-layer metadata in cell notes) with a few
//! extra top-level keys (`model`/`arch`/`batch`/`total_ms`) — so profile
//! dumps feed straight into `bmxnet bench-compare`.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::bench::record::{json_str, Cell, PerfRecord, Provenance, Unit};
use crate::bench::Stats;
use crate::gemm::{dispatch, Method};

/// One timed layer execution (or the aggregate of several reps).
#[derive(Debug, Clone)]
pub struct LayerRecord {
    pub name: String,
    /// Layer kind, e.g. `conv_f32`, `qconv`, `batchnorm`, `tanh`.
    pub kind: &'static str,
    pub wall: Duration,
    /// Approximate bytes touched (activations + weights), for crude
    /// arithmetic-intensity eyeballing.
    pub bytes: usize,
    /// GEMM method label, for layers that run a GEMM.
    pub method: Option<&'static str>,
    /// Row kernel the method resolves to right now (None for float GEMM).
    pub kernel: Option<&'static str>,
}

/// Collects [`LayerRecord`]s from one or more profiled forwards.
/// A plain mutex: the profiled path is diagnostic, not hot.
#[derive(Debug, Default)]
pub struct Profiler {
    records: Mutex<Vec<LayerRecord>>,
}

impl Profiler {
    pub fn new() -> Profiler {
        Profiler::default()
    }

    pub fn record(&self, rec: LayerRecord) {
        self.records.lock().unwrap().push(rec);
    }

    /// Drain everything recorded so far, in execution order.
    pub fn take(&self) -> Vec<LayerRecord> {
        std::mem::take(&mut *self.records.lock().unwrap())
    }
}

/// The per-layer hook. `name` is a closure so the disabled path never
/// builds the string; `gemm` is a `Copy` method token so the disabled
/// path never resolves kernel labels either.
#[inline]
pub fn layer<T>(
    prof: Option<&Profiler>,
    name: impl FnOnce() -> String,
    kind: &'static str,
    gemm: Option<Method>,
    bytes: usize,
    f: impl FnOnce() -> T,
) -> T {
    match prof {
        None => f(),
        Some(p) => {
            let t0 = Instant::now();
            let out = f();
            let wall = t0.elapsed();
            p.record(LayerRecord {
                name: name(),
                kind,
                wall,
                bytes,
                method: gemm.map(|m| m.label()),
                kernel: gemm
                    .and_then(dispatch::effective_kernel)
                    .map(|k| k.label()),
            });
            out
        }
    }
}

/// One layer aggregated over reps: noise-aware time stats plus the
/// metadata the single-run [`LayerRecord`] carried.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerProfile {
    pub name: String,
    pub kind: &'static str,
    /// Per-rep wall time in ms (median/min/MAD).
    pub stats: Stats,
    pub bytes: usize,
    pub method: Option<&'static str>,
    pub kernel: Option<&'static str>,
}

/// Aggregated per-layer profile of one model.
#[derive(Debug)]
pub struct ProfileReport {
    /// Registry/file name of the model (callers set this; the engine
    /// only knows its architecture).
    pub model: String,
    pub arch: String,
    pub batch: usize,
    pub reps: usize,
    /// [`crate::nn::Engine::dispatch_summary`] at profile time.
    pub dispatch: String,
    pub force_scalar: bool,
    /// Full-forward wall time stats (ms) over reps.
    pub total: Stats,
    /// Per layer, forward order, stats over reps.
    pub layers: Vec<LayerProfile>,
}

impl ProfileReport {
    /// Aggregate raw records (reps × layers, execution order) by layer
    /// name: each layer's per-rep wall times become its [`Stats`].
    /// `totals` is one full-forward duration per rep.
    pub fn from_runs(
        arch: &str,
        batch: usize,
        reps: usize,
        dispatch: String,
        force_scalar: bool,
        totals: &[Duration],
        records: Vec<LayerRecord>,
    ) -> ProfileReport {
        let mut layers: Vec<(LayerProfile, Vec<f64>)> = Vec::new();
        for rec in records {
            let ms = rec.wall.as_secs_f64() * 1e3;
            match layers.iter_mut().find(|(l, _)| l.name == rec.name) {
                Some((_, samples)) => samples.push(ms),
                None => layers.push((
                    LayerProfile {
                        name: rec.name,
                        kind: rec.kind,
                        stats: Stats::exact(0.0),
                        bytes: rec.bytes,
                        method: rec.method,
                        kernel: rec.kernel,
                    },
                    vec![ms],
                )),
            }
        }
        let layers = layers
            .into_iter()
            .map(|(mut l, samples)| {
                l.stats = Stats::from_samples(&samples);
                l
            })
            .collect();
        ProfileReport {
            model: arch.to_string(),
            arch: arch.to_string(),
            batch,
            reps: reps.max(1),
            dispatch,
            force_scalar,
            total: Stats::from_durations(totals),
            layers,
        }
    }

    fn layer_sum_ms(&self) -> f64 {
        self.layers.iter().map(|l| l.stats.median).sum()
    }

    /// Human table: one row per layer (median ms) plus a sum line.
    pub fn render_table(&self) -> String {
        let sum = self.layer_sum_ms().max(1e-9);
        let mut out = format!(
            "profile: {} (arch {}, batch {}, reps {})\ndispatch: {} (force_scalar={})\n\
             {:<14} {:>10} {:>10} {:>6}  {:>10}  {:<12} {}\n",
            self.model,
            self.arch,
            self.batch,
            self.reps,
            self.dispatch,
            self.force_scalar,
            "layer",
            "ms",
            "±mad",
            "pct",
            "kbytes",
            "method",
            "kernel",
        );
        for l in &self.layers {
            out.push_str(&format!(
                "{:<14} {:>10.3} {:>10.3} {:>5.1}%  {:>10}  {:<12} {}\n",
                l.name,
                l.stats.median,
                l.stats.mad,
                100.0 * l.stats.median / sum,
                l.bytes / 1024,
                l.method.unwrap_or("-"),
                l.kernel.unwrap_or("-"),
            ));
        }
        out.push_str(&format!(
            "{:<14} {:>10.3}   (forward total median {:.3} ms, min {:.3}, mad {:.3})\n",
            "sum",
            self.layer_sum_ms(),
            self.total.median,
            self.total.min,
            self.total.mad,
        ));
        out
    }

    /// Convert to the schema-2 perf record: `forward/total` plus one
    /// `layer/<name>` cell per layer, metadata in cell notes
    /// (`kind=… method=… kernel=… bytes=…`).  This is the `profile`
    /// family of `bmxnet bench-suite` / `bench-compare`.
    pub fn to_perf_record(&self, tool: &str) -> PerfRecord {
        let mut prov = Provenance::capture(tool);
        prov.reps = self.reps;
        prov.note = format!("model {} · arch {} · batch {}", self.model, self.arch, self.batch);
        let mut rec = PerfRecord::new("profile", prov);
        rec.push("forward/total", Unit::Ms, self.total);
        for l in &self.layers {
            let mut note = format!("kind={}", l.kind);
            if let Some(m) = l.method {
                note.push_str(&format!(" method={m}"));
            }
            if let Some(k) = l.kernel {
                note.push_str(&format!(" kernel={k}"));
            }
            note.push_str(&format!(" bytes={}", l.bytes));
            rec.cells
                .push(Cell::new(format!("layer/{}", l.name), Unit::Ms, l.stats).with_note(note));
        }
        rec
    }

    /// JSON document: the perf record with extra top-level convenience
    /// keys (`model`/`arch`/`batch`/`total_ms`).  Parseable as a plain
    /// [`PerfRecord`], so saved profiles diff with `bmxnet bench-compare`.
    pub fn render_json(&self) -> String {
        self.to_perf_record("bmxnet profile").render_json_extra(&[
            ("model", json_str(&self.model)),
            ("arch", json_str(&self.arch)),
            ("batch", self.batch.to_string()),
            ("total_ms", format!("{:.6}", self.total.median)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(name: &str, us: u64) -> LayerRecord {
        LayerRecord {
            name: name.to_string(),
            kind: "conv_f32",
            wall: Duration::from_micros(us),
            bytes: 4096,
            method: Some("xnor_fused"),
            kernel: Some("avx2"),
        }
    }

    #[test]
    fn disabled_hook_runs_the_closure_and_nothing_else() {
        let out = layer(
            None,
            || unreachable!("name closure must not run when disabled"),
            "k",
            Some(Method::XnorFused),
            0,
            || 41 + 1,
        );
        assert_eq!(out, 42);
    }

    #[test]
    fn enabled_hook_records_labels_and_time() {
        let p = Profiler::new();
        let out = layer(
            Some(&p),
            || "conv1".to_string(),
            "qconv",
            Some(Method::XnorFused),
            128,
            || 7,
        );
        assert_eq!(out, 7);
        let recs = p.take();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].name, "conv1");
        assert_eq!(recs[0].kind, "qconv");
        assert_eq!(recs[0].bytes, 128);
        assert_eq!(recs[0].method, Some("xnor_fused"));
        assert!(recs[0].kernel.is_some(), "binary gemm must resolve a kernel");
    }

    #[test]
    fn from_runs_aggregates_by_name_across_reps() {
        let records = vec![rec("a", 100), rec("b", 300), rec("a", 300), rec("b", 500)];
        let totals = [Duration::from_micros(400), Duration::from_micros(900)];
        let r = ProfileReport::from_runs("lenet", 4, 2, "test".into(), false, &totals, records);
        assert_eq!(r.layers.len(), 2);
        assert_eq!(r.layers[0].name, "a");
        // median of {0.1ms, 0.3ms}
        assert!((r.layers[0].stats.median - 0.2).abs() < 1e-9);
        assert_eq!(r.layers[0].stats.reps, 2);
        assert!((r.layers[1].stats.median - 0.4).abs() < 1e-9);
        assert!((r.layers[0].stats.min - 0.1).abs() < 1e-9, "min is the noise-free bound");
        assert!((r.total.median - 0.65).abs() < 1e-9);
        assert_eq!(r.total.reps, 2);
    }

    fn sample_report() -> ProfileReport {
        ProfileReport::from_runs(
            "lenet",
            2,
            1,
            "x86_64 · method xnor_fused · kernel avx2".into(),
            false,
            &[Duration::from_micros(900)],
            vec![
                rec("conv1", 600),
                LayerRecord {
                    name: "bn1".into(),
                    kind: "batchnorm",
                    wall: Duration::from_micros(50),
                    bytes: 256,
                    method: None,
                    kernel: None,
                },
            ],
        )
    }

    #[test]
    fn perf_record_has_total_and_annotated_layer_cells() {
        let rec = sample_report().to_perf_record("unit test");
        assert_eq!(rec.bench, "profile");
        assert_eq!(rec.provenance.tool, "unit test");
        assert_eq!(rec.provenance.reps, 1);
        assert!(rec.provenance.note.contains("batch 2"), "{}", rec.provenance.note);
        let total = rec.cell("forward/total").unwrap();
        assert!((total.stats.median - 0.9).abs() < 1e-9);
        let conv = rec.cell("layer/conv1").unwrap();
        assert!((conv.stats.median - 0.6).abs() < 1e-9);
        assert!(conv.note.contains("kind=conv_f32"));
        assert!(conv.note.contains("method=xnor_fused") && conv.note.contains("kernel=avx2"));
        assert!(conv.note.contains("bytes=4096"));
        let bn = rec.cell("layer/bn1").unwrap();
        assert!(!bn.note.contains("method="), "non-gemm layer has no method: {}", bn.note);
    }

    #[test]
    fn json_report_parses_as_perf_record_with_extras() {
        let r = sample_report();
        let text = r.render_json();
        // parseable as a plain schema-2 record (extras ignored)…
        let rec = PerfRecord::parse(&text).unwrap();
        assert_eq!(rec.bench, "profile");
        assert_eq!(rec.cells.len(), 3);
        // …and the convenience keys are there for humans/dashboards
        let doc = crate::model::json::parse(&text).unwrap();
        assert_eq!(doc.get("model").and_then(|v| v.as_str()), Some("lenet"));
        assert_eq!(doc.get("arch").and_then(|v| v.as_str()), Some("lenet"));
        assert_eq!(doc.get("batch").and_then(|v| v.as_usize()), Some(2));
        assert!(doc.get("total_ms").and_then(|v| v.as_f64()).unwrap() > 0.0);
        let table = r.render_table();
        assert!(table.contains("conv1") && table.contains("xnor_fused"));
        assert!(table.contains("mad"), "table reports the noise floor");
    }
}
