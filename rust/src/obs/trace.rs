//! Request-scoped span tracing.
//!
//! A [`Trace`] lives with its connection for the duration of one request
//! and records *cumulative* microsecond offsets from request start at the
//! end of each pipeline stage:
//!
//! ```text
//! read → parse → admission → queue_wait → batch_window → forward → respond → write
//! ```
//!
//! `read` (socket → complete request bytes) and `write` (response bytes →
//! socket flushed) are stamped by the reactor's event-loop worker; with a
//! non-blocking gateway both can span many readiness polls, which is
//! exactly why they are worth tracing.  `parse`, `admission` and `respond`
//! are stamped on the same worker ([`Trace::mark`]); the middle three
//! happen inside the batcher on another thread, so the coordinator
//! measures them per-request ([`BatchTiming`] rides back on the
//! `Response`) and the gateway anchors them after its own admission stamp
//! ([`Trace::absorb_batch_timing`]).
//! Because each absorbed offset is `previous + delta`, stage offsets are
//! monotone by construction — the property `rust/tests` assert.
//!
//! [`Trace::finish`] freezes the builder into a [`TraceRecord`]: a
//! fixed-size, heap-free POD (the model name is truncated into an inline
//! byte array) that the [`super::journal::Journal`] can store without
//! allocating on the hot path.

use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Pipeline stages in order. `index()` is the array slot everywhere a
/// `[u64; Stage::COUNT]` appears (trace records, stage histograms).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Request bytes read off the socket (first byte → body complete);
    /// spans many readiness polls on a slow client.
    Read,
    /// Body decoded (JSON or binary) + image tensor built.
    Parse,
    /// Shard chosen and the request accepted into a bounded queue.
    Admission,
    /// Waiting in the shard queue before the batcher picked it up.
    QueueWait,
    /// Held while the batcher waited for the batch window to fill.
    BatchWindow,
    /// Engine forward pass (amortised across the whole batch).
    Forward,
    /// Response serialized and queued on the connection.
    Respond,
    /// Response bytes flushed to the socket (spans partial writes).
    Write,
}

impl Stage {
    pub const COUNT: usize = 8;

    pub fn all() -> [Stage; Stage::COUNT] {
        [
            Stage::Read,
            Stage::Parse,
            Stage::Admission,
            Stage::QueueWait,
            Stage::BatchWindow,
            Stage::Forward,
            Stage::Respond,
            Stage::Write,
        ]
    }

    pub fn index(self) -> usize {
        match self {
            Stage::Read => 0,
            Stage::Parse => 1,
            Stage::Admission => 2,
            Stage::QueueWait => 3,
            Stage::BatchWindow => 4,
            Stage::Forward => 5,
            Stage::Respond => 6,
            Stage::Write => 7,
        }
    }

    /// Stable label used in `/metrics` (`stage="..."`) and trace JSON.
    pub fn label(self) -> &'static str {
        match self {
            Stage::Read => "read",
            Stage::Parse => "parse",
            Stage::Admission => "admission",
            Stage::QueueWait => "queue_wait",
            Stage::BatchWindow => "batch_window",
            Stage::Forward => "forward",
            Stage::Respond => "respond",
            Stage::Write => "write",
        }
    }
}

/// Sentinel for "stage never reached" (e.g. a 400 stops after parse).
pub const UNSET: u64 = u64::MAX;

/// Inline capacity for the model name in a [`TraceRecord`]. Longer names
/// are truncated on a UTF-8 boundary — traces are diagnostics, not a
/// registry; the journal must not allocate.
pub const NAME_CAP: usize = 24;

/// Per-request timing breakdown (µs) measured inside the coordinator's
/// batcher and carried back on `coordinator::Response`. All three are
/// durations, not offsets: `queue_us` is submit→dequeue, `window_us` is
/// dequeue→forward-start, `forward_us` is the batch forward wall time
/// (shared by every request in the batch).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchTiming {
    pub queue_us: u64,
    pub window_us: u64,
    pub forward_us: u64,
}

/// A completed, fixed-size trace. `Copy`, no heap — storable in the
/// journal's atomic slots and reconstructable from them.
#[derive(Debug, Clone, Copy)]
pub struct TraceRecord {
    /// Journal sequence number (assigned at publish; 0 before).
    pub id: u64,
    /// Wall-clock request start, µs since the Unix epoch.
    pub start_unix_us: u64,
    pub name: [u8; NAME_CAP],
    pub name_len: u8,
    /// Cumulative µs offset from request start at each stage end;
    /// [`UNSET`] where the request never reached the stage.
    pub stages: [u64; Stage::COUNT],
    pub total_us: u64,
    /// HTTP status the request resolved to.
    pub status: u16,
    /// Pool shard that served it (0 when it never reached a shard).
    pub shard: u16,
    /// Batch size it was served in (0 when it never reached the batcher).
    pub batch: u16,
}

impl TraceRecord {
    /// The (possibly truncated) model name.
    pub fn model(&self) -> &str {
        std::str::from_utf8(&self.name[..self.name_len as usize]).unwrap_or("?")
    }

    /// Duration spent *in* one stage: its offset minus the previous
    /// reached stage's offset. `None` when the stage was never reached.
    pub fn stage_us(&self, s: Stage) -> Option<u64> {
        let off = self.stages[s.index()];
        if off == UNSET {
            return None;
        }
        let prev = self.stages[..s.index()]
            .iter()
            .rev()
            .find(|&&v| v != UNSET)
            .copied()
            .unwrap_or(0);
        Some(off.saturating_sub(prev))
    }
}

/// Request-scoped trace builder. Stack-allocated; nothing here touches
/// the heap (asserted by `rust/tests/profiler_overhead.rs`).
pub struct Trace {
    start: Instant,
    start_unix_us: u64,
    stages: [u64; Stage::COUNT],
}

impl Trace {
    pub fn begin() -> Trace {
        Trace {
            start: Instant::now(),
            start_unix_us: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_micros() as u64)
                .unwrap_or(0),
            stages: [UNSET; Stage::COUNT],
        }
    }

    /// Highest offset recorded for any stage before `idx` (0 if none) —
    /// the monotonicity floor for new stamps.
    fn floor(&self, idx: usize) -> u64 {
        self.stages[..idx]
            .iter()
            .rev()
            .find(|&&v| v != UNSET)
            .copied()
            .unwrap_or(0)
    }

    /// Stamp a stage at "now", clamped so offsets stay monotone even if
    /// the monotonic clock reads equal across adjacent calls.
    pub fn mark(&mut self, s: Stage) {
        let now = self.start.elapsed().as_micros() as u64;
        self.stages[s.index()] = now.max(self.floor(s.index()));
    }

    /// Fill queue-wait / batch-window / forward from the batcher's own
    /// per-request measurements, anchored after the admission stamp.
    /// Offsets are cumulative sums of durations, so monotone by
    /// construction.
    pub fn absorb_batch_timing(&mut self, t: &BatchTiming) {
        let anchor = self.floor(Stage::QueueWait.index());
        let q = anchor.saturating_add(t.queue_us);
        let w = q.saturating_add(t.window_us);
        let f = w.saturating_add(t.forward_us);
        self.stages[Stage::QueueWait.index()] = q;
        self.stages[Stage::BatchWindow.index()] = w;
        self.stages[Stage::Forward.index()] = f;
    }

    pub fn elapsed_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    /// Freeze into a fixed-size record. `total_us` is clamped up to the
    /// largest stage offset so absorbed batcher time can never exceed it.
    pub fn finish(&self, model: &str, status: u16, shard: u16, batch: u16) -> TraceRecord {
        let bytes = model.as_bytes();
        let mut len = bytes.len().min(NAME_CAP);
        if len < bytes.len() {
            // don't split a multi-byte UTF-8 character on truncation
            while len > 0 && bytes[len] & 0xC0 == 0x80 {
                len -= 1;
            }
        }
        let mut name = [0u8; NAME_CAP];
        name[..len].copy_from_slice(&bytes[..len]);
        let total = self.elapsed_us().max(self.floor(Stage::COUNT));
        TraceRecord {
            id: 0,
            start_unix_us: self.start_unix_us,
            name,
            name_len: len as u8,
            stages: self.stages,
            total_us: total,
            status,
            shard,
            batch,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mark_and_absorb_keep_offsets_monotone() {
        let mut t = Trace::begin();
        t.mark(Stage::Read);
        t.mark(Stage::Parse);
        t.mark(Stage::Admission);
        t.absorb_batch_timing(&BatchTiming { queue_us: 10, window_us: 0, forward_us: 250 });
        t.mark(Stage::Respond);
        t.mark(Stage::Write);
        let rec = t.finish("lenet_bin", 200, 1, 4);
        let mut prev = 0u64;
        let mut named = 0;
        for s in Stage::all() {
            let off = rec.stages[s.index()];
            assert_ne!(off, UNSET, "stage {} unset", s.label());
            assert!(off >= prev, "stage {} offset {off} < previous {prev}", s.label());
            prev = off;
            named += 1;
        }
        assert_eq!(named, 8);
        assert!(rec.total_us >= prev, "total below last stage offset");
    }

    #[test]
    fn stage_us_returns_durations_relative_to_previous_reached_stage() {
        let mut t = Trace::begin();
        t.mark(Stage::Parse);
        t.mark(Stage::Admission);
        t.absorb_batch_timing(&BatchTiming { queue_us: 7, window_us: 3, forward_us: 90 });
        let rec = t.finish("m", 200, 0, 1);
        assert_eq!(rec.stage_us(Stage::QueueWait), Some(7));
        assert_eq!(rec.stage_us(Stage::BatchWindow), Some(3));
        assert_eq!(rec.stage_us(Stage::Forward), Some(90));
        assert_eq!(rec.stage_us(Stage::Respond), None);
    }

    #[test]
    fn unreached_stages_stay_unset() {
        let mut t = Trace::begin();
        t.mark(Stage::Parse);
        let rec = t.finish("m", 400, 0, 0);
        assert_ne!(rec.stages[Stage::Parse.index()], UNSET);
        for s in [Stage::Admission, Stage::QueueWait, Stage::BatchWindow, Stage::Forward] {
            assert_eq!(rec.stages[s.index()], UNSET);
            assert_eq!(rec.stage_us(s), None);
        }
        assert_eq!(rec.status, 400);
    }

    #[test]
    fn long_names_truncate_on_utf8_boundary() {
        let long = "model_with_a_really_long_name_αβγδ";
        let mut t = Trace::begin();
        t.mark(Stage::Parse);
        let rec = t.finish(long, 200, 0, 1);
        assert!(rec.name_len as usize <= NAME_CAP);
        let m = rec.model();
        assert!(long.starts_with(m), "{m:?} is not a prefix of {long:?}");
        assert_ne!(m, "?", "truncation split a UTF-8 character");
    }
}
