//! Byte-exact model size accounting — the "Model Size" columns of
//! Table 1 and Table 2.
//!
//! The inventory mirrors the parameter lists of `python/compile/lenet.py`
//! and `python/compile/resnet.py` (plus the 224×224 ImageNet stem variant
//! the paper's Table 2 numbers come from) and computes:
//!
//! * `fp32_bytes`  — every parameter and BN statistic stored as f32;
//! * `bmx_bytes`   — binary-layer weights packed to 1 bit (64-bit words per
//!   output row, as the converter stores them), everything else f32.
//!
//! The paper reports ResNet-18: 44.7 MB fp32 → 1.5 MB binary (29×, Table 1)
//! and the 3.6→47 MB Table 2 ladder; those ratios fall out of this
//! accounting exactly (see `benches/table1_sizes.rs`).

/// One parameter tensor in a model.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// True if the `.bmx` converter packs this tensor to 1 bit/weight.
    pub binary: bool,
}

impl ParamSpec {
    fn fp(name: impl Into<String>, shape: Vec<usize>) -> Self {
        Self { name: name.into(), shape, binary: false }
    }

    fn bin(name: impl Into<String>, shape: Vec<usize>) -> Self {
        Self { name: name.into(), shape, binary: true }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Bytes in the packed `.bmx` encoding: binary weights are stored as
    /// one u64-word row per output channel; f32 otherwise.
    pub fn bmx_bytes(&self) -> usize {
        if self.binary {
            let out = self.shape[0];
            let k: usize = self.shape[1..].iter().product();
            out * k.div_ceil(64) * 8
        } else {
            4 * self.numel()
        }
    }
}

/// A model's full parameter inventory.
#[derive(Debug, Clone)]
pub struct Inventory {
    pub params: Vec<ParamSpec>,
}

impl Inventory {
    pub fn fp32_bytes(&self) -> usize {
        self.params.iter().map(|p| 4 * p.numel()).sum()
    }

    pub fn bmx_bytes(&self) -> usize {
        self.params.iter().map(|p| p.bmx_bytes()).sum()
    }

    pub fn numel(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    pub fn compression(&self) -> f64 {
        self.fp32_bytes() as f64 / self.bmx_bytes() as f64
    }

    /// Names of tensors the converter must pack.
    pub fn binary_names(&self) -> Vec<String> {
        self.params
            .iter()
            .filter(|p| p.binary)
            .map(|p| p.name.clone())
            .collect()
    }
}

impl Inventory {
    /// Deterministic synthetic checkpoint matching this inventory: every
    /// parameter filled from a splitmix-style LCG of `seed`, with BN
    /// variances forced positive and the `params.`/`state.` name prefixes
    /// the converter expects.  This is how tests, benches and the serving
    /// smoke path build loadable models without trained artifacts.
    pub fn synthetic_checkpoint(&self, seed: u64) -> super::ckpt::Checkpoint {
        let mut ck = super::ckpt::Checkpoint::new();
        let mut s = seed.max(1);
        for p in &self.params {
            let n = p.numel();
            let data: Vec<f32> = (0..n)
                .map(|_| {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                    let v = ((s >> 33) as f32 / (1u64 << 31) as f32) - 1.0;
                    v * 0.1
                })
                .collect();
            let name = if p.name.starts_with("state.") {
                p.name.clone()
            } else {
                format!("params.{}", p.name)
            };
            // variances must be positive
            let data = if name.contains(".var") {
                data.iter().map(|v| v.abs() + 0.5).collect()
            } else {
                data
            };
            ck.push_f32(&name, p.shape.clone(), data);
        }
        ck
    }
}

fn bn(v: &mut Vec<ParamSpec>, name: &str, ch: usize) {
    v.push(ParamSpec::fp(format!("{name}.gamma"), vec![ch]));
    v.push(ParamSpec::fp(format!("{name}.beta"), vec![ch]));
    // running stats ship with the deployed model
    v.push(ParamSpec::fp(format!("state.{name}.mean"), vec![ch]));
    v.push(ParamSpec::fp(format!("state.{name}.var"), vec![ch]));
}

/// LeNet inventory (Table 1 row 1).  `binary` selects Listing 2 vs 1.
pub fn lenet(binary: bool) -> Inventory {
    let mut p = Vec::new();
    p.push(ParamSpec::fp("conv1.w", vec![32, 1, 5, 5]));
    p.push(ParamSpec::fp("conv1.b", vec![32]));
    bn(&mut p, "bn1", 32);
    if binary {
        p.push(ParamSpec::bin("conv2.w", vec![64, 32, 5, 5]));
    } else {
        p.push(ParamSpec::fp("conv2.w", vec![64, 32, 5, 5]));
        p.push(ParamSpec::fp("conv2.b", vec![64]));
    }
    bn(&mut p, "bn2", 64);
    if binary {
        p.push(ParamSpec::bin("fc1.w", vec![512, 64 * 4 * 4]));
    } else {
        p.push(ParamSpec::fp("fc1.w", vec![512, 64 * 4 * 4]));
        p.push(ParamSpec::fp("fc1.b", vec![512]));
    }
    bn(&mut p, "bn3", 512);
    p.push(ParamSpec::fp("fc2.w", vec![10, 512]));
    p.push(ParamSpec::fp("fc2.b", vec![10]));
    Inventory { params: p }
}

/// Stem style: CIFAR (3×3 s1) or ImageNet (7×7 s2) — affects sizes only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stem {
    Cifar,
    Imagenet,
}

/// ResNet-18 inventory with stage-wise binarization (Tables 1 and 2).
///
/// `fp_stages` lists 1-based stages kept full precision.  The stem conv,
/// downsample convs and the final FC are always full precision (§3.2).
pub fn resnet18(width: usize, classes: usize, stem: Stem, fp_stages: &[usize]) -> Inventory {
    let widths = [width, width * 2, width * 4, width * 8];
    let mut p = Vec::new();
    match stem {
        Stem::Cifar => p.push(ParamSpec::fp("stem.w", vec![widths[0], 3, 3, 3])),
        Stem::Imagenet => p.push(ParamSpec::fp("stem.w", vec![widths[0], 3, 7, 7])),
    }
    bn(&mut p, "stem_bn", widths[0]);
    let mut in_ch = widths[0];
    for s in 1..=4 {
        let out = widths[s - 1];
        let binary = !fp_stages.contains(&s);
        for b in 1..=2 {
            let name = format!("s{s}b{b}");
            let stride2 = s > 1 && b == 1;
            let mk = |n: String, shape: Vec<usize>| {
                if binary {
                    ParamSpec::bin(n, shape)
                } else {
                    ParamSpec::fp(n, shape)
                }
            };
            p.push(mk(format!("{name}.conv1.w"), vec![out, in_ch, 3, 3]));
            bn(&mut p, &format!("{name}.bn1"), out);
            p.push(mk(format!("{name}.conv2.w"), vec![out, out, 3, 3]));
            bn(&mut p, &format!("{name}.bn2"), out);
            if stride2 || in_ch != out {
                p.push(ParamSpec::fp(format!("{name}.down.w"), vec![out, in_ch, 1, 1]));
                bn(&mut p, &format!("{name}.down_bn"), out);
            }
            in_ch = out;
        }
    }
    p.push(ParamSpec::fp("fc.w", vec![classes, widths[3]]));
    p.push(ParamSpec::fp("fc.b", vec![classes]));
    Inventory { params: p }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: f64 = 1024.0 * 1024.0;

    #[test]
    fn resnet18_imagenet_fp_matches_paper_47mb() {
        // Table 2 "All" row: 47 MB; our accounting includes BN run-stats.
        let inv = resnet18(64, 1000, Stem::Imagenet, &[1, 2, 3, 4]);
        let mb = inv.fp32_bytes() as f64 / MB;
        assert!((43.0..48.0).contains(&mb), "fp ResNet-18 = {mb:.1} MB");
    }

    #[test]
    fn resnet18_imagenet_binary_matches_paper_3_6mb() {
        // Table 2 "none" row: 3.6 MB fully binarized.
        let inv = resnet18(64, 1000, Stem::Imagenet, &[]);
        let mb = inv.bmx_bytes() as f64 / MB;
        assert!((2.5..4.2).contains(&mb), "binary ResNet-18 = {mb:.1} MB");
    }

    #[test]
    fn resnet18_cifar_compression_near_29x() {
        // Table 1 row 2: 44.7 MB -> 1.5 MB is ~29x.
        let inv = resnet18(64, 10, Stem::Cifar, &[]);
        let c = inv.compression();
        assert!((20.0..32.0).contains(&c), "compression {c:.1}x");
    }

    #[test]
    fn table2_sizes_strictly_increase_with_fp_stages() {
        let cfgs: [&[usize]; 7] = [&[], &[1], &[2], &[3], &[4], &[1, 2], &[1, 2, 3, 4]];
        let sizes: Vec<usize> = cfgs
            .iter()
            .map(|fp| resnet18(64, 1000, Stem::Imagenet, fp).bmx_bytes())
            .collect();
        // none < fp1 < fp2 < fp3 < fp4 (later stages are wider)
        assert!(sizes[0] < sizes[1]);
        assert!(sizes[1] < sizes[2]);
        assert!(sizes[2] < sizes[3]);
        assert!(sizes[3] < sizes[4]);
        // fp12 between fp2 and fp3; all-fp the largest
        assert!(sizes[5] > sizes[2] && sizes[5] < sizes[4]);
        assert!(sizes[6] > sizes[4]);
    }

    #[test]
    fn lenet_binary_smaller_than_fp() {
        let fp = lenet(false);
        let bin = lenet(true);
        assert!(bin.bmx_bytes() < fp.fp32_bytes() / 4);
        // conv1/fc2 stay fp in both
        assert!(bin.binary_names() == vec!["conv2.w", "fc1.w"]);
    }

    #[test]
    fn binary_packing_rounds_to_words() {
        let p = ParamSpec::bin("w", vec![3, 70]); // 70 bits -> 2 words
        assert_eq!(p.bmx_bytes(), 3 * 2 * 8);
    }

    #[test]
    fn synthetic_checkpoint_is_deterministic_and_complete() {
        let inv = lenet(true);
        let a = inv.synthetic_checkpoint(7);
        let b = inv.synthetic_checkpoint(7);
        let c = inv.synthetic_checkpoint(8);
        assert_eq!(a.len(), inv.params.len());
        for ((na, sa, da), (nb, _, db)) in a.tensors.iter().zip(&b.tensors) {
            assert_eq!(na, nb);
            assert_eq!(da.as_f32(), db.as_f32(), "{na} not deterministic");
            assert_eq!(sa.iter().product::<usize>(), da.len());
        }
        let same: usize = a
            .tensors
            .iter()
            .zip(&c.tensors)
            .filter(|((_, _, da), (_, _, dc))| da.as_f32() == dc.as_f32())
            .count();
        assert!(same < a.len(), "seed ignored: all tensors identical");
        // BN variances are strictly positive
        for (name, _, data) in &a.tensors {
            if name.contains(".var") {
                assert!(data.as_f32().unwrap().iter().all(|&v| v > 0.0), "{name}");
            }
        }
    }

    #[test]
    fn param_counts_match_known_formulas() {
        // fp LeNet parameter count (excluding BN run stats)
        let inv = lenet(false);
        let params: usize = inv
            .params
            .iter()
            .filter(|p| !p.name.starts_with("state."))
            .map(|p| p.numel())
            .sum();
        // conv1 832, conv2 51264, fc1 524800, fc2 5130, bns 2*(32+64+512)
        assert_eq!(params, 832 + 51264 + 524800 + 5130 + 2 * (32 + 64 + 512));
    }
}
