//! BMXC checkpoint format — byte-compatible with `python/compile/ckpt.py`.
//!
//! Layout (little-endian): magic `BMXC`, u32 version, u32 count, then per
//! tensor: u16 name-len + UTF-8 name, u8 dtype (0 = f32, 1 = u32), u8 ndim,
//! u32 dims, raw row-major data.

use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"BMXC";
const VERSION: u32 = 1;

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    U32,
}

/// Tensor payload.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    U32(Vec<u32>),
}

impl TensorData {
    pub fn dtype(&self) -> Dtype {
        match self {
            TensorData::F32(_) => Dtype::F32,
            TensorData::U32(_) => Dtype::U32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::U32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            TensorData::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_u32(&self) -> Option<&[u32]> {
        match self {
            TensorData::U32(v) => Some(v),
            _ => None,
        }
    }
}

/// A named-tensor container preserving insertion order.
#[derive(Debug, Clone, Default)]
pub struct Checkpoint {
    pub tensors: Vec<(String, Vec<usize>, TensorData)>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push_f32(&mut self, name: &str, shape: Vec<usize>, data: Vec<f32>) {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "{name}: shape/data mismatch");
        self.tensors.push((name.to_string(), shape, TensorData::F32(data)));
    }

    pub fn push_u32(&mut self, name: &str, shape: Vec<usize>, data: Vec<u32>) {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "{name}: shape/data mismatch");
        self.tensors.push((name.to_string(), shape, TensorData::U32(data)));
    }

    pub fn get(&self, name: &str) -> Option<(&[usize], &TensorData)> {
        self.tensors
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, s, d)| (s.as_slice(), d))
    }

    pub fn get_f32(&self, name: &str) -> Option<(&[usize], &[f32])> {
        let (s, d) = self.get(name)?;
        Some((s, d.as_f32()?))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Serialize to the BMXC wire format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, shape, data) in &self.tensors {
            let nb = name.as_bytes();
            out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
            out.extend_from_slice(nb);
            let code: u8 = match data.dtype() {
                Dtype::F32 => 0,
                Dtype::U32 => 1,
            };
            out.push(code);
            out.push(shape.len() as u8);
            for &d in shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            match data {
                TensorData::F32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                TensorData::U32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        out
    }

    /// Parse from the BMXC wire format.
    pub fn from_bytes(data: &[u8]) -> Result<Self> {
        let mut r = Cursor { data, pos: 0 };
        let magic = r.take(4)?;
        if magic != MAGIC {
            bail!("bad magic {magic:?} (expected BMXC)");
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!("unsupported BMXC version {version}");
        }
        let count = r.u32()? as usize;
        let mut ck = Checkpoint::new();
        for _ in 0..count {
            let nlen = r.u16()? as usize;
            let name = String::from_utf8(r.take(nlen)?.to_vec())
                .context("tensor name not UTF-8")?;
            let code = r.u8()?;
            let ndim = r.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(r.u32()? as usize);
            }
            // untrusted sizes: reject overflow instead of wrapping
            let nbytes = super::checked_numel(&shape)
                .and_then(|n| n.checked_mul(4))
                .ok_or_else(|| anyhow!("tensor {name} size overflows"))?;
            match code {
                0 => {
                    let raw = r.take(nbytes)?;
                    let v = raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    ck.tensors.push((name, shape, TensorData::F32(v)));
                }
                1 => {
                    let raw = r.take(nbytes)?;
                    let v = raw
                        .chunks_exact(4)
                        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    ck.tensors.push((name, shape, TensorData::U32(v)));
                }
                c => bail!("unknown dtype code {c} for tensor {name}"),
            }
        }
        Ok(ck)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {:?}", path.as_ref()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut buf = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {:?}", path.as_ref()))?
            .read_to_end(&mut buf)?;
        Self::from_bytes(&buf).with_context(|| format!("parse {:?}", path.as_ref()))
    }
}

struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // overflow-proof bounds check: n comes from untrusted size fields
        if n > self.data.len().saturating_sub(self.pos) {
            bail!("truncated BMXC file at byte {}", self.pos);
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_mixed_dtypes() {
        let mut ck = Checkpoint::new();
        ck.push_f32("a.w", vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, 5.5, -6.0]);
        ck.push_u32("a.packed", vec![4], vec![0, u32::MAX, 7, 42]);
        ck.push_f32("scalar", vec![], vec![9.0]);
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.len(), 3);
        for ((n1, s1, d1), (n2, s2, d2)) in ck.tensors.iter().zip(&back.tensors) {
            assert_eq!(n1, n2);
            assert_eq!(s1, s2);
            assert_eq!(d1, d2);
        }
    }

    #[test]
    fn scalar_shape_has_one_element() {
        let mut ck = Checkpoint::new();
        ck.push_f32("s", vec![], vec![3.25]);
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.get_f32("s").unwrap().1, &[3.25]);
    }

    #[test]
    fn rejects_bad_magic() {
        let err = Checkpoint::from_bytes(b"NOPE\x01\x00\x00\x00\x00\x00\x00\x00");
        assert!(err.is_err());
        assert!(format!("{:?}", err.unwrap_err()).contains("bad magic"));
    }

    #[test]
    fn rejects_truncation() {
        let mut ck = Checkpoint::new();
        ck.push_f32("x", vec![8], vec![0.0; 8]);
        let bytes = ck.to_bytes();
        for cut in [5, 12, 20, bytes.len() - 1] {
            assert!(Checkpoint::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn push_checks_shape() {
        Checkpoint::new().push_f32("x", vec![3], vec![0.0; 2]);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("bmxc_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bmxc");
        let mut ck = Checkpoint::new();
        ck.push_f32("w", vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.get_f32("w").unwrap().1, &[1.0, 2.0, 3.0, 4.0]);
        std::fs::remove_file(path).ok();
    }
}
