//! Minimal JSON parser for the artifact manifest.
//!
//! The offline build environment has no serde; this hand-rolled recursive
//! descent parser covers the full JSON grammar (objects, arrays, strings
//! with escapes, numbers, bools, null) which is all `manifest.json` needs.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("42").unwrap(), Value::Number(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Number(-350.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn escapes() {
        assert_eq!(
            parse(r#""a\nb\t\"c\" A""#).unwrap(),
            Value::String("a\nb\t\"c\" A".into())
        );
    }

    #[test]
    fn nested_structure() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": {"d": null}}"#).unwrap();
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].get("b"), Some(&Value::Bool(false)));
        assert_eq!(v.get("c").unwrap().get("d"), Some(&Value::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{'single': 1}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Array(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Object(BTreeMap::new()));
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" {\n \"k\" :\t[ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_array().unwrap().len(), 2);
    }

    #[test]
    fn accessor_type_mismatches_return_none() {
        let v = parse("[1]").unwrap();
        assert!(v.get("x").is_none());
        assert!(v.as_str().is_none());
        assert!(v.as_object().is_none());
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(parse("\"héllo ≤\"").unwrap(), Value::String("héllo ≤".into()));
    }
}
