//! Model storage: checkpoints, the packed `.bmx` format, the converter
//! (paper §2.2.3) and exact model-size inventories (Tables 1–2).
//!
//! * [`json`] — minimal JSON parser (offline env: no serde) for the
//!   artifact manifest emitted by `python/compile/aot.py`.
//! * [`ckpt`] — BMXC f32 checkpoint format shared with the Python side.
//! * [`bmx`] — the `.bmx` deployment format: Q-layer weights bit-packed to
//!   1 bit/weight, everything else f32; plus the f32→packed converter.
//! * [`inventory`] — byte-exact size accounting for LeNet and ResNet-18
//!   at full precision vs (partially) binarized — the model-size columns
//!   of Table 1 and Table 2.

pub mod bmx;
pub mod ckpt;
pub mod inventory;
pub mod json;

pub use bmx::{convert, BmxModel, BmxTensor};
pub use ckpt::{Checkpoint, Dtype, TensorData};

/// Element count of an untrusted shape; `None` on usize overflow.
/// Shared by the BMXC ([`ckpt`]) and `.bmx` ([`bmx`]) wire-format
/// parsers so hardening fixes cannot drift between them.
pub(crate) fn checked_numel(shape: &[usize]) -> Option<usize> {
    shape.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d))
}
