//! The `.bmx` deployment format + model converter (paper §2.2.3).
//!
//! After training, weights — including those of binary layers — live in f32
//! checkpoints.  The converter packs every Q-layer weight to 1 bit/weight
//! (64-bit BINARY_WORD rows, B-operand padding) and stores everything else
//! as f32, yielding the paper's ~29× size reduction for ResNet-18.
//!
//! Wire format (little-endian):
//!
//! ```text
//! magic  b"BMX1"
//! u32    version (1)
//! u32    meta length, then UTF-8 JSON metadata (arch, act_bit, ...)
//! u32    tensor count
//! per tensor:
//!     u16  name length + UTF-8 name
//!     u8   kind: 0 = f32, 1 = packed-binary
//!     u8   ndim, then u32 dims   (logical shape, pre-packing)
//!     packed only: u32 words_per_row
//!     payload: f32 LE  |  u64 LE words (rows * words_per_row)
//! ```

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use super::checked_numel;
use super::ckpt::Checkpoint;
use crate::gemm::{PackedMatrix, Side};

const MAGIC: &[u8; 4] = b"BMX1";
const VERSION: u32 = 1;

/// Bounds-checked cursor advance over the raw `.bmx` bytes.  The length
/// comparison is overflow-proof: `n` comes from untrusted size fields.
fn take<'a>(data: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
    if n > data.len().saturating_sub(*pos) {
        bail!("truncated .bmx at byte {pos}");
    }
    let s = &data[*pos..*pos + n];
    *pos += n;
    Ok(s)
}

/// One tensor in a `.bmx` model.
#[derive(Debug, Clone)]
pub enum BmxTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    /// Bit-packed binary weight: logical `shape` = [out, ...in dims...],
    /// packed row-major as `out` rows of `words_per_row` u64 words.
    Packed { shape: Vec<usize>, packed: PackedMatrix },
}

impl BmxTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            BmxTensor::F32 { shape, .. } | BmxTensor::Packed { shape, .. } => shape,
        }
    }

    /// Payload bytes (size accounting).
    pub fn payload_bytes(&self) -> usize {
        match self {
            BmxTensor::F32 { data, .. } => 4 * data.len(),
            BmxTensor::Packed { packed, .. } => packed.payload_bytes(),
        }
    }
}

/// A converted model: metadata + named tensors (insertion-ordered).
#[derive(Debug, Clone)]
pub struct BmxModel {
    /// Raw JSON metadata string (arch, act_bit, classes, ...).
    pub meta: String,
    pub tensors: Vec<(String, BmxTensor)>,
}

impl BmxModel {
    pub fn get(&self, name: &str) -> Option<&BmxTensor> {
        self.tensors.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    pub fn get_f32(&self, name: &str) -> Option<(&[usize], &[f32])> {
        match self.get(name)? {
            BmxTensor::F32 { shape, data } => Some((shape, data)),
            _ => None,
        }
    }

    pub fn get_packed(&self, name: &str) -> Option<(&[usize], &PackedMatrix)> {
        match self.get(name)? {
            BmxTensor::Packed { shape, packed } => Some((shape, packed)),
            _ => None,
        }
    }

    /// Total payload bytes (the number Tables 1–2 report, sans header).
    pub fn payload_bytes(&self) -> usize {
        self.tensors.iter().map(|(_, t)| t.payload_bytes()).sum()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        let mb = self.meta.as_bytes();
        out.extend_from_slice(&(mb.len() as u32).to_le_bytes());
        out.extend_from_slice(mb);
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            let nb = name.as_bytes();
            out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
            out.extend_from_slice(nb);
            match t {
                BmxTensor::F32 { shape, data } => {
                    out.push(0);
                    out.push(shape.len() as u8);
                    for &d in shape {
                        out.extend_from_slice(&(d as u32).to_le_bytes());
                    }
                    for x in data {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                BmxTensor::Packed { shape, packed } => {
                    out.push(1);
                    out.push(shape.len() as u8);
                    for &d in shape {
                        out.extend_from_slice(&(d as u32).to_le_bytes());
                    }
                    out.extend_from_slice(&(packed.words_per_row as u32).to_le_bytes());
                    for w in &packed.words {
                        out.extend_from_slice(&w.to_le_bytes());
                    }
                }
            }
        }
        out
    }

    pub fn from_bytes(data: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        if take(data, &mut pos, 4)? != MAGIC {
            bail!("bad .bmx magic");
        }
        let version = u32::from_le_bytes(take(data, &mut pos, 4)?.try_into().unwrap());
        if version != VERSION {
            bail!("unsupported .bmx version {version}");
        }
        let mlen = u32::from_le_bytes(take(data, &mut pos, 4)?.try_into().unwrap()) as usize;
        let meta = String::from_utf8(take(data, &mut pos, mlen)?.to_vec())
            .context("metadata not UTF-8")?;
        let count = u32::from_le_bytes(take(data, &mut pos, 4)?.try_into().unwrap()) as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let nlen = u16::from_le_bytes(take(data, &mut pos, 2)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(take(data, &mut pos, nlen)?.to_vec())
                .context("name not UTF-8")?;
            let kind = take(data, &mut pos, 1)?[0];
            let ndim = take(data, &mut pos, 1)?[0] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(
                    u32::from_le_bytes(take(data, &mut pos, 4)?.try_into().unwrap()) as usize,
                );
            }
            match kind {
                0 => {
                    let nbytes = checked_numel(&shape)
                        .and_then(|n| n.checked_mul(4))
                        .ok_or_else(|| anyhow!("{name}: tensor size overflows"))?;
                    let raw = take(data, &mut pos, nbytes)?;
                    let v = raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    tensors.push((name, BmxTensor::F32 { shape, data: v }));
                }
                1 => {
                    let wpr =
                        u32::from_le_bytes(take(data, &mut pos, 4)?.try_into().unwrap()) as usize;
                    let rows = *shape
                        .first()
                        .ok_or_else(|| anyhow!("{name}: packed tensor needs >= 1 dim"))?;
                    let k = checked_numel(&shape[1..])
                        .ok_or_else(|| anyhow!("{name}: tensor size overflows"))?;
                    if wpr != k.div_ceil(crate::gemm::pack::WORD_BITS) {
                        bail!("{name}: words_per_row {wpr} inconsistent with k = {k}");
                    }
                    let nbytes = rows
                        .checked_mul(wpr)
                        .and_then(|w| w.checked_mul(8))
                        .ok_or_else(|| anyhow!("{name}: packed payload overflows"))?;
                    let raw = take(data, &mut pos, nbytes)?;
                    let words = raw
                        .chunks_exact(8)
                        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    tensors.push((
                        name,
                        BmxTensor::Packed {
                            shape,
                            packed: PackedMatrix { rows, k, words_per_row: wpr, words },
                        },
                    ));
                }
                k => bail!("unknown tensor kind {k} for {name}"),
            }
        }
        Ok(BmxModel { meta, tensors })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {:?}", path.as_ref()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut buf = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {:?}", path.as_ref()))?
            .read_to_end(&mut buf)?;
        Self::from_bytes(&buf).with_context(|| format!("parse {:?}", path.as_ref()))
    }
}

/// Build a loadable synthetic-weight LeNet model: 1-bit packed when
/// `act_bit == 1`, else Eq. 1 `act_bit`-bit quantized (stored f32).
///
/// This is the one generator behind `bmxnet synth-models`, the registry
/// unit tests and the gateway integration test — the meta JSON and the
/// conversion call live here so the copies cannot drift.
pub fn synth_lenet(seed: u64, act_bit: u32) -> Result<BmxModel> {
    let inv = crate::model::inventory::lenet(true);
    let names = inv.binary_names();
    let ck = inv.synthetic_checkpoint(seed);
    let meta = format!(r#"{{"arch": "lenet", "binary": true, "act_bit": {act_bit}}}"#);
    if act_bit > 1 {
        convert_kbit(&ck, &names, act_bit, &meta)
    } else {
        convert(&ck, &names, &meta)
    }
}

/// The model converter (paper §2.2.3): pack the weights named in
/// `binary_names` (Q-layer weights, first dim = output channels) to 1
/// bit/weight; pass every other tensor through as f32.
pub fn convert(ckpt: &Checkpoint, binary_names: &[String], meta: &str) -> Result<BmxModel> {
    let binary: std::collections::BTreeSet<&str> =
        binary_names.iter().map(|s| s.as_str()).collect();
    let mut seen: BTreeMap<&str, bool> = binary_names.iter().map(|s| (s.as_str(), false)).collect();
    let mut tensors = Vec::with_capacity(ckpt.tensors.len());
    for (name, shape, data) in &ckpt.tensors {
        // ckpt names carry a "params." / "state." prefix; match on the tail
        let logical = name.strip_prefix("params.").unwrap_or(name);
        if binary.contains(logical) {
            let f = data
                .as_f32()
                .with_context(|| format!("{name}: binary weight must be f32"))?;
            let rows = shape[0];
            let k: usize = shape[1..].iter().product();
            let packed = PackedMatrix::pack_rows(f, rows, k, Side::B);
            tensors.push((
                logical.to_string(),
                BmxTensor::Packed { shape: shape.clone(), packed },
            ));
            if let Some(s) = seen.get_mut(logical) {
                *s = true;
            }
        } else {
            let f = data
                .as_f32()
                .with_context(|| format!("{name}: expected f32 tensor"))?;
            tensors.push((
                name.clone(),
                BmxTensor::F32 { shape: shape.clone(), data: f.to_vec() },
            ));
        }
    }
    if let Some((missing, _)) = seen.iter().find(|(_, s)| !**s) {
        bail!("binary weight {missing} not found in checkpoint");
    }
    Ok(BmxModel { meta: meta.to_string(), tensors })
}

/// k-bit variant of the converter (paper §2.1): the named Q-layer weights
/// are Eq. 1-quantized to 2^k levels but — exactly as BMXNet does for
/// act_bit in [2, 31] — **stored back as f32** (no packing; standard dot
/// products at inference).  Everything else passes through.
pub fn convert_kbit(
    ckpt: &Checkpoint,
    quant_names: &[String],
    k: u32,
    meta: &str,
) -> Result<BmxModel> {
    anyhow::ensure!(k > 1, "use convert() for 1-bit models");
    let quant: std::collections::BTreeSet<&str> =
        quant_names.iter().map(|s| s.as_str()).collect();
    let mut tensors = Vec::with_capacity(ckpt.tensors.len());
    for (name, shape, data) in &ckpt.tensors {
        let logical = name.strip_prefix("params.").unwrap_or(name);
        let f = data
            .as_f32()
            .with_context(|| format!("{name}: expected f32 tensor"))?;
        let out = if quant.contains(logical) {
            crate::quant::quantize_weights_kbit(f, k)
        } else {
            f.to_vec()
        };
        tensors.push((name.clone(), BmxTensor::F32 { shape: shape.clone(), data: out }));
    }
    Ok(BmxModel { meta: meta.to_string(), tensors })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::sign_binarize;

    fn sample_ckpt() -> Checkpoint {
        let mut ck = Checkpoint::new();
        ck.push_f32("params.conv.w", vec![4, 2, 3, 3], (0..72).map(|i| i as f32 - 36.0).collect());
        ck.push_f32("params.fc.w", vec![8, 70], (0..560).map(|i| (i % 7) as f32 - 3.0).collect());
        ck.push_f32("params.bn.gamma", vec![4], vec![1.0; 4]);
        ck.push_f32("state.bn.mean", vec![4], vec![0.5; 4]);
        ck
    }

    #[test]
    fn convert_packs_named_weights_only() {
        let ck = sample_ckpt();
        let m = convert(&ck, &["conv.w".into(), "fc.w".into()], "{}").unwrap();
        assert!(m.get_packed("conv.w").is_some());
        assert!(m.get_packed("fc.w").is_some());
        assert!(m.get_f32("params.bn.gamma").is_some());
        assert!(m.get_f32("state.bn.mean").is_some());
    }

    #[test]
    fn packed_bits_match_sign() {
        let ck = sample_ckpt();
        let m = convert(&ck, &["conv.w".into()], "{}").unwrap();
        let (shape, packed) = m.get_packed("conv.w").unwrap();
        assert_eq!(shape, &[4, 2, 3, 3]);
        let unpacked = packed.unpack();
        let (_, orig) = ck.get_f32("params.conv.w").unwrap();
        for (u, o) in unpacked.iter().zip(orig) {
            assert_eq!(*u, sign_binarize(*o));
        }
    }

    #[test]
    fn convert_rejects_missing_weight() {
        let ck = sample_ckpt();
        let err = convert(&ck, &["nope.w".into()], "{}");
        assert!(err.is_err());
    }

    #[test]
    fn roundtrip_bytes() {
        let ck = sample_ckpt();
        let m = convert(&ck, &["fc.w".into()], r#"{"arch":"test"}"#).unwrap();
        let back = BmxModel::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(back.meta, r#"{"arch":"test"}"#);
        assert_eq!(back.tensors.len(), m.tensors.len());
        let (s1, p1) = m.get_packed("fc.w").unwrap();
        let (s2, p2) = back.get_packed("fc.w").unwrap();
        assert_eq!(s1, s2);
        assert_eq!(p1, p2);
        let (_, g1) = m.get_f32("params.bn.gamma").unwrap();
        let (_, g2) = back.get_f32("params.bn.gamma").unwrap();
        assert_eq!(g1, g2);
    }

    #[test]
    fn compression_on_fc_dominant_model() {
        // fc.w is 8x70 f32 = 2240B fp; packed = 8 rows * 2 words * 8B = 128B
        let ck = sample_ckpt();
        let m = convert(&ck, &["conv.w".into(), "fc.w".into()], "{}").unwrap();
        let fp: usize = ck.tensors.iter().map(|(_, s, _)| 4 * s.iter().product::<usize>()).sum();
        assert!(m.payload_bytes() * 4 < fp, "{} vs {fp}", m.payload_bytes());
    }

    /// Header for a crafted single-tensor file: magic, version, empty
    /// meta, count 1, name "w", the given kind byte.
    fn crafted_header(kind: u8) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(b"BMX1");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&0u32.to_le_bytes()); // meta len 0
        b.extend_from_slice(&1u32.to_le_bytes()); // 1 tensor
        b.extend_from_slice(&1u16.to_le_bytes()); // name len
        b.push(b'w');
        b.push(kind);
        b
    }

    #[test]
    fn packed_tensor_without_dims_rejected() {
        // kind=1, ndim=0: must be a clean Err, not a shape[0] panic
        let mut b = crafted_header(1);
        b.push(0); // ndim = 0
        b.extend_from_slice(&1u32.to_le_bytes()); // words_per_row
        assert!(BmxModel::from_bytes(&b).is_err());
    }

    #[test]
    fn overflowing_shape_rejected_not_wrapped() {
        // dims whose product overflows usize must error, not wrap into a
        // tiny bogus payload length that silently misparses
        let mut b = crafted_header(0);
        b.push(4); // ndim = 4
        for _ in 0..4 {
            b.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        assert!(BmxModel::from_bytes(&b).is_err());
    }

    #[test]
    fn packed_words_per_row_mismatch_rejected() {
        // k = 70 needs 2 words/row; a file claiming 1 would build a
        // PackedMatrix whose row() slices lie about their length
        let mut b = crafted_header(1);
        b.push(2); // ndim = 2
        b.extend_from_slice(&1u32.to_le_bytes()); // rows
        b.extend_from_slice(&70u32.to_le_bytes()); // k
        b.extend_from_slice(&1u32.to_le_bytes()); // words_per_row (wrong)
        b.extend_from_slice(&[0u8; 8]); // 1 row x 1 word payload
        assert!(BmxModel::from_bytes(&b).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let ck = sample_ckpt();
        let m = convert(&ck, &[], "{}").unwrap();
        let bytes = m.to_bytes();
        assert!(BmxModel::from_bytes(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn convert_kbit_quantizes_named_only() {
        let ck = sample_ckpt();
        let m = convert_kbit(&ck, &["fc.w".into()], 2, "{}").unwrap();
        // quantized tensor keeps its full name and f32 storage
        let (_, q) = m.get_f32("params.fc.w").unwrap();
        let mut levels = std::collections::BTreeSet::new();
        for v in q {
            levels.insert(v.to_bits());
        }
        assert!(levels.len() <= 4, "k=2 must give <= 4 levels, got {}", levels.len());
        // unnamed tensor unchanged
        let (_, orig) = ck.get_f32("params.conv.w").unwrap();
        let (_, kept) = m.get_f32("params.conv.w").unwrap();
        assert_eq!(orig, kept);
        // no packing: same payload size as f32
        let fp: usize =
            ck.tensors.iter().map(|(_, s, _)| 4 * s.iter().product::<usize>()).sum();
        assert_eq!(m.payload_bytes(), fp);
    }

    #[test]
    fn convert_kbit_rejects_k1() {
        assert!(convert_kbit(&sample_ckpt(), &[], 1, "{}").is_err());
    }
}
