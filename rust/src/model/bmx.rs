//! The `.bmx` deployment format + model converter (paper §2.2.3).
//!
//! After training, weights — including those of binary layers — live in f32
//! checkpoints.  The converter packs every Q-layer weight to 1 bit/weight
//! (64-bit BINARY_WORD rows, B-operand padding) and stores everything else
//! as f32, yielding the paper's ~29× size reduction for ResNet-18.
//!
//! Wire format (little-endian):
//!
//! ```text
//! magic  b"BMX1"
//! u32    version (1 or 2; 2 adds tensor kind 2)
//! u32    meta length, then UTF-8 JSON metadata (arch, act_bit, ...)
//! u32    tensor count
//! per tensor:
//!     u16  name length + UTF-8 name
//!     u8   kind: 0 = f32, 1 = packed-binary, 2 = fold thresholds (v2)
//!     u8   ndim, then u32 dims   (logical shape, pre-packing)
//!     packed only: u32 words_per_row
//!     payload: f32 LE  |  u64 LE words (rows * words_per_row)
//!              |  per channel: u8 op (0=Ge 1=Le 2=ConstFalse 3=ConstTrue) + i32 LE threshold
//! ```
//!
//! Version 2 (`bmxnet convert --fold-thresholds` / [`fold_thresholds`])
//! replaces each {binary conv → BatchNorm → sign} triple's four f32 BN
//! vectors with one kind-2 threshold vector (5 bytes/channel instead of
//! 16) — smaller checkpoints *and* the integer-only folded forward with
//! no fold work at load.  Version-1 files keep loading unchanged; the
//! engine folds their legacy scale/shift at load time instead.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use super::checked_numel;
use super::ckpt::Checkpoint;
use crate::gemm::{ChannelRule, PackedMatrix, Side};

const MAGIC: &[u8; 4] = b"BMX1";
const VERSION: u32 = 2;

/// Bounds-checked cursor advance over the raw `.bmx` bytes.  The length
/// comparison is overflow-proof: `n` comes from untrusted size fields.
fn take<'a>(data: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
    if n > data.len().saturating_sub(*pos) {
        bail!("truncated .bmx at byte {pos}");
    }
    let s = &data[*pos..*pos + n];
    *pos += n;
    Ok(s)
}

/// One tensor in a `.bmx` model.
#[derive(Debug, Clone)]
pub enum BmxTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    /// Bit-packed binary weight: logical `shape` = [out, ...in dims...],
    /// packed row-major as `out` rows of `words_per_row` u64 words.
    Packed { shape: Vec<usize>, packed: PackedMatrix },
    /// Folded BN+sign thresholds (format v2): one [`ChannelRule`] per
    /// output channel of the binary layer this tensor belongs to.
    Thresholds { rules: Vec<ChannelRule> },
}

impl BmxTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            BmxTensor::F32 { shape, .. } | BmxTensor::Packed { shape, .. } => shape,
            BmxTensor::Thresholds { .. } => &[],
        }
    }

    /// Payload bytes (size accounting).
    pub fn payload_bytes(&self) -> usize {
        match self {
            BmxTensor::F32 { data, .. } => 4 * data.len(),
            BmxTensor::Packed { packed, .. } => packed.payload_bytes(),
            // u8 op + i32 threshold per channel
            BmxTensor::Thresholds { rules } => 5 * rules.len(),
        }
    }
}

/// A converted model: metadata + named tensors (insertion-ordered).
#[derive(Debug, Clone)]
pub struct BmxModel {
    /// Raw JSON metadata string (arch, act_bit, classes, ...).
    pub meta: String,
    pub tensors: Vec<(String, BmxTensor)>,
}

impl BmxModel {
    pub fn get(&self, name: &str) -> Option<&BmxTensor> {
        self.tensors.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    pub fn get_f32(&self, name: &str) -> Option<(&[usize], &[f32])> {
        match self.get(name)? {
            BmxTensor::F32 { shape, data } => Some((shape, data)),
            _ => None,
        }
    }

    pub fn get_packed(&self, name: &str) -> Option<(&[usize], &PackedMatrix)> {
        match self.get(name)? {
            BmxTensor::Packed { shape, packed } => Some((shape, packed)),
            _ => None,
        }
    }

    /// Folded thresholds for a binary layer, if this model carries them
    /// (format v2 / `--fold-thresholds`).
    pub fn get_thresholds(&self, name: &str) -> Option<&[ChannelRule]> {
        match self.get(name)? {
            BmxTensor::Thresholds { rules } => Some(rules),
            _ => None,
        }
    }

    /// Total payload bytes (the number Tables 1–2 report, sans header).
    pub fn payload_bytes(&self) -> usize {
        self.tensors.iter().map(|(_, t)| t.payload_bytes()).sum()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        let mb = self.meta.as_bytes();
        out.extend_from_slice(&(mb.len() as u32).to_le_bytes());
        out.extend_from_slice(mb);
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            let nb = name.as_bytes();
            out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
            out.extend_from_slice(nb);
            match t {
                BmxTensor::F32 { shape, data } => {
                    out.push(0);
                    out.push(shape.len() as u8);
                    for &d in shape {
                        out.extend_from_slice(&(d as u32).to_le_bytes());
                    }
                    for x in data {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                BmxTensor::Packed { shape, packed } => {
                    out.push(1);
                    out.push(shape.len() as u8);
                    for &d in shape {
                        out.extend_from_slice(&(d as u32).to_le_bytes());
                    }
                    out.extend_from_slice(&(packed.words_per_row as u32).to_le_bytes());
                    for w in &packed.words {
                        out.extend_from_slice(&w.to_le_bytes());
                    }
                }
                BmxTensor::Thresholds { rules } => {
                    out.push(2);
                    out.push(1); // ndim
                    out.extend_from_slice(&(rules.len() as u32).to_le_bytes());
                    for r in rules {
                        let (op, t): (u8, i32) = match *r {
                            ChannelRule::Ge(t) => (0, t),
                            ChannelRule::Le(t) => (1, t),
                            ChannelRule::Const(false) => (2, 0),
                            ChannelRule::Const(true) => (3, 0),
                        };
                        out.push(op);
                        out.extend_from_slice(&t.to_le_bytes());
                    }
                }
            }
        }
        out
    }

    pub fn from_bytes(data: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        if take(data, &mut pos, 4)? != MAGIC {
            bail!("bad .bmx magic");
        }
        let version = u32::from_le_bytes(take(data, &mut pos, 4)?.try_into().unwrap());
        if version == 0 || version > VERSION {
            bail!("unsupported .bmx version {version}");
        }
        let mlen = u32::from_le_bytes(take(data, &mut pos, 4)?.try_into().unwrap()) as usize;
        let meta = String::from_utf8(take(data, &mut pos, mlen)?.to_vec())
            .context("metadata not UTF-8")?;
        let count = u32::from_le_bytes(take(data, &mut pos, 4)?.try_into().unwrap()) as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let nlen = u16::from_le_bytes(take(data, &mut pos, 2)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(take(data, &mut pos, nlen)?.to_vec())
                .context("name not UTF-8")?;
            let kind = take(data, &mut pos, 1)?[0];
            let ndim = take(data, &mut pos, 1)?[0] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(
                    u32::from_le_bytes(take(data, &mut pos, 4)?.try_into().unwrap()) as usize,
                );
            }
            match kind {
                0 => {
                    let nbytes = checked_numel(&shape)
                        .and_then(|n| n.checked_mul(4))
                        .ok_or_else(|| anyhow!("{name}: tensor size overflows"))?;
                    let raw = take(data, &mut pos, nbytes)?;
                    let v = raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    tensors.push((name, BmxTensor::F32 { shape, data: v }));
                }
                1 => {
                    let wpr =
                        u32::from_le_bytes(take(data, &mut pos, 4)?.try_into().unwrap()) as usize;
                    let rows = *shape
                        .first()
                        .ok_or_else(|| anyhow!("{name}: packed tensor needs >= 1 dim"))?;
                    let k = checked_numel(&shape[1..])
                        .ok_or_else(|| anyhow!("{name}: tensor size overflows"))?;
                    if wpr != k.div_ceil(crate::gemm::pack::WORD_BITS) {
                        bail!("{name}: words_per_row {wpr} inconsistent with k = {k}");
                    }
                    let nbytes = rows
                        .checked_mul(wpr)
                        .and_then(|w| w.checked_mul(8))
                        .ok_or_else(|| anyhow!("{name}: packed payload overflows"))?;
                    let raw = take(data, &mut pos, nbytes)?;
                    let words = raw
                        .chunks_exact(8)
                        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    tensors.push((
                        name,
                        BmxTensor::Packed {
                            shape,
                            packed: PackedMatrix { rows, k, words_per_row: wpr, words },
                        },
                    ));
                }
                2 => {
                    let ch = *shape
                        .first()
                        .ok_or_else(|| anyhow!("{name}: threshold tensor needs 1 dim"))?;
                    let nbytes = ch
                        .checked_mul(5)
                        .ok_or_else(|| anyhow!("{name}: threshold payload overflows"))?;
                    let raw = take(data, &mut pos, nbytes)?;
                    let rules = raw
                        .chunks_exact(5)
                        .map(|c| {
                            let t = i32::from_le_bytes(c[1..5].try_into().unwrap());
                            match c[0] {
                                0 => Ok(ChannelRule::Ge(t)),
                                1 => Ok(ChannelRule::Le(t)),
                                2 => Ok(ChannelRule::Const(false)),
                                3 => Ok(ChannelRule::Const(true)),
                                op => bail!("{name}: unknown threshold op {op}"),
                            }
                        })
                        .collect::<Result<Vec<_>>>()?;
                    tensors.push((name, BmxTensor::Thresholds { rules }));
                }
                k => bail!("unknown tensor kind {k} for {name}"),
            }
        }
        Ok(BmxModel { meta, tensors })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {:?}", path.as_ref()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut buf = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {:?}", path.as_ref()))?
            .read_to_end(&mut buf)?;
        Self::from_bytes(&buf).with_context(|| format!("parse {:?}", path.as_ref()))
    }
}

/// Build a loadable synthetic-weight LeNet model: 1-bit packed when
/// `act_bit == 1`, else Eq. 1 `act_bit`-bit quantized (stored f32).
///
/// This is the one generator behind `bmxnet synth-models`, the registry
/// unit tests and the gateway integration test — the meta JSON and the
/// conversion call live here so the copies cannot drift.
pub fn synth_lenet(seed: u64, act_bit: u32) -> Result<BmxModel> {
    let inv = crate::model::inventory::lenet(true);
    let names = inv.binary_names();
    let ck = inv.synthetic_checkpoint(seed);
    let meta = format!(r#"{{"arch": "lenet", "binary": true, "act_bit": {act_bit}}}"#);
    if act_bit > 1 {
        convert_kbit(&ck, &names, act_bit, &meta)
    } else {
        convert(&ck, &names, &meta)
    }
}

/// The model converter (paper §2.2.3): pack the weights named in
/// `binary_names` (Q-layer weights, first dim = output channels) to 1
/// bit/weight; pass every other tensor through as f32.
pub fn convert(ckpt: &Checkpoint, binary_names: &[String], meta: &str) -> Result<BmxModel> {
    let binary: std::collections::BTreeSet<&str> =
        binary_names.iter().map(|s| s.as_str()).collect();
    let mut seen: BTreeMap<&str, bool> = binary_names.iter().map(|s| (s.as_str(), false)).collect();
    let mut tensors = Vec::with_capacity(ckpt.tensors.len());
    for (name, shape, data) in &ckpt.tensors {
        // ckpt names carry a "params." / "state." prefix; match on the tail
        let logical = name.strip_prefix("params.").unwrap_or(name);
        if binary.contains(logical) {
            let f = data
                .as_f32()
                .with_context(|| format!("{name}: binary weight must be f32"))?;
            let rows = shape[0];
            let k: usize = shape[1..].iter().product();
            let packed = PackedMatrix::pack_rows(f, rows, k, Side::B);
            tensors.push((
                logical.to_string(),
                BmxTensor::Packed { shape: shape.clone(), packed },
            ));
            if let Some(s) = seen.get_mut(logical) {
                *s = true;
            }
        } else {
            let f = data
                .as_f32()
                .with_context(|| format!("{name}: expected f32 tensor"))?;
            tensors.push((
                name.clone(),
                BmxTensor::F32 { shape: shape.clone(), data: f.to_vec() },
            ));
        }
    }
    if let Some((missing, _)) = seen.iter().find(|(_, s)| !**s) {
        bail!("binary weight {missing} not found in checkpoint");
    }
    Ok(BmxModel { meta: meta.to_string(), tensors })
}

/// k-bit variant of the converter (paper §2.1): the named Q-layer weights
/// are Eq. 1-quantized to 2^k levels but — exactly as BMXNet does for
/// act_bit in [2, 31] — **stored back as f32** (no packing; standard dot
/// products at inference).  Everything else passes through.
pub fn convert_kbit(
    ckpt: &Checkpoint,
    quant_names: &[String],
    k: u32,
    meta: &str,
) -> Result<BmxModel> {
    anyhow::ensure!(k > 1, "use convert() for 1-bit models");
    let quant: std::collections::BTreeSet<&str> =
        quant_names.iter().map(|s| s.as_str()).collect();
    let mut tensors = Vec::with_capacity(ckpt.tensors.len());
    for (name, shape, data) in &ckpt.tensors {
        let logical = name.strip_prefix("params.").unwrap_or(name);
        let f = data
            .as_f32()
            .with_context(|| format!("{name}: expected f32 tensor"))?;
        let out = if quant.contains(logical) {
            crate::quant::quantize_weights_kbit(f, k)
        } else {
            f.to_vec()
        };
        tensors.push((name.clone(), BmxTensor::F32 { shape: shape.clone(), data: out }));
    }
    Ok(BmxModel { meta: meta.to_string(), tensors })
}

/// Fold every {binary conv → BatchNorm → sign} triple the architecture
/// exposes into stored thresholds (format v2): each packed weight's BN
/// (gamma/beta/mean/var — 16 bytes/channel of f32) is removed and
/// replaced by one kind-2 threshold vector (5 bytes/channel) named
/// `thr.<layer>`.  The fold math is [`BatchNorm::fold_sign_rules`], so a
/// folded file loads into exactly the rules the engine would fold from
/// the legacy tensors at load time.
///
/// Foldable triples per architecture: LeNet `conv2 → bn2 → sign` (bn3
/// feeds tanh, not sign — not foldable); ResNet-18 `s*b*.conv1 → bn1 →
/// sign` for binary blocks (conv2's bn2 feeds the residual add).
/// Returns the folded-triple count; errors when there are none (k-bit
/// and fp models have no sign activation to fold).
///
/// [`BatchNorm::fold_sign_rules`]: crate::nn::layers::BatchNorm::fold_sign_rules
pub fn fold_thresholds(m: &mut BmxModel) -> Result<usize> {
    let meta = super::json::parse(&m.meta).map_err(|e| anyhow!("bad .bmx metadata: {e}"))?;
    let arch = meta
        .get("arch")
        .and_then(|v| v.as_str())
        .context("fold-thresholds: metadata missing \"arch\"")?
        .to_string();
    // (packed weight, BN prefix, threshold tensor name)
    let triples: Vec<(String, String, String)> = match arch.as_str() {
        "lenet" => vec![("conv2.w".into(), "bn2".into(), "thr.conv2".into())],
        "resnet18" => m
            .tensors
            .iter()
            .filter_map(|(name, t)| {
                if !matches!(t, BmxTensor::Packed { .. }) {
                    return None;
                }
                let base = name.strip_suffix(".conv1.w")?;
                Some((name.clone(), format!("{base}.bn1"), format!("thr.{base}.conv1")))
            })
            .collect(),
        other => bail!("fold-thresholds: unknown architecture {other:?}"),
    };
    let mut folded = 0usize;
    for (wname, bn_name, thr_name) in triples {
        let Some((_, packed)) = m.get_packed(&wname) else { continue };
        let (rows, k) = (packed.rows, packed.k);
        let getv = |n: String| -> Result<Vec<f32>> {
            Ok(m.get_f32(&n)
                .with_context(|| format!("fold-thresholds: missing tensor {n}"))?
                .1
                .to_vec())
        };
        let bn = crate::nn::layers::BatchNorm {
            gamma: getv(format!("params.{bn_name}.gamma"))?,
            beta: getv(format!("params.{bn_name}.beta"))?,
            mean: getv(format!("state.{bn_name}.mean"))?,
            var: getv(format!("state.{bn_name}.var"))?,
        };
        anyhow::ensure!(
            bn.gamma.len() == rows,
            "fold-thresholds: {bn_name} has {} channels, {wname} has {rows}",
            bn.gamma.len()
        );
        let rules = bn.fold_sign_rules(k);
        let dead = [
            format!("params.{bn_name}.gamma"),
            format!("params.{bn_name}.beta"),
            format!("state.{bn_name}.mean"),
            format!("state.{bn_name}.var"),
        ];
        m.tensors.retain(|(n, _)| !dead.contains(n));
        m.tensors.push((thr_name, BmxTensor::Thresholds { rules }));
        folded += 1;
    }
    anyhow::ensure!(
        folded > 0,
        "fold-thresholds: no {{binary conv → BatchNorm → sign}} triple found \
         (k-bit and fp models have nothing to fold)"
    );
    Ok(folded)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::sign_binarize;

    fn sample_ckpt() -> Checkpoint {
        let mut ck = Checkpoint::new();
        ck.push_f32("params.conv.w", vec![4, 2, 3, 3], (0..72).map(|i| i as f32 - 36.0).collect());
        ck.push_f32("params.fc.w", vec![8, 70], (0..560).map(|i| (i % 7) as f32 - 3.0).collect());
        ck.push_f32("params.bn.gamma", vec![4], vec![1.0; 4]);
        ck.push_f32("state.bn.mean", vec![4], vec![0.5; 4]);
        ck
    }

    #[test]
    fn convert_packs_named_weights_only() {
        let ck = sample_ckpt();
        let m = convert(&ck, &["conv.w".into(), "fc.w".into()], "{}").unwrap();
        assert!(m.get_packed("conv.w").is_some());
        assert!(m.get_packed("fc.w").is_some());
        assert!(m.get_f32("params.bn.gamma").is_some());
        assert!(m.get_f32("state.bn.mean").is_some());
    }

    #[test]
    fn packed_bits_match_sign() {
        let ck = sample_ckpt();
        let m = convert(&ck, &["conv.w".into()], "{}").unwrap();
        let (shape, packed) = m.get_packed("conv.w").unwrap();
        assert_eq!(shape, &[4, 2, 3, 3]);
        let unpacked = packed.unpack();
        let (_, orig) = ck.get_f32("params.conv.w").unwrap();
        for (u, o) in unpacked.iter().zip(orig) {
            assert_eq!(*u, sign_binarize(*o));
        }
    }

    #[test]
    fn convert_rejects_missing_weight() {
        let ck = sample_ckpt();
        let err = convert(&ck, &["nope.w".into()], "{}");
        assert!(err.is_err());
    }

    #[test]
    fn roundtrip_bytes() {
        let ck = sample_ckpt();
        let m = convert(&ck, &["fc.w".into()], r#"{"arch":"test"}"#).unwrap();
        let back = BmxModel::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(back.meta, r#"{"arch":"test"}"#);
        assert_eq!(back.tensors.len(), m.tensors.len());
        let (s1, p1) = m.get_packed("fc.w").unwrap();
        let (s2, p2) = back.get_packed("fc.w").unwrap();
        assert_eq!(s1, s2);
        assert_eq!(p1, p2);
        let (_, g1) = m.get_f32("params.bn.gamma").unwrap();
        let (_, g2) = back.get_f32("params.bn.gamma").unwrap();
        assert_eq!(g1, g2);
    }

    #[test]
    fn compression_on_fc_dominant_model() {
        // fc.w is 8x70 f32 = 2240B fp; packed = 8 rows * 2 words * 8B = 128B
        let ck = sample_ckpt();
        let m = convert(&ck, &["conv.w".into(), "fc.w".into()], "{}").unwrap();
        let fp: usize = ck.tensors.iter().map(|(_, s, _)| 4 * s.iter().product::<usize>()).sum();
        assert!(m.payload_bytes() * 4 < fp, "{} vs {fp}", m.payload_bytes());
    }

    /// Header for a crafted single-tensor file: magic, version, empty
    /// meta, count 1, name "w", the given kind byte.
    fn crafted_header(kind: u8) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(b"BMX1");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&0u32.to_le_bytes()); // meta len 0
        b.extend_from_slice(&1u32.to_le_bytes()); // 1 tensor
        b.extend_from_slice(&1u16.to_le_bytes()); // name len
        b.push(b'w');
        b.push(kind);
        b
    }

    #[test]
    fn packed_tensor_without_dims_rejected() {
        // kind=1, ndim=0: must be a clean Err, not a shape[0] panic
        let mut b = crafted_header(1);
        b.push(0); // ndim = 0
        b.extend_from_slice(&1u32.to_le_bytes()); // words_per_row
        assert!(BmxModel::from_bytes(&b).is_err());
    }

    #[test]
    fn overflowing_shape_rejected_not_wrapped() {
        // dims whose product overflows usize must error, not wrap into a
        // tiny bogus payload length that silently misparses
        let mut b = crafted_header(0);
        b.push(4); // ndim = 4
        for _ in 0..4 {
            b.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        assert!(BmxModel::from_bytes(&b).is_err());
    }

    #[test]
    fn packed_words_per_row_mismatch_rejected() {
        // k = 70 needs 2 words/row; a file claiming 1 would build a
        // PackedMatrix whose row() slices lie about their length
        let mut b = crafted_header(1);
        b.push(2); // ndim = 2
        b.extend_from_slice(&1u32.to_le_bytes()); // rows
        b.extend_from_slice(&70u32.to_le_bytes()); // k
        b.extend_from_slice(&1u32.to_le_bytes()); // words_per_row (wrong)
        b.extend_from_slice(&[0u8; 8]); // 1 row x 1 word payload
        assert!(BmxModel::from_bytes(&b).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let ck = sample_ckpt();
        let m = convert(&ck, &[], "{}").unwrap();
        let bytes = m.to_bytes();
        assert!(BmxModel::from_bytes(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn convert_kbit_quantizes_named_only() {
        let ck = sample_ckpt();
        let m = convert_kbit(&ck, &["fc.w".into()], 2, "{}").unwrap();
        // quantized tensor keeps its full name and f32 storage
        let (_, q) = m.get_f32("params.fc.w").unwrap();
        let mut levels = std::collections::BTreeSet::new();
        for v in q {
            levels.insert(v.to_bits());
        }
        assert!(levels.len() <= 4, "k=2 must give <= 4 levels, got {}", levels.len());
        // unnamed tensor unchanged
        let (_, orig) = ck.get_f32("params.conv.w").unwrap();
        let (_, kept) = m.get_f32("params.conv.w").unwrap();
        assert_eq!(orig, kept);
        // no packing: same payload size as f32
        let fp: usize =
            ck.tensors.iter().map(|(_, s, _)| 4 * s.iter().product::<usize>()).sum();
        assert_eq!(m.payload_bytes(), fp);
    }

    #[test]
    fn convert_kbit_rejects_k1() {
        assert!(convert_kbit(&sample_ckpt(), &[], 1, "{}").is_err());
    }

    #[test]
    fn fold_thresholds_replaces_bn2_with_smaller_thresholds() {
        let mut m = synth_lenet(3, 1).unwrap();
        let before = m.payload_bytes();
        assert_eq!(fold_thresholds(&mut m).unwrap(), 1);
        let (shape, packed) = m.get_packed("conv2.w").unwrap();
        let rules = m.get_thresholds("thr.conv2").unwrap();
        assert_eq!(rules.len(), shape[0]);
        assert!(m.get_f32("params.bn2.gamma").is_none(), "folded BN must be dropped");
        assert!(m.get_f32("state.bn2.var").is_none());
        // bn1 precedes a float conv and bn3 feeds tanh: both stay
        assert!(m.get_f32("params.bn1.gamma").is_some());
        assert!(m.get_f32("params.bn3.gamma").is_some());
        assert!(m.payload_bytes() < before, "thresholds must shrink the payload");
        // stored rules must equal a load-time fold of the original model
        let orig = synth_lenet(3, 1).unwrap();
        let bn = crate::nn::layers::BatchNorm {
            gamma: orig.get_f32("params.bn2.gamma").unwrap().1.to_vec(),
            beta: orig.get_f32("params.bn2.beta").unwrap().1.to_vec(),
            mean: orig.get_f32("state.bn2.mean").unwrap().1.to_vec(),
            var: orig.get_f32("state.bn2.var").unwrap().1.to_vec(),
        };
        assert_eq!(rules, &bn.fold_sign_rules(packed.k)[..]);
    }

    #[test]
    fn threshold_tensors_roundtrip_bytes() {
        let mut m = synth_lenet(4, 1).unwrap();
        fold_thresholds(&mut m).unwrap();
        let back = BmxModel::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(
            back.get_thresholds("thr.conv2").unwrap(),
            m.get_thresholds("thr.conv2").unwrap()
        );
        assert_eq!(back.tensors.len(), m.tensors.len());
    }

    #[test]
    fn version1_files_still_load() {
        // a v1 reader never wrote kind-2 tensors; a v2 reader must still
        // accept v1 bytes unchanged (loader back-compat)
        let m = synth_lenet(5, 1).unwrap();
        let mut bytes = m.to_bytes();
        assert_eq!(&bytes[4..8], &2u32.to_le_bytes(), "writer stamps v2");
        bytes[4..8].copy_from_slice(&1u32.to_le_bytes());
        let back = BmxModel::from_bytes(&bytes).unwrap();
        assert_eq!(back.tensors.len(), m.tensors.len());
        assert!(back.get_packed("conv2.w").is_some());
    }

    #[test]
    fn future_versions_rejected() {
        let m = synth_lenet(6, 1).unwrap();
        let mut bytes = m.to_bytes();
        bytes[4..8].copy_from_slice(&3u32.to_le_bytes());
        assert!(BmxModel::from_bytes(&bytes).is_err());
    }

    #[test]
    fn fold_thresholds_rejects_unfoldable_models() {
        // k-bit lenet stores conv2.w as f32 — nothing to fold
        let mut m = synth_lenet(7, 4).unwrap();
        assert!(fold_thresholds(&mut m).is_err());
    }
}
