//! Cache-blocked float GEMM — the `Cblas(Atlas)` stand-in of Figure 1.
//!
//! i-k-j loop order (unit-stride over B and C rows, LLVM auto-vectorizes
//! the inner loop), blocked over k and j to keep the working set in L1/L2.
//! On this box it reaches a few GFLOP/s single-threaded, playing the
//! "optimized float BLAS" role against which the xnor kernels are compared.

const KC: usize = 256; // k-panel: KC * 4B * (1 row A + NB cols B) << L2
const NC: usize = 1024; // j-panel kept hot across the i loop

/// C = A·B with A (m, k), B (k, n) row-major; returns C (m, n).
pub fn gemm_f32(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    for jc in (0..n).step_by(NC) {
        let nb = NC.min(n - jc);
        for kc in (0..k).step_by(KC) {
            let kb = KC.min(k - kc);
            for i in 0..m {
                let a_row = &a[i * k + kc..i * k + kc + kb];
                let c_row = &mut c[i * n + jc..i * n + jc + nb];
                for (kk, &aik) in a_row.iter().enumerate() {
                    let b_row = &b[(kc + kk) * n + jc..(kc + kk) * n + jc + nb];
                    // unit-stride fma loop; vectorizes cleanly
                    for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::super::naive;
    use super::*;

    #[test]
    fn matches_naive_small() {
        let a: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..12).map(|i| (i as f32) - 5.0).collect();
        assert_eq!(gemm_f32(&a, &b, 2, 4, 3), naive::gemm_f32(&a, &b, 2, 4, 3));
    }

    #[test]
    fn matches_naive_across_block_boundaries() {
        // k and n straddle KC/NC boundaries
        let (m, n, k) = (3, NC + 7, KC + 5);
        let a: Vec<f32> = (0..m * k).map(|i| ((i % 13) as f32) - 6.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| ((i % 7) as f32) - 3.0).collect();
        let got = gemm_f32(&a, &b, m, n, k);
        let expect = naive::gemm_f32(&a, &b, m, n, k);
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() <= 1e-2 * e.abs().max(1.0), "{g} vs {e}");
        }
    }

    #[test]
    fn exact_on_plus_minus_one() {
        // ±1 accumulations are exact in f32 up to 2^24: bitwise equality
        let (m, n, k) = (4, 33, 129);
        let a: Vec<f32> = (0..m * k).map(|i| if i % 3 == 0 { 1.0 } else { -1.0 }).collect();
        let b: Vec<f32> = (0..k * n).map(|i| if i % 5 == 0 { -1.0 } else { 1.0 }).collect();
        assert_eq!(gemm_f32(&a, &b, m, n, k), naive::gemm_f32(&a, &b, m, n, k));
    }
}
