//! Multi-threaded xnor_64 — the paper's `xnor_64_omp` (OpenMP) variant.
//!
//! Row-partitioned `std::thread::scope` parallelism: each worker owns a
//! disjoint band of C rows, so no synchronization is needed inside the
//! kernel (the same decomposition OpenMP's `parallel for` over `i` gives).
//! Each band runs the best available SIMD row kernel
//! ([`super::simd::best_kernel`]) — threads and SIMD compose, matching the
//! paper's OpenMP-over-intrinsics structure.
//!
//! NOTE: this box exposes a single core (`available_parallelism() == 1`),
//! so the measured speedup over the blocked single-thread kernel is ~1×;
//! the paper's 4-core machine showed ~2–3× on top of xnor_64.  Recorded in
//! EXPERIMENTS.md — the variant is still exercised by tests with forced
//! thread counts to validate the decomposition.

use super::pack::PackedMatrix;
use super::simd;
use super::xnor::blocked_rows_with;

/// Threads to use by default: one per available core.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map_or(1, |n| n.get())
}

/// Multi-threaded blocked xnor GEMM with an explicit thread count.
pub fn gemm_u64_mt_with(a: &PackedMatrix, b: &PackedMatrix, threads: usize) -> Vec<i32> {
    assert_eq!(a.k, b.k, "reduction length mismatch");
    let (m, n) = (a.rows, b.rows);
    let threads = threads.clamp(1, m.max(1));
    // Resolve the SIMD row kernel once for the whole GEMM (env read +
    // preference match), then share the fn pointer across workers: the
    // omp variant composes threading *on top of* the best row kernel.
    let row = simd::row_fn(simd::best_kernel());
    let mut c = vec![0i32; m * n];
    if threads == 1 {
        blocked_rows_with(a, b, &mut c, 0, m, 0, row);
        return c;
    }
    let rows_per = m.div_ceil(threads);
    // Split C into disjoint row bands; scoped threads borrow a and b.
    let mut bands: Vec<&mut [i32]> = Vec::with_capacity(threads);
    let mut rest = c.as_mut_slice();
    for t in 0..threads {
        let begin = t * rows_per;
        let end = ((t + 1) * rows_per).min(m);
        let take = end.saturating_sub(begin) * n;
        let (band, tail) = rest.split_at_mut(take);
        bands.push(band);
        rest = tail;
    }
    std::thread::scope(|s| {
        for (t, band) in bands.into_iter().enumerate() {
            let begin = t * rows_per;
            let end = ((t + 1) * rows_per).min(m);
            if begin >= end {
                continue;
            }
            s.spawn(move || {
                // band is rows [begin, end) of C; recompute indices locally
                let mut local = vec![0i32; (end - begin) * n];
                blocked_rows_with(a, b, &mut local, begin, end, begin, row);
                band.copy_from_slice(&local);
            });
        }
    });
    c
}

/// Multi-threaded blocked xnor GEMM with the default thread count.
pub fn gemm_u64_mt(a: &PackedMatrix, b: &PackedMatrix) -> Vec<i32> {
    gemm_u64_mt_with(a, b, default_threads())
}

#[cfg(test)]
mod tests {
    use super::super::pack::Side;
    use super::super::tests::lcg_floats;
    use super::super::xnor;
    use super::*;
    use crate::quant::sign_binarize;

    fn setup(m: usize, n: usize, k: usize) -> (PackedMatrix, PackedMatrix) {
        let a: Vec<f32> = lcg_floats(11, m * k).iter().map(|&x| sign_binarize(x)).collect();
        let b: Vec<f32> = lcg_floats(12, k * n).iter().map(|&x| sign_binarize(x)).collect();
        (
            PackedMatrix::pack_rows(&a, m, k, Side::A),
            PackedMatrix::pack_cols(&b, k, n),
        )
    }

    #[test]
    fn mt_matches_single_thread_for_all_thread_counts() {
        let (pa, pb) = setup(37, 53, 200);
        let expect = xnor::gemm_u64(&pa, &pb);
        for threads in [1, 2, 3, 4, 8, 37, 64] {
            assert_eq!(gemm_u64_mt_with(&pa, &pb, threads), expect, "threads={threads}");
        }
    }

    #[test]
    fn mt_handles_fewer_rows_than_threads() {
        let (pa, pb) = setup(2, 5, 64);
        let expect = xnor::gemm_u64(&pa, &pb);
        assert_eq!(gemm_u64_mt_with(&pa, &pb, 16), expect);
    }

    #[test]
    fn mt_single_row() {
        let (pa, pb) = setup(1, 9, 100);
        assert_eq!(gemm_u64_mt_with(&pa, &pb, 4), xnor::gemm_u64(&pa, &pb));
    }

    #[test]
    fn default_threads_at_least_one() {
        assert!(default_threads() >= 1);
    }
}
