//! The BMXNet xnor+popcount GEMM family (paper §2.2.1, Listing 3).
//!
//! Measured head-to-head by Figures 1–3:
//!
//! | variant           | paper name            | notes                           |
//! |-------------------|-----------------------|---------------------------------|
//! | [`naive::gemm_f32`]        | `naive gemm`  | i-j-k loop, column-strided B    |
//! | [`blocked::gemm_f32`]      | `Cblas(Atlas)`| register/cache-blocked float    |
//! | [`xnor::gemm_u32`]         | `xnor_32`     | Listing 3 on 32-bit words       |
//! | [`xnor::gemm_u64`]         | `xnor_64`     | Listing 3 on 64-bit words       |
//! | [`xnor::gemm_u64_blocked`] | —             | blocked + unrolled xnor_64      |
//! | [`parallel::gemm_u64_mt`]  | `xnor_64_omp` | row-partitioned threads × SIMD  |
//! | [`xnor::gemm_u64_blocked_with`] | `xnor_64_avx2` / `_avx512` / `_neon` | blocked with a pinned [`simd`] row kernel |
//! | [`fused::gemm_fused`]      | `xnor_fused`  | binarize→pack→GEMM, no packed-A buffer |
//!
//! Bit convention (shared with `python/compile/kernels/ref.py` and the
//! Pallas kernel): bit 1 encodes +1, bit 0 encodes −1, LSB-first within a
//! word.  A-side padding packs 1-bits and B-side padding packs 0-bits so
//! padded lanes xnor to 0 and the true dot is `2*pop − K` (no correction
//! term) — see [`pack`].

pub mod blocked;
pub mod dispatch;
pub mod fused;
pub mod naive;
pub mod pack;
pub mod parallel;
pub mod simd;
pub mod xnor;

pub use dispatch::{
    binary_gemm_f32, binary_gemm_packed_b, binary_gemm_packed_b_threshold, xnor_gemm_prepacked,
    Method,
};
pub use fused::{fold_bn_sign, fold_bn_sign_all, gemm_fused, gemm_fused_threshold, ChannelRule};
pub use pack::{PackedMatrix, Side};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::sign_binarize;

    /// Deterministic pseudo-random ±1-ish floats without a rand dep.
    pub(crate) fn lcg_floats(seed: u64, n: usize) -> Vec<f32> {
        let mut s = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((s >> 33) as f32 / (1u64 << 31) as f32) - 1.0
            })
            .collect()
    }

    /// Every executable variant must equal the naive float GEMM on
    /// binarized data (`available()`, not `all()`: the pinned-SIMD
    /// variants cannot run on CPUs without their instruction set).
    #[test]
    fn all_variants_agree_on_pm_one() {
        for (m, n, k) in [(1, 1, 1), (3, 5, 7), (8, 16, 64), (13, 9, 100), (4, 4, 129)] {
            let a: Vec<f32> = lcg_floats(1, m * k).iter().map(|&x| sign_binarize(x)).collect();
            let b: Vec<f32> = lcg_floats(2, k * n).iter().map(|&x| sign_binarize(x)).collect();
            let expect = naive::gemm_f32(&a, &b, m, n, k);
            for method in Method::available() {
                let got = binary_gemm_f32(method, &a, &b, m, n, k);
                assert_eq!(got, expect, "method {method:?} m={m} n={n} k={k}");
            }
        }
    }

    /// On arbitrary floats, the xnor variants implicitly binarize; they must
    /// equal naive-on-binarized (the training/inference equivalence §2.2.2).
    #[test]
    fn xnor_variants_binarize_implicitly() {
        let (m, n, k) = (6, 10, 70);
        let a = lcg_floats(3, m * k);
        let b = lcg_floats(4, k * n);
        let ab: Vec<f32> = a.iter().map(|&x| sign_binarize(x)).collect();
        let bb: Vec<f32> = b.iter().map(|&x| sign_binarize(x)).collect();
        let expect = naive::gemm_f32(&ab, &bb, m, n, k);
        for method in Method::available().into_iter().filter(|m| m.is_binary()) {
            assert_eq!(binary_gemm_f32(method, &a, &b, m, n, k), expect, "{method:?}");
        }
    }
}
