//! BINARY_WORD bit packing (paper §2.2.1).
//!
//! `PackedMatrix` stores one packed row per *logical* row: for the A
//! operand that is a row of the (M, K) activation matrix; for the B operand
//! it is a **column** of the (K, N) weight matrix (i.e. a row of Bᵀ), so
//! both operands stream contiguously in the xnor inner loop — the same
//! transposed-B layout the paper's packed weights use.
//!
//! Padding: K is padded up to a multiple of 64.  A-side pads encode +1
//! (bit 1), B-side pads encode −1 (bit 0); a padded lane therefore xnors to
//! 0 and contributes nothing, giving `dot = 2*pop − K_true` with no
//! correction term.

use crate::quant::sign_binarize;

pub const WORD_BITS: usize = 64;

/// Which operand a matrix is packed as (decides the pad bit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    /// Left operand rows; pads with 1-bits (+1).
    A,
    /// Right operand columns (rows of Bᵀ); pads with 0-bits (−1).
    B,
}

/// Bit-packed ±1 matrix: `rows` packed rows of `k` logical elements in
/// `words_per_row` u64 words each.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedMatrix {
    pub rows: usize,
    pub k: usize,
    pub words_per_row: usize,
    pub words: Vec<u64>,
}

impl PackedMatrix {
    /// Pack `rows` rows of length `k` from row-major f32 data, binarizing
    /// with sign (bit 1 == x >= 0).
    pub fn pack_rows(data: &[f32], rows: usize, k: usize, side: Side) -> Self {
        assert_eq!(data.len(), rows * k, "pack_rows: data length mismatch");
        let words_per_row = k.div_ceil(WORD_BITS);
        let mut words = vec![0u64; rows * words_per_row];
        for r in 0..rows {
            pack_row_into(
                &data[r * k..(r + 1) * k],
                &mut words[r * words_per_row..(r + 1) * words_per_row],
                side,
            );
        }
        Self { rows, k, words_per_row, words }
    }

    /// Pack the transpose of a row-major (k, n) matrix: packed row `j`
    /// holds column `j` of B.  This is the B-operand layout.
    ///
    /// §Perf: packs directly from the (k, n) layout in 64-row bands — all
    /// reads are sequential and the per-band accumulator (n u64 words)
    /// stays in L1/L2.  The first implementation materialized the full
    /// f32 transpose (k·n·4 bytes, 32 MB at Fig-1 scale) before packing
    /// and was ~35% slower end-to-end on the "binarize input" bar.
    pub fn pack_cols(data: &[f32], k: usize, n: usize) -> Self {
        assert_eq!(data.len(), k * n, "pack_cols: data length mismatch");
        let words_per_row = k.div_ceil(WORD_BITS);
        let mut words = vec![0u64; n * words_per_row];
        let mut band = vec![0u64; n];
        for wi in 0..words_per_row {
            band.iter_mut().for_each(|w| *w = 0);
            let k_begin = wi * WORD_BITS;
            let k_end = (k_begin + WORD_BITS).min(k);
            for kk in k_begin..k_end {
                let bit = kk - k_begin;
                let row = &data[kk * n..(kk + 1) * n];
                for (acc, &v) in band.iter_mut().zip(row) {
                    *acc |= u64::from(v >= 0.0) << bit;
                }
            }
            // B-side pads are 0-bits: nothing to set for kk >= k.
            for (j, &w) in band.iter().enumerate() {
                words[j * words_per_row + wi] = w;
            }
        }
        Self { rows: n, k, words_per_row, words }
    }

    /// An all-(−1) matrix with the side's pad bits preset, for writers
    /// that set live bits in place (the fused threshold epilogue,
    /// `super::fused::gemm_fused_threshold`, writes next-layer A bits
    /// straight from popcount accumulators).  Live lanes start 0 (−1);
    /// pad lanes already carry the side convention, so a writer only
    /// ever touches lanes `< k`.
    pub fn zeroed(rows: usize, k: usize, side: Side) -> Self {
        let words_per_row = k.div_ceil(WORD_BITS);
        let mut words = vec![0u64; rows * words_per_row];
        let tail = k % WORD_BITS;
        if side == Side::A && tail != 0 {
            let pad = !0u64 << tail;
            for r in 0..rows {
                words[r * words_per_row + words_per_row - 1] = pad;
            }
        }
        Self { rows, k, words_per_row, words }
    }

    /// Set live lane `i` of row `r` to +1 (bit 1).  Lanes default to −1
    /// in a [`PackedMatrix::zeroed`] matrix.
    #[inline]
    pub fn set_bit(&mut self, r: usize, i: usize) {
        debug_assert!(i < self.k, "set_bit: lane {i} out of {k}", k = self.k);
        self.words[r * self.words_per_row + i / WORD_BITS] |= 1u64 << (i % WORD_BITS);
    }

    /// Read live lane `i` of row `r` (true == +1).
    #[inline]
    pub fn get_bit(&self, r: usize, i: usize) -> bool {
        (self.words[r * self.words_per_row + i / WORD_BITS] >> (i % WORD_BITS)) & 1 == 1
    }

    /// Packed row slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[u64] {
        &self.words[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// Mutable packed row slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [u64] {
        &mut self.words[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// Unpack back to ±1 floats (test/debug helper; drops pad lanes).
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.k];
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..self.k {
                let bit = (row[i / WORD_BITS] >> (i % WORD_BITS)) & 1;
                out[r * self.k + i] = if bit == 1 { 1.0 } else { -1.0 };
            }
        }
        out
    }

    /// View the words as u32 halves for the `xnor_32` variant.  On
    /// little-endian this preserves lane order (low u32 = bits 0..32).
    pub fn words_u32(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.words.len() * 2);
        for &w in &self.words {
            out.push(w as u32);
            out.push((w >> 32) as u32);
        }
        out
    }

    /// Bytes used by the packed payload (model-size accounting).
    pub fn payload_bytes(&self) -> usize {
        self.words.len() * 8
    }
}

/// Pack one logical row of floats into `out` words, applying the side's
/// pad-bit convention to the final partial word.  `out.len()` must be
/// `row.len().div_ceil(64)`.  This is the single source of truth for the
/// bit/pad layout; [`PackedMatrix::pack_rows`] and the fused GEMM path
/// (`super::fused`) both go through it so they can never disagree.
pub fn pack_row_into(row: &[f32], out: &mut [u64], side: Side) {
    debug_assert_eq!(out.len(), row.len().div_ceil(WORD_BITS));
    let pad_word_fill = match side {
        Side::A => u64::MAX,
        Side::B => 0,
    };
    for (wi, chunk) in row.chunks(WORD_BITS).enumerate() {
        let mut w: u64 = 0;
        for (b, &v) in chunk.iter().enumerate() {
            if v >= 0.0 {
                w |= 1u64 << b;
            }
        }
        if chunk.len() < WORD_BITS && pad_word_fill != 0 {
            // set pad bits above chunk.len()
            w |= !0u64 << chunk.len();
        }
        out[wi] = w;
    }
}

/// Binarize a float slice out-of-place (the paper's "binarize input" cost).
pub fn binarize_slice(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| sign_binarize(v)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip_unaligned() {
        let data: Vec<f32> = (0..3 * 70)
            .map(|i| if (i * 7) % 3 == 0 { 1.0 } else { -1.0 })
            .collect();
        let p = PackedMatrix::pack_rows(&data, 3, 70, Side::A);
        assert_eq!(p.words_per_row, 2);
        assert_eq!(p.unpack(), data);
    }

    #[test]
    fn lsb_first_bit_order() {
        let mut row = vec![-1.0f32; 64];
        row[0] = 1.0;
        let p = PackedMatrix::pack_rows(&row, 1, 64, Side::B);
        assert_eq!(p.words[0], 1);
        let mut row = vec![-1.0f32; 64];
        row[63] = 1.0;
        let p = PackedMatrix::pack_rows(&row, 1, 64, Side::B);
        assert_eq!(p.words[0], 1u64 << 63);
    }

    #[test]
    fn a_side_pads_ones_b_side_pads_zeros() {
        let row = vec![-1.0f32; 10];
        let a = PackedMatrix::pack_rows(&row, 1, 10, Side::A);
        let b = PackedMatrix::pack_rows(&row, 1, 10, Side::B);
        assert_eq!(a.words[0], !0u64 << 10);
        assert_eq!(b.words[0], 0);
        // pads xnor to 0: xnor = !(a ^ b) has zeros above bit 10
        assert_eq!((!(a.words[0] ^ b.words[0])).count_ones(), 10);
    }

    #[test]
    fn zero_packs_as_plus_one() {
        let p = PackedMatrix::pack_rows(&[0.0; 64], 1, 64, Side::A);
        assert_eq!(p.words[0], u64::MAX);
    }

    #[test]
    fn pack_cols_is_transpose() {
        // B (k=2, n=3): columns are [1,-1], [-1,-1], [1,1]
        let b = vec![1.0, -1.0, 1.0, -1.0, -1.0, 1.0];
        let p = PackedMatrix::pack_cols(&b, 2, 3);
        assert_eq!(p.rows, 3);
        assert_eq!(p.k, 2);
        assert_eq!(p.words[0] & 0b11, 0b01);
        assert_eq!(p.words[1] & 0b11, 0b00);
        assert_eq!(p.words[2] & 0b11, 0b11);
    }

    #[test]
    fn u32_view_preserves_lane_order() {
        let mut row = vec![-1.0f32; 64];
        row[0] = 1.0; // bit 0 -> low u32
        row[33] = 1.0; // bit 33 -> high u32 bit 1
        let p = PackedMatrix::pack_rows(&row, 1, 64, Side::B);
        let w32 = p.words_u32();
        assert_eq!(w32[0], 1);
        assert_eq!(w32[1], 2);
    }

    #[test]
    fn zeroed_presets_pad_bits_and_set_bit_round_trips() {
        let mut a = PackedMatrix::zeroed(2, 10, Side::A);
        assert_eq!(a.words[0], !0u64 << 10, "A-side pads must start 1");
        assert_eq!(a.words[1], !0u64 << 10);
        a.set_bit(1, 3);
        assert!(a.get_bit(1, 3));
        assert!(!a.get_bit(0, 3));
        assert_eq!(a.unpack()[10 + 3], 1.0);
        let b = PackedMatrix::zeroed(1, 10, Side::B);
        assert_eq!(b.words[0], 0, "B-side pads must start 0");
        // aligned k: no pad word to preset
        let a64 = PackedMatrix::zeroed(1, 64, Side::A);
        assert_eq!(a64.words[0], 0);
    }

    #[test]
    fn payload_bytes_counts_words() {
        let p = PackedMatrix::pack_rows(&vec![1.0; 2 * 130], 2, 130, Side::A);
        assert_eq!(p.words_per_row, 3);
        assert_eq!(p.payload_bytes(), 2 * 3 * 8);
    }
}
