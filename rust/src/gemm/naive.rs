//! Naive float GEMM — the paper's `naive gemm` baseline (Figures 1–3 are
//! speedups relative to this kernel).
//!
//! Deliberately cache-hostile i-j-k ordering with a column walk over B,
//! mirroring the textbook triple loop the paper benchmarks against.  Do
//! not "fix" it: its badness is part of the reproduced measurement.

/// C = A·B with A (m, k), B (k, n) row-major; returns C (m, n).
pub fn gemm_f32(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a[i * k + kk] * b[kk * n + j];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity() {
        // 2x2 identity times arbitrary
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![3.0, 4.0, 5.0, 6.0];
        assert_eq!(gemm_f32(&a, &b, 2, 2, 2), b);
    }

    #[test]
    fn known_product() {
        // [[1,2],[3,4]] x [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        assert_eq!(gemm_f32(&a, &b, 2, 2, 2), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn rectangular() {
        // (1,3) x (3,2)
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        assert_eq!(gemm_f32(&a, &b, 1, 2, 3), vec![4.0, 5.0]);
    }
}
