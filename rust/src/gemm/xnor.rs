//! The xnor+popcount GEMM kernels — ports of the paper's Listing 3 plus
//! the "several optimized versions" (§2.2.1: blocking, packing, unrolling).
//!
//! All kernels return raw popcounts (the xnor dot in `[0, K]`); callers map
//! to the ±1 dot range with `2*pop − K` (see [`crate::quant::xnor_to_dot`]).
//! `!(a ^ b)` is xnor; `count_ones()` compiles to `popcnt` on x86-64, the
//! single-instruction hardware support the paper leans on.

use super::pack::PackedMatrix;
use super::simd::{self, RowFn};

/// Listing 3 on 32-bit BINARY_WORDs (`xnor_32`): x86/ARMv7 width.
pub fn gemm_u32(a: &PackedMatrix, b: &PackedMatrix) -> Vec<i32> {
    assert_eq!(a.k, b.k, "reduction length mismatch");
    let (m, n) = (a.rows, b.rows);
    let aw = a.words_u32();
    let bw = b.words_u32();
    let wpr = a.words_per_row * 2;
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        let arow = &aw[i * wpr..(i + 1) * wpr];
        for j in 0..n {
            let brow = &bw[j * wpr..(j + 1) * wpr];
            let mut acc: u32 = 0;
            for w in 0..wpr {
                acc += (!(arow[w] ^ brow[w])).count_ones();
            }
            // subtract the phantom matches of the high pad half-words:
            // none exist because A pads are 1s and B pads are 0s -> xnor 0.
            c[i * n + j] = acc as i32 - pad_correction(a.k);
        }
    }
    c
}

/// Phantom popcount from whole pad words beyond k: with A=1/B=0 padding
/// xnor is 0 everywhere, so the correction is always 0.  Kept as a function
/// (and asserted in tests) to document the invariant the packing creates.
#[inline]
fn pad_correction(_k: usize) -> i32 {
    0
}

/// Listing 3 on 64-bit BINARY_WORDs (`xnor_64`): x64 width.
pub fn gemm_u64(a: &PackedMatrix, b: &PackedMatrix) -> Vec<i32> {
    assert_eq!(a.k, b.k, "reduction length mismatch");
    let (m, n, wpr) = (a.rows, b.rows, a.words_per_row);
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        let arow = a.row(i);
        for j in 0..n {
            let brow = b.row(j);
            let mut acc: u32 = 0;
            for w in 0..wpr {
                acc += (!(arow[w] ^ brow[w])).count_ones();
            }
            c[i * n + j] = acc as i32;
        }
    }
    c
}

/// Blocked + 4-way-unrolled xnor_64 — the paper's cache-hierarchy
/// optimization.  Tiles the output so each A row block is reused across a
/// B column block held in cache; the inner reduction is unrolled into four
/// independent popcount chains to hide `popcnt` latency.
pub fn gemm_u64_blocked(a: &PackedMatrix, b: &PackedMatrix) -> Vec<i32> {
    assert_eq!(a.k, b.k, "reduction length mismatch");
    let (m, n) = (a.rows, b.rows);
    let mut c = vec![0i32; m * n];
    gemm_u64_blocked_into(a, b, &mut c, 0, m);
    c
}

/// Row-range worker shared with the multi-threaded variant: computes rows
/// `[row_begin, row_end)` of C into `c` (full-size M×N buffer).
pub(crate) fn gemm_u64_blocked_into(
    a: &PackedMatrix,
    b: &PackedMatrix,
    c: &mut [i32],
    row_begin: usize,
    row_end: usize,
) {
    blocked_rows_with(a, b, c, row_begin, row_end, 0, simd::scalar_row);
}

/// Blocked xnor GEMM with an explicit SIMD row kernel — the entry point
/// behind the `xnor_64_avx2` / `xnor_64_avx512` / `xnor_64_neon` /
/// `xnor_fused` dispatch variants.  Same tiling as [`gemm_u64_blocked`];
/// only the inner popcount reduction changes.
pub fn gemm_u64_blocked_with(a: &PackedMatrix, b: &PackedMatrix, row: RowFn) -> Vec<i32> {
    assert_eq!(a.k, b.k, "reduction length mismatch");
    let (m, n) = (a.rows, b.rows);
    let mut c = vec![0i32; m * n];
    blocked_rows_with(a, b, &mut c, 0, m, 0, row);
    c
}

/// Tile loop shared by the single-threaded and per-band multi-threaded
/// paths: computes C rows `[row_begin, row_end)` with row kernel `row`
/// into `c`, whose row 0 corresponds to A row `out_row0` (pass
/// `out_row0 = row_begin` for a band-local buffer, 0 for a full buffer).
pub(crate) fn blocked_rows_with(
    a: &PackedMatrix,
    b: &PackedMatrix,
    c: &mut [i32],
    row_begin: usize,
    row_end: usize,
    out_row0: usize,
    row: RowFn,
) {
    const JB: usize = 64; // B rows (output cols) per tile: JB*wpr*8B in L1/L2
    let n = b.rows;
    for jc in (0..n).step_by(JB) {
        let jb = JB.min(n - jc);
        for i in row_begin..row_end {
            let arow = a.row(i);
            let ci = (i - out_row0) * n + jc;
            let crow = &mut c[ci..ci + jb];
            for (dj, cv) in crow.iter_mut().enumerate() {
                *cv = row(arow, b.row(jc + dj)) as i32;
            }
        }
    }
}

// The single-row scalar reduction lives in [`super::simd::scalar_row`]
// (with its §Perf note about auto-vectorization); this module's blocked
// loops take any [`RowFn`] and default to it.

#[cfg(test)]
mod tests {
    use super::super::pack::Side;
    use super::super::{naive, tests::lcg_floats};
    use super::*;
    use crate::quant::{sign_binarize, xnor_to_dot};

    fn setup(m: usize, n: usize, k: usize) -> (PackedMatrix, PackedMatrix, Vec<f32>) {
        let a: Vec<f32> = lcg_floats(7, m * k).iter().map(|&x| sign_binarize(x)).collect();
        let b: Vec<f32> = lcg_floats(8, k * n).iter().map(|&x| sign_binarize(x)).collect();
        let expect = naive::gemm_f32(&a, &b, m, n, k);
        (
            PackedMatrix::pack_rows(&a, m, k, Side::A),
            PackedMatrix::pack_cols(&b, k, n),
            expect,
        )
    }

    fn check(pop: &[i32], expect: &[f32], n: usize, k: usize) {
        for (idx, (&p, &e)) in pop.iter().zip(expect).enumerate() {
            assert_eq!(xnor_to_dot(p, k), e, "element ({}, {})", idx / n, idx % n);
        }
    }

    #[test]
    fn u32_matches_float_dot() {
        for (m, n, k) in [(1, 1, 1), (5, 7, 64), (3, 4, 65), (8, 8, 200)] {
            let (pa, pb, expect) = setup(m, n, k);
            check(&gemm_u32(&pa, &pb), &expect, n, k);
        }
    }

    #[test]
    fn u64_matches_float_dot() {
        for (m, n, k) in [(1, 1, 1), (5, 7, 64), (3, 4, 65), (8, 8, 200), (2, 3, 1000)] {
            let (pa, pb, expect) = setup(m, n, k);
            check(&gemm_u64(&pa, &pb), &expect, n, k);
        }
    }

    #[test]
    fn blocked_matches_plain_u64() {
        for (m, n, k) in [(1, 100, 64), (17, 130, 333), (64, 64, 256)] {
            let (pa, pb, _) = setup(m, n, k);
            assert_eq!(gemm_u64_blocked(&pa, &pb), gemm_u64(&pa, &pb), "m={m} n={n} k={k}");
        }
    }

    #[test]
    fn popcount_range_is_zero_to_k() {
        let (m, n, k) = (6, 6, 97);
        let (pa, pb, _) = setup(m, n, k);
        for p in gemm_u64(&pa, &pb) {
            assert!((0..=k as i32).contains(&p), "pop {p} outside [0, {k}]");
        }
    }

    #[test]
    fn all_match_gives_pop_k() {
        let ones = vec![1.0f32; 70];
        let pa = PackedMatrix::pack_rows(&ones, 1, 70, Side::A);
        let pb = PackedMatrix::pack_cols(&ones, 70, 1);
        assert_eq!(gemm_u64(&pa, &pb), vec![70]);
        assert_eq!(gemm_u32(&pa, &pb), vec![70]);
    }

    #[test]
    fn all_mismatch_gives_pop_zero() {
        let plus = vec![1.0f32; 70];
        let minus = vec![-1.0f32; 70];
        let pa = PackedMatrix::pack_rows(&plus, 1, 70, Side::A);
        let pb = PackedMatrix::pack_cols(&minus, 70, 1);
        assert_eq!(gemm_u64(&pa, &pb), vec![0]);
    }
}
