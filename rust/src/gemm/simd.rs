//! Explicit SIMD popcount row kernels with runtime CPU-feature dispatch.
//!
//! This module is the repo's only home for `unsafe` code.  It provides one
//! job — the xnor+popcount reduction over a pair of packed `u64` rows — in
//! four implementations:
//!
//! | [`Kernel`]         | instruction set        | words / step | technique |
//! |--------------------|------------------------|--------------|-----------|
//! | [`Kernel::Scalar`] | portable               | 1            | `count_ones()` zip/sum (auto-vectorizes under `-C target-cpu=native`) |
//! | [`Kernel::Avx2`]   | AVX2                   | 64           | Harley–Seal carry-save adder tree over 16×256-bit lanes + Muła nibble-LUT popcount |
//! | [`Kernel::Avx512`] | AVX-512 `VPOPCNTDQ`    | 8            | hardware 64-bit lane popcount (`--features simd-avx512`; intrinsics need rustc ≥ 1.89) |
//! | [`Kernel::Neon`]   | AArch64 NEON           | 2            | `vcnt` byte popcount + `vpaddl` widening-pairwise reduction |
//!
//! Dispatch is decided at **runtime** ([`best_kernel`]) from std's cached
//! CPU-feature detection, and can be pinned to the portable path with the
//! `BMXNET_FORCE_SCALAR` environment variable (any of `1`/`true`/`yes`) —
//! the override the CI test matrix uses to exercise the fallback path.
//!
//! # Input convention (shared with [`super::pack`])
//!
//! Kernels never mask tail words themselves: they rely on the packing
//! invariant that A-side pad bits are 1 and B-side pad bits are 0, so every
//! padded lane xnors to 0 and contributes nothing.  A corrupted pad bit
//! therefore *shifts the popcount* — the differential tests
//! (`rust/tests/gemm_differential.rs`, `rust/tests/proptests.rs`) pin both
//! the invariant and the loud failure mode.
//!
//! # Safety argument (see also DESIGN.md §SIMD popcount dispatch)
//!
//! Every `unsafe fn` below is a `#[target_feature]` kernel; the only
//! obligation a caller must discharge is "the CPU supports that feature"
//! (all memory access is through slice reads with explicit bounds: the
//! vector loops consume `len() - len() % STEP` words via unaligned loads
//! and the scalar tail handles the rest, so no out-of-bounds access is
//! possible regardless of feature support).  The kernels are reachable
//! only through the safe `*_checked` wrappers, each of which re-verifies
//! the CPU feature via std's cached `is_*_feature_detected!` on every call
//! and falls back to [`scalar_row`] when unsupported — misuse degrades to
//! the portable path, never to undefined behavior.

use std::sync::OnceLock;

/// A popcount row-kernel implementation level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kernel {
    /// Portable `count_ones()` zip/sum.
    Scalar,
    /// AVX2 Harley–Seal (x86-64).
    Avx2,
    /// AVX-512 `VPOPCNTDQ` (x86-64, `--features simd-avx512`).
    Avx512,
    /// NEON `vcnt`+`vpaddl` (aarch64).
    Neon,
}

/// The signature every row kernel shares: xnor+popcount over
/// `min(a.len(), b.len())` packed words.
pub type RowFn = fn(&[u64], &[u64]) -> u32;

/// The 2×2 register-tile signature: two A rows against two B rows,
/// returning `[a0·b0, a0·b1, a1·b0, a1·b1]` popcounts.  The tile kernel
/// loads each operand word once and feeds it into two products — the
/// operand-reuse win a single-row kernel cannot express.
pub type Tile2Fn = fn(&[u64], &[u64], &[u64], &[u64]) -> [u32; 4];

impl Kernel {
    /// Stable display name (used in logs and bench provenance strings).
    pub fn label(&self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
            Kernel::Avx512 => "avx512",
            Kernel::Neon => "neon",
        }
    }

    /// Does the running CPU (and compiled feature set) support this
    /// kernel?  Ignores the `BMXNET_FORCE_SCALAR` override — see
    /// [`Kernel::dispatchable`].
    pub fn cpu_supported(&self) -> bool {
        match self {
            Kernel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(all(target_arch = "x86_64", feature = "simd-avx512"))]
            Kernel::Avx512 => {
                is_x86_feature_detected!("avx512f")
                    && is_x86_feature_detected!("avx512vpopcntdq")
            }
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// Would [`best_kernel`]-style dispatch be allowed to pick this kernel
    /// right now?  `cpu_supported` gated by the force-scalar override.
    pub fn dispatchable(&self) -> bool {
        matches!(self, Kernel::Scalar) || (!force_scalar() && self.cpu_supported())
    }
}

/// True when the `BMXNET_FORCE_SCALAR` env override pins the scalar path.
///
/// Read on every call (not cached) so tests and long-running processes
/// observe changes; the read happens once per GEMM entry, not per row.
pub fn force_scalar() -> bool {
    matches!(
        std::env::var("BMXNET_FORCE_SCALAR").ok().as_deref(),
        Some("1") | Some("true") | Some("yes")
    )
}

/// CPU capability probe, cached once per process (detection macros cache
/// internally too; this avoids re-matching the preference order).
fn detected_best() -> Kernel {
    static BEST: OnceLock<Kernel> = OnceLock::new();
    *BEST.get_or_init(|| {
        for k in [Kernel::Avx512, Kernel::Avx2, Kernel::Neon] {
            if k.cpu_supported() {
                return k;
            }
        }
        Kernel::Scalar
    })
}

/// The kernel runtime dispatch selects right now: the widest supported
/// SIMD level, unless `BMXNET_FORCE_SCALAR` pins the scalar path.
pub fn best_kernel() -> Kernel {
    if force_scalar() {
        Kernel::Scalar
    } else {
        detected_best()
    }
}

/// Every kernel [`Kernel::dispatchable`] on this machine, scalar first.
pub fn available_kernels() -> Vec<Kernel> {
    [Kernel::Scalar, Kernel::Avx2, Kernel::Avx512, Kernel::Neon]
        .into_iter()
        .filter(|k| k.dispatchable())
        .collect()
}

/// Resolve a kernel to its callable row function.  Kernels that are not
/// supported by the running CPU resolve to [`scalar_row`] (the safe
/// wrappers re-check, so even a stale pointer can never execute an
/// unsupported instruction — see the module-level safety argument).
pub fn row_fn(kernel: Kernel) -> RowFn {
    match kernel {
        Kernel::Scalar => scalar_row,
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => row_avx2_checked,
        #[cfg(all(target_arch = "x86_64", feature = "simd-avx512"))]
        Kernel::Avx512 => row_avx512_checked,
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => row_neon_checked,
        #[allow(unreachable_patterns)]
        _ => scalar_row,
    }
}

/// Resolve a kernel to its 2×2 tile function.  Only AVX2 has a dedicated
/// register-tile microkernel (the Harley–Seal row kernel's natural
/// multi-row extension); every other kernel composes four calls of its
/// own row function, so tiling never changes which instruction set runs —
/// `BMXNET_FORCE_SCALAR` and the pinned-kernel ablations stay honest.
pub fn tile2_fn(kernel: Kernel) -> Tile2Fn {
    match kernel {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => tile2_avx2_checked,
        #[cfg(all(target_arch = "x86_64", feature = "simd-avx512"))]
        Kernel::Avx512 => tile2_avx512_composed,
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => tile2_neon_composed,
        #[allow(unreachable_patterns)]
        _ => tile2_scalar,
    }
}

/// Portable 2×2 tile: four scalar row reductions.
pub fn tile2_scalar(a0: &[u64], a1: &[u64], b0: &[u64], b1: &[u64]) -> [u32; 4] {
    [scalar_row(a0, b0), scalar_row(a0, b1), scalar_row(a1, b0), scalar_row(a1, b1)]
}

/// Portable xnor+popcount row reduction — the reference every SIMD kernel
/// is differentially pinned against.
///
/// §Perf note: deliberately the *simple* zip/sum form; with
/// `-C target-cpu=native` LLVM auto-vectorizes it (EXPERIMENTS.md §Perf
/// records how a manual scalar unroll defeated that and lost 1.6×).
#[inline]
pub fn scalar_row(arow: &[u64], brow: &[u64]) -> u32 {
    arow.iter().zip(brow).map(|(&a, &b)| (!(a ^ b)).count_ones()).sum()
}

// ---------------------------------------------------------------------------
// x86-64: AVX2 Harley–Seal
// ---------------------------------------------------------------------------

/// Safe wrapper: re-verifies AVX2 via std's cached detection on every
/// call; falls back to [`scalar_row`] when unsupported.  This check is the
/// entire safety argument for calling the `#[target_feature]` kernel.
#[cfg(target_arch = "x86_64")]
fn row_avx2_checked(arow: &[u64], brow: &[u64]) -> u32 {
    if is_x86_feature_detected!("avx2") {
        // SAFETY: AVX2 support was just confirmed; the kernel performs
        // only in-bounds slice reads (see module safety argument).
        unsafe { x86::row_avx2(arow, brow) }
    } else {
        scalar_row(arow, brow)
    }
}

/// Safe wrapper for the AVX2 2×2 register-tile kernel: re-verifies AVX2
/// on every call and falls back to the scalar tile composition.
#[cfg(target_arch = "x86_64")]
fn tile2_avx2_checked(a0: &[u64], a1: &[u64], b0: &[u64], b1: &[u64]) -> [u32; 4] {
    if is_x86_feature_detected!("avx2") {
        // SAFETY: AVX2 support was just confirmed; the kernel performs
        // only in-bounds slice reads (see module safety argument).
        unsafe { x86::tile2x2_avx2(a0, a1, b0, b1) }
    } else {
        tile2_scalar(a0, a1, b0, b1)
    }
}

/// AVX-512 tile: four VPOPCNTDQ row reductions (the zmm kernel already
/// saturates the popcount port; a dedicated tile buys nothing).
#[cfg(all(target_arch = "x86_64", feature = "simd-avx512"))]
fn tile2_avx512_composed(a0: &[u64], a1: &[u64], b0: &[u64], b1: &[u64]) -> [u32; 4] {
    let f = row_fn(Kernel::Avx512);
    [f(a0, b0), f(a0, b1), f(a1, b0), f(a1, b1)]
}

/// NEON tile: four `vcnt` row reductions.
#[cfg(target_arch = "aarch64")]
fn tile2_neon_composed(a0: &[u64], a1: &[u64], b0: &[u64], b1: &[u64]) -> [u32; 4] {
    let f = row_fn(Kernel::Neon);
    [f(a0, b0), f(a0, b1), f(a1, b0), f(a1, b1)]
}

/// Safe wrapper for the AVX-512 VPOPCNTDQ kernel; same contract as
/// [`row_avx2_checked`].
#[cfg(all(target_arch = "x86_64", feature = "simd-avx512"))]
fn row_avx512_checked(arow: &[u64], brow: &[u64]) -> u32 {
    if is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512vpopcntdq") {
        // SAFETY: AVX-512F + VPOPCNTDQ support was just confirmed; the
        // kernel performs only in-bounds slice reads.
        unsafe { x86_512::row_avx512(arow, brow) }
    } else {
        scalar_row(arow, brow)
    }
}

/// Safe wrapper for the NEON kernel; same contract as
/// [`row_avx2_checked`].
#[cfg(target_arch = "aarch64")]
fn row_neon_checked(arow: &[u64], brow: &[u64]) -> u32 {
    if std::arch::is_aarch64_feature_detected!("neon") {
        // SAFETY: NEON support was just confirmed; the kernel performs
        // only in-bounds slice reads.
        unsafe { arm::row_neon(arow, brow) }
    } else {
        scalar_row(arow, brow)
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2 Harley–Seal popcount (Muła / Kurz / Lemire, "Faster population
    //! counts using AVX2 instructions").  A carry-save adder (CSA) tree
    //! compresses 16 input vectors per iteration so the relatively
    //! expensive byte-LUT popcount runs once per 16 vectors instead of
    //! once per vector; lower CSA tiers carry the remainder weights out
    //! of the loop.

    use std::arch::x86_64::*;

    /// Per-64-bit-lane popcount of a 256-bit vector via the 4-bit nibble
    /// lookup table (`vpshufb`) and `vpsadbw` byte-sum.
    ///
    /// # Safety
    /// Requires AVX2.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcount64x4(v: __m256i) -> __m256i {
        let lookup = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low_mask = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(v, low_mask);
        let hi = _mm256_and_si256(_mm256_srli_epi16(v, 4), low_mask);
        let cnt = _mm256_add_epi8(
            _mm256_shuffle_epi8(lookup, lo),
            _mm256_shuffle_epi8(lookup, hi),
        );
        _mm256_sad_epu8(cnt, _mm256_setzero_si256())
    }

    /// Carry-save adder: (high, low) full-adder over three bit-vectors.
    ///
    /// # Safety
    /// Requires AVX2.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn csa(a: __m256i, b: __m256i, c: __m256i) -> (__m256i, __m256i) {
        let u = _mm256_xor_si256(a, b);
        let h = _mm256_or_si256(_mm256_and_si256(a, b), _mm256_and_si256(u, c));
        let l = _mm256_xor_si256(u, c);
        (h, l)
    }

    /// Load 4 words from each operand (unaligned) and xnor them.
    ///
    /// # Safety
    /// Requires AVX2; `a` and `b` must be readable for 4 u64 words.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn xnor4(a: *const u64, b: *const u64, inv: __m256i) -> __m256i {
        let va = _mm256_loadu_si256(a as *const __m256i);
        let vb = _mm256_loadu_si256(b as *const __m256i);
        _mm256_xor_si256(_mm256_xor_si256(va, vb), inv)
    }

    /// Harley–Seal xnor+popcount over `min(len, len)` words: 64 words per
    /// CSA iteration, 4-word vector remainder, scalar tail.
    ///
    /// # Safety
    /// Requires AVX2 at runtime (enforced by `row_avx2_checked`).  All
    /// loads are bounded: the 64-word loop and the 4-word loop only run
    /// while `i + STEP <= n`, and the tail uses safe slice indexing.
    #[target_feature(enable = "avx2")]
    pub unsafe fn row_avx2(arow: &[u64], brow: &[u64]) -> u32 {
        let n = arow.len().min(brow.len());
        let ap = arow.as_ptr();
        let bp = brow.as_ptr();
        let inv = _mm256_set1_epi64x(-1);
        let mut total = _mm256_setzero_si256();
        let mut ones = _mm256_setzero_si256();
        let mut twos = _mm256_setzero_si256();
        let mut fours = _mm256_setzero_si256();
        let mut eights = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 64 <= n {
            let d0 = xnor4(ap.add(i), bp.add(i), inv);
            let d1 = xnor4(ap.add(i + 4), bp.add(i + 4), inv);
            let (twos_a, l) = csa(ones, d0, d1);
            ones = l;
            let d2 = xnor4(ap.add(i + 8), bp.add(i + 8), inv);
            let d3 = xnor4(ap.add(i + 12), bp.add(i + 12), inv);
            let (twos_b, l) = csa(ones, d2, d3);
            ones = l;
            let (fours_a, l) = csa(twos, twos_a, twos_b);
            twos = l;
            let d4 = xnor4(ap.add(i + 16), bp.add(i + 16), inv);
            let d5 = xnor4(ap.add(i + 20), bp.add(i + 20), inv);
            let (twos_a, l) = csa(ones, d4, d5);
            ones = l;
            let d6 = xnor4(ap.add(i + 24), bp.add(i + 24), inv);
            let d7 = xnor4(ap.add(i + 28), bp.add(i + 28), inv);
            let (twos_b, l) = csa(ones, d6, d7);
            ones = l;
            let (fours_b, l) = csa(twos, twos_a, twos_b);
            twos = l;
            let (eights_a, l) = csa(fours, fours_a, fours_b);
            fours = l;
            let d8 = xnor4(ap.add(i + 32), bp.add(i + 32), inv);
            let d9 = xnor4(ap.add(i + 36), bp.add(i + 36), inv);
            let (twos_a, l) = csa(ones, d8, d9);
            ones = l;
            let d10 = xnor4(ap.add(i + 40), bp.add(i + 40), inv);
            let d11 = xnor4(ap.add(i + 44), bp.add(i + 44), inv);
            let (twos_b, l) = csa(ones, d10, d11);
            ones = l;
            let (fours_a, l) = csa(twos, twos_a, twos_b);
            twos = l;
            let d12 = xnor4(ap.add(i + 48), bp.add(i + 48), inv);
            let d13 = xnor4(ap.add(i + 52), bp.add(i + 52), inv);
            let (twos_a, l) = csa(ones, d12, d13);
            ones = l;
            let d14 = xnor4(ap.add(i + 56), bp.add(i + 56), inv);
            let d15 = xnor4(ap.add(i + 60), bp.add(i + 60), inv);
            let (twos_b, l) = csa(ones, d14, d15);
            ones = l;
            let (fours_b, l) = csa(twos, twos_a, twos_b);
            twos = l;
            let (eights_b, l) = csa(fours, fours_a, fours_b);
            fours = l;
            let (sixteens, l) = csa(eights, eights_a, eights_b);
            eights = l;
            total = _mm256_add_epi64(total, popcount64x4(sixteens));
            i += 64;
        }
        // Weight the CSA tiers: total counted 16s; eights/fours/twos/ones
        // hold the deferred remainder bits at weights 8/4/2/1.
        total = _mm256_slli_epi64(total, 4);
        total = _mm256_add_epi64(total, _mm256_slli_epi64(popcount64x4(eights), 3));
        total = _mm256_add_epi64(total, _mm256_slli_epi64(popcount64x4(fours), 2));
        total = _mm256_add_epi64(total, _mm256_slli_epi64(popcount64x4(twos), 1));
        total = _mm256_add_epi64(total, popcount64x4(ones));
        while i + 4 <= n {
            total = _mm256_add_epi64(total, popcount64x4(xnor4(ap.add(i), bp.add(i), inv)));
            i += 4;
        }
        // SAFETY: __m256i is plain 256-bit data; viewing it as 4 u64
        // lanes is the layout `_mm256_add_epi64` already assumes.
        let lanes: [u64; 4] = core::mem::transmute(total);
        let mut acc = lanes[0] + lanes[1] + lanes[2] + lanes[3];
        while i < n {
            acc += u64::from((!(arow[i] ^ brow[i])).count_ones());
            i += 1;
        }
        acc as u32
    }

    /// Sum the four u64 lanes of a popcount accumulator.
    ///
    /// # Safety
    /// Requires AVX2 (the accumulator was built with AVX2 adds).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn lane_sum(v: __m256i) -> u64 {
        // SAFETY: __m256i is plain 256-bit data; viewing it as 4 u64
        // lanes is the layout `_mm256_add_epi64` already assumes.
        let lanes: [u64; 4] = core::mem::transmute(v);
        lanes[0] + lanes[1] + lanes[2] + lanes[3]
    }

    /// 2×2 register-tile xnor+popcount: 4 words per step, each of the
    /// four operand vectors loaded **once** and consumed by two products.
    /// A row-at-a-time kernel loads A `n`-times per B pass; this tile
    /// halves both operand load streams — the classic GEMM register-tile
    /// argument applied to the popcount reduction.  Accumulators are
    /// per-64-bit-lane u64 counts (≤ 256 added per step — no overflow for
    /// any representable row length).
    ///
    /// # Safety
    /// Requires AVX2 at runtime (enforced by `tile2_avx2_checked`).  The
    /// vector loop runs only while `i + 4 <= n` where `n` is the minimum
    /// of all four slice lengths; the tail uses safe slice indexing.
    #[target_feature(enable = "avx2")]
    pub unsafe fn tile2x2_avx2(a0: &[u64], a1: &[u64], b0: &[u64], b1: &[u64]) -> [u32; 4] {
        let n = a0.len().min(a1.len()).min(b0.len()).min(b1.len());
        let (a0p, a1p) = (a0.as_ptr(), a1.as_ptr());
        let (b0p, b1p) = (b0.as_ptr(), b1.as_ptr());
        let inv = _mm256_set1_epi64x(-1);
        let mut c00 = _mm256_setzero_si256();
        let mut c01 = _mm256_setzero_si256();
        let mut c10 = _mm256_setzero_si256();
        let mut c11 = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 4 <= n {
            // SAFETY: i + 4 <= n bounds all four 32-byte reads.
            let va0 = _mm256_loadu_si256(a0p.add(i) as *const __m256i);
            let va1 = _mm256_loadu_si256(a1p.add(i) as *const __m256i);
            let vb0 = _mm256_loadu_si256(b0p.add(i) as *const __m256i);
            let vb1 = _mm256_loadu_si256(b1p.add(i) as *const __m256i);
            let x00 = _mm256_xor_si256(_mm256_xor_si256(va0, vb0), inv);
            let x01 = _mm256_xor_si256(_mm256_xor_si256(va0, vb1), inv);
            let x10 = _mm256_xor_si256(_mm256_xor_si256(va1, vb0), inv);
            let x11 = _mm256_xor_si256(_mm256_xor_si256(va1, vb1), inv);
            c00 = _mm256_add_epi64(c00, popcount64x4(x00));
            c01 = _mm256_add_epi64(c01, popcount64x4(x01));
            c10 = _mm256_add_epi64(c10, popcount64x4(x10));
            c11 = _mm256_add_epi64(c11, popcount64x4(x11));
            i += 4;
        }
        let mut out =
            [lane_sum(c00) as u32, lane_sum(c01) as u32, lane_sum(c10) as u32, lane_sum(c11) as u32];
        while i < n {
            out[0] += (!(a0[i] ^ b0[i])).count_ones();
            out[1] += (!(a0[i] ^ b1[i])).count_ones();
            out[2] += (!(a1[i] ^ b0[i])).count_ones();
            out[3] += (!(a1[i] ^ b1[i])).count_ones();
            i += 1;
        }
        out
    }
}

// ---------------------------------------------------------------------------
// x86-64: AVX-512 VPOPCNTDQ (feature-gated: intrinsics stabilized in 1.89,
// after this crate's 1.74 MSRV — mirror of the `pjrt` gating pattern)
// ---------------------------------------------------------------------------

#[cfg(all(target_arch = "x86_64", feature = "simd-avx512"))]
mod x86_512 {
    //! Hardware per-lane popcount: `vpopcntq` counts all 8 u64 lanes of a
    //! zmm register in one instruction — the instruction the Harley–Seal
    //! tree above exists to approximate on AVX2-only parts.

    use std::arch::x86_64::*;

    /// xnor+popcount over packed words, 8 per step, scalar tail.
    ///
    /// # Safety
    /// Requires AVX-512F + AVX-512VPOPCNTDQ at runtime (enforced by
    /// `row_avx512_checked`).  Loads are `read_unaligned` of 8-word
    /// blocks only while `i + 8 <= n`; the tail uses safe indexing.
    #[target_feature(enable = "avx512f,avx512vpopcntdq")]
    pub unsafe fn row_avx512(arow: &[u64], brow: &[u64]) -> u32 {
        let n = arow.len().min(brow.len());
        let ap = arow.as_ptr();
        let bp = brow.as_ptr();
        let inv = _mm512_set1_epi64(-1);
        let mut acc = _mm512_setzero_si512();
        let mut i = 0usize;
        while i + 8 <= n {
            // SAFETY: i + 8 <= n bounds both 64-byte reads; unaligned
            // reads avoid any alignment requirement on the slices.
            let va = core::ptr::read_unaligned(ap.add(i) as *const __m512i);
            let vb = core::ptr::read_unaligned(bp.add(i) as *const __m512i);
            let x = _mm512_xor_si512(_mm512_xor_si512(va, vb), inv);
            acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64(x));
            i += 8;
        }
        // SAFETY: __m512i viewed as its 8 u64 lanes (same layout
        // _mm512_add_epi64 assumes).
        let lanes: [u64; 8] = core::mem::transmute(acc);
        let mut total: u64 = lanes.iter().sum();
        while i < n {
            total += u64::from((!(arow[i] ^ brow[i])).count_ones());
            i += 1;
        }
        total as u32
    }
}

// ---------------------------------------------------------------------------
// aarch64: NEON vcnt
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    //! NEON byte popcount: `vcnt.8` counts bits per byte, then a
    //! `vpaddl` widening-pairwise ladder (u8→u16→u32→u64) folds the 16
    //! byte counts into two u64 lane accumulators — the daBNN/XNOR-Net
    //! deployment ISA the paper targets for low-power inference.

    use std::arch::aarch64::*;

    /// xnor+popcount over packed words, 2 per step, scalar tail.
    ///
    /// # Safety
    /// Requires NEON at runtime (enforced by `row_neon_checked`; NEON is
    /// architecturally mandatory on AArch64).  Loads run only while
    /// `i + 2 <= n`; the tail uses safe indexing.
    #[target_feature(enable = "neon")]
    pub unsafe fn row_neon(arow: &[u64], brow: &[u64]) -> u32 {
        let n = arow.len().min(brow.len());
        let ap = arow.as_ptr();
        let bp = brow.as_ptr();
        let mut acc = vdupq_n_u64(0);
        let mut i = 0usize;
        while i + 2 <= n {
            // SAFETY: i + 2 <= n bounds both 16-byte reads.
            let va = vld1q_u64(ap.add(i));
            let vb = vld1q_u64(bp.add(i));
            let x = vmvnq_u8(vreinterpretq_u8_u64(veorq_u64(va, vb)));
            let cnt = vcntq_u8(x);
            acc = vaddq_u64(acc, vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(cnt))));
            i += 2;
        }
        let mut total = vgetq_lane_u64(acc, 0) + vgetq_lane_u64(acc, 1);
        while i < n {
            total += u64::from((!(arow[i] ^ brow[i])).count_ones());
            i += 1;
        }
        total as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic word patterns exercising dense, sparse and
    /// alternating bit layouts.
    fn words(seed: u64, n: usize) -> Vec<u64> {
        let mut s = seed;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                s ^ (s >> 29)
            })
            .collect()
    }

    #[test]
    fn scalar_row_matches_direct_popcount() {
        for n in [0, 1, 2, 3, 4, 7, 8, 63, 64, 65, 100, 200] {
            let a = words(1, n);
            let b = words(2, n);
            let expect: u32 = (0..n).map(|i| (!(a[i] ^ b[i])).count_ones()).sum();
            assert_eq!(scalar_row(&a, &b), expect, "n={n}");
        }
    }

    #[test]
    fn every_dispatchable_kernel_matches_scalar() {
        // The in-process differential gate: each kernel the CPU supports
        // must agree with the scalar reference on every length class
        // (sub-vector, vector remainder, full CSA blocks, odd tails).
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 31, 63, 64, 65, 127, 128, 129, 200, 257]
        {
            let a = words(3 + n as u64, n);
            let b = words(1000 + n as u64, n);
            let expect = scalar_row(&a, &b);
            for k in available_kernels() {
                assert_eq!(row_fn(k)(&a, &b), expect, "kernel {k:?} n={n}");
            }
        }
    }

    #[test]
    fn kernels_handle_all_match_and_all_mismatch() {
        for n in [1usize, 64, 65, 130] {
            let ones = vec![u64::MAX; n];
            let zeros = vec![0u64; n];
            for k in available_kernels() {
                let f = row_fn(k);
                assert_eq!(f(&ones, &ones), (n * 64) as u32, "{k:?} all-match n={n}");
                assert_eq!(f(&ones, &zeros), 0, "{k:?} all-mismatch n={n}");
                assert_eq!(f(&zeros, &zeros), (n * 64) as u32, "{k:?} zeros match n={n}");
            }
        }
    }

    #[test]
    fn every_kernels_tile2_matches_four_scalar_rows() {
        // The 2×2 tile must be a pure reordering of the row reductions:
        // same popcounts, every length class (sub-vector, 4-word blocks,
        // odd tails).
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 63, 64, 65, 127, 128, 129, 200] {
            let a0 = words(11 + n as u64, n);
            let a1 = words(500 + n as u64, n);
            let b0 = words(900 + n as u64, n);
            let b1 = words(1300 + n as u64, n);
            let expect = tile2_scalar(&a0, &a1, &b0, &b1);
            for k in available_kernels() {
                assert_eq!(tile2_fn(k)(&a0, &a1, &b0, &b1), expect, "kernel {k:?} n={n}");
            }
        }
    }

    #[test]
    fn tile2_handles_constant_extremes() {
        for n in [1usize, 4, 5, 64] {
            let ones = vec![u64::MAX; n];
            let zeros = vec![0u64; n];
            for k in available_kernels() {
                let t = tile2_fn(k)(&ones, &zeros, &ones, &zeros);
                assert_eq!(t, [(n * 64) as u32, 0, 0, (n * 64) as u32], "{k:?} n={n}");
            }
        }
    }

    #[test]
    fn scalar_always_dispatchable_and_first() {
        let ks = available_kernels();
        assert_eq!(ks.first(), Some(&Kernel::Scalar));
        assert!(Kernel::Scalar.dispatchable());
    }

    #[test]
    fn best_kernel_is_dispatchable() {
        assert!(best_kernel().dispatchable());
        assert!(available_kernels().contains(&best_kernel()));
    }

    #[test]
    fn force_scalar_env_pins_scalar() {
        // Only meaningful when the harness (CI matrix leg) sets the env;
        // asserts the override is honored end to end in that case.
        if force_scalar() {
            assert_eq!(best_kernel(), Kernel::Scalar);
            assert_eq!(available_kernels(), vec![Kernel::Scalar]);
            assert!(!Kernel::Avx2.dispatchable());
            assert!(!Kernel::Avx512.dispatchable());
            assert!(!Kernel::Neon.dispatchable());
        }
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(Kernel::Scalar.label(), "scalar");
        assert_eq!(Kernel::Avx2.label(), "avx2");
        assert_eq!(Kernel::Avx512.label(), "avx512");
        assert_eq!(Kernel::Neon.label(), "neon");
    }
}
