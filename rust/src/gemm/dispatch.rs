//! Method dispatch: one enum naming every GEMM variant in Figures 1–3,
//! plus the high-level entry points the inference engine and the bench
//! harness share.
//!
//! Two layers of dispatch compose here (DESIGN.md §SIMD popcount
//! dispatch):
//!
//! 1. **Method** — *which algorithm*: float vs xnor, word width,
//!    blocking, threading, fusion.  Chosen by the caller (CLI flag, layer
//!    config) or [`Method::auto`].
//! 2. **Kernel** ([`super::simd`]) — *which instruction set* runs the
//!    inner popcount row reduction.  Chosen at runtime from CPU features,
//!    overridable with `BMXNET_FORCE_SCALAR=1`.
//!
//! The pinned-SIMD methods (`xnor_64_avx2` / `xnor_64_avx512` /
//! `xnor_64_neon`) exist so benches can measure one kernel in isolation;
//! they are only [`Method::is_available`] when their kernel is
//! dispatchable on the running CPU.  `xnor_fused` and `xnor_64_omp`
//! delegate kernel choice to [`simd::best_kernel`] and are always
//! available.

use super::pack::{PackedMatrix, Side};
use super::simd::{self, Kernel};
use super::{blocked, fused, naive, parallel, xnor};
use crate::quant::xnor_to_dot;

/// Every GEMM variant the paper benchmarks (Figure 1 legend) plus the
/// explicit-SIMD and fused variants this repo adds on top.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// Textbook i-j-k float GEMM (`naive gemm`).
    NaiveF32,
    /// Cache-blocked float GEMM (the `Cblas(Atlas)` stand-in).
    BlockedF32,
    /// Listing 3 on 32-bit words (`xnor_32`).
    Xnor32,
    /// Listing 3 on 64-bit words (`xnor_64`).
    Xnor64,
    /// Blocked + unrolled xnor_64 (scalar row kernel).
    Xnor64Blocked,
    /// Multi-threaded blocked xnor_64 (`xnor_64_omp`); rows run the best
    /// available SIMD kernel.
    Xnor64Mt,
    /// Blocked xnor_64 pinned to the AVX2 Harley–Seal kernel.
    Xnor64Avx2,
    /// Blocked xnor_64 pinned to the AVX-512 `VPOPCNTDQ` kernel
    /// (requires `--features simd-avx512` and CPU support).
    Xnor64Avx512,
    /// Blocked xnor_64 pinned to the NEON `vcnt` kernel.
    Xnor64Neon,
    /// Fused binarize→pack→GEMM with the best available kernel — the
    /// inference default ([`Method::auto`]).
    XnorFused,
    /// `XnorFused` plus the integer threshold epilogue: popcount
    /// accumulators are compared against folded BatchNorm+sign
    /// thresholds and written out as packed sign bits
    /// ([`fused::gemm_fused_threshold`]).  This is the inter-layer path
    /// of the folded engine; through the generic f32/popcount entry
    /// points it behaves exactly like `XnorFused` (the epilogue needs
    /// per-channel rules those signatures cannot carry — use
    /// [`binary_gemm_packed_b_threshold`]).
    XnorFusedThresh,
}

impl Method {
    /// The full static catalog — every variant that can ever exist, on
    /// any architecture.  Use for label round-trips and documentation;
    /// use [`Method::available`] to know what can *execute* here.
    pub fn all() -> &'static [Method] {
        &[
            Method::NaiveF32,
            Method::BlockedF32,
            Method::Xnor32,
            Method::Xnor64,
            Method::Xnor64Blocked,
            Method::Xnor64Mt,
            Method::Xnor64Avx2,
            Method::Xnor64Avx512,
            Method::Xnor64Neon,
            Method::XnorFused,
            Method::XnorFusedThresh,
        ]
    }

    /// The variants that can execute on the running CPU right now
    /// (respects the `BMXNET_FORCE_SCALAR` override, which hides the
    /// pinned-SIMD variants).  Tests and benches iterate this.
    pub fn available() -> Vec<Method> {
        Method::all().iter().copied().filter(|m| m.is_available()).collect()
    }

    /// Can this variant execute on the running CPU?  Only the
    /// pinned-SIMD variants are ever unavailable; everything else
    /// (including `xnor_fused` / `xnor_64_omp`, which fall back to the
    /// scalar row kernel) always is.
    pub fn is_available(&self) -> bool {
        match self.pinned_kernel() {
            Some(k) => k.dispatchable(),
            None => true,
        }
    }

    /// The kernel a pinned-SIMD variant insists on; `None` for variants
    /// that delegate to [`simd::best_kernel`] or don't use row kernels.
    fn pinned_kernel(&self) -> Option<Kernel> {
        match self {
            Method::Xnor64Avx2 => Some(Kernel::Avx2),
            Method::Xnor64Avx512 => Some(Kernel::Avx512),
            Method::Xnor64Neon => Some(Kernel::Neon),
            _ => None,
        }
    }

    /// The default method for inference forward paths: fused
    /// binarize→pack→GEMM with runtime kernel dispatch.
    pub fn auto() -> Method {
        Method::XnorFused
    }

    /// Figure-1 legend name.
    ///
    /// **API contract** (EXPERIMENTS.md §Perf): these exact strings key
    /// the recorded bench results (`BENCH_*.json`) and the CLI/bench
    /// table columns, and [`Method::from_label`] must round-trip every
    /// one of them — `Method::from_label(m.label()) == Some(m)` for all
    /// variants (enforced by unit tests here and in
    /// `rust/tests/cli_smoke.rs`).  Renaming a label is a breaking
    /// change to every stored benchmark record.  Labels are stable
    /// across architectures: `xnor_64_neon` names the same variant on a
    /// machine that cannot run it.
    pub fn label(&self) -> &'static str {
        match self {
            Method::NaiveF32 => "naive",
            Method::BlockedF32 => "cblas",
            Method::Xnor32 => "xnor_32",
            Method::Xnor64 => "xnor_64",
            Method::Xnor64Blocked => "xnor_64_blk",
            Method::Xnor64Mt => "xnor_64_omp",
            Method::Xnor64Avx2 => "xnor_64_avx2",
            Method::Xnor64Avx512 => "xnor_64_avx512",
            Method::Xnor64Neon => "xnor_64_neon",
            Method::XnorFused => "xnor_fused",
            Method::XnorFusedThresh => "xnor_fused_thr",
        }
    }

    pub fn is_binary(&self) -> bool {
        !matches!(self, Method::NaiveF32 | Method::BlockedF32)
    }

    /// Inverse of [`Method::label`]; `None` for unknown strings.  Stable
    /// round-trip with `label()` is part of the public API contract (see
    /// [`Method::label`]).
    pub fn from_label(s: &str) -> Option<Method> {
        Method::all().iter().copied().find(|m| m.label() == s)
    }
}

/// The row kernel a method would run *right now*: the pinned kernel for
/// pinned-SIMD variants, [`simd::best_kernel`] for the delegating ones,
/// scalar for the plain xnor loops, `None` for float GEMMs (no bit
/// kernel).  This is what the profiler and the
/// `bmxnet_kernel_calls_total` counters label calls with.
pub fn effective_kernel(method: Method) -> Option<Kernel> {
    match method {
        Method::NaiveF32 | Method::BlockedF32 => None,
        Method::Xnor32 | Method::Xnor64 | Method::Xnor64Blocked => Some(Kernel::Scalar),
        Method::Xnor64Mt | Method::XnorFused | Method::XnorFusedThresh => Some(simd::best_kernel()),
        pinned => pinned.pinned_kernel(),
    }
}

/// Run a prepacked xnor GEMM variant, returning raw popcounts.
///
/// Panics if called with a float method, or with a pinned-SIMD method
/// whose kernel the running CPU cannot dispatch ([`Method::is_available`]
/// is the guard) — a loud failure beats silently timing the wrong kernel.
///
/// `XnorFused` degenerates here: with A already packed there is nothing
/// left to fuse, so it runs the blocked loop with the best row kernel.
pub fn xnor_gemm_prepacked(method: Method, a: &PackedMatrix, b: &PackedMatrix) -> Vec<i32> {
    if method.is_binary() {
        // one bump per GEMM entry, not per row — see obs::counters
        let k = effective_kernel(method).unwrap_or(Kernel::Scalar);
        crate::obs::counters::record_gemm(method, k);
    }
    if let Some(k) = method.pinned_kernel() {
        assert!(
            method.is_available(),
            "{m:?} ({label}) needs the {kernel} kernel, which this CPU/build \
             cannot dispatch (check Method::is_available before pinning)",
            m = method,
            label = method.label(),
            kernel = k.label(),
        );
        return xnor::gemm_u64_blocked_with(a, b, simd::row_fn(k));
    }
    match method {
        Method::Xnor32 => xnor::gemm_u32(a, b),
        Method::Xnor64 => xnor::gemm_u64(a, b),
        Method::Xnor64Blocked => xnor::gemm_u64_blocked(a, b),
        Method::Xnor64Mt => parallel::gemm_u64_mt(a, b),
        Method::XnorFused | Method::XnorFusedThresh => {
            xnor::gemm_u64_blocked_with(a, b, simd::row_fn(simd::best_kernel()))
        }
        m => panic!("{m:?} is not a packed xnor method"),
    }
}

/// Binary GEMM through any method, float in / float out:
/// inputs are sign-binarized implicitly; output is the ±1 dot product.
///
/// This is the semantic contract the paper's Eq. 2 establishes: every
/// method returns the *same* C for the same A, B.
pub fn binary_gemm_f32(
    method: Method,
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
) -> Vec<f32> {
    match method {
        Method::NaiveF32 => {
            crate::obs::counters::record_gemm_f32(method);
            let ab = super::pack::binarize_slice(a);
            let bb = super::pack::binarize_slice(b);
            naive::gemm_f32(&ab, &bb, m, n, k)
        }
        Method::BlockedF32 => {
            crate::obs::counters::record_gemm_f32(method);
            let ab = super::pack::binarize_slice(a);
            let bb = super::pack::binarize_slice(b);
            blocked::gemm_f32(&ab, &bb, m, n, k)
        }
        Method::XnorFused | Method::XnorFusedThresh => {
            crate::obs::counters::record_gemm(method, simd::best_kernel());
            let pb = PackedMatrix::pack_cols(b, k, n);
            fused::gemm_fused(a, m, k, &pb)
                .into_iter()
                .map(|p| xnor_to_dot(p, k))
                .collect()
        }
        _ => {
            let pa = PackedMatrix::pack_rows(a, m, k, Side::A);
            let pb = PackedMatrix::pack_cols(b, k, n);
            xnor_gemm_prepacked(method, &pa, &pb)
                .into_iter()
                .map(|p| xnor_to_dot(p, k))
                .collect()
        }
    }
}

/// The inference-forward entry point: float activations against a
/// pre-packed weight operand, returning raw popcounts.  `XnorFused`
/// avoids materializing packed A entirely; other binary methods pack A
/// then run prepacked.  Panics on float methods (layers hold only packed
/// weights — there is no float B to multiply).
pub fn binary_gemm_packed_b(
    method: Method,
    a: &[f32],
    m: usize,
    k: usize,
    b: &PackedMatrix,
) -> Vec<i32> {
    match method {
        Method::XnorFused | Method::XnorFusedThresh => {
            crate::obs::counters::record_gemm(method, simd::best_kernel());
            fused::gemm_fused(a, m, k, b)
        }
        _ if method.is_binary() => {
            let pa = PackedMatrix::pack_rows(a, m, k, Side::A);
            xnor_gemm_prepacked(method, &pa, b)
        }
        _ => panic!("{method:?} is not a binary method; layers hold packed weights only"),
    }
}

/// The folded inter-layer entry point: float activations × pre-packed
/// weights, popcounts compared against per-channel folded BN+sign rules,
/// packed sign bits out ([`fused::gemm_fused_threshold`]).  This is the
/// only dispatch entry whose output is a [`PackedMatrix`]; it always runs
/// the fused kernel and counts under `xnor_fused_thr` so `/metrics`,
/// `dispatch_summary()` and `bmxnet profile` can attribute the epilogue.
pub fn binary_gemm_packed_b_threshold(
    a: &[f32],
    m: usize,
    k: usize,
    b: &PackedMatrix,
    rules: &[fused::ChannelRule],
) -> PackedMatrix {
    crate::obs::counters::record_gemm(Method::XnorFusedThresh, simd::best_kernel());
    fused::gemm_fused_threshold(a, m, k, b, rules)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::from_label(m.label()), Some(*m));
        }
        assert_eq!(Method::from_label("nope"), None);
    }

    #[test]
    fn binary_flags() {
        assert!(!Method::NaiveF32.is_binary());
        assert!(!Method::BlockedF32.is_binary());
        assert!(Method::Xnor64.is_binary());
        assert!(Method::XnorFused.is_binary());
    }

    #[test]
    #[should_panic(expected = "not a packed xnor method")]
    fn prepacked_rejects_float_methods() {
        let p = PackedMatrix::pack_rows(&[1.0; 64], 1, 64, Side::A);
        xnor_gemm_prepacked(Method::NaiveF32, &p, &p);
    }

    #[test]
    fn available_is_subset_of_all_and_contains_portables() {
        let avail = Method::available();
        for m in &avail {
            assert!(Method::all().contains(m));
            assert!(m.is_available());
        }
        // The portable variants can never be unavailable.
        for m in [
            Method::NaiveF32,
            Method::BlockedF32,
            Method::Xnor32,
            Method::Xnor64,
            Method::Xnor64Blocked,
            Method::Xnor64Mt,
            Method::XnorFused,
            Method::XnorFusedThresh,
        ] {
            assert!(avail.contains(&m), "{m:?} must always be available");
        }
    }

    #[test]
    fn auto_is_fused_and_available() {
        assert_eq!(Method::auto(), Method::XnorFused);
        assert!(Method::auto().is_available());
    }

    #[test]
    fn effective_kernel_matches_dispatch_rules() {
        assert_eq!(effective_kernel(Method::NaiveF32), None);
        assert_eq!(effective_kernel(Method::BlockedF32), None);
        assert_eq!(effective_kernel(Method::Xnor64), Some(Kernel::Scalar));
        assert_eq!(effective_kernel(Method::Xnor64Blocked), Some(Kernel::Scalar));
        assert_eq!(effective_kernel(Method::XnorFused), Some(simd::best_kernel()));
        assert_eq!(effective_kernel(Method::XnorFusedThresh), Some(simd::best_kernel()));
        assert_eq!(effective_kernel(Method::Xnor64Mt), Some(simd::best_kernel()));
        assert_eq!(effective_kernel(Method::Xnor64Avx2), Some(Kernel::Avx2));
        assert_eq!(effective_kernel(Method::Xnor64Neon), Some(Kernel::Neon));
    }

    #[test]
    fn gemm_entries_bump_kernel_call_counters() {
        use crate::obs::counters;
        let total = |method: &str| {
            counters::gemm_calls()
                .iter()
                .filter(|(m, _, _)| *m == method)
                .map(|(_, _, n)| *n)
                .sum::<u64>()
        };
        let a: Vec<f32> = (0..2 * 64).map(|i| i as f32 - 60.0).collect();
        let b: Vec<f32> = (0..64 * 3).map(|i| 90.0 - i as f32).collect();

        let fused_before = total("xnor_fused");
        let f32_before = total("cblas");
        binary_gemm_f32(Method::XnorFused, &a, &b, 2, 3, 64);
        binary_gemm_f32(Method::BlockedF32, &a, &b, 2, 3, 64);
        assert_eq!(total("xnor_fused") - fused_before, 1);
        assert_eq!(total("cblas") - f32_before, 1);
        // the float entry counts under the "f32" pseudo-kernel
        assert!(counters::gemm_calls()
            .iter()
            .any(|(m, k, _)| *m == "cblas" && *k == "f32"));
    }

    #[test]
    fn pinned_unavailable_method_panics_loudly() {
        // Find a pinned-SIMD variant the running CPU cannot dispatch (on
        // x86 that is at least xnor_64_neon; on aarch64 the avx ones).
        let unavailable = Method::all().iter().copied().find(|m| !m.is_available());
        if let Some(m) = unavailable {
            let p = PackedMatrix::pack_rows(&[1.0; 64], 1, 64, Side::A);
            let err = std::panic::catch_unwind(|| xnor_gemm_prepacked(m, &p, &p));
            assert!(err.is_err(), "{m:?} must panic, not run the wrong kernel");
        }
    }

    #[test]
    fn threshold_entry_matches_rules_and_counts_under_its_label() {
        use crate::obs::counters;
        let total = || {
            counters::gemm_calls()
                .iter()
                .filter(|(m, _, _)| *m == "xnor_fused_thr")
                .map(|(_, _, n)| *n)
                .sum::<u64>()
        };
        let (m, n, k) = (3, 5, 70);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32) * 0.7 - 40.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| 30.0 - (i as f32) * 0.3).collect();
        let pb = PackedMatrix::pack_cols(&b, k, n);
        let rules: Vec<fused::ChannelRule> =
            (0..n).map(|j| fused::fold_bn_sign(1.0 - j as f32, 2.0, k)).collect();
        let before = total();
        let out = binary_gemm_packed_b_threshold(&a, m, k, &pb, &rules);
        assert_eq!(total() - before, 1, "threshold entry must count under xnor_fused_thr");
        let pops = binary_gemm_packed_b(Method::XnorFused, &a, m, k, &pb);
        for i in 0..m {
            for j in 0..n {
                assert_eq!(out.get_bit(i, j), rules[j].fires(pops[i * n + j]), "({i}, {j})");
            }
        }
    }

    #[test]
    fn packed_b_agrees_with_f32_entry() {
        let a: Vec<f32> = (0..3 * 70).map(|i| (i as f32) * 0.7 - 40.0).collect();
        let b: Vec<f32> = (0..70 * 5).map(|i| 30.0 - (i as f32) * 0.3).collect();
        let pb = PackedMatrix::pack_cols(&b, 70, 5);
        for m in Method::available().into_iter().filter(|m| m.is_binary()) {
            let pops = binary_gemm_packed_b(m, &a, 3, 70, &pb);
            let dots: Vec<f32> = pops.iter().map(|&p| xnor_to_dot(p, 70)).collect();
            assert_eq!(dots, binary_gemm_f32(m, &a, &b, 3, 5, 70), "{m:?}");
        }
    }
}
