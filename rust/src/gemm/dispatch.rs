//! Method dispatch: one enum naming every GEMM variant in Figures 1–3,
//! plus the high-level entry points the inference engine and the bench
//! harness share.

use super::pack::{PackedMatrix, Side};
use super::{blocked, naive, parallel, xnor};
use crate::quant::xnor_to_dot;

/// Every GEMM variant the paper benchmarks (Figure 1 legend).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// Textbook i-j-k float GEMM (`naive gemm`).
    NaiveF32,
    /// Cache-blocked float GEMM (the `Cblas(Atlas)` stand-in).
    BlockedF32,
    /// Listing 3 on 32-bit words (`xnor_32`).
    Xnor32,
    /// Listing 3 on 64-bit words (`xnor_64`).
    Xnor64,
    /// Blocked + unrolled xnor_64.
    Xnor64Blocked,
    /// Multi-threaded blocked xnor_64 (`xnor_64_omp`).
    Xnor64Mt,
}

impl Method {
    pub fn all() -> &'static [Method] {
        &[
            Method::NaiveF32,
            Method::BlockedF32,
            Method::Xnor32,
            Method::Xnor64,
            Method::Xnor64Blocked,
            Method::Xnor64Mt,
        ]
    }

    /// Figure-1 legend name.
    ///
    /// **API contract** (EXPERIMENTS.md §Perf): these exact strings key
    /// the recorded bench results (`BENCH_*.json`) and the CLI/bench
    /// table columns, and [`Method::from_label`] must round-trip every
    /// one of them — `Method::from_label(m.label()) == Some(m)` for all
    /// variants (enforced by unit tests here and in
    /// `rust/tests/cli_smoke.rs`).  Renaming a label is a breaking
    /// change to every stored benchmark record.
    pub fn label(&self) -> &'static str {
        match self {
            Method::NaiveF32 => "naive",
            Method::BlockedF32 => "cblas",
            Method::Xnor32 => "xnor_32",
            Method::Xnor64 => "xnor_64",
            Method::Xnor64Blocked => "xnor_64_blk",
            Method::Xnor64Mt => "xnor_64_omp",
        }
    }

    pub fn is_binary(&self) -> bool {
        !matches!(self, Method::NaiveF32 | Method::BlockedF32)
    }

    /// Inverse of [`Method::label`]; `None` for unknown strings.  Stable
    /// round-trip with `label()` is part of the public API contract (see
    /// [`Method::label`]).
    pub fn from_label(s: &str) -> Option<Method> {
        Method::all().iter().copied().find(|m| m.label() == s)
    }
}

/// Run a prepacked xnor GEMM variant, returning raw popcounts.
/// Panics if called with a float method.
pub fn xnor_gemm_prepacked(method: Method, a: &PackedMatrix, b: &PackedMatrix) -> Vec<i32> {
    match method {
        Method::Xnor32 => xnor::gemm_u32(a, b),
        Method::Xnor64 => xnor::gemm_u64(a, b),
        Method::Xnor64Blocked => xnor::gemm_u64_blocked(a, b),
        Method::Xnor64Mt => parallel::gemm_u64_mt(a, b),
        m => panic!("{m:?} is not a packed xnor method"),
    }
}

/// Binary GEMM through any method, float in / float out:
/// inputs are sign-binarized implicitly; output is the ±1 dot product.
///
/// This is the semantic contract the paper's Eq. 2 establishes: every
/// method returns the *same* C for the same A, B.
pub fn binary_gemm_f32(
    method: Method,
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
) -> Vec<f32> {
    match method {
        Method::NaiveF32 => {
            let ab = super::pack::binarize_slice(a);
            let bb = super::pack::binarize_slice(b);
            naive::gemm_f32(&ab, &bb, m, n, k)
        }
        Method::BlockedF32 => {
            let ab = super::pack::binarize_slice(a);
            let bb = super::pack::binarize_slice(b);
            blocked::gemm_f32(&ab, &bb, m, n, k)
        }
        _ => {
            let pa = PackedMatrix::pack_rows(a, m, k, Side::A);
            let pb = PackedMatrix::pack_cols(b, k, n);
            xnor_gemm_prepacked(method, &pa, &pb)
                .into_iter()
                .map(|p| xnor_to_dot(p, k))
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::from_label(m.label()), Some(*m));
        }
        assert_eq!(Method::from_label("nope"), None);
    }

    #[test]
    fn binary_flags() {
        assert!(!Method::NaiveF32.is_binary());
        assert!(!Method::BlockedF32.is_binary());
        assert!(Method::Xnor64.is_binary());
    }

    #[test]
    #[should_panic(expected = "not a packed xnor method")]
    fn prepacked_rejects_float_methods() {
        let p = PackedMatrix::pack_rows(&[1.0; 64], 1, 64, Side::A);
        xnor_gemm_prepacked(Method::NaiveF32, &p, &p);
    }
}
