//! Fused binarize→pack→GEMM and the integer threshold epilogue.
//!
//! Two fusions live here:
//!
//! 1. **Input fusion** ([`gemm_fused`]): the layer forward path holds B
//!    (the weights) pre-packed at load time, but A (the activations /
//!    im2col buffer) is fresh every call.  The unfused path packs all of
//!    A into a heap `PackedMatrix` (M×⌈K/64⌉×8 bytes) and only then
//!    starts the GEMM — at Fig-3 scale that intermediate is megabytes of
//!    traffic that is written once, read once, and thrown away.  This
//!    path instead packs an `MR`-row panel into a reusable stack-sized
//!    scratch and immediately consumes it against every B tile while it
//!    is still L1-hot (daBNN's bit-pack fusion, PAPERS.md).
//!
//! 2. **Output fusion** ([`gemm_fused_threshold`]): when a binary GEMM is
//!    followed by BatchNorm and a sign activation, the whole
//!    BN+sign tail collapses into one per-channel integer compare
//!    against the popcount accumulator ([`ChannelRule`], folded by
//!    [`fold_bn_sign`] — the `batch_norm_threshold` trick from the BNN
//!    literature).  The epilogue writes the resulting sign bits straight
//!    into the **next layer's packed-A layout**: no f32 tensor is ever
//!    materialized between consecutive binary layers.
//!
//! Both the packing and the epilogue output go through
//! [`pack::pack_row_into`] / [`PackedMatrix::zeroed`], so the fused paths
//! cannot drift from the packing convention (A-side: pad bits are 1).
//!
//! The inner loops run the 2×2 register-tile kernel
//! ([`simd::tile2_fn`]) over row/column pairs — each packed operand word
//! is loaded once and feeds two products — with single-row
//! ([`simd::row_fn`]) cleanup for odd edges.

use super::pack::{self, PackedMatrix, WORD_BITS};
use super::simd;

/// A-panel rows packed per pass; 8 rows × wpr words stays resident while
/// the J tile loop streams B.
const MR: usize = 8;
/// B rows (output columns) per tile, matching the blocked kernels.
const JB: usize = 64;

/// One output channel's folded BatchNorm+sign decision, evaluated
/// directly on the popcount accumulator `p ∈ [0, K]`.
///
/// Folding starts from the affine BN form `y = scale·dot + shift` with
/// `dot = 2p − K`; the sign bit is `y >= 0`.  Dividing through by
/// `scale` **flips the comparison direction when `scale < 0`** (negative
/// BN gamma), and `scale == 0` (gamma exactly zero) makes the output
/// independent of `p` — hence three rule shapes, not one threshold
/// integer.  [`fold_bn_sign`] constructs the rule; DESIGN.md §Threshold
/// folding derives it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChannelRule {
    /// `bit = (p >= t)` — positive BN scale.  `t > K` never fires.
    Ge(i32),
    /// `bit = (p <= t)` — negative BN scale (flipped comparison).
    /// `t < 0` never fires.
    Le(i32),
    /// `bit` is independent of the popcount (`scale == 0`).
    Const(bool),
}

impl ChannelRule {
    /// Evaluate the rule on one popcount accumulator.
    #[inline]
    pub fn fires(&self, p: i32) -> bool {
        match *self {
            ChannelRule::Ge(t) => p >= t,
            ChannelRule::Le(t) => p <= t,
            ChannelRule::Const(b) => b,
        }
    }
}

/// Fold one channel's BatchNorm+sign into a [`ChannelRule`] over the
/// popcount domain `p ∈ [0, k]`.
///
/// `scale`/`shift` are the channel's inference-time BN affine form (see
/// `nn::layers::BatchNorm`); `k` is the GEMM reduction length (so
/// `dot = 2p − k`).  The rule is **bit-exact against the f32 reference**
/// `scale * ((2p − k) as f32) + shift >= 0.0` for every `p` in range:
/// the threshold candidate comes from exact f64 algebra
/// (`t = ⌈(k − shift/scale)/2⌉`), then is nudged against the actual f32
/// expression — which is monotone in `p`, so a local walk finds the true
/// f32 crossover even when f32 rounding moves it off the algebraic one.
/// That exactness is what lets the differential tests demand
/// folded ≡ unfused down to the last bit.
pub fn fold_bn_sign(scale: f32, shift: f32, k: usize) -> ChannelRule {
    assert!(
        scale.is_finite() && shift.is_finite(),
        "fold_bn_sign: non-finite BN scale/shift ({scale}, {shift})"
    );
    assert!(k < i32::MAX as usize / 2, "fold_bn_sign: k {k} out of range");
    let kk = k as i64;
    // The unfused f32 pipeline this rule must reproduce exactly.
    let fires = |p: i64| -> bool {
        let dot = (2 * p - kk) as f32;
        scale * dot + shift >= 0.0
    };
    if scale == 0.0 {
        return ChannelRule::Const(shift >= 0.0);
    }
    // Sign crossover of scale·dot + shift in the dot domain, exact f64.
    let r = -(shift as f64) / (scale as f64);
    let cand = (r + kk as f64) / 2.0;
    if scale > 0.0 {
        let mut t = if cand.is_finite() { cand.ceil() as i64 } else { 0 };
        t = t.clamp(0, kk + 1);
        while t > 0 && fires(t - 1) {
            t -= 1;
        }
        while t <= kk && !fires(t) {
            t += 1;
        }
        ChannelRule::Ge(t as i32)
    } else {
        let mut t = if cand.is_finite() { cand.floor() as i64 } else { kk };
        t = t.clamp(-1, kk);
        while t < kk && fires(t + 1) {
            t += 1;
        }
        while t >= 0 && !fires(t) {
            t -= 1;
        }
        ChannelRule::Le(t as i32)
    }
}

/// Fold a whole BN layer: one rule per output channel.  `k` is the GEMM
/// reduction length shared by every channel of the preceding binary
/// conv/dense layer.
pub fn fold_bn_sign_all(scale: &[f32], shift: &[f32], k: usize) -> Vec<ChannelRule> {
    assert_eq!(scale.len(), shift.len(), "fold_bn_sign_all: channel mismatch");
    scale.iter().zip(shift).map(|(&s, &b)| fold_bn_sign(s, b, k)).collect()
}

/// Fused binarize→pack→xnor GEMM.  `a` is row-major (m, k) floats
/// (binarized by sign on the fly); `b` is the pre-packed weight operand
/// ([`PackedMatrix::pack_cols`] layout).  Returns raw popcounts like the
/// other xnor kernels; map with [`crate::quant::xnor_to_dot`].
pub fn gemm_fused(a: &[f32], m: usize, k: usize, b: &PackedMatrix) -> Vec<i32> {
    assert_eq!(a.len(), m * k, "gemm_fused: A length mismatch");
    assert_eq!(b.k, k, "gemm_fused: reduction length mismatch");
    let n = b.rows;
    let wpr = k.div_ceil(WORD_BITS);
    debug_assert_eq!(wpr, b.words_per_row);
    // Kernels resolved once per GEMM call (env override + CPU probe).
    let kern = simd::best_kernel();
    let row = simd::row_fn(kern);
    let tile = simd::tile2_fn(kern);
    let mut c = vec![0i32; m * n];
    let mut panel = vec![0u64; MR * wpr];
    for ic in (0..m).step_by(MR) {
        let mb = MR.min(m - ic);
        // Binarize+pack this A panel once...
        for di in 0..mb {
            let src = &a[(ic + di) * k..(ic + di + 1) * k];
            pack::pack_row_into(src, &mut panel[di * wpr..(di + 1) * wpr], pack::Side::A);
        }
        // ...then reuse it across every B tile while it is cache-hot.
        // 2×2 register tiles over row/column pairs; single-row edges.
        for jc in (0..n).step_by(JB) {
            let jb = JB.min(n - jc);
            let mut di = 0;
            while di + 2 <= mb {
                let r0 = &panel[di * wpr..(di + 1) * wpr];
                let r1 = &panel[(di + 1) * wpr..(di + 2) * wpr];
                let c0 = (ic + di) * n + jc;
                let c1 = (ic + di + 1) * n + jc;
                let mut dj = 0;
                while dj + 2 <= jb {
                    let t = tile(r0, r1, b.row(jc + dj), b.row(jc + dj + 1));
                    c[c0 + dj] = t[0] as i32;
                    c[c0 + dj + 1] = t[1] as i32;
                    c[c1 + dj] = t[2] as i32;
                    c[c1 + dj + 1] = t[3] as i32;
                    dj += 2;
                }
                if dj < jb {
                    c[c0 + dj] = row(r0, b.row(jc + dj)) as i32;
                    c[c1 + dj] = row(r1, b.row(jc + dj)) as i32;
                }
                di += 2;
            }
            if di < mb {
                let r0 = &panel[di * wpr..(di + 1) * wpr];
                let ci = (ic + di) * n + jc;
                for dj in 0..jb {
                    c[ci + dj] = row(r0, b.row(jc + dj)) as i32;
                }
            }
        }
    }
    c
}

/// Fused binarize→pack→GEMM→threshold: the integer-only inter-layer hop.
///
/// Same operands as [`gemm_fused`], plus one [`ChannelRule`] per output
/// column (= output channel).  Instead of materializing popcounts or f32
/// activations, each accumulator is compared against its channel's rule
/// **in the epilogue** and the resulting sign bit is written straight
/// into the returned matrix — which is laid out as the *next* layer's
/// packed-A operand (`rows = m`, `k = n`, A-side pad bits preset by
/// [`PackedMatrix::zeroed`]).  Between two binary layers nothing wider
/// than one bit per activation ever touches memory.
pub fn gemm_fused_threshold(
    a: &[f32],
    m: usize,
    k: usize,
    b: &PackedMatrix,
    rules: &[ChannelRule],
) -> PackedMatrix {
    assert_eq!(a.len(), m * k, "gemm_fused_threshold: A length mismatch");
    assert_eq!(b.k, k, "gemm_fused_threshold: reduction length mismatch");
    let n = b.rows;
    assert_eq!(rules.len(), n, "gemm_fused_threshold: one rule per output channel");
    let wpr = k.div_ceil(WORD_BITS);
    debug_assert_eq!(wpr, b.words_per_row);
    let kern = simd::best_kernel();
    let row = simd::row_fn(kern);
    let tile = simd::tile2_fn(kern);
    let mut out = PackedMatrix::zeroed(m, n, pack::Side::A);
    let mut panel = vec![0u64; MR * wpr];
    for ic in (0..m).step_by(MR) {
        let mb = MR.min(m - ic);
        for di in 0..mb {
            let src = &a[(ic + di) * k..(ic + di + 1) * k];
            pack::pack_row_into(src, &mut panel[di * wpr..(di + 1) * wpr], pack::Side::A);
        }
        for jc in (0..n).step_by(JB) {
            let jb = JB.min(n - jc);
            let mut di = 0;
            while di + 2 <= mb {
                let r0 = &panel[di * wpr..(di + 1) * wpr];
                let r1 = &panel[(di + 1) * wpr..(di + 2) * wpr];
                let mut dj = 0;
                while dj + 2 <= jb {
                    let t = tile(r0, r1, b.row(jc + dj), b.row(jc + dj + 1));
                    if rules[jc + dj].fires(t[0] as i32) {
                        out.set_bit(ic + di, jc + dj);
                    }
                    if rules[jc + dj + 1].fires(t[1] as i32) {
                        out.set_bit(ic + di, jc + dj + 1);
                    }
                    if rules[jc + dj].fires(t[2] as i32) {
                        out.set_bit(ic + di + 1, jc + dj);
                    }
                    if rules[jc + dj + 1].fires(t[3] as i32) {
                        out.set_bit(ic + di + 1, jc + dj + 1);
                    }
                    dj += 2;
                }
                if dj < jb {
                    if rules[jc + dj].fires(row(r0, b.row(jc + dj)) as i32) {
                        out.set_bit(ic + di, jc + dj);
                    }
                    if rules[jc + dj].fires(row(r1, b.row(jc + dj)) as i32) {
                        out.set_bit(ic + di + 1, jc + dj);
                    }
                }
                di += 2;
            }
            if di < mb {
                let r0 = &panel[di * wpr..(di + 1) * wpr];
                for dj in 0..jb {
                    if rules[jc + dj].fires(row(r0, b.row(jc + dj)) as i32) {
                        out.set_bit(ic + di, jc + dj);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::pack::Side;
    use super::super::tests::lcg_floats;
    use super::super::xnor;
    use super::*;

    #[test]
    fn fused_matches_pack_then_blocked() {
        for (m, n, k) in [
            (1, 1, 1),
            (1, 9, 63),
            (9, 1, 64),
            (5, 7, 65),
            (8, 8, 128),
            (17, 70, 333),
            (23, 40, 1000),
        ] {
            let a = lcg_floats(21, m * k);
            let b = lcg_floats(22, k * n);
            let pa = PackedMatrix::pack_rows(&a, m, k, Side::A);
            let pb = PackedMatrix::pack_cols(&b, k, n);
            assert_eq!(
                gemm_fused(&a, m, k, &pb),
                xnor::gemm_u64_blocked(&pa, &pb),
                "m={m} n={n} k={k}"
            );
        }
    }

    #[test]
    fn fused_handles_partial_last_panel() {
        // m not a multiple of MR and n not a multiple of JB.
        let (m, n, k) = (MR + 3, JB + 5, 100);
        let a = lcg_floats(31, m * k);
        let b = lcg_floats(32, k * n);
        let pa = PackedMatrix::pack_rows(&a, m, k, Side::A);
        let pb = PackedMatrix::pack_cols(&b, k, n);
        assert_eq!(gemm_fused(&a, m, k, &pb), xnor::gemm_u64(&pa, &pb));
    }

    #[test]
    fn fused_handles_odd_tile_edges() {
        // odd row and column counts exercise the single-row/column
        // cleanup paths around the 2×2 tiles.
        for (m, n, k) in [(1, 1, 10), (3, 3, 65), (7, 63, 129), (9, 65, 64), (2, 2, 64)] {
            let a = lcg_floats(41, m * k);
            let b = lcg_floats(42, k * n);
            let pa = PackedMatrix::pack_rows(&a, m, k, Side::A);
            let pb = PackedMatrix::pack_cols(&b, k, n);
            assert_eq!(gemm_fused(&a, m, k, &pb), xnor::gemm_u64(&pa, &pb), "m={m} n={n} k={k}");
        }
    }

    #[test]
    fn fused_binarizes_by_sign() {
        // zeros binarize to +1 on both sides: every lane matches, pop = k.
        let k = 70;
        let a = vec![0.0f32; k];
        let pb = PackedMatrix::pack_cols(&vec![1.0f32; k], k, 1);
        assert_eq!(gemm_fused(&a, 1, k, &pb), vec![k as i32]);
    }

    /// The unfused f32 reference `fold_bn_sign` must reproduce.
    fn unfused_bit(scale: f32, shift: f32, p: i32, k: usize) -> bool {
        let dot = (2 * p - k as i32) as f32;
        scale * dot + shift >= 0.0
    }

    #[test]
    fn fold_matches_unfused_reference_exhaustively() {
        // Every popcount in [0, K] for a spread of scales/shifts,
        // including negative scale (flipped comparison) and scale == 0.
        let k = 65;
        for &scale in &[2.5f32, 0.03, -1.0, -0.004, 0.0, 17.0, -300.0] {
            for &shift in &[0.0f32, 1.0, -1.0, 13.7, -77.7, 1e-3, -1e-3, 200.0, -200.0] {
                let rule = fold_bn_sign(scale, shift, k);
                for p in 0..=(k as i32) {
                    assert_eq!(
                        rule.fires(p),
                        unfused_bit(scale, shift, p, k),
                        "scale={scale} shift={shift} p={p} rule={rule:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn fold_comparison_direction_follows_scale_sign() {
        // scale > 0: bit set for *high* popcounts; scale < 0 flips it.
        match fold_bn_sign(1.0, 0.0, 100) {
            ChannelRule::Ge(t) => assert_eq!(t, 50),
            r => panic!("positive scale must fold to Ge, got {r:?}"),
        }
        match fold_bn_sign(-1.0, 0.0, 100) {
            ChannelRule::Le(t) => assert_eq!(t, 50),
            r => panic!("negative scale must fold to Le, got {r:?}"),
        }
        assert_eq!(fold_bn_sign(0.0, 3.0, 100), ChannelRule::Const(true));
        assert_eq!(fold_bn_sign(0.0, -3.0, 100), ChannelRule::Const(false));
    }

    #[test]
    fn fold_saturates_at_popcount_extremes() {
        // Shift so large the sign never (or always) flips within [0, K]:
        // the rule must still be exact at p = 0 and p = K.
        let k = 64;
        let always = fold_bn_sign(1.0, 1e9, k);
        let never = fold_bn_sign(1.0, -1e9, k);
        for p in [0, 1, 63, 64] {
            assert!(always.fires(p));
            assert!(!never.fires(p));
        }
    }

    #[test]
    fn fused_threshold_equals_gemm_then_rules() {
        // Odd channel counts and odd m exercise the epilogue's pad and
        // edge handling; mixed-sign scales exercise both directions.
        for (m, n, k) in [(1, 1, 1), (3, 7, 65), (8, 64, 128), (9, 65, 100), (5, 33, 1000)] {
            let a = lcg_floats(51, m * k);
            let b = lcg_floats(52, k * n);
            let pb = PackedMatrix::pack_cols(&b, k, n);
            let scales: Vec<f32> =
                (0..n).map(|j| if j % 3 == 2 { 0.0 } else { (j as f32 - n as f32 / 2.0) / 7.0 }).collect();
            let shifts: Vec<f32> = (0..n).map(|j| (j as f32) * 0.3 - 4.0).collect();
            let rules = fold_bn_sign_all(&scales, &shifts, k);
            let pops = gemm_fused(&a, m, k, &pb);
            let folded = gemm_fused_threshold(&a, m, k, &pb, &rules);
            assert_eq!(folded.rows, m);
            assert_eq!(folded.k, n);
            for i in 0..m {
                for j in 0..n {
                    assert_eq!(
                        folded.get_bit(i, j),
                        rules[j].fires(pops[i * n + j]),
                        "m={m} n={n} k={k} i={i} j={j}"
                    );
                }
            }
            // A-side pad bits above n must be 1 so the matrix is a valid
            // next-layer A operand.
            if n % WORD_BITS != 0 {
                let pad = !0u64 << (n % WORD_BITS);
                for i in 0..m {
                    let last = folded.row(i)[folded.words_per_row - 1];
                    assert_eq!(last & pad, pad, "row {i} pad bits must be set");
                }
            }
        }
    }
}
