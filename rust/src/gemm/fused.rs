//! Fused binarize→pack→GEMM: the inference-forward entry point that skips
//! materializing the full packed A matrix.
//!
//! The layer forward path (`nn/layers.rs`) holds B (the weights)
//! pre-packed at load time, but A (the activations / im2col buffer) is
//! fresh every call.  The unfused path packs all of A into a heap
//! `PackedMatrix` (M×⌈K/64⌉×8 bytes) and only then starts the GEMM — at
//! Fig-3 scale that intermediate is megabytes of traffic that is written
//! once, read once, and thrown away.  This path instead packs an `MR`-row
//! panel into a reusable stack-sized scratch and immediately consumes it
//! against every B tile while it is still L1-hot (daBNN's bit-pack fusion,
//! PAPERS.md).
//!
//! Bit layout is shared with [`super::pack`] via [`pack::pack_row_into`]
//! — the fused path cannot drift from the packing convention because both
//! go through the same row packer (A-side: pad bits are 1).

use super::pack::{self, PackedMatrix, WORD_BITS};
use super::simd;

/// A-panel rows packed per pass; 8 rows × wpr words stays resident while
/// the J tile loop streams B.
const MR: usize = 8;
/// B rows (output columns) per tile, matching the blocked kernels.
const JB: usize = 64;

/// Fused binarize→pack→xnor GEMM.  `a` is row-major (m, k) floats
/// (binarized by sign on the fly); `b` is the pre-packed weight operand
/// ([`PackedMatrix::pack_cols`] layout).  Returns raw popcounts like the
/// other xnor kernels; map with [`crate::quant::xnor_to_dot`].
pub fn gemm_fused(a: &[f32], m: usize, k: usize, b: &PackedMatrix) -> Vec<i32> {
    assert_eq!(a.len(), m * k, "gemm_fused: A length mismatch");
    assert_eq!(b.k, k, "gemm_fused: reduction length mismatch");
    let n = b.rows;
    let wpr = k.div_ceil(WORD_BITS);
    debug_assert_eq!(wpr, b.words_per_row);
    // Row kernel resolved once per GEMM call (env override + CPU probe).
    let row = simd::row_fn(simd::best_kernel());
    let mut c = vec![0i32; m * n];
    let mut panel = vec![0u64; MR * wpr];
    for ic in (0..m).step_by(MR) {
        let mb = MR.min(m - ic);
        // Binarize+pack this A panel once...
        for di in 0..mb {
            let src = &a[(ic + di) * k..(ic + di + 1) * k];
            pack::pack_row_into(src, &mut panel[di * wpr..(di + 1) * wpr], pack::Side::A);
        }
        // ...then reuse it across every B tile while it is cache-hot.
        for jc in (0..n).step_by(JB) {
            let jb = JB.min(n - jc);
            for di in 0..mb {
                let arow = &panel[di * wpr..(di + 1) * wpr];
                let ci = (ic + di) * n + jc;
                let crow = &mut c[ci..ci + jb];
                for (dj, cv) in crow.iter_mut().enumerate() {
                    *cv = row(arow, b.row(jc + dj)) as i32;
                }
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::super::pack::Side;
    use super::super::tests::lcg_floats;
    use super::super::xnor;
    use super::*;

    #[test]
    fn fused_matches_pack_then_blocked() {
        for (m, n, k) in [
            (1, 1, 1),
            (1, 9, 63),
            (9, 1, 64),
            (5, 7, 65),
            (8, 8, 128),
            (17, 70, 333),
            (23, 40, 1000),
        ] {
            let a = lcg_floats(21, m * k);
            let b = lcg_floats(22, k * n);
            let pa = PackedMatrix::pack_rows(&a, m, k, Side::A);
            let pb = PackedMatrix::pack_cols(&b, k, n);
            assert_eq!(
                gemm_fused(&a, m, k, &pb),
                xnor::gemm_u64_blocked(&pa, &pb),
                "m={m} n={n} k={k}"
            );
        }
    }

    #[test]
    fn fused_handles_partial_last_panel() {
        // m not a multiple of MR and n not a multiple of JB.
        let (m, n, k) = (MR + 3, JB + 5, 100);
        let a = lcg_floats(31, m * k);
        let b = lcg_floats(32, k * n);
        let pa = PackedMatrix::pack_rows(&a, m, k, Side::A);
        let pb = PackedMatrix::pack_cols(&b, k, n);
        assert_eq!(gemm_fused(&a, m, k, &pb), xnor::gemm_u64(&pa, &pb));
    }

    #[test]
    fn fused_binarizes_by_sign() {
        // zeros binarize to +1 on both sides: every lane matches, pop = k.
        let k = 70;
        let a = vec![0.0f32; k];
        let pb = PackedMatrix::pack_cols(&vec![1.0f32; k], k, 1);
        assert_eq!(gemm_fused(&a, 1, k, &pb), vec![k as i32]);
    }
}
