//! Serving metrics: request/batch counters, a batch-size histogram and
//! latency percentiles.
//!
//! Snapshots are self-contained (they carry the sorted raw latencies and
//! the histogram), so per-shard snapshots can be merged losslessly into a
//! per-model view — see [`MetricsSnapshot::merge`], used by the serving
//! gateway's `/metrics` endpoint to aggregate across pool shards.
//!
//! The raw latency store is a bounded ring ([`LATENCY_WINDOW`] samples per
//! sink): the gateway runs indefinitely, so an unbounded vector would grow
//! ~8 bytes/request forever and make every `/metrics` scrape clone+sort
//! all history.  Percentiles therefore describe the most recent window —
//! what a live dashboard wants anyway; counters remain all-time.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Per-sink cap on retained raw latency samples (512 KiB at u64 each).
pub const LATENCY_WINDOW: usize = 65_536;

/// Shared, thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    batches: u64,
    batch_size_sum: u64,
    rejected: u64,
    /// batch size -> number of batches dispatched at that size.
    batch_hist: BTreeMap<usize, u64>,
    /// Ring of the most recent [`LATENCY_WINDOW`] latency samples (µs).
    latencies_us: Vec<u64>,
    /// Next overwrite position once the ring is full.
    lat_cursor: usize,
    /// All-time latency sample count (not windowed like the ring).
    lat_count: u64,
    /// All-time latency sum in µs.
    lat_sum_us: u64,
}

/// Point-in-time summary.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub rejected: u64,
    pub mean_batch: f64,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub max: Duration,
    /// (batch size, batches dispatched at that size), ascending by size.
    /// Invariant: sum(size * count) == requests.
    pub batch_hist: Vec<(usize, u64)>,
    /// Sorted per-request latencies in microseconds (the percentile
    /// basis) — the most recent [`LATENCY_WINDOW`] samples.
    pub latencies_us: Vec<u64>,
    /// All-time latency sample count — with [`lat_sum_us`] this backs the
    /// Prometheus `bmxnet_latency_us_count`/`_sum` families, which keep
    /// increasing monotonically (so `rate()` works) even though the raw
    /// percentile window is bounded.
    ///
    /// [`lat_sum_us`]: MetricsSnapshot::lat_sum_us
    pub lat_count: u64,
    /// All-time latency sum in µs.
    pub lat_sum_us: u64,
}

/// Nearest-rank percentile over sorted microsecond latencies:
/// idx = ceil(p * N) - 1.
fn percentile(sorted_us: &[u64], p: f64) -> Duration {
    if sorted_us.is_empty() {
        return Duration::ZERO;
    }
    let rank = (p * sorted_us.len() as f64).ceil() as usize;
    Duration::from_micros(sorted_us[rank.clamp(1, sorted_us.len()) - 1])
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, batch_size: usize, latencies: &[Duration]) {
        let mut g = self.inner.lock().unwrap();
        g.requests += latencies.len() as u64;
        g.batches += 1;
        g.batch_size_sum += batch_size as u64;
        *g.batch_hist.entry(batch_size).or_insert(0) += 1;
        for l in latencies {
            let us = l.as_micros() as u64;
            g.lat_count += 1;
            g.lat_sum_us += us;
            if g.latencies_us.len() < LATENCY_WINDOW {
                g.latencies_us.push(us);
            } else {
                let at = g.lat_cursor;
                g.latencies_us[at] = us;
                g.lat_cursor = (at + 1) % LATENCY_WINDOW;
            }
        }
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let mut ls = g.latencies_us.clone();
        ls.sort_unstable();
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            rejected: g.rejected,
            mean_batch: if g.batches == 0 {
                0.0
            } else {
                g.batch_size_sum as f64 / g.batches as f64
            },
            p50: percentile(&ls, 0.50),
            p95: percentile(&ls, 0.95),
            p99: percentile(&ls, 0.99),
            max: ls.last().map_or(Duration::ZERO, |&u| Duration::from_micros(u)),
            batch_hist: g.batch_hist.iter().map(|(&s, &c)| (s, c)).collect(),
            latencies_us: ls,
            lat_count: g.lat_count,
            lat_sum_us: g.lat_sum_us,
        }
    }
}

impl MetricsSnapshot {
    /// An empty snapshot (identity element for [`MetricsSnapshot::merge`]).
    pub fn empty() -> Self {
        MetricsSnapshot {
            requests: 0,
            batches: 0,
            rejected: 0,
            mean_batch: 0.0,
            p50: Duration::ZERO,
            p95: Duration::ZERO,
            p99: Duration::ZERO,
            max: Duration::ZERO,
            batch_hist: Vec::new(),
            latencies_us: Vec::new(),
            lat_count: 0,
            lat_sum_us: 0,
        }
    }

    /// Losslessly merge per-shard snapshots into one aggregate: counters
    /// add, histograms add bucket-wise, and percentiles are recomputed
    /// over the pooled raw latencies (averaging per-shard percentiles
    /// would be wrong).
    pub fn merge<'a>(snaps: impl IntoIterator<Item = &'a MetricsSnapshot>) -> MetricsSnapshot {
        let mut requests = 0u64;
        let mut batches = 0u64;
        let mut rejected = 0u64;
        let mut size_sum = 0u64;
        let mut hist: BTreeMap<usize, u64> = BTreeMap::new();
        let mut ls: Vec<u64> = Vec::new();
        let mut lat_count = 0u64;
        let mut lat_sum_us = 0u64;
        for s in snaps {
            requests += s.requests;
            batches += s.batches;
            rejected += s.rejected;
            lat_count += s.lat_count;
            lat_sum_us += s.lat_sum_us;
            for &(size, count) in &s.batch_hist {
                size_sum += size as u64 * count;
                *hist.entry(size).or_insert(0) += count;
            }
            ls.extend_from_slice(&s.latencies_us);
        }
        ls.sort_unstable();
        MetricsSnapshot {
            requests,
            batches,
            rejected,
            mean_batch: if batches == 0 { 0.0 } else { size_sum as f64 / batches as f64 },
            p50: percentile(&ls, 0.50),
            p95: percentile(&ls, 0.95),
            p99: percentile(&ls, 0.99),
            max: ls.last().map_or(Duration::ZERO, |&u| Duration::from_micros(u)),
            batch_hist: hist.into_iter().collect(),
            latencies_us: ls,
            lat_count,
            lat_sum_us,
        }
    }

    /// Human-readable one-liner for logs and benches.
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.1} rejected={} p50={:?} p95={:?} p99={:?}",
            self.requests, self.batches, self.mean_batch, self.rejected,
            self.p50, self.p95, self.p99
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_from_known_data() {
        let m = ServerMetrics::new();
        let lats: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        m.record_batch(100, &lats);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch, 100.0);
        assert_eq!(s.p50, Duration::from_micros(50));
        assert_eq!(s.p99, Duration::from_micros(99));
        assert_eq!(s.max, Duration::from_micros(100));
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = ServerMetrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p50, Duration::ZERO);
        assert_eq!(s.mean_batch, 0.0);
        assert!(s.batch_hist.is_empty());
        assert!(s.latencies_us.is_empty());
    }

    #[test]
    fn batches_accumulate() {
        let m = ServerMetrics::new();
        m.record_batch(2, &[Duration::from_micros(5); 2]);
        m.record_batch(4, &[Duration::from_micros(7); 4]);
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.batches, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.mean_batch, 3.0);
    }

    #[test]
    fn single_sample_percentiles_all_equal_it() {
        let m = ServerMetrics::new();
        m.record_batch(1, &[Duration::from_micros(42)]);
        let s = m.snapshot();
        assert_eq!(s.p50, Duration::from_micros(42));
        assert_eq!(s.p95, Duration::from_micros(42));
        assert_eq!(s.p99, Duration::from_micros(42));
        assert_eq!(s.max, Duration::from_micros(42));
    }

    #[test]
    fn snapshots_are_monotone() {
        let m = ServerMetrics::new();
        let mut prev = m.snapshot();
        for round in 1..=5u64 {
            m.record_batch(2, &[Duration::from_micros(round); 2]);
            if round % 2 == 0 {
                m.record_rejected();
            }
            let s = m.snapshot();
            assert!(s.requests >= prev.requests, "requests went backwards");
            assert!(s.batches >= prev.batches, "batches went backwards");
            assert!(s.rejected >= prev.rejected, "rejected went backwards");
            assert!(s.max >= prev.max, "max latency went backwards");
            assert_eq!(s.requests, 2 * round);
            prev = s;
        }
    }

    #[test]
    fn histogram_sums_to_requests() {
        let m = ServerMetrics::new();
        m.record_batch(1, &[Duration::from_micros(1); 1]);
        m.record_batch(3, &[Duration::from_micros(2); 3]);
        m.record_batch(3, &[Duration::from_micros(3); 3]);
        m.record_batch(8, &[Duration::from_micros(4); 8]);
        let s = m.snapshot();
        assert_eq!(s.batch_hist, vec![(1, 1), (3, 2), (8, 1)]);
        let total: u64 = s.batch_hist.iter().map(|&(size, n)| size as u64 * n).sum();
        assert_eq!(total, s.requests);
    }

    #[test]
    fn concurrent_increments_all_land() {
        let m = std::sync::Arc::new(ServerMetrics::new());
        let threads = 8;
        let per_thread = 50;
        std::thread::scope(|s| {
            for t in 0..threads {
                let m = m.clone();
                s.spawn(move || {
                    for i in 0..per_thread {
                        m.record_batch(2, &[Duration::from_micros((t * i) as u64 + 1); 2]);
                        if i % 10 == 0 {
                            m.record_rejected();
                        }
                    }
                });
            }
        });
        let s = m.snapshot();
        assert_eq!(s.requests, (threads * per_thread * 2) as u64);
        assert_eq!(s.batches, (threads * per_thread) as u64);
        assert_eq!(s.rejected, (threads * per_thread / 10) as u64);
        assert_eq!(s.latencies_us.len(), s.requests as usize);
    }

    #[test]
    fn latency_ring_is_bounded() {
        let m = ServerMetrics::new();
        let lats = vec![Duration::from_micros(7); 1000];
        for _ in 0..(LATENCY_WINDOW / 1000 + 2) {
            m.record_batch(1000, &lats);
        }
        let s = m.snapshot();
        // counters are all-time; the raw sample store is capped
        assert!(s.requests as usize > LATENCY_WINDOW);
        assert_eq!(s.latencies_us.len(), LATENCY_WINDOW);
        assert_eq!(s.p99, Duration::from_micros(7));
        // count/sum are NOT windowed — they track every sample ever seen
        assert_eq!(s.lat_count, s.requests);
        assert_eq!(s.lat_sum_us, s.requests * 7);
    }

    #[test]
    fn merge_pools_raw_latencies() {
        let a = ServerMetrics::new();
        let b = ServerMetrics::new();
        // shard a sees the fast half, shard b the slow half
        let fast: Vec<Duration> = (1..=50).map(Duration::from_micros).collect();
        let slow: Vec<Duration> = (51..=100).map(Duration::from_micros).collect();
        a.record_batch(50, &fast);
        b.record_batch(50, &slow);
        b.record_rejected();
        let merged = MetricsSnapshot::merge([&a.snapshot(), &b.snapshot()]);
        assert_eq!(merged.requests, 100);
        assert_eq!(merged.batches, 2);
        assert_eq!(merged.rejected, 1);
        assert_eq!(merged.mean_batch, 50.0);
        // identical to recording everything into one sink
        assert_eq!(merged.p50, Duration::from_micros(50));
        assert_eq!(merged.p99, Duration::from_micros(99));
        assert_eq!(merged.max, Duration::from_micros(100));
        assert_eq!(merged.batch_hist, vec![(50, 2)]);
        assert_eq!(merged.lat_count, 100);
        assert_eq!(merged.lat_sum_us, (1..=100u64).sum::<u64>());
    }

    #[test]
    fn merge_of_nothing_is_empty() {
        let merged = MetricsSnapshot::merge([]);
        assert_eq!(merged.requests, 0);
        assert_eq!(merged.p50, Duration::ZERO);
        let e = MetricsSnapshot::empty();
        assert_eq!(e.requests, merged.requests);
    }
}
