//! Serving metrics: request/batch counters and latency percentiles.

use std::sync::Mutex;
use std::time::Duration;

/// Shared, thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    batches: u64,
    batch_size_sum: u64,
    rejected: u64,
    latencies_us: Vec<u64>,
}

/// Point-in-time summary.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub rejected: u64,
    pub mean_batch: f64,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub max: Duration,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, batch_size: usize, latencies: &[Duration]) {
        let mut g = self.inner.lock().unwrap();
        g.requests += latencies.len() as u64;
        g.batches += 1;
        g.batch_size_sum += batch_size as u64;
        for l in latencies {
            g.latencies_us.push(l.as_micros() as u64);
        }
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let mut ls = g.latencies_us.clone();
        ls.sort_unstable();
        // nearest-rank percentile: idx = ceil(p * N) - 1
        let pct = |p: f64| -> Duration {
            if ls.is_empty() {
                return Duration::ZERO;
            }
            let rank = (p * ls.len() as f64).ceil() as usize;
            Duration::from_micros(ls[rank.clamp(1, ls.len()) - 1])
        };
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            rejected: g.rejected,
            mean_batch: if g.batches == 0 {
                0.0
            } else {
                g.batch_size_sum as f64 / g.batches as f64
            },
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            max: ls.last().map_or(Duration::ZERO, |&u| Duration::from_micros(u)),
        }
    }
}

impl MetricsSnapshot {
    /// Human-readable one-liner for logs and benches.
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.1} rejected={} p50={:?} p95={:?} p99={:?}",
            self.requests, self.batches, self.mean_batch, self.rejected,
            self.p50, self.p95, self.p99
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_from_known_data() {
        let m = ServerMetrics::new();
        let lats: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        m.record_batch(100, &lats);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch, 100.0);
        assert_eq!(s.p50, Duration::from_micros(50));
        assert_eq!(s.p99, Duration::from_micros(99));
        assert_eq!(s.max, Duration::from_micros(100));
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = ServerMetrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p50, Duration::ZERO);
        assert_eq!(s.mean_batch, 0.0);
    }

    #[test]
    fn batches_accumulate() {
        let m = ServerMetrics::new();
        m.record_batch(2, &[Duration::from_micros(5); 2]);
        m.record_batch(4, &[Duration::from_micros(7); 4]);
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.batches, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.mean_batch, 3.0);
    }
}
