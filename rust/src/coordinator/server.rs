//! The request router + worker loop.
//!
//! One ingress mpsc channel fans into the batcher thread; each request
//! carries its own response channel (the std stand-in for a oneshot).
//! Backpressure: the ingress channel is bounded (`queue_cap`); when it is
//! full, `Client::try_classify` fails fast instead of queueing unboundedly.
//!
//! Shutdown uses an in-band `Stop` sentinel rather than a polled flag: the
//! idle batcher blocks in `recv()` (zero idle wakeups), and the straggler
//! wait inside a forming batch is `recv_timeout(policy.remaining(..))`, so
//! sub-millisecond batching windows are honored exactly.  FIFO ordering
//! guarantees every request enqueued before `shutdown()` is served.

use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::BatchPolicy;
use super::metrics::{MetricsSnapshot, ServerMetrics};
use crate::obs::BatchTiming;

/// Anything that can classify a batch of flat NCHW images.
///
/// The production impl is [`crate::nn::Engine`]; tests use mocks.  The
/// trait is object-safe on purpose: the server and the serving pool hold
/// `Arc<dyn Backend>`, so N pool shards can share one loaded engine
/// without re-loading it per shard.
pub trait Backend: Send + Sync + 'static {
    /// Expected per-image shape [C, H, W].
    fn input_shape(&self) -> [usize; 3];
    /// Classify `batch` images packed into `images`.
    fn classify_batch(&self, images: &[f32], batch: usize) -> Result<Vec<(usize, f32)>>;
}

impl Backend for crate::nn::Engine {
    fn input_shape(&self) -> [usize; 3] {
        crate::nn::Engine::input_shape(self)
    }

    fn classify_batch(&self, images: &[f32], batch: usize) -> Result<Vec<(usize, f32)>> {
        self.classify(images, batch)
    }
}

/// A flat image payload moving through the batcher: a `Vec<f32>` plus an
/// optional return-to-pool hook.  The gateway's [`crate::serve::bufpool::
/// FloatPool`] checks buffers out per request; the batcher copies the
/// pixels into its contiguous batch and calls [`ImageBuf::recycle`], so
/// the backing storage goes straight back to the pool instead of being
/// freed — the admission→batcher hand-off moves one pooled allocation
/// end-to-end.  `From<Vec<f32>>` keeps plain (unpooled) submission
/// working everywhere else; the Drop impl guarantees every exit path
/// (engine failure, dropped waiter, shutdown drain) returns the buffer.
pub struct ImageBuf {
    data: Vec<f32>,
    home: Option<Arc<dyn Fn(Vec<f32>) + Send + Sync>>,
}

impl ImageBuf {
    /// Wrap pool-owned storage; `home` receives the storage back on
    /// recycle/drop.
    pub fn pooled(data: Vec<f32>, home: Arc<dyn Fn(Vec<f32>) + Send + Sync>) -> ImageBuf {
        ImageBuf { data, home: Some(home) }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Append one value (the binary decode paths fill checked-out
    /// buffers in place).
    pub fn push(&mut self, v: f32) {
        self.data.push(v);
    }

    /// Append a slice of values.
    pub fn extend_from_slice(&mut self, vs: &[f32]) {
        self.data.extend_from_slice(vs);
    }

    /// Return the backing storage to its pool *now* (the batcher calls
    /// this right after copying into the batch, rather than holding the
    /// buffer hostage through the whole forward pass).  Idempotent; a
    /// recycled buffer reads as an empty slice.
    pub fn recycle(&mut self) {
        let data = std::mem::take(&mut self.data);
        if let Some(home) = self.home.take() {
            home(data);
        }
    }
}

impl From<Vec<f32>> for ImageBuf {
    fn from(data: Vec<f32>) -> ImageBuf {
        ImageBuf { data, home: None }
    }
}

impl std::ops::Deref for ImageBuf {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl std::ops::DerefMut for ImageBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

impl std::fmt::Debug for ImageBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ImageBuf")
            .field("len", &self.data.len())
            .field("pooled", &self.home.is_some())
            .finish()
    }
}

impl Drop for ImageBuf {
    fn drop(&mut self) {
        self.recycle();
    }
}

/// One classification request.
pub struct Request {
    pub image: ImageBuf,
    pub submitted: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// What flows through the ingress channel: work, or the shutdown sentinel.
enum Msg {
    Req(Request),
    Stop,
}

/// The server's answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub class: usize,
    pub score: f32,
    /// Queue + compute latency, measured at reply time.
    pub latency: Duration,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// Where the latency went: queue wait / batch window / forward, in
    /// µs, measured by the batcher per request. The gateway folds this
    /// into the request's trace (`obs::Trace::absorb_batch_timing`).
    pub timing: BatchTiming,
}

/// Server construction parameters.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub policy: BatchPolicy,
    /// Ingress queue bound (backpressure).
    pub queue_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { policy: BatchPolicy::default(), queue_cap: 1024 }
    }
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::SyncSender<Msg>,
    image_len: usize,
}

impl Client {
    /// Blocking classify: submit and wait for the response.
    pub fn classify(&self, image: impl Into<ImageBuf>) -> Result<Response> {
        let rx = self.submit(image)?;
        rx.recv().map_err(|_| anyhow!("server dropped the request"))
    }

    /// Submit without waiting; returns the response channel.
    pub fn submit(&self, image: impl Into<ImageBuf>) -> Result<mpsc::Receiver<Response>> {
        let image = image.into();
        anyhow::ensure!(
            image.len() == self.image_len,
            "image must have {} floats, got {}",
            self.image_len,
            image.len()
        );
        self.try_submit(image).map_err(|(_, why)| anyhow!("{why}"))
    }

    /// Non-blocking submit that hands the image back on failure, so a
    /// multi-shard caller (the serving pool) can retry another shard
    /// without cloning the pixels.
    pub fn try_submit(
        &self,
        image: impl Into<ImageBuf>,
    ) -> std::result::Result<mpsc::Receiver<Response>, (ImageBuf, &'static str)> {
        let image = image.into();
        if image.len() != self.image_len {
            return Err((image, "wrong image length"));
        }
        let (reply, rx) = mpsc::channel();
        match self.tx.try_send(Msg::Req(Request { image, submitted: Instant::now(), reply })) {
            Ok(()) => Ok(rx),
            Err(mpsc::TrySendError::Full(Msg::Req(r))) => Err((r.image, "queue full")),
            Err(mpsc::TrySendError::Disconnected(Msg::Req(r))) => Err((r.image, "server down")),
            // we only ever send Msg::Req here
            Err(_) => Err((ImageBuf::from(Vec::new()), "server down")),
        }
    }

    /// Expected flat image length (C*H*W) for this server.
    pub fn image_len(&self) -> usize {
        self.image_len
    }
}

/// A running server (batcher + worker thread).
pub struct Server {
    tx: Option<mpsc::SyncSender<Msg>>,
    handle: Option<JoinHandle<()>>,
    metrics: Arc<ServerMetrics>,
    image_len: usize,
}

impl Server {
    /// Spawn the batcher/worker thread over the given backend.
    pub fn start(backend: Arc<dyn Backend>, cfg: ServerConfig) -> Server {
        let [c, h, w] = backend.input_shape();
        let image_len = c * h * w;
        let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.queue_cap);
        let metrics = Arc::new(ServerMetrics::new());
        let m = metrics.clone();
        let policy = cfg.policy;
        let handle = std::thread::Builder::new()
            .name("bmxnet-batcher".into())
            .spawn(move || batcher_loop(rx, backend, policy, m))
            .expect("spawn batcher thread");
        Server { tx: Some(tx), handle: Some(handle), metrics, image_len }
    }

    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone().expect("server running"), image_len: self.image_len }
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Send the stop sentinel, let the batcher serve everything queued
    /// before it (FIFO), join the worker and return final metrics.  Safe
    /// to call with outstanding `Client` clones: the sentinel travels
    /// in-band, so no flag polling and no reliance on sender disconnection.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.stop_and_join();
        self.metrics.snapshot()
    }

    fn stop_and_join(&mut self) {
        if let Some(tx) = self.tx.take() {
            // Blocking send: if the queue is momentarily full the batcher
            // is actively draining it, so space opens up; if the batcher
            // is already gone the send fails — both are fine.
            let _ = tx.send(Msg::Stop);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// A request plus the instant the batcher dequeued it — the boundary
/// between queue wait (submit→dequeue) and batch window (dequeue→forward).
struct Queued {
    req: Request,
    received: Instant,
}

fn batcher_loop(
    rx: mpsc::Receiver<Msg>,
    backend: Arc<dyn Backend>,
    policy: BatchPolicy,
    metrics: Arc<ServerMetrics>,
) {
    let [c, h, w] = backend.input_shape();
    let per = c * h * w;
    let mut batch: Vec<Queued> = Vec::new();
    loop {
        // Idle: block until the first request of the next batch arrives.
        // No timeout and no flag polling — shutdown arrives in-band.
        let first = match rx.recv() {
            Ok(Msg::Req(r)) => r,
            Ok(Msg::Stop) | Err(_) => break,
        };
        let first_arrival = Instant::now();
        batch.push(Queued { req: first, received: first_arrival });
        let mut stopping = false;
        // Coalesce until the policy says dispatch; the straggler wait is
        // exactly the remaining window, so sub-ms windows are honored.
        loop {
            let now = Instant::now();
            if policy.should_dispatch(batch.len(), first_arrival, now) {
                break;
            }
            match rx.recv_timeout(policy.remaining(first_arrival, now)) {
                Ok(Msg::Req(r)) => batch.push(Queued { req: r, received: Instant::now() }),
                Ok(Msg::Stop) => {
                    stopping = true;
                    break;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    stopping = true;
                    break;
                }
            }
        }
        dispatch(&backend, per, &mut batch, &metrics);
        if stopping {
            break;
        }
    }
    // Drain requests that raced in behind the sentinel, in max_batch bites.
    while let Ok(msg) = rx.try_recv() {
        if let Msg::Req(r) = msg {
            batch.push(Queued { req: r, received: Instant::now() });
            if batch.len() >= policy.max_batch.max(1) {
                dispatch(&backend, per, &mut batch, &metrics);
            }
        }
    }
    if !batch.is_empty() {
        dispatch(&backend, per, &mut batch, &metrics);
    }
}

fn dispatch(
    backend: &Arc<dyn Backend>,
    per: usize,
    batch: &mut Vec<Queued>,
    metrics: &Arc<ServerMetrics>,
) {
    let bsz = batch.len();
    let mut images = Vec::with_capacity(bsz * per);
    for q in batch.iter_mut() {
        images.extend_from_slice(&q.req.image);
        // the pixels now live in the contiguous batch; send the pooled
        // buffer home before the forward instead of after it
        q.req.image.recycle();
    }
    let forward_start = Instant::now();
    match backend.classify_batch(&images, bsz) {
        Ok(preds) => {
            let done = Instant::now();
            // the forward is shared by the whole batch; queue/window are
            // per-request (Instant::duration_since saturates to zero)
            let forward_us = done.duration_since(forward_start).as_micros() as u64;
            let mut lats = Vec::with_capacity(bsz);
            for (q, (class, score)) in batch.drain(..).zip(preds) {
                let latency = done.duration_since(q.req.submitted);
                lats.push(latency);
                let timing = BatchTiming {
                    queue_us: q.received.duration_since(q.req.submitted).as_micros() as u64,
                    window_us: forward_start.duration_since(q.received).as_micros() as u64,
                    forward_us,
                };
                // receiver may have given up; ignore send errors
                let _ = q.req.reply.send(Response {
                    class,
                    score,
                    latency,
                    batch_size: bsz,
                    timing,
                });
            }
            metrics.record_batch(bsz, &lats);
        }
        Err(_) => {
            // engine failure: drop replies (clients see disconnect)
            for _ in batch.drain(..) {
                metrics.record_rejected();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mock backend: class = index of max pixel value % 10.
    struct Mock {
        delay: Duration,
    }

    impl Backend for Mock {
        fn input_shape(&self) -> [usize; 3] {
            [1, 2, 2]
        }

        fn classify_batch(&self, images: &[f32], batch: usize) -> Result<Vec<(usize, f32)>> {
            std::thread::sleep(self.delay);
            Ok(images
                .chunks(4)
                .take(batch)
                .map(|img| {
                    let (i, &v) = img
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .unwrap();
                    (i, v)
                })
                .collect())
        }
    }

    fn img(hot: usize) -> Vec<f32> {
        let mut v = vec![0.0; 4];
        v[hot] = 1.0;
        v
    }

    #[test]
    fn single_request_roundtrip() {
        let server = Server::start(
            Arc::new(Mock { delay: Duration::ZERO }),
            ServerConfig::default(),
        );
        let resp = server.client().classify(img(2)).unwrap();
        assert_eq!(resp.class, 2);
        assert!(resp.batch_size >= 1);
        let snap = server.shutdown();
        assert_eq!(snap.requests, 1);
    }

    #[test]
    fn concurrent_requests_get_correct_answers() {
        let server = Server::start(
            Arc::new(Mock { delay: Duration::from_micros(200) }),
            ServerConfig {
                policy: BatchPolicy { max_batch: 8, window: Duration::from_millis(5) },
                queue_cap: 64,
            },
        );
        let client = server.client();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let c = client.clone();
                std::thread::spawn(move || {
                    let r = c.classify(img(i % 4)).unwrap();
                    assert_eq!(r.class, i % 4, "request {i}");
                    r.batch_size
                })
            })
            .collect();
        let sizes: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // batching happened at least once under concurrency
        assert!(sizes.iter().any(|&s| s > 1), "no batching observed: {sizes:?}");
        let snap = server.shutdown();
        assert_eq!(snap.requests, 16);
        assert!(snap.batches < 16, "every request served alone");
    }

    #[test]
    fn response_timing_decomposes_latency() {
        let server = Server::start(
            Arc::new(Mock { delay: Duration::from_millis(2) }),
            ServerConfig {
                policy: BatchPolicy { max_batch: 4, window: Duration::from_millis(3) },
                queue_cap: 64,
            },
        );
        let resp = server.client().classify(img(1)).unwrap();
        let t = resp.timing;
        // the mock sleeps 2ms inside classify_batch
        assert!(t.forward_us >= 1_000, "forward_us {t:?}");
        // queue + window + forward is exactly the measured latency up to
        // µs truncation (three floor() operations)
        let latency_us = resp.latency.as_micros() as u64;
        let sum = t.queue_us + t.window_us + t.forward_us;
        assert!(sum <= latency_us + 3, "sum {sum} > latency {latency_us}");
        assert!(sum + 3 >= latency_us, "sum {sum} undercounts latency {latency_us}");
        server.shutdown();
    }

    #[test]
    fn rejects_wrong_image_size() {
        let server = Server::start(
            Arc::new(Mock { delay: Duration::ZERO }),
            ServerConfig::default(),
        );
        assert!(server.client().classify(vec![0.0; 3]).is_err());
    }

    #[test]
    fn shutdown_drains() {
        let server = Server::start(
            Arc::new(Mock { delay: Duration::ZERO }),
            ServerConfig::default(),
        );
        let c = server.client();
        let rx = c.submit(img(1)).unwrap();
        drop(c);
        let snap = server.shutdown();
        // submitted request was answered before shutdown completed
        assert_eq!(rx.recv().unwrap().class, 1);
        assert_eq!(snap.requests, 1);
    }

    #[test]
    fn max_batch_respected() {
        let server = Server::start(
            Arc::new(Mock { delay: Duration::from_micros(50) }),
            ServerConfig {
                policy: BatchPolicy { max_batch: 2, window: Duration::from_millis(20) },
                queue_cap: 64,
            },
        );
        let client = server.client();
        let handles: Vec<_> = (0..6)
            .map(|_| {
                let c = client.clone();
                std::thread::spawn(move || c.classify(img(0)).unwrap().batch_size)
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap() <= 2);
        }
        drop(client);
        server.shutdown();
    }

    #[test]
    fn requests_submitted_before_shutdown_all_answered() {
        // FIFO guarantee: everything enqueued ahead of the sentinel is
        // served, even when shutdown() races with in-flight submissions.
        let server = Server::start(
            Arc::new(Mock { delay: Duration::from_micros(100) }),
            ServerConfig {
                policy: BatchPolicy { max_batch: 4, window: Duration::from_millis(1) },
                queue_cap: 64,
            },
        );
        let c = server.client();
        let pending: Vec<_> = (0..12).map(|i| c.submit(img(i % 4)).unwrap()).collect();
        drop(c);
        let snap = server.shutdown();
        for (i, rx) in pending.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().class, i % 4, "request {i} lost in shutdown");
        }
        assert_eq!(snap.requests, 12);
    }

    #[test]
    fn drop_without_shutdown_joins_cleanly() {
        let server = Server::start(
            Arc::new(Mock { delay: Duration::ZERO }),
            ServerConfig::default(),
        );
        let rx = server.client().submit(img(3)).unwrap();
        drop(server); // Drop path must also send the sentinel and join
        assert_eq!(rx.recv().unwrap().class, 3);
    }
}
