//! Dynamic batching policy: how many queued requests to coalesce and how
//! long to wait for stragglers.
//!
//! The policy is deliberately explicit (instead of buried in the server
//! loop) so the ablation bench `serving_throughput.rs` can sweep window
//! and batch-size settings — the knobs every serving paper tunes.

use std::time::{Duration, Instant};

/// Batch formation policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Hard cap on batch size (compiled executables / engine width).
    pub max_batch: usize,
    /// How long the first request in a batch may wait for company.
    pub window: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 32, window: Duration::from_millis(2) }
    }
}

impl BatchPolicy {
    /// Deadline for a batch whose first request arrived at `first`.
    pub fn deadline(&self, first: Instant) -> Instant {
        first + self.window
    }

    /// Should we dispatch now, given queue depth and the first arrival?
    pub fn should_dispatch(&self, queued: usize, first: Instant, now: Instant) -> bool {
        queued >= self.max_batch || now >= self.deadline(first)
    }

    /// Remaining wait budget (zero if past deadline).
    pub fn remaining(&self, first: Instant, now: Instant) -> Duration {
        self.deadline(first).saturating_duration_since(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatches_on_full_batch() {
        let p = BatchPolicy { max_batch: 4, window: Duration::from_secs(10) };
        let now = Instant::now();
        assert!(p.should_dispatch(4, now, now));
        assert!(p.should_dispatch(9, now, now));
        assert!(!p.should_dispatch(3, now, now));
    }

    #[test]
    fn dispatches_on_deadline() {
        let p = BatchPolicy { max_batch: 100, window: Duration::from_millis(1) };
        let first = Instant::now();
        assert!(!p.should_dispatch(1, first, first));
        let later = first + Duration::from_millis(2);
        assert!(p.should_dispatch(1, first, later));
    }

    #[test]
    fn remaining_saturates_at_zero() {
        let p = BatchPolicy { max_batch: 8, window: Duration::from_millis(1) };
        let first = Instant::now();
        assert!(p.remaining(first, first) <= Duration::from_millis(1));
        assert_eq!(
            p.remaining(first, first + Duration::from_secs(1)),
            Duration::ZERO
        );
    }

    #[test]
    fn default_policy_reasonable() {
        let p = BatchPolicy::default();
        assert!(p.max_batch >= 1);
        assert!(p.window > Duration::ZERO);
    }
}
