//! The serving coordinator — the deployment role the paper's mobile apps
//! play (§4.2), built like an inference server: request router, dynamic
//! batcher, a worker owning the binary engine, and latency/throughput
//! metrics.
//!
//! std-only (offline environment): threads + mpsc channels instead of
//! tokio.  Requests enter through [`Client`] handles, the batcher coalesces
//! them up to `max_batch` within `batch_window`, the worker runs one
//! engine forward per batch, and responses flow back through per-request
//! channels.

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::BatchPolicy;
pub use metrics::{MetricsSnapshot, ServerMetrics};
pub use server::{Backend, Client, ImageBuf, Request, Response, Server, ServerConfig};
