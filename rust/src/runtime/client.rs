//! PJRT client/executable wrappers + Literal conversion glue.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO text ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`.  All artifacts are lowered with
//! `return_tuple=True`, so outputs always arrive as one tuple literal that
//! [`Executable::run`] flattens back into a `Vec<Literal>`.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Wrapper over the PJRT CPU client with an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU runtime (the only backend in this environment).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact (no cache).
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {path:?}"))?;
        Ok(Executable { exe, path: path.to_path_buf() })
    }

    /// Compile-or-reuse an executable, keyed by path.
    pub fn load_cached(&self, path: impl AsRef<Path>) -> Result<std::sync::Arc<Executable>> {
        let path = path.as_ref().to_path_buf();
        if let Some(e) = self.cache.lock().unwrap().get(&path) {
            return Ok(e.clone());
        }
        let exe = std::sync::Arc::new(self.load_hlo_text(&path)?);
        self.cache.lock().unwrap().insert(path, exe.clone());
        Ok(exe)
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

impl Executable {
    /// Execute with the given inputs; flatten the output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute {:?}", self.path))?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("no output buffer from {:?}", self.path))?
            .to_literal_sync()?;
        // return_tuple=True => always a tuple, possibly of arity 1
        lit.to_tuple().context("decompose output tuple")
    }
}

// ---------------------------------------------------------------------------
// Literal glue
// ---------------------------------------------------------------------------

/// Build an f32 literal with the given dims.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "lit_f32: {dims:?} needs {n}, got {}", data.len());
    let d: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&d)?)
}

/// Build an i32 literal with the given dims.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "lit_i32: {dims:?} needs {n}, got {}", data.len());
    let d: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&d)?)
}

/// Build a u32 literal with the given dims.
pub fn lit_u32(data: &[u32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "lit_u32: {dims:?} needs {n}, got {}", data.len());
    let d: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&d)?)
}

/// Scalar f32 literal.
pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read an f32 literal back to a host vector.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Read an i32 literal back to a host vector.
pub fn to_i32_vec(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

/// Read a scalar f32 from a literal.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Literal-only tests (no PJRT client needed; cheap).
    #[test]
    fn lit_f32_roundtrip() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32_vec(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
    }

    #[test]
    fn lit_shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0; 3], &[2, 2]).is_err());
        assert!(lit_i32(&[1; 5], &[2, 2]).is_err());
    }

    #[test]
    fn lit_i32_roundtrip() {
        let l = lit_i32(&[-1, 7], &[2]).unwrap();
        assert_eq!(to_i32_vec(&l).unwrap(), vec![-1, 7]);
    }

    #[test]
    fn scalar_roundtrip() {
        let l = lit_scalar_f32(0.125);
        assert_eq!(scalar_f32(&l).unwrap(), 0.125);
    }

    // Full PJRT round-trip is covered by rust/tests/runtime_integration.rs
    // (needs artifacts/ built).
}
