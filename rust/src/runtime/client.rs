//! PJRT client/executable wrappers + Literal conversion glue.
//!
//! Two implementations share one API surface, selected by the `pjrt`
//! cargo feature, so [`crate::train`], the integration tests and the
//! examples compile identically either way:
//!
//! * **`pjrt` enabled** — the real thing, over the external `xla`
//!   bindings (xla-rs + xla_extension).  Pattern follows
//!   /opt/xla-example/load_hlo: HLO text -> `HloModuleProto::from_text_file`
//!   -> `XlaComputation::from_proto` -> `client.compile` -> `execute`.
//!   All artifacts are lowered with `return_tuple=True`, so outputs always
//!   arrive as one tuple literal that [`Executable::run`] flattens back
//!   into a `Vec<Literal>`.
//! * **`pjrt` disabled (default)** — a stub: [`Runtime::cpu`] returns a
//!   descriptive error so every artifact-driven path (training, the
//!   runtime integration tests, `bmxnet train`) fails fast or skips,
//!   while the [`Literal`] container and the `lit_*` / `to_*` conversion
//!   helpers stay fully functional.  The pure-Rust xnor engine, the
//!   converter and the serving coordinator never touch PJRT and are
//!   unaffected.  See DESIGN.md §PJRT runtime gating.

#[cfg(feature = "pjrt")]
mod imp {
    use anyhow::{anyhow, Context, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    /// Literal type of the real backend.
    pub use xla::Literal;

    /// Wrapper over the PJRT CPU client with an executable cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        cache: Mutex<HashMap<PathBuf, std::sync::Arc<Executable>>>,
    }

    impl Runtime {
        /// Create a CPU runtime (the only backend in this environment).
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Self { client, cache: Mutex::new(HashMap::new()) })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile an HLO-text artifact (no cache).
        pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
            let path = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parse HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {path:?}"))?;
            Ok(Executable { exe, path: path.to_path_buf() })
        }

        /// Compile-or-reuse an executable, keyed by path.
        pub fn load_cached(&self, path: impl AsRef<Path>) -> Result<std::sync::Arc<Executable>> {
            let path = path.as_ref().to_path_buf();
            if let Some(e) = self.cache.lock().unwrap().get(&path) {
                return Ok(e.clone());
            }
            let exe = std::sync::Arc::new(self.load_hlo_text(&path)?);
            self.cache.lock().unwrap().insert(path, exe.clone());
            Ok(exe)
        }
    }

    /// A compiled artifact ready to execute.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub path: PathBuf,
    }

    impl Executable {
        /// Execute with the given inputs; flatten the output tuple.
        pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let result = self
                .exe
                .execute::<xla::Literal>(inputs)
                .with_context(|| format!("execute {:?}", self.path))?;
            let lit = result
                .first()
                .and_then(|d| d.first())
                .ok_or_else(|| anyhow!("no output buffer from {:?}", self.path))?
                .to_literal_sync()?;
            // return_tuple=True => always a tuple, possibly of arity 1
            lit.to_tuple().context("decompose output tuple")
        }
    }

    /// Build an f32 literal with the given dims.
    pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(n == data.len(), "lit_f32: {dims:?} needs {n}, got {}", data.len());
        let d: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&d)?)
    }

    /// Build an i32 literal with the given dims.
    pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(n == data.len(), "lit_i32: {dims:?} needs {n}, got {}", data.len());
        let d: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&d)?)
    }

    /// Build a u32 literal with the given dims.
    pub fn lit_u32(data: &[u32], dims: &[usize]) -> Result<xla::Literal> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(n == data.len(), "lit_u32: {dims:?} needs {n}, got {}", data.len());
        let d: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&d)?)
    }

    /// Scalar f32 literal.
    pub fn lit_scalar_f32(v: f32) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    /// Read an f32 literal back to a host vector.
    pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
        Ok(lit.to_vec::<f32>()?)
    }

    /// Read an i32 literal back to a host vector.
    pub fn to_i32_vec(lit: &xla::Literal) -> Result<Vec<i32>> {
        Ok(lit.to_vec::<i32>()?)
    }

    /// Read a scalar f32 from a literal.
    pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
        Ok(lit.get_first_element::<f32>()?)
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use anyhow::{anyhow, bail, Result};
    use std::path::{Path, PathBuf};

    /// Host-side literal: typed data + dims.  The stub's stand-in for
    /// `xla::Literal`, API-compatible with the subset this crate uses
    /// (`to_vec`, `element_count`), so all callers compile unchanged.
    #[derive(Debug, Clone, PartialEq)]
    pub enum Literal {
        F32 { data: Vec<f32>, dims: Vec<usize> },
        I32 { data: Vec<i32>, dims: Vec<usize> },
        U32 { data: Vec<u32>, dims: Vec<usize> },
    }

    /// Element types a [`Literal`] can be read back as.
    pub trait LiteralElem: Sized {
        fn read(lit: &Literal) -> Result<Vec<Self>>;
    }

    impl LiteralElem for f32 {
        fn read(lit: &Literal) -> Result<Vec<f32>> {
            match lit {
                Literal::F32 { data, .. } => Ok(data.clone()),
                other => bail!("literal is not f32: {other:?}"),
            }
        }
    }

    impl LiteralElem for i32 {
        fn read(lit: &Literal) -> Result<Vec<i32>> {
            match lit {
                Literal::I32 { data, .. } => Ok(data.clone()),
                other => bail!("literal is not i32: {other:?}"),
            }
        }
    }

    impl LiteralElem for u32 {
        fn read(lit: &Literal) -> Result<Vec<u32>> {
            match lit {
                Literal::U32 { data, .. } => Ok(data.clone()),
                other => bail!("literal is not u32: {other:?}"),
            }
        }
    }

    impl Literal {
        pub fn element_count(&self) -> usize {
            match self {
                Literal::F32 { data, .. } => data.len(),
                Literal::I32 { data, .. } => data.len(),
                Literal::U32 { data, .. } => data.len(),
            }
        }

        pub fn dims(&self) -> &[usize] {
            match self {
                Literal::F32 { dims, .. }
                | Literal::I32 { dims, .. }
                | Literal::U32 { dims, .. } => dims,
            }
        }

        /// Read the payload back as a typed host vector.
        pub fn to_vec<T: LiteralElem>(&self) -> Result<Vec<T>> {
            T::read(self)
        }
    }

    fn unavailable(what: &str) -> anyhow::Error {
        anyhow!(
            "{what}: PJRT runtime unavailable — this build has the `pjrt` cargo \
             feature disabled (no XLA bindings in this environment). The pure-Rust \
             xnor engine, converter and serving coordinator are unaffected; \
             artifact-driven paths (train, runtime integration tests) skip. \
             See DESIGN.md §PJRT runtime gating."
        )
    }

    /// Stub runtime: construction always fails with a descriptive error.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        /// Always errors in stub builds; enable the `pjrt` feature (and
        /// provide the `xla` bindings) for the real CPU client.
        pub fn cpu() -> Result<Self> {
            Err(unavailable("Runtime::cpu"))
        }

        pub fn platform(&self) -> String {
            "pjrt-disabled".to_string()
        }

        /// Unreachable in stub builds ([`Runtime::cpu`] never succeeds).
        pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
            Err(unavailable(&format!("load_hlo_text {:?}", path.as_ref())))
        }

        /// Unreachable in stub builds ([`Runtime::cpu`] never succeeds).
        pub fn load_cached(&self, path: impl AsRef<Path>) -> Result<std::sync::Arc<Executable>> {
            Err(unavailable(&format!("load_cached {:?}", path.as_ref())))
        }
    }

    /// Stub executable (never constructed; [`Runtime::cpu`] always errors).
    pub struct Executable {
        pub path: PathBuf,
    }

    impl Executable {
        pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
            Err(unavailable(&format!("execute {:?}", self.path)))
        }
    }

    fn check_len(kind: &str, dims: &[usize], len: usize) -> Result<()> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(n == len, "{kind}: {dims:?} needs {n}, got {len}");
        Ok(())
    }

    /// Build an f32 literal with the given dims.
    pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
        check_len("lit_f32", dims, data.len())?;
        Ok(Literal::F32 { data: data.to_vec(), dims: dims.to_vec() })
    }

    /// Build an i32 literal with the given dims.
    pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
        check_len("lit_i32", dims, data.len())?;
        Ok(Literal::I32 { data: data.to_vec(), dims: dims.to_vec() })
    }

    /// Build a u32 literal with the given dims.
    pub fn lit_u32(data: &[u32], dims: &[usize]) -> Result<Literal> {
        check_len("lit_u32", dims, data.len())?;
        Ok(Literal::U32 { data: data.to_vec(), dims: dims.to_vec() })
    }

    /// Scalar f32 literal.
    pub fn lit_scalar_f32(v: f32) -> Literal {
        Literal::F32 { data: vec![v], dims: vec![] }
    }

    /// Read an f32 literal back to a host vector.
    pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>()
    }

    /// Read an i32 literal back to a host vector.
    pub fn to_i32_vec(lit: &Literal) -> Result<Vec<i32>> {
        lit.to_vec::<i32>()
    }

    /// Read a scalar f32 from a literal.
    pub fn scalar_f32(lit: &Literal) -> Result<f32> {
        lit.to_vec::<f32>()?
            .first()
            .copied()
            .ok_or_else(|| anyhow!("empty literal has no scalar"))
    }
}

pub use imp::*;

#[cfg(test)]
mod tests {
    use super::*;

    // Literal-only tests (no PJRT client needed; run in both modes).
    #[test]
    fn lit_f32_roundtrip() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32_vec(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
    }

    #[test]
    fn lit_shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0; 3], &[2, 2]).is_err());
        assert!(lit_i32(&[1; 5], &[2, 2]).is_err());
    }

    #[test]
    fn lit_i32_roundtrip() {
        let l = lit_i32(&[-1, 7], &[2]).unwrap();
        assert_eq!(to_i32_vec(&l).unwrap(), vec![-1, 7]);
    }

    #[test]
    fn lit_u32_roundtrip() {
        let l = lit_u32(&[5, u32::MAX], &[2]).unwrap();
        assert_eq!(l.to_vec::<u32>().unwrap(), vec![5, u32::MAX]);
    }

    #[test]
    fn scalar_roundtrip() {
        let l = lit_scalar_f32(0.125);
        assert_eq!(scalar_f32(&l).unwrap(), 0.125);
    }

    // Full PJRT round-trip is covered by rust/tests/runtime_integration.rs
    // (needs artifacts/ built and the `pjrt` feature).

    #[cfg(not(feature = "pjrt"))]
    mod stub {
        use super::super::*;

        #[test]
        fn runtime_cpu_fails_with_descriptive_error() {
            let err = Runtime::cpu().err().expect("stub Runtime::cpu must error");
            let msg = format!("{err:#}");
            assert!(msg.contains("pjrt"), "unhelpful stub error: {msg}");
        }

        #[test]
        fn typed_reads_reject_wrong_dtype() {
            let l = lit_f32(&[1.0], &[1]).unwrap();
            assert!(to_i32_vec(&l).is_err());
            assert!(l.to_vec::<u32>().is_err());
        }

        #[test]
        fn dims_preserved() {
            let l = lit_u32(&[0; 6], &[2, 3]).unwrap();
            assert_eq!(l.dims(), &[2, 3]);
        }
    }
}
