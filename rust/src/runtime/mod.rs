//! PJRT runtime: load and execute the AOT artifacts from `make artifacts`.
//!
//! The interchange format is **HLO text** (never serialized protos — jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns them).  One [`Runtime`] wraps the PJRT CPU
//! client; [`Executable`]s are compiled once and cached by artifact path.
//!
//! * [`manifest`] — typed view over `artifacts/manifest.json`.
//! * [`client`] — the client/executable wrappers + Literal glue.
//!
//! The XLA bindings are optional: without the `pjrt` cargo feature the
//! [`client`] module compiles as an API-compatible stub whose
//! [`Runtime::cpu`] returns a descriptive error, and every
//! artifact-driven test skips.  See DESIGN.md §PJRT runtime gating.

pub mod client;
pub mod manifest;

pub use client::{Executable, Runtime};
pub use manifest::{InferEntry, Manifest, ModelEntry, TensorSpec};
