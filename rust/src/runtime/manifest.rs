//! Typed view over `artifacts/manifest.json` (emitted by aot.py).
//!
//! The manifest makes the Rust coordinator fully self-describing: flat
//! parameter order, shapes, artifact file names, batch sizes and model
//! hyperparameters all come from here — no hardcoded layouts.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::model::json::{self, Value};

/// Name + shape of one flat tensor (params/state flattening order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One inference artifact (fixed batch size).
#[derive(Debug, Clone)]
pub struct InferEntry {
    pub file: String,
    pub batch: usize,
}

/// One model in the manifest.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub arch: String,
    pub params: Vec<TensorSpec>,
    pub state: Vec<TensorSpec>,
    pub init_ckpt: String,
    pub train_file: String,
    pub train_batch: usize,
    pub infer: Vec<InferEntry>,
    pub infer_pallas: Option<InferEntry>,
    pub input_shape: Vec<usize>,
    pub classes: usize,
    /// Raw metadata for arch-specific keys (width, fp_stages, binary, ...).
    pub raw: Value,
}

impl ModelEntry {
    /// Pick the inference artifact with the given batch size.
    pub fn infer_for_batch(&self, batch: usize) -> Option<&InferEntry> {
        self.infer.iter().find(|e| e.batch == batch)
    }

    /// Smallest compiled batch size >= n (for the dynamic batcher).
    pub fn infer_at_least(&self, n: usize) -> Option<&InferEntry> {
        self.infer
            .iter()
            .filter(|e| e.batch >= n)
            .min_by_key(|e| e.batch)
    }

    /// fp_stages list (resnet18) or empty.
    pub fn fp_stages(&self) -> Vec<usize> {
        self.raw
            .get("fp_stages")
            .and_then(|v| v.as_array())
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default()
    }

    /// act_bit (paper §2.1); 1 when absent.
    pub fn act_bit(&self) -> u32 {
        self.raw
            .get("act_bit")
            .and_then(|v| v.as_usize())
            .unwrap_or(1) as u32
    }

    /// Compact metadata JSON for embedding into a `.bmx` model.
    pub fn bmx_meta(&self) -> String {
        let binary = matches!(self.raw.get("binary"), Some(Value::Bool(true)));
        let fp: Vec<String> = self.fp_stages().iter().map(|s| s.to_string()).collect();
        format!(
            r#"{{"arch": "{}", "binary": {}, "classes": {}, "act_bit": {}, "fp_stages": [{}]}}"#,
            self.arch,
            binary,
            self.classes,
            self.act_bit(),
            fp.join(", ")
        )
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
    /// kernel name -> (file, raw entry)
    pub kernels: BTreeMap<String, (String, Value)>,
}

fn specs(v: &Value, key: &str) -> Result<Vec<TensorSpec>> {
    v.get(key)
        .and_then(|p| p.as_array())
        .ok_or_else(|| anyhow!("manifest model missing {key}"))?
        .iter()
        .map(|pair| {
            let a = pair.as_array().ok_or_else(|| anyhow!("bad {key} entry"))?;
            let name = a[0].as_str().ok_or_else(|| anyhow!("bad name"))?.to_string();
            let shape = a[1]
                .as_array()
                .ok_or_else(|| anyhow!("bad shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSpec { name, shape })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let root = json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        if root.get("version").and_then(|v| v.as_usize()) != Some(1) {
            bail!("unsupported manifest version");
        }
        let mut models = BTreeMap::new();
        for (name, m) in root
            .get("models")
            .and_then(|v| v.as_object())
            .context("manifest missing models")?
        {
            let train = m.get("train").context("model missing train")?;
            let infer = m
                .get("infer")
                .and_then(|v| v.as_array())
                .context("model missing infer")?
                .iter()
                .map(|e| {
                    Ok(InferEntry {
                        file: e
                            .get("file")
                            .and_then(|v| v.as_str())
                            .context("infer missing file")?
                            .to_string(),
                        batch: e
                            .get("batch")
                            .and_then(|v| v.as_usize())
                            .context("infer missing batch")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let infer_pallas = m.get("infer_pallas").map(|e| InferEntry {
                file: e.get("file").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                batch: e.get("batch").and_then(|v| v.as_usize()).unwrap_or(0),
            });
            models.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    arch: m
                        .get("arch")
                        .and_then(|v| v.as_str())
                        .context("model missing arch")?
                        .to_string(),
                    params: specs(m, "params")?,
                    state: specs(m, "state")?,
                    init_ckpt: m
                        .get("init_ckpt")
                        .and_then(|v| v.as_str())
                        .context("model missing init_ckpt")?
                        .to_string(),
                    train_file: train
                        .get("file")
                        .and_then(|v| v.as_str())
                        .context("train missing file")?
                        .to_string(),
                    train_batch: train
                        .get("batch")
                        .and_then(|v| v.as_usize())
                        .context("train missing batch")?,
                    infer,
                    infer_pallas,
                    input_shape: m
                        .get("input_shape")
                        .and_then(|v| v.as_array())
                        .context("model missing input_shape")?
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect(),
                    classes: m.get("classes").and_then(|v| v.as_usize()).unwrap_or(10),
                    raw: m.clone(),
                },
            );
        }
        let mut kernels = BTreeMap::new();
        if let Some(ks) = root.get("kernels").and_then(|v| v.as_object()) {
            for (name, k) in ks {
                let file = k
                    .get("file")
                    .and_then(|v| v.as_str())
                    .context("kernel missing file")?
                    .to_string();
                kernels.insert(name.clone(), (file, k.clone()));
            }
        }
        Ok(Manifest { dir, models, kernels })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()))
    }

    /// Absolute path of an artifact file.
    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {
        "m1": {
          "arch": "lenet", "binary": true, "act_bit": 1, "classes": 10,
          "input": [1, 28, 28], "input_shape": [1, 28, 28],
          "params": [["a.w", [4, 3]], ["b.b", [4]]],
          "state": [["bn.mean", [4]]],
          "init_ckpt": "m1_init.bmxc",
          "train": {"file": "m1_train_b64.hlo.txt", "batch": 64},
          "infer": [{"file": "m1_infer_b1.hlo.txt", "batch": 1},
                    {"file": "m1_infer_b8.hlo.txt", "batch": 8}]
        }
      },
      "kernels": {"k": {"file": "k.hlo.txt", "m": 4}}
    }"#;

    fn sample_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        dir
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::load(sample_dir()).unwrap();
        let e = m.model("m1").unwrap();
        assert_eq!(e.arch, "lenet");
        assert_eq!(e.params.len(), 2);
        assert_eq!(e.params[0].name, "a.w");
        assert_eq!(e.params[0].numel(), 12);
        assert_eq!(e.state[0].shape, vec![4]);
        assert_eq!(e.train_batch, 64);
        assert_eq!(e.infer.len(), 2);
        assert_eq!(m.kernels["k"].0, "k.hlo.txt");
    }

    #[test]
    fn infer_batch_selection() {
        let m = Manifest::load(sample_dir()).unwrap();
        let e = m.model("m1").unwrap();
        assert_eq!(e.infer_for_batch(8).unwrap().file, "m1_infer_b8.hlo.txt");
        assert!(e.infer_for_batch(2).is_none());
        assert_eq!(e.infer_at_least(2).unwrap().batch, 8);
        assert_eq!(e.infer_at_least(1).unwrap().batch, 1);
        assert!(e.infer_at_least(9).is_none());
    }

    #[test]
    fn bmx_meta_roundtrips_through_json() {
        let m = Manifest::load(sample_dir()).unwrap();
        let meta = m.model("m1").unwrap().bmx_meta();
        let v = json::parse(&meta).unwrap();
        assert_eq!(v.get("arch").unwrap().as_str(), Some("lenet"));
        assert_eq!(v.get("binary"), Some(&Value::Bool(true)));
    }

    #[test]
    fn unknown_model_is_error() {
        let m = Manifest::load(sample_dir()).unwrap();
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn missing_file_is_helpful() {
        let err = Manifest::load("/definitely/not/here").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
