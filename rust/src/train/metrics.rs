//! Training metrics log: per-step loss/accuracy/lr/wall-time plus eval
//! points, with CSV export for EXPERIMENTS.md plots.

use anyhow::Result;
use std::io::Write;
use std::path::Path;

/// One optimization step's metrics.
#[derive(Debug, Clone, Copy)]
pub struct StepMetrics {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub lr: f32,
    pub ms: f64,
}

/// Accumulated training log.
#[derive(Debug, Default)]
pub struct MetricsLog {
    pub steps: Vec<StepMetrics>,
    pub evals: Vec<(usize, f64)>,
}

impl MetricsLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, m: StepMetrics) {
        self.steps.push(m);
    }

    pub fn push_eval(&mut self, step: usize, acc: f64) {
        self.evals.push((step, acc));
    }

    /// Mean loss over the first / last `n` steps (loss-decrease checks).
    pub fn mean_loss_head(&self, n: usize) -> f32 {
        if self.steps.is_empty() {
            return 0.0;
        }
        let k = n.min(self.steps.len()).max(1);
        self.steps[..k].iter().map(|m| m.loss).sum::<f32>() / k as f32
    }

    pub fn mean_loss_tail(&self, n: usize) -> f32 {
        if self.steps.is_empty() {
            return 0.0;
        }
        let k = n.min(self.steps.len()).max(1);
        let s = &self.steps[self.steps.len() - k..];
        s.iter().map(|m| m.loss).sum::<f32>() / k as f32
    }

    pub fn mean_step_ms(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.steps.iter().map(|m| m.ms).sum::<f64>() / self.steps.len() as f64
    }

    /// Write `step,loss,acc,lr,ms` rows plus `# eval` comment lines.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,loss,acc,lr,ms")?;
        for m in &self.steps {
            writeln!(f, "{},{},{},{},{:.3}", m.step, m.loss, m.acc, m.lr, m.ms)?;
        }
        for (step, acc) in &self.evals {
            writeln!(f, "# eval,{step},{acc}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log3() -> MetricsLog {
        let mut l = MetricsLog::new();
        for (i, loss) in [2.0f32, 1.0, 0.5].iter().enumerate() {
            l.push(StepMetrics { step: i, loss: *loss, acc: 0.5, lr: 0.1, ms: 10.0 });
        }
        l
    }

    #[test]
    fn head_tail_means() {
        let l = log3();
        assert_eq!(l.mean_loss_head(1), 2.0);
        assert_eq!(l.mean_loss_tail(1), 0.5);
        assert_eq!(l.mean_loss_head(2), 1.5);
        assert!((l.mean_loss_tail(10) - 3.5 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn csv_written() {
        let mut l = log3();
        l.push_eval(3, 0.9);
        let path = std::env::temp_dir().join(format!("metrics_{}.csv", std::process::id()));
        l.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("step,loss,acc,lr,ms"));
        assert!(text.contains("# eval,3,0.9"));
        assert_eq!(text.lines().count(), 5);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn empty_log_safe() {
        let l = MetricsLog::new();
        assert_eq!(l.mean_step_ms(), 0.0);
        assert_eq!(l.mean_loss_head(5), 0.0);
    }
}
