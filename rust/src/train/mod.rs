//! Training orchestrator: drives the AOT `train_step` artifacts through
//! PJRT with host-side parameter state, LR scheduling, metrics and
//! checkpointing.  This is the paper's "train with GPU/BLAS dots, deploy
//! with xnor" pipeline (§2.2.2) with XLA-CPU standing in for CuDNN.

pub mod metrics;

use anyhow::{ensure, Context, Result};
use std::path::PathBuf;
use std::time::Instant;

use crate::data::{Dataset, Kind};
use crate::model::ckpt::Checkpoint;
use crate::runtime::client::{
    lit_f32, lit_i32, lit_scalar_f32, scalar_f32, to_f32_vec,
};
use crate::runtime::{Manifest, ModelEntry, Runtime};
pub use metrics::{MetricsLog, StepMetrics};

/// Training configuration (CLI-facing).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Manifest model name (e.g. "lenet_bin").
    pub model: String,
    pub dataset: Kind,
    pub steps: usize,
    pub lr: f32,
    /// Multiply lr by `lr_decay` every `lr_decay_steps` (0 = constant).
    pub lr_decay_steps: usize,
    pub lr_decay: f32,
    pub train_examples: usize,
    pub test_examples: usize,
    pub seed: u64,
    pub log_every: usize,
    /// Evaluate on the test split every N steps (0 = only at the end).
    pub eval_every: usize,
    pub out_ckpt: Option<PathBuf>,
    pub metrics_csv: Option<PathBuf>,
}

impl TrainConfig {
    pub fn quick(model: &str, dataset: Kind, steps: usize) -> Self {
        Self {
            model: model.to_string(),
            dataset,
            steps,
            lr: 0.05,
            lr_decay_steps: 0,
            lr_decay: 0.5,
            train_examples: 2048,
            test_examples: 512,
            seed: 42,
            log_every: 10,
            eval_every: 0,
            out_ckpt: None,
            metrics_csv: None,
        }
    }
}

/// Result of a training run.
#[derive(Debug)]
pub struct TrainReport {
    pub metrics: MetricsLog,
    pub final_train_loss: f32,
    pub final_eval_acc: f64,
    pub steps_per_sec: f64,
    /// Final flat params/state (manifest order) for conversion/eval.
    pub params: Vec<Vec<f32>>,
    pub state: Vec<Vec<f32>>,
}

/// Host-side mirror of the flat training state.
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    manifest: &'rt Manifest,
    pub entry: ModelEntry,
    pub params: Vec<Vec<f32>>,
    pub state: Vec<Vec<f32>>,
    pub momentum: Vec<Vec<f32>>,
}

impl<'rt> Trainer<'rt> {
    /// Load the init checkpoint + train executable for a manifest model.
    pub fn new(rt: &'rt Runtime, manifest: &'rt Manifest, model: &str) -> Result<Self> {
        let entry = manifest.model(model)?.clone();
        let ck = Checkpoint::load(manifest.path(&entry.init_ckpt))?;
        let mut params = Vec::with_capacity(entry.params.len());
        for spec in &entry.params {
            let (shape, data) = ck
                .get_f32(&format!("params.{}", spec.name))
                .with_context(|| format!("init ckpt missing params.{}", spec.name))?;
            ensure!(shape == spec.shape.as_slice(), "shape mismatch for {}", spec.name);
            params.push(data.to_vec());
        }
        let mut state = Vec::with_capacity(entry.state.len());
        for spec in &entry.state {
            let (_, data) = ck
                .get_f32(&format!("state.{}", spec.name))
                .with_context(|| format!("init ckpt missing state.{}", spec.name))?;
            state.push(data.to_vec());
        }
        let momentum = params.iter().map(|p| vec![0.0f32; p.len()]).collect();
        Ok(Self { rt, manifest, entry, params, state, momentum })
    }

    /// Restore params/state from a trained checkpoint (momentum reset).
    pub fn load_checkpoint(&mut self, ck: &Checkpoint) -> Result<()> {
        for (spec, slot) in self.entry.params.iter().zip(self.params.iter_mut()) {
            let (_, data) = ck
                .get_f32(&format!("params.{}", spec.name))
                .with_context(|| format!("ckpt missing params.{}", spec.name))?;
            *slot = data.to_vec();
        }
        for (spec, slot) in self.entry.state.iter().zip(self.state.iter_mut()) {
            let (_, data) = ck
                .get_f32(&format!("state.{}", spec.name))
                .with_context(|| format!("ckpt missing state.{}", spec.name))?;
            *slot = data.to_vec();
        }
        for m in &mut self.momentum {
            m.iter_mut().for_each(|v| *v = 0.0);
        }
        Ok(())
    }

    /// Flat params+state as a BMXC checkpoint.
    pub fn to_checkpoint(&self) -> Checkpoint {
        let mut ck = Checkpoint::new();
        for (spec, data) in self.entry.params.iter().zip(&self.params) {
            ck.push_f32(&format!("params.{}", spec.name), spec.shape.clone(), data.clone());
        }
        for (spec, data) in self.entry.state.iter().zip(&self.state) {
            ck.push_f32(&format!("state.{}", spec.name), spec.shape.clone(), data.clone());
        }
        ck
    }

    /// Run one train step; returns (loss, accuracy).
    pub fn step(
        &mut self,
        exe: &crate::runtime::Executable,
        images: &[f32],
        labels: &[i32],
        lr: f32,
    ) -> Result<(f32, f32)> {
        let b = self.entry.train_batch;
        let mut dims = vec![b];
        dims.extend(&self.entry.input_shape);
        let mut inputs = Vec::with_capacity(
            self.params.len() + self.state.len() + self.momentum.len() + 3,
        );
        for (spec, data) in self.entry.params.iter().zip(&self.params) {
            inputs.push(lit_f32(data, &spec.shape)?);
        }
        for (spec, data) in self.entry.state.iter().zip(&self.state) {
            inputs.push(lit_f32(data, &spec.shape)?);
        }
        for (spec, data) in self.entry.params.iter().zip(&self.momentum) {
            inputs.push(lit_f32(data, &spec.shape)?);
        }
        inputs.push(lit_f32(images, &dims)?);
        inputs.push(lit_i32(labels, &[b])?);
        inputs.push(lit_scalar_f32(lr));

        let out = exe.run(&inputs)?;
        let n_p = self.params.len();
        let n_s = self.state.len();
        ensure!(out.len() == 2 * n_p + n_s + 2, "train_step output arity {}", out.len());
        for (slot, lit) in self.params.iter_mut().zip(&out[..n_p]) {
            *slot = to_f32_vec(lit)?;
        }
        for (slot, lit) in self.state.iter_mut().zip(&out[n_p..n_p + n_s]) {
            *slot = to_f32_vec(lit)?;
        }
        for (slot, lit) in self.momentum.iter_mut().zip(&out[n_p + n_s..2 * n_p + n_s]) {
            *slot = to_f32_vec(lit)?;
        }
        let loss = scalar_f32(&out[2 * n_p + n_s])?;
        let acc = scalar_f32(&out[2 * n_p + n_s + 1])?;
        Ok((loss, acc))
    }

    /// Evaluate top-1 accuracy with a PJRT inference artifact.
    pub fn evaluate(&self, dataset: &Dataset) -> Result<f64> {
        let entry = self
            .entry
            .infer
            .iter()
            .max_by_key(|e| e.batch)
            .context("model has no inference artifacts")?;
        let exe = self.rt.load_cached(self.manifest.path(&entry.file))?;
        let b = entry.batch;
        let per: usize = self.entry.input_shape.iter().product();
        let mut correct = 0usize;
        let mut total = 0usize;
        let n_batches = dataset.len().div_ceil(b);
        for bi in 0..n_batches {
            let idx: Vec<usize> = (bi * b..(bi + 1) * b).collect();
            let batch = dataset.gather(&idx);
            let mut inputs = Vec::new();
            for (spec, data) in self.entry.params.iter().zip(&self.params) {
                inputs.push(lit_f32(data, &spec.shape)?);
            }
            for (spec, data) in self.entry.state.iter().zip(&self.state) {
                inputs.push(lit_f32(data, &spec.shape)?);
            }
            let mut dims = vec![b];
            dims.extend(&self.entry.input_shape);
            inputs.push(lit_f32(&batch.images, &dims)?);
            let out = exe.run(&inputs)?;
            let logits = to_f32_vec(&out[0])?;
            let classes = logits.len() / b;
            // only the first `valid` rows are real examples (rest wrapped)
            let valid = (dataset.len() - bi * b).min(b);
            for r in 0..valid {
                let row = &logits[r * classes..(r + 1) * classes];
                // first occurrence wins on ties (matches jnp.argmax)
                let mut pred = 0usize;
                for (i, &v) in row.iter().enumerate().skip(1) {
                    if v > row[pred] {
                        pred = i;
                    }
                }
                if pred == batch.labels[r] as usize {
                    correct += 1;
                }
            }
            total += valid;
            let _ = per;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }
}

/// Full training run per the config; the end-to-end driver calls this.
pub fn train(rt: &Runtime, manifest: &Manifest, cfg: &TrainConfig) -> Result<TrainReport> {
    let mut trainer = Trainer::new(rt, manifest, &cfg.model)?;
    let exe = rt.load_cached(manifest.path(&trainer.entry.train_file))?;
    let all = cfg.dataset.generate(cfg.train_examples + cfg.test_examples, cfg.seed);
    let frac = cfg.test_examples as f32 / (cfg.train_examples + cfg.test_examples) as f32;
    let (train_set, test_set) = all.split(frac);
    let b = trainer.entry.train_batch;

    let mut metrics = MetricsLog::new();
    let mut last_loss = f32::NAN;
    let start = Instant::now();
    let mut step_idx = 0usize;
    'outer: for epoch in 0.. {
        for batch in train_set.epoch(b, cfg.seed.wrapping_add(epoch)) {
            if step_idx >= cfg.steps {
                break 'outer;
            }
            let lr = if cfg.lr_decay_steps > 0 {
                cfg.lr * cfg.lr_decay.powi((step_idx / cfg.lr_decay_steps) as i32)
            } else {
                cfg.lr
            };
            let t0 = Instant::now();
            let (loss, acc) = trainer.step(&exe, &batch.images, &batch.labels, lr)?;
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            last_loss = loss;
            metrics.push(StepMetrics { step: step_idx, loss, acc, lr, ms });
            if cfg.log_every > 0 && step_idx % cfg.log_every == 0 {
                println!(
                    "step {step_idx:>5}  loss {loss:.4}  batch-acc {acc:.3}  lr {lr:.4}  {ms:.0}ms"
                );
            }
            if cfg.eval_every > 0 && step_idx > 0 && step_idx % cfg.eval_every == 0 {
                let acc = trainer.evaluate(&test_set)?;
                println!("step {step_idx:>5}  EVAL acc {acc:.4}");
                metrics.push_eval(step_idx, acc);
            }
            step_idx += 1;
        }
    }
    let wall = start.elapsed().as_secs_f64();
    let final_eval_acc = trainer.evaluate(&test_set)?;
    metrics.push_eval(step_idx, final_eval_acc);

    if let Some(path) = &cfg.out_ckpt {
        trainer.to_checkpoint().save(path)?;
        println!("checkpoint -> {path:?}");
    }
    if let Some(path) = &cfg.metrics_csv {
        metrics.write_csv(path)?;
    }
    Ok(TrainReport {
        final_train_loss: last_loss,
        final_eval_acc,
        steps_per_sec: step_idx as f64 / wall.max(1e-9),
        params: trainer.params.clone(),
        state: trainer.state.clone(),
        metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_config_sane() {
        let cfg = TrainConfig::quick("lenet_bin", Kind::Digits, 100);
        assert_eq!(cfg.steps, 100);
        assert!(cfg.lr > 0.0);
        assert!(cfg.train_examples > cfg.test_examples);
    }

    // PJRT-backed trainer tests live in rust/tests/runtime_integration.rs.
}
