//! bmxnet — the L3 coordinator CLI.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//!   info                          manifest + platform summary
//!   train    --model M [...]      drive the AOT train_step via PJRT
//!   convert  --model M --ckpt F   f32 checkpoint -> packed .bmx (§2.2.3)
//!   predict  --bmx F [...]        run the Rust xnor engine on synth data
//!   profile  --bmx F | --model M  per-layer wall time / bytes / dispatch
//!   serve    --models-dir D [...] multi-model HTTP gateway (sharded pools)
//!   synth-models --out D          write synthetic .bmx models (smoke/demo)
//!   bench-gemm --figure 1|2|3     reproduce the paper's GEMM figures
//!   bench-suite --json DIR        run every bench family -> perf records
//!   bench-compare BASE NEW        noise-aware perf-record diff (CI gate)
//!
//! Run `bmxnet <cmd> --help` for per-command flags.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use repro::bench::harness::fmt_ms;
use repro::bench::{
    compare, fig1_workloads, fig2_workloads, fig3_workloads, run_gemm_figure_methods, run_suite,
    write_gemm_json, CompareOpts, GemmFigureRecord, GemmWorkload, PerfRecord, Provenance,
    SuiteOpts,
};
use repro::gemm::{simd, Method};
use repro::coordinator::BatchPolicy;
use repro::data::Kind;
use repro::model::bmx::{convert, BmxModel};
use repro::model::ckpt::Checkpoint;
use repro::nn::Engine;
use repro::runtime::{Manifest, Runtime};
use repro::serve::{
    binary_names_for, Gateway, GatewayConfig, ModelRegistry, PoolConfig, RegistryConfig,
};
use repro::train::{train, TrainConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    // bench-compare takes positional BASE NEW paths; everything else is
    // pure --flag commands.
    if cmd == "bench-compare" {
        return cmd_bench_compare(&args[1..]);
    }
    let flags = Flags::parse(&args[1.min(args.len())..])?;
    match cmd {
        "info" => cmd_info(&flags),
        "train" => cmd_train(&flags),
        "convert" => cmd_convert(&flags),
        "predict" => cmd_predict(&flags),
        "profile" => cmd_profile(&flags),
        "serve" => cmd_serve(&flags),
        "synth-models" => cmd_synth_models(&flags),
        "bench-gemm" => cmd_bench_gemm(&flags),
        "bench-suite" => cmd_bench_suite(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `bmxnet help`)"),
    }
}

fn print_help() {
    println!(
        "bmxnet — BMXNet reproduction (rust coordinator + JAX/Pallas AOT)\n\n\
         commands:\n\
         \x20 info                                   manifest + platform summary\n\
         \x20 train   --model M [--steps N] [--lr X] [--dataset D]\n\
         \x20         [--train-examples N] [--test-examples N] [--eval-every N]\n\
         \x20         [--out-ckpt F] [--metrics-csv F] [--seed S]\n\
         \x20 convert --model M --ckpt F --out F.bmx  pack Q-weights to 1 bit\n\
         \x20         [--fold-thresholds]             fold BN+sign into integer\n\
         \x20                                         popcount thresholds (.bmx v2)\n\
         \x20 predict --bmx F [--n N] [--batch B]     xnor engine accuracy+speed\n\
         \x20 profile --bmx F | --model M [--models-dir D] [--batch B] [--reps R]\n\
         \x20         [--json [F.json]]               per-layer time/bytes/dispatch\n\
         \x20 serve   [--models-dir D] [--workers N] [--port P] [--host H]\n\
         \x20         [--max-batch B] [--window-us U] [--queue-cap Q]\n\
         \x20         [--mem-budget-mb M] [--io-workers N] [--max-conns C]\n\
         \x20         [--idle-timeout-ms T] [--request-timeout-ms T]\n\
         \x20                                         multi-model HTTP gateway\n\
         \x20 synth-models --out D [--seed S]         synthetic lenet_bin/_q4 .bmx\n\
         \x20 bench-gemm [--figure 1|2|3] [--full] [--reps N]\n\
         \x20         [--json F.json]                 record rows to BENCH_gemm.json\n\
         \x20         [--method LABEL]                time one method (see labels below)\n\
         \x20 bench-suite [--json DIR] [--quick] [--full] [--reps N]\n\
         \x20         [--requests N] [--filter FAM]   run every bench family; one\n\
         \x20                                         BENCH_<family>.json per family\n\
         \x20 bench-compare BASE NEW [--fail-on PCT] [--min-effect MADX] [--json]\n\
         \x20         files or dirs of perf records;  exits non-zero on regression\n\n\
         common: --artifacts DIR (default ./artifacts)\n\
         env:    BMXNET_FORCE_SCALAR=1 pins the scalar popcount kernel\n\
         \x20       BMXNET_NO_FOLD=1 keeps the float BN+sign epilogue (no\n\
         \x20       integer threshold folding at engine load)\n\
         gemm methods on this machine: {}",
        Method::available()
            .iter()
            .map(|m| m.label())
            .collect::<Vec<_>>()
            .join(" ")
    );
}

/// Tiny --key value / --flag parser.
struct Flags {
    map: HashMap<String, String>,
}

impl Flags {
    fn parse(args: &[String]) -> Result<Self> {
        let mut map = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {a:?}"))?;
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                map.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                map.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(Self { map })
    }

    fn str(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(String::as_str)
    }

    fn req(&self, key: &str) -> Result<&str> {
        self.str(key).ok_or_else(|| anyhow!("missing required --{key}"))
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.str(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
        }
    }

    fn f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.str(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
        }
    }

    fn bool(&self, key: &str) -> bool {
        matches!(self.str(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error on flags this command does not read — otherwise a typo (or a
    /// flag from an older CLI, e.g. the pre-gateway `serve --bmx`) would
    /// be silently ignored.
    fn reject_unknown(&self, allowed: &[&str]) -> Result<()> {
        for key in self.map.keys() {
            if !allowed.contains(&key.as_str()) {
                bail!(
                    "unknown flag --{key} for this command (allowed: {})",
                    allowed.iter().map(|a| format!("--{a}")).collect::<Vec<_>>().join(" ")
                );
            }
        }
        Ok(())
    }

    fn artifacts(&self) -> PathBuf {
        PathBuf::from(self.str("artifacts").unwrap_or(repro::ARTIFACTS_DIR))
    }

    fn dataset(&self, default: Kind) -> Result<Kind> {
        match self.str("dataset") {
            None => Ok(default),
            Some(v) => Kind::from_name(v).ok_or_else(|| anyhow!("unknown dataset {v:?}")),
        }
    }
}

fn cmd_info(flags: &Flags) -> Result<()> {
    let manifest = Manifest::load(flags.artifacts())?;
    let rt = Runtime::cpu()?;
    println!("platform: {}", rt.platform());
    println!("artifacts: {:?}", manifest.dir);
    println!("models:");
    for (name, m) in &manifest.models {
        println!(
            "  {name:<24} arch={:<9} params={:<3} train_b={:<3} infer_b={:?}",
            m.arch,
            m.params.len(),
            m.train_batch,
            m.infer.iter().map(|e| e.batch).collect::<Vec<_>>(),
        );
    }
    println!("kernels: {:?}", manifest.kernels.keys().collect::<Vec<_>>());
    Ok(())
}

fn cmd_train(flags: &Flags) -> Result<()> {
    let model = flags.req("model")?.to_string();
    let default_ds = if model.starts_with("lenet") {
        Kind::Digits
    } else if model.contains("img") {
        Kind::Imagenet
    } else {
        Kind::Cifar
    };
    let cfg = TrainConfig {
        model: model.clone(),
        dataset: flags.dataset(default_ds)?,
        steps: flags.usize("steps", 200)?,
        lr: flags.f32("lr", 0.05)?,
        lr_decay_steps: flags.usize("lr-decay-steps", 0)?,
        lr_decay: flags.f32("lr-decay", 0.5)?,
        train_examples: flags.usize("train-examples", 2048)?,
        test_examples: flags.usize("test-examples", 512)?,
        seed: flags.usize("seed", 42)? as u64,
        log_every: flags.usize("log-every", 10)?,
        eval_every: flags.usize("eval-every", 0)?,
        out_ckpt: flags.str("out-ckpt").map(PathBuf::from),
        metrics_csv: flags.str("metrics-csv").map(PathBuf::from),
    };
    let manifest = Manifest::load(flags.artifacts())?;
    let rt = Runtime::cpu()?;
    let report = train(&rt, &manifest, &cfg)?;
    println!(
        "done: {} steps, final loss {:.4}, eval acc {:.4}, {:.2} steps/s",
        cfg.steps, report.final_train_loss, report.final_eval_acc, report.steps_per_sec
    );
    Ok(())
}

fn cmd_convert(flags: &Flags) -> Result<()> {
    let model = flags.req("model")?;
    let ckpt_path = flags.req("ckpt")?;
    let out = flags
        .str("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(format!("{model}.bmx")));
    let manifest = Manifest::load(flags.artifacts())?;
    let (names, meta) = binary_names_for(&manifest, model)?;
    let ck = Checkpoint::load(ckpt_path)?;
    let fp_bytes: usize = ck
        .tensors
        .iter()
        .map(|(_, s, _)| 4 * s.iter().product::<usize>())
        .sum();
    let act_bit = manifest.model(model)?.act_bit();
    let mut bmx = if act_bit > 1 {
        // paper §2.1: k-bit weights are quantized but stored as f32
        repro::model::bmx::convert_kbit(&ck, &names, act_bit, &meta)?
    } else {
        convert(&ck, &names, &meta)?
    };
    if flags.bool("fold-thresholds") {
        let folded = repro::model::bmx::fold_thresholds(&mut bmx)?;
        println!("folded {folded} BN+sign triple(s) into integer popcount thresholds");
    }
    bmx.save(&out)?;
    let packed_bytes = bmx.payload_bytes();
    println!(
        "{model}: {} packed tensors | f32 {:.2} MB -> .bmx {:.2} MB ({:.1}x)",
        names.len(),
        fp_bytes as f64 / 1e6,
        packed_bytes as f64 / 1e6,
        fp_bytes as f64 / packed_bytes as f64,
    );
    println!("wrote {out:?}");
    Ok(())
}

fn cmd_predict(flags: &Flags) -> Result<()> {
    let bmx = BmxModel::load(flags.req("bmx")?)?;
    let engine = Engine::from_bmx(&bmx)?;
    let n = flags.usize("n", 512)?;
    let batch = flags.usize("batch", 32)?;
    let kind = match engine.input_shape() {
        [1, 28, 28] => Kind::Digits,
        _ if engine.classes() == 100 => Kind::Imagenet,
        _ => Kind::Cifar,
    };
    let kind = flags.dataset(kind)?;
    let ds = kind.generate(n, flags.usize("seed", 7)? as u64);
    println!("dispatch: {}", engine.dispatch_summary());
    let t0 = Instant::now();
    let acc = engine.accuracy(&ds.images, &ds.labels, batch)?;
    let wall = t0.elapsed();
    println!(
        "{n} images  batch {batch}  acc {acc:.4}  {:.1} img/s  ({} total)",
        n as f64 / wall.as_secs_f64(),
        fmt_ms(wall)
    );
    Ok(())
}

/// Per-layer profile of one engine: `--bmx F` loads a packed file
/// directly; `--model M` resolves `<models-dir>/M.bmx` (models-dir
/// defaults to the artifacts dir, matching `serve`).  `--json` prints the
/// machine-readable report to stdout; `--json F.json` writes it to a file
/// (same schema-tagged shape as `bench/record.rs` outputs).
fn cmd_profile(flags: &Flags) -> Result<()> {
    flags.reject_unknown(&["bmx", "model", "models-dir", "batch", "reps", "json", "artifacts"])?;
    let path = match (flags.str("bmx"), flags.str("model")) {
        (Some(p), _) => PathBuf::from(p),
        (None, Some(name)) => {
            let dir = flags
                .str("models-dir")
                .map(PathBuf::from)
                .unwrap_or_else(|| flags.artifacts());
            dir.join(format!("{name}.bmx"))
        }
        (None, None) => bail!("profile needs --bmx F or --model M"),
    };
    let engine = Engine::load(&path).with_context(|| format!("load {path:?}"))?;
    let batch = flags.usize("batch", 8)?;
    let reps = flags.usize("reps", 5)?;
    let mut report = engine.profile(batch, reps)?;
    report.model = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| report.arch.clone());
    match flags.str("json") {
        None => print!("{}", report.render_table()),
        Some("true") => println!("{}", report.render_json()),
        Some(out) => {
            std::fs::write(out, report.render_json()).with_context(|| format!("write {out:?}"))?;
            print!("{}", report.render_table());
            println!("recorded profile to {out}");
        }
    }
    Ok(())
}

/// The multi-model HTTP serving gateway (DESIGN.md §Serving architecture).
///
/// Serves every model resolvable from `--models-dir` (packed `<name>.bmx`
/// files and/or artifact-manifest entries), each sharded over `--workers`
/// batcher threads, until the process is killed.
fn cmd_serve(flags: &Flags) -> Result<()> {
    flags.reject_unknown(&[
        "models-dir",
        "workers",
        "port",
        "host",
        "max-batch",
        "window-us",
        "queue-cap",
        "mem-budget-mb",
        "max-conns",
        "idle-timeout-ms",
        "request-timeout-ms",
        "io-workers",
        "artifacts",
    ])?;
    let models_dir = flags
        .str("models-dir")
        .map(PathBuf::from)
        .unwrap_or_else(|| flags.artifacts());
    let cfg = RegistryConfig {
        pool: PoolConfig {
            workers: flags.usize("workers", 2)?,
            policy: BatchPolicy {
                max_batch: flags.usize("max-batch", 32)?,
                window: Duration::from_micros(flags.usize("window-us", 2000)? as u64),
            },
            queue_cap: flags.usize("queue-cap", 256)?,
            ..Default::default()
        },
        max_resident_bytes: flags.usize("mem-budget-mb", 0)? * (1 << 20),
        ..RegistryConfig::new(models_dir)
    };
    let host = flags.str("host").unwrap_or("127.0.0.1").to_string();
    let port = flags.usize("port", 8080)?;
    let gw_cfg = GatewayConfig {
        io_workers: flags.usize("io-workers", 0)?,
        max_conns: flags.usize("max-conns", GatewayConfig::default().max_conns)?,
        idle_timeout: Duration::from_millis(flags.usize(
            "idle-timeout-ms",
            GatewayConfig::default().idle_timeout.as_millis() as usize,
        )? as u64),
        request_timeout: Duration::from_millis(flags.usize(
            "request-timeout-ms",
            GatewayConfig::default().request_timeout.as_millis() as usize,
        )? as u64),
    };
    let registry = Arc::new(ModelRegistry::new(cfg.clone()));
    let available = registry.list();
    let gateway = Gateway::start_with(registry, &format!("{host}:{port}"), gw_cfg.clone())?;
    println!("listening on http://{}", gateway.addr());
    println!(
        "reactor: {} io workers, max {} conns, idle timeout {:?}, request timeout {:?}",
        gateway.stats().workers(),
        gw_cfg.max_conns,
        gw_cfg.idle_timeout,
        gw_cfg.request_timeout,
    );
    println!(
        "models dir {:?}: {} available ({} workers/model, max_batch {}, window {:?})",
        cfg.models_dir,
        available.len(),
        cfg.pool.workers.max(1),
        cfg.pool.policy.max_batch,
        cfg.pool.policy.window,
    );
    for m in &available {
        println!("  {:<24} [{}]", m.name, m.source);
    }
    println!(
        "gemm dispatch: method {} · kernel {} (force_scalar={})",
        Method::auto().label(),
        simd::best_kernel().label(),
        simd::force_scalar(),
    );
    match std::env::var(repro::obs::SLOW_REQ_ENV) {
        Ok(v) => println!("slow-request log: threshold {v} us ({})", repro::obs::SLOW_REQ_ENV),
        Err(_) => println!("slow-request log: off (set {} to enable)", repro::obs::SLOW_REQ_ENV),
    }
    println!("try: curl http://{}/v1/models", gateway.addr());
    println!("     curl http://{}/v1/debug/trace?n=8", gateway.addr());
    // Models load lazily on first request; serve until the process dies.
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

/// Write synthetic-weight `.bmx` models (a packed 1-bit LeNet and a 4-bit
/// quantized one) so the serving gateway can be smoke-tested on checkouts
/// without trained artifacts — `artifacts/` is gitignored, but
/// `scripts/serve_smoke.sh` must run anywhere, CI included.
fn cmd_synth_models(flags: &Flags) -> Result<()> {
    flags.reject_unknown(&["out", "seed"])?;
    let out = PathBuf::from(flags.req("out")?);
    std::fs::create_dir_all(&out).with_context(|| format!("create {out:?}"))?;
    let seed = flags.usize("seed", 1)? as u64;
    let bin = repro::model::bmx::synth_lenet(seed, 1)?;
    bin.save(out.join("lenet_bin.bmx"))?;
    let q4 = repro::model::bmx::synth_lenet(seed + 1, 4)?;
    q4.save(out.join("lenet_q4.bmx"))?;
    println!(
        "wrote {:?} ({} B) and {:?} ({} B)",
        out.join("lenet_bin.bmx"),
        bin.payload_bytes(),
        out.join("lenet_q4.bmx"),
        q4.payload_bytes(),
    );
    Ok(())
}

fn cmd_bench_gemm(flags: &Flags) -> Result<()> {
    flags.reject_unknown(&["figure", "full", "reps", "json", "method", "artifacts"])?;
    let reduced = !flags.bool("full");
    let reps = flags.usize("reps", 2)?;
    // --method LABEL times a single variant (speedup columns would divide
    // by themselves, so single-method runs always print absolute ms).
    let methods: Vec<Method> = match flags.str("method") {
        None => Method::available(),
        Some(label) => {
            let m = Method::from_label(label).ok_or_else(|| {
                anyhow!(
                    "unknown method {label:?} (known: {})",
                    Method::all().iter().map(|m| m.label()).collect::<Vec<_>>().join(" ")
                )
            })?;
            if !m.is_available() {
                bail!(
                    "method {label:?} cannot run on this machine (available: {})",
                    Method::available()
                        .iter()
                        .map(|m| m.label())
                        .collect::<Vec<_>>()
                        .join(" ")
                );
            }
            vec![m]
        }
    };
    let single = methods.len() == 1;
    let figures: Vec<usize> = match flags.str("figure") {
        None => vec![1, 2, 3],
        Some(f) => vec![f.parse().context("--figure")?],
    };
    let mut records = Vec::new();
    for fig in &figures {
        let (title, xlabel, workloads): (&str, &str, Vec<GemmWorkload>) = match fig {
            1 => ("Figure 1: GEMM time vs input channels", "C", fig1_workloads(reduced)),
            2 => ("Figure 2: speedup vs filter number", "filters", fig2_workloads(reduced)),
            3 => ("Figure 3: speedup vs kernel size", "kernel", fig3_workloads(reduced)),
            other => bail!("unknown figure {other}"),
        };
        let absolute = *fig == 1 || single;
        let rows = run_gemm_figure_methods(title, xlabel, &workloads, reps, absolute, &methods);
        records.push(GemmFigureRecord {
            figure: format!("fig{fig}"),
            xlabel: xlabel.to_string(),
            absolute_times: absolute,
            rows,
        });
    }
    if let Some(path) = flags.str("json") {
        let mut provenance = Provenance::capture("bmxnet bench-gemm");
        provenance.reps = reps;
        provenance.note = format!(
            "{}{}",
            if reduced { "reduced shapes (batch 20)" } else { "paper-exact shapes (batch 200)" },
            if single {
                format!(" · single method {}", methods[0].label())
            } else {
                String::new()
            },
        );
        write_gemm_json(path, provenance, &records)
            .with_context(|| format!("write {path:?}"))?;
        println!("recorded {} figure(s) to {path}", records.len());
    }
    if reduced {
        println!("(reduced shapes: batch 20; pass --full for paper-exact batch 200)");
    }
    Ok(())
}

/// Run every bench family through the shared harness, one perf record
/// per family (`BENCH_<family>.json` under `--json DIR`).  CLI flags
/// override the `BENCH_*` env knobs the `cargo bench` targets read.
fn cmd_bench_suite(flags: &Flags) -> Result<()> {
    flags.reject_unknown(&["json", "quick", "full", "reps", "requests", "filter", "artifacts"])?;
    let mut opts = SuiteOpts::from_env();
    opts.quick = opts.quick || flags.bool("quick");
    opts.full = opts.full || flags.bool("full");
    if let Some(r) = flags.str("reps") {
        opts.reps = r.parse().with_context(|| format!("--reps {r:?}"))?;
    }
    if let Some(r) = flags.str("requests") {
        opts.requests = r.parse().with_context(|| format!("--requests {r:?}"))?;
    }
    opts.filter = flags.str("filter").map(str::to_string);
    let out = match flags.str("json") {
        None => None,
        Some("true") => bail!("--json needs a directory (e.g. --json out/)"),
        Some(dir) => Some(PathBuf::from(dir)),
    };
    let recs = run_suite(&opts, out.as_deref())?;
    println!(
        "bench-suite: {} family record(s){}",
        recs.len(),
        match &out {
            Some(d) => format!(" in {}", d.display()),
            None => " (pass --json DIR to save records)".to_string(),
        }
    );
    Ok(())
}

/// `bmxnet bench-compare BASE NEW` — BASE/NEW are either two record
/// files or two directories of `BENCH_*.json` records.  Exits non-zero
/// when any cell regresses past the noise floor and `--fail-on`.
fn cmd_bench_compare(args: &[String]) -> Result<()> {
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut opts = CompareOpts::default();
    let mut json = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--fail-on" | "--min-effect" => {
                let key = args[i].clone();
                let v = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("{key} needs a value"))?
                    .parse::<f64>()
                    .with_context(|| format!("{key} {:?}", args[i + 1]))?;
                if key == "--fail-on" {
                    opts.fail_on_pct = v;
                } else {
                    opts.min_effect_mad = v;
                }
                i += 2;
            }
            "--json" => {
                json = true;
                i += 1;
            }
            "--help" | "-h" => {
                println!(
                    "usage: bmxnet bench-compare BASE NEW [--fail-on PCT] \
                     [--min-effect MADX] [--json]"
                );
                return Ok(());
            }
            flag if flag.starts_with("--") => bail!(
                "unknown flag {flag} (allowed: --fail-on --min-effect --json)"
            ),
            _ => {
                paths.push(PathBuf::from(&args[i]));
                i += 1;
            }
        }
    }
    let [base, new] = paths.as_slice() else {
        bail!("bench-compare needs exactly two paths (got {})", paths.len());
    };
    let pairs = collect_record_pairs(base, new)?;
    let mut failures = 0usize;
    for (base_rec, new_rec) in &pairs {
        let report = compare(base_rec, new_rec, opts)?;
        if json {
            print!("{}", report.render_json());
        } else {
            print!("{}", report.render_table());
        }
        if report.failed() {
            failures += 1;
        }
    }
    if failures > 0 {
        bail!("bench-compare: {failures} famil{} with regressions at/above {:.1}%",
            if failures == 1 { "y" } else { "ies" },
            opts.fail_on_pct);
    }
    println!("bench-compare: OK ({} famil{})", pairs.len(),
        if pairs.len() == 1 { "y" } else { "ies" });
    Ok(())
}

/// Resolve BASE/NEW into aligned record pairs: two files load directly;
/// two directories match on their `BENCH_*.json` file names (families
/// present on one side only are reported, not failed — kernel sets and
/// bench coverage legitimately differ across machines and commits).
fn collect_record_pairs(base: &Path, new: &Path) -> Result<Vec<(PerfRecord, PerfRecord)>> {
    if base.is_dir() != new.is_dir() {
        bail!("cannot compare a directory with a file: {base:?} vs {new:?}");
    }
    if !base.is_dir() {
        return Ok(vec![(PerfRecord::load(base)?, PerfRecord::load(new)?)]);
    }
    let names = |dir: &Path| -> Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(dir).with_context(|| format!("read {dir:?}"))? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            if name.starts_with("BENCH_") && name.ends_with(".json") {
                out.push(name);
            }
        }
        out.sort();
        Ok(out)
    };
    let base_names = names(base)?;
    let new_names = names(new)?;
    let mut pairs = Vec::new();
    for name in &base_names {
        if new_names.contains(name) {
            pairs.push((PerfRecord::load(base.join(name))?, PerfRecord::load(new.join(name))?));
        } else {
            println!("bench-compare: {name} only in base {base:?} (skipped)");
        }
    }
    for name in &new_names {
        if !base_names.contains(name) {
            println!("bench-compare: {name} only in new {new:?} (skipped)");
        }
    }
    if pairs.is_empty() {
        bail!("no common BENCH_*.json records between {base:?} and {new:?}");
    }
    Ok(pairs)
}
