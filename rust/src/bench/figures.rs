//! Shared driver for the Figure 1–3 GEMM benchmarks (used by the
//! `bmxnet bench-gemm` / `bench-suite` CLI and the `cargo bench` targets).
//!
//! Measurement protocol (matches the paper's):
//! * float methods time the full GEMM on float operands;
//! * `xnor_*` columns time the GEMM on **pre-packed** operands (weights are
//!   packed offline; activations are assumed packed by the previous layer);
//! * `xnor_fused` times the fused binarize→pack→GEMM on float activations
//!   against pre-packed weights — its packing cost is inherent to the
//!   variant, so it is timed (that is the column's whole point);
//! * the final `bin+xnor_omp` column adds activation binarization+packing
//!   to the threaded kernel (Fig 1's "binarize input and xnor_64_omp" bar).
//!
//! Columns cover [`Method::available`] — what the running CPU can
//! execute — so a recorded figure from an AVX2 box and one from a NEON box
//! carry different (correctly labelled) column sets.
//!
//! Every timing is a [`Stats`] (median/min/MAD over reps, via
//! [`time_stats`]); tables print the median, records keep the full stats.

use super::harness::{fmt_ms_val, time_stats, BenchTable, Stats};
use super::workloads::GemmWorkload;
use crate::gemm::{
    binary_gemm_f32, gemm_fused, xnor_gemm_prepacked, Method, PackedMatrix, Side,
};

/// One measured row: noise-aware time stats per method at a given x.
#[derive(Debug, Clone)]
pub struct FigureRow {
    pub x: usize,
    /// (method label, ms stats) in catalog order + "bin+xnor_omp".
    pub timings: Vec<(&'static str, Stats)>,
}

impl FigureRow {
    pub fn naive(&self) -> Stats {
        self.timings[0].1
    }

    /// Median-over-median speedup of column `idx` vs the first column.
    pub fn speedup(&self, idx: usize) -> f64 {
        self.naive().median / self.timings[idx].1.median.max(1e-12)
    }
}

/// Measure every available method over one workload.
pub fn measure_workload(w: &GemmWorkload, reps: usize) -> FigureRow {
    measure_workload_methods(w, reps, &Method::available())
}

/// Measure an explicit method list over one workload (the `--method`
/// CLI path and the availability-filtered default share this body).
pub fn measure_workload_methods(
    w: &GemmWorkload,
    reps: usize,
    methods: &[Method],
) -> FigureRow {
    let (a, b) = w.operands(42);
    let pa = PackedMatrix::pack_rows(&a, w.m, w.k, Side::A);
    let pb = PackedMatrix::pack_cols(&b, w.k, w.n);
    let mut timings = Vec::new();
    for method in methods {
        let s = if *method == Method::XnorFused {
            time_stats(reps, || gemm_fused(&a, w.m, w.k, &pb))
        } else if method.is_binary() {
            time_stats(reps, || xnor_gemm_prepacked(*method, &pa, &pb))
        } else {
            time_stats(reps, || binary_gemm_f32(*method, &a, &b, w.m, w.n, w.k))
        };
        timings.push((method.label(), s));
    }
    // activation packing (the conv input side) + threaded kernel
    let s = time_stats(reps, || {
        let pa2 = PackedMatrix::pack_rows(&a, w.m, w.k, Side::A);
        xnor_gemm_prepacked(Method::Xnor64Mt, &pa2, &pb)
    });
    timings.push(("bin+xnor_omp", s));
    FigureRow { x: w.x, timings }
}

/// Run a full figure over every available method and print a paper-style
/// table.  `absolute_times` prints ms (Fig 1); otherwise speedup vs the
/// first column (Figs 2–3).
pub fn run_gemm_figure(
    title: &str,
    xlabel: &str,
    workloads: &[GemmWorkload],
    reps: usize,
    absolute_times: bool,
) -> Vec<FigureRow> {
    run_gemm_figure_methods(title, xlabel, workloads, reps, absolute_times, &Method::available())
}

/// [`run_gemm_figure`] with an explicit method list.
pub fn run_gemm_figure_methods(
    title: &str,
    xlabel: &str,
    workloads: &[GemmWorkload],
    reps: usize,
    absolute_times: bool,
    methods: &[Method],
) -> Vec<FigureRow> {
    let mut headers: Vec<&str> = vec![xlabel];
    let mut rows = Vec::new();
    let mut table: Option<BenchTable> = None;
    for w in workloads {
        let row = measure_workload_methods(w, reps, methods);
        if table.is_none() {
            headers.extend(row.timings.iter().map(|(l, _)| *l));
            table = Some(BenchTable::new(title, &headers));
        }
        let mut cells = vec![row.x.to_string()];
        for (i, (_, s)) in row.timings.iter().enumerate() {
            cells.push(if absolute_times || i == 0 {
                format!("{}ms", fmt_ms_val(s.median))
            } else {
                format!("{:.1}x", row.speedup(i))
            });
        }
        table.as_mut().unwrap().row(cells);
        rows.push(row);
    }
    if let Some(t) = table {
        t.print();
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads::fig1_workloads;

    #[test]
    fn measure_tiny_workload() {
        let w = GemmWorkload { x: 8, m: 4, n: 32, k: 64 };
        let row = measure_workload(&w, 2);
        // every available method + the bin+xnor column
        assert_eq!(row.timings.len(), Method::available().len() + 1);
        assert!(row.timings.iter().all(|(_, s)| s.median > 0.0 && s.reps == 2));
        assert!(row.timings.iter().all(|(_, s)| s.min <= s.median));
        assert!(row.speedup(0) == 1.0);
    }

    #[test]
    fn fused_column_present_and_labelled() {
        let w = GemmWorkload { x: 8, m: 4, n: 32, k: 100 };
        let row = measure_workload(&w, 1);
        assert!(row.timings.iter().any(|(l, _)| *l == "xnor_fused"));
        assert_eq!(row.timings.last().unwrap().0, "bin+xnor_omp");
    }

    #[test]
    fn explicit_method_list_is_respected() {
        let w = GemmWorkload { x: 8, m: 2, n: 16, k: 64 };
        let row = measure_workload_methods(&w, 1, &[Method::Xnor64, Method::XnorFused]);
        let labels: Vec<&str> = row.timings.iter().map(|(l, _)| *l).collect();
        assert_eq!(labels, ["xnor_64", "xnor_fused", "bin+xnor_omp"]);
    }

    #[test]
    fn figure_rows_match_workloads() {
        let mut ws = fig1_workloads(true);
        ws.truncate(1);
        // shrink for test speed
        ws[0].n = 64;
        let rows = run_gemm_figure("t", "C", &ws, 1, true);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].x, 64);
    }
}
