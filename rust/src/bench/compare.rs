//! Noise-aware comparison of two [`PerfRecord`]s — the `bmxnet
//! bench-compare` CI gate.
//!
//! Records are aligned cell-by-cell on the exact cell id.  A delta only
//! counts when it clears the **noise floor** `min_effect_mad ×
//! max(base.mad, new.mad)` (MAD is the per-cell dispersion over reps the
//! suite recorded); within the floor the cell is [`Verdict::WithinNoise`]
//! regardless of the percentage.  Above the floor, the cell's unit
//! decides direction (`ms`/`bytes` lower-is-better, `req_s` higher), and
//! the gate fails — exit non-zero — when any regression reaches
//! `fail_on_pct`.
//!
//! Cells present on one side only are reported ([`Verdict::MissingBase`]
//! / [`Verdict::MissingNew`]) but never fail the gate: bench families
//! legitimately grow and shrink cells as hardware kernel sets differ.
//! Comparing records of *different families* or a cell whose unit changed
//! is an error — that is a schema mismatch, not a perf delta.

use anyhow::{bail, Result};

use super::record::{Cell, PerfRecord, Unit};

/// Gate thresholds (`--fail-on`, `--min-effect`).
#[derive(Debug, Clone, Copy)]
pub struct CompareOpts {
    /// Fail when a regression's |delta| reaches this percentage.
    pub fail_on_pct: f64,
    /// Noise floor multiplier: deltas within `min_effect_mad × max(MADs)`
    /// are suppressed.
    pub min_effect_mad: f64,
}

impl Default for CompareOpts {
    fn default() -> Self {
        CompareOpts { fail_on_pct: 10.0, min_effect_mad: 3.0 }
    }
}

/// What the gate concluded about one cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Got worse by more than the noise floor.
    Regressed,
    /// Got better by more than the noise floor.
    Improved,
    /// Delta within the noise floor (or both medians zero).
    WithinNoise,
    /// Cell only in the new record.
    MissingBase,
    /// Cell only in the base record.
    MissingNew,
}

impl Verdict {
    pub fn label(self) -> &'static str {
        match self {
            Verdict::Regressed => "REGRESSED",
            Verdict::Improved => "improved",
            Verdict::WithinNoise => "~noise",
            Verdict::MissingBase => "new cell",
            Verdict::MissingNew => "removed",
        }
    }
}

/// One aligned cell with its delta and verdict.
#[derive(Debug, Clone)]
pub struct CellDelta {
    pub id: String,
    pub unit: Unit,
    /// Base / new medians (0.0 on the missing side).
    pub base: f64,
    pub new: f64,
    /// Signed percentage, positive = regression in the unit's direction.
    /// 0.0 for missing cells.
    pub pct: f64,
    /// The noise floor this delta was tested against (ms/bytes/req_s).
    pub floor: f64,
    pub verdict: Verdict,
}

/// The full comparison of one record pair.
#[derive(Debug, Clone)]
pub struct CompareReport {
    pub bench: String,
    pub opts: CompareOpts,
    /// Every aligned cell, base-record order first, then new-only cells.
    pub deltas: Vec<CellDelta>,
}

/// Compare two records of the same family.
pub fn compare(base: &PerfRecord, new: &PerfRecord, opts: CompareOpts) -> Result<CompareReport> {
    if base.bench != new.bench {
        bail!(
            "cannot compare different bench families: base is {:?}, new is {:?}",
            base.bench,
            new.bench
        );
    }
    let mut deltas = Vec::new();
    for b in &base.cells {
        match new.cell(&b.id) {
            None => deltas.push(missing(b, Verdict::MissingNew)),
            Some(n) => deltas.push(align(b, n, opts)?),
        }
    }
    for n in &new.cells {
        if base.cell(&n.id).is_none() {
            deltas.push(missing(n, Verdict::MissingBase));
        }
    }
    Ok(CompareReport { bench: base.bench.clone(), opts, deltas })
}

fn missing(c: &Cell, verdict: Verdict) -> CellDelta {
    let (base, new) = match verdict {
        Verdict::MissingNew => (c.stats.median, 0.0),
        _ => (0.0, c.stats.median),
    };
    CellDelta { id: c.id.clone(), unit: c.unit, base, new, pct: 0.0, floor: 0.0, verdict }
}

fn align(b: &Cell, n: &Cell, opts: CompareOpts) -> Result<CellDelta> {
    if b.unit != n.unit {
        bail!(
            "cell {:?} changed unit between records: {} vs {}",
            b.id,
            b.unit.label(),
            n.unit.label()
        );
    }
    let (base, new) = (b.stats.median, n.stats.median);
    let floor = opts.min_effect_mad * b.stats.mad.max(n.stats.mad);
    // Signed so that positive = worse: for lower-is-better units an
    // increase regresses; for req/s a decrease does.
    let raw = new - base;
    let worse = if b.unit.lower_is_better() { raw } else { -raw };
    let pct = if base.abs() > 0.0 { 100.0 * worse / base.abs() } else { 0.0 };
    let verdict = if raw.abs() <= floor || base == new {
        Verdict::WithinNoise
    } else if worse > 0.0 {
        Verdict::Regressed
    } else {
        Verdict::Improved
    };
    Ok(CellDelta { id: b.id.clone(), unit: b.unit, base, new, pct, floor, verdict })
}

impl CompareReport {
    /// True when any regression reaches the failure threshold — the
    /// non-zero-exit condition.
    pub fn failed(&self) -> bool {
        self.deltas
            .iter()
            .any(|d| d.verdict == Verdict::Regressed && d.pct >= self.opts.fail_on_pct)
    }

    fn counts(&self) -> (usize, usize, usize, usize) {
        let (mut reg, mut imp, mut noise, mut miss) = (0, 0, 0, 0);
        for d in &self.deltas {
            match d.verdict {
                Verdict::Regressed => reg += 1,
                Verdict::Improved => imp += 1,
                Verdict::WithinNoise => noise += 1,
                _ => miss += 1,
            }
        }
        (reg, imp, noise, miss)
    }

    /// Human table: cells that cleared the noise floor plus missing
    /// cells, with a one-line summary of what was suppressed.
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        let (reg, imp, noise, miss) = self.counts();
        out.push_str(&format!(
            "bench-compare [{}]: {} cells — {} regressed, {} improved, {} within noise, {} missing\n",
            self.bench,
            self.deltas.len(),
            reg,
            imp,
            noise,
            miss
        ));
        out.push_str(&format!(
            "(noise floor {:.1}×MAD, fail threshold {:.1}%)\n",
            self.opts.min_effect_mad, self.opts.fail_on_pct
        ));
        let shown: Vec<&CellDelta> =
            self.deltas.iter().filter(|d| d.verdict != Verdict::WithinNoise).collect();
        if shown.is_empty() {
            out.push_str("all deltas within the noise floor\n");
            return out;
        }
        let wid = shown.iter().map(|d| d.id.len()).max().unwrap_or(4).max(4);
        out.push_str(&format!(
            "{:wid$}  {:>12}  {:>12}  {:>8}  {}\n",
            "cell", "base", "new", "delta", "verdict"
        ));
        for d in shown {
            let delta = match d.verdict {
                Verdict::MissingBase | Verdict::MissingNew => "-".to_string(),
                _ => format!("{:+.1}%", if d.unit.lower_is_better() { d.pct } else { -d.pct }),
            };
            out.push_str(&format!(
                "{:wid$}  {:>12}  {:>12}  {:>8}  {}{}\n",
                d.id,
                fmt_val(d.base, d.unit),
                fmt_val(d.new, d.unit),
                delta,
                d.verdict.label(),
                if d.verdict == Verdict::Regressed && d.pct >= self.opts.fail_on_pct {
                    "  << FAIL"
                } else {
                    ""
                },
            ));
        }
        out
    }

    /// Machine verdict for CI logs.
    pub fn render_json(&self) -> String {
        let (reg, imp, noise, miss) = self.counts();
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": {},\n", super::record::json_str(&self.bench)));
        out.push_str(&format!("  \"failed\": {},\n", self.failed()));
        out.push_str(&format!("  \"fail_on_pct\": {:.3},\n", self.opts.fail_on_pct));
        out.push_str(&format!("  \"min_effect_mad\": {:.3},\n", self.opts.min_effect_mad));
        out.push_str(&format!(
            "  \"counts\": {{\"regressed\": {reg}, \"improved\": {imp}, \"within_noise\": {noise}, \"missing\": {miss}}},\n"
        ));
        out.push_str("  \"cells\": [\n");
        for (i, d) in self.deltas.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"id\": {}, \"unit\": {}, \"base\": {:.6}, \"new\": {:.6}, \"pct_worse\": {:.3}, \"floor\": {:.6}, \"verdict\": {}}}{}\n",
                super::record::json_str(&d.id),
                super::record::json_str(d.unit.label()),
                d.base,
                d.new,
                d.pct,
                d.floor,
                super::record::json_str(d.verdict.label()),
                if i + 1 < self.deltas.len() { "," } else { "" },
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }
}

fn fmt_val(v: f64, unit: Unit) -> String {
    match unit {
        Unit::Ms => format!("{v:.3}ms"),
        Unit::Bytes => format!("{v:.0}B"),
        Unit::ReqPerSec => format!("{v:.0}req/s"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::harness::Stats;
    use crate::bench::record::Provenance;

    fn rec(bench: &str, cells: &[(&str, Unit, f64, f64)]) -> PerfRecord {
        let mut r = PerfRecord::new(bench, Provenance::capture("test"));
        for &(id, unit, median, mad) in cells {
            r.push(id, unit, Stats { median, min: median, mad, reps: 3 });
        }
        r
    }

    #[test]
    fn self_compare_is_all_within_noise_and_passes() {
        let r = rec("gemm", &[("a", Unit::Ms, 5.0, 0.2), ("b", Unit::ReqPerSec, 100.0, 2.0)]);
        let rep = compare(&r, &r, CompareOpts::default()).unwrap();
        assert!(!rep.failed());
        assert!(rep.deltas.iter().all(|d| d.verdict == Verdict::WithinNoise));
        assert!(rep.render_table().contains("all deltas within the noise floor"));
    }

    #[test]
    fn regression_above_floor_and_threshold_fails() {
        let base = rec("gemm", &[("a", Unit::Ms, 10.0, 0.1)]);
        let new = rec("gemm", &[("a", Unit::Ms, 15.0, 0.1)]);
        let rep = compare(&base, &new, CompareOpts::default()).unwrap();
        assert_eq!(rep.deltas[0].verdict, Verdict::Regressed);
        assert!((rep.deltas[0].pct - 50.0).abs() < 1e-9);
        assert!(rep.failed());
        assert!(rep.render_table().contains("<< FAIL"));
        assert!(rep.render_json().contains("\"failed\": true"));
    }

    #[test]
    fn improvement_never_fails() {
        let base = rec("gemm", &[("a", Unit::Ms, 10.0, 0.1)]);
        let new = rec("gemm", &[("a", Unit::Ms, 5.0, 0.1)]);
        let rep = compare(&base, &new, CompareOpts::default()).unwrap();
        assert_eq!(rep.deltas[0].verdict, Verdict::Improved);
        assert!(rep.deltas[0].pct < 0.0);
        assert!(!rep.failed());
    }

    #[test]
    fn req_s_direction_is_inverted() {
        // throughput DROP is the regression
        let base = rec("serve", &[("w=1/req_s", Unit::ReqPerSec, 100.0, 0.5)]);
        let new = rec("serve", &[("w=1/req_s", Unit::ReqPerSec, 60.0, 0.5)]);
        let rep = compare(&base, &new, CompareOpts::default()).unwrap();
        assert_eq!(rep.deltas[0].verdict, Verdict::Regressed);
        assert!((rep.deltas[0].pct - 40.0).abs() < 1e-9);
        assert!(rep.failed());
        // throughput GAIN improves
        let up = rec("serve", &[("w=1/req_s", Unit::ReqPerSec, 160.0, 0.5)]);
        let rep = compare(&base, &up, CompareOpts::default()).unwrap();
        assert_eq!(rep.deltas[0].verdict, Verdict::Improved);
    }

    #[test]
    fn noise_floor_suppresses_large_percentage_on_noisy_cell() {
        // +40% but MAD is huge: within 3×MAD floor -> suppressed
        let base = rec("gemm", &[("a", Unit::Ms, 1.0, 0.2)]);
        let new = rec("gemm", &[("a", Unit::Ms, 1.4, 0.2)]);
        let rep = compare(&base, &new, CompareOpts::default()).unwrap();
        assert_eq!(rep.deltas[0].verdict, Verdict::WithinNoise);
        assert!(!rep.failed());
        // same delta with a tight MAD -> regression
        let base = rec("gemm", &[("a", Unit::Ms, 1.0, 0.01)]);
        let new = rec("gemm", &[("a", Unit::Ms, 1.4, 0.01)]);
        let rep = compare(&base, &new, CompareOpts::default()).unwrap();
        assert_eq!(rep.deltas[0].verdict, Verdict::Regressed);
        assert!(rep.failed());
    }

    #[test]
    fn threshold_edge_is_inclusive() {
        let base = rec("gemm", &[("a", Unit::Ms, 10.0, 0.0)]);
        let new = rec("gemm", &[("a", Unit::Ms, 11.0, 0.0)]);
        // exactly 10% with fail_on 10% -> fails
        let rep = compare(&base, &new, CompareOpts { fail_on_pct: 10.0, min_effect_mad: 3.0 })
            .unwrap();
        assert!(rep.failed());
        // raise the threshold past it -> regressed but gate passes
        let rep = compare(&base, &new, CompareOpts { fail_on_pct: 10.1, min_effect_mad: 3.0 })
            .unwrap();
        assert_eq!(rep.deltas[0].verdict, Verdict::Regressed);
        assert!(!rep.failed());
    }

    #[test]
    fn missing_cells_reported_but_never_fail() {
        let base = rec("gemm", &[("a", Unit::Ms, 1.0, 0.0), ("gone", Unit::Ms, 2.0, 0.0)]);
        let new = rec("gemm", &[("a", Unit::Ms, 1.0, 0.0), ("added", Unit::Ms, 3.0, 0.0)]);
        let rep = compare(&base, &new, CompareOpts::default()).unwrap();
        assert!(!rep.failed());
        let gone = rep.deltas.iter().find(|d| d.id == "gone").unwrap();
        assert_eq!(gone.verdict, Verdict::MissingNew);
        let added = rep.deltas.iter().find(|d| d.id == "added").unwrap();
        assert_eq!(added.verdict, Verdict::MissingBase);
        let table = rep.render_table();
        assert!(table.contains("removed") && table.contains("new cell"));
    }

    #[test]
    fn family_and_unit_mismatch_error() {
        let a = rec("gemm", &[("a", Unit::Ms, 1.0, 0.0)]);
        let b = rec("serve", &[("a", Unit::Ms, 1.0, 0.0)]);
        assert!(compare(&a, &b, CompareOpts::default())
            .unwrap_err()
            .to_string()
            .contains("different bench families"));
        let c = rec("gemm", &[("a", Unit::Bytes, 1.0, 0.0)]);
        assert!(compare(&a, &c, CompareOpts::default())
            .unwrap_err()
            .to_string()
            .contains("changed unit"));
    }

    #[test]
    fn zero_base_median_does_not_divide_by_zero() {
        let base = rec("tables", &[("a", Unit::Bytes, 0.0, 0.0)]);
        let new = rec("tables", &[("a", Unit::Bytes, 5.0, 0.0)]);
        let rep = compare(&base, &new, CompareOpts::default()).unwrap();
        // above floor so flagged, but pct stays finite (0 by convention)
        assert_eq!(rep.deltas[0].verdict, Verdict::Regressed);
        assert_eq!(rep.deltas[0].pct, 0.0);
        assert!(!rep.failed(), "0% never reaches the threshold");
    }
}
