//! Serve-scaling workload: offered load (closed-loop producer count)
//! swept against pool worker count — the scaling question the gateway
//! exists to answer (EXPERIMENTS.md §Serve scaling).
//!
//! Shared by `cargo bench --bench serve_scaling` and tests, in the same
//! pattern as [`super::figures`] for the GEMM figures: the workload grid
//! and measurement live in the library, the bench target is a thin driver.

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::harness::BenchTable;
use crate::coordinator::{Backend, BatchPolicy, MetricsSnapshot};
use crate::serve::{ModelPool, PoolConfig};

/// One measurement point of the sweep.
#[derive(Debug, Clone, Copy)]
pub struct ServeWorkload {
    /// Pool shards (batcher threads).
    pub workers: usize,
    /// Closed-loop producers (each waits for its reply before re-sending).
    pub producers: usize,
    /// Total requests across all producers.
    pub requests: usize,
}

/// The default grid: workers × offered load.
pub fn serve_scaling_workloads(requests: usize) -> Vec<ServeWorkload> {
    let mut ws = Vec::new();
    for &workers in &[1usize, 2, 4] {
        for &producers in &[1usize, 4, 16] {
            ws.push(ServeWorkload { workers, producers, requests });
        }
    }
    ws
}

/// The CI-sized grid for `bench-suite --quick`: the scaling question's
/// endpoints (1 vs 2 workers, idle vs contended offered load).
pub fn quick_serve_workloads(requests: usize) -> Vec<ServeWorkload> {
    let mut ws = Vec::new();
    for &workers in &[1usize, 2] {
        for &producers in &[1usize, 4] {
            ws.push(ServeWorkload { workers, producers, requests });
        }
    }
    ws
}

/// One point of the dynamic-batcher policy sweep (the `serve_policy`
/// family / `cargo bench --bench serving_throughput`).
#[derive(Debug, Clone, Copy)]
pub struct PolicyPoint {
    pub max_batch: usize,
    pub window_ms: u64,
}

impl PolicyPoint {
    pub fn policy(&self) -> BatchPolicy {
        BatchPolicy {
            max_batch: self.max_batch,
            window: Duration::from_millis(self.window_ms),
        }
    }

    /// Cell-id fragment, e.g. `b=32,w=4ms`.
    pub fn label(&self) -> String {
        format!("b={},w={}ms", self.max_batch, self.window_ms)
    }
}

/// The (max_batch, window) knob sweep; the b=1/w=0 point is the
/// no-batching baseline.
pub fn policy_points(quick: bool) -> Vec<PolicyPoint> {
    let pairs: &[(usize, u64)] = if quick {
        &[(1, 0), (32, 4)]
    } else {
        &[(1, 0), (8, 1), (8, 4), (32, 1), (32, 4), (32, 16)]
    };
    pairs.iter().map(|&(max_batch, window_ms)| PolicyPoint { max_batch, window_ms }).collect()
}

/// One measured row of the sweep.
#[derive(Debug, Clone)]
pub struct ServeScalingRow {
    pub workload: ServeWorkload,
    pub wall: Duration,
    /// Requests answered (closed-loop: equals sent minus rejections).
    pub served: usize,
    /// Requests refused at submit (all shard queues full).
    pub rejected: usize,
    /// Merged pool metrics at shutdown.
    pub snapshot: MetricsSnapshot,
}

impl ServeScalingRow {
    pub fn req_per_sec(&self) -> f64 {
        self.served as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Deterministic stand-in engine for artifact-free runs: LeNet input
/// geometry, `cost_per_image` of busy-spin compute per image (spinning,
/// not sleeping, so worker scaling contends for CPU like a real engine).
pub struct SyntheticBackend {
    pub cost_per_image: Duration,
}

impl Backend for SyntheticBackend {
    fn input_shape(&self) -> [usize; 3] {
        [1, 28, 28]
    }

    fn classify_batch(&self, images: &[f32], batch: usize) -> anyhow::Result<Vec<(usize, f32)>> {
        let budget = self.cost_per_image * batch as u32;
        let t0 = Instant::now();
        while t0.elapsed() < budget {
            std::hint::spin_loop();
        }
        Ok(images
            .chunks(images.len() / batch.max(1))
            .take(batch)
            .map(|img| {
                let mut best = 0usize;
                for (i, &v) in img.iter().enumerate().skip(1) {
                    if v > img[best] {
                        best = i;
                    }
                }
                (best % 10, img[best])
            })
            .collect())
    }
}

/// Closed-loop drive of one workload over a fresh pool.
pub fn measure_serve_workload(
    backend: Arc<dyn Backend>,
    w: &ServeWorkload,
    policy: BatchPolicy,
    queue_cap: usize,
) -> ServeScalingRow {
    let pool = ModelPool::start(
        backend,
        &PoolConfig { workers: w.workers, policy, queue_cap, ..Default::default() },
    );
    let image_len = pool.image_len();
    let t0 = Instant::now();
    let (served, rejected) = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for p in 0..w.producers {
            let pool = &pool;
            handles.push(s.spawn(move || {
                let mut img = vec![0.0f32; image_len];
                let mut ok = 0usize;
                let mut rej = 0usize;
                for i in (p..w.requests).step_by(w.producers.max(1)) {
                    // vary the hot pixel so argmax answers differ
                    img[(i * 37) % image_len] = 1.0;
                    match pool.classify(img.clone()) {
                        Ok(_) => ok += 1,
                        Err(_) => rej += 1,
                    }
                    img[(i * 37) % image_len] = 0.0;
                }
                (ok, rej)
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .fold((0, 0), |a, b| (a.0 + b.0, a.1 + b.1))
    });
    let wall = t0.elapsed();
    let snapshot = pool.shutdown();
    ServeScalingRow { workload: *w, wall, served, rejected, snapshot }
}

/// Run the grid and print a paper-style table; returns the raw rows.
pub fn run_serve_scaling(
    backend: Arc<dyn Backend>,
    workloads: &[ServeWorkload],
    policy: BatchPolicy,
    queue_cap: usize,
) -> Vec<ServeScalingRow> {
    let mut table = BenchTable::new(
        "Serve scaling: offered load vs worker count",
        &["workers", "producers", "req/s", "mean_batch", "p50", "p95", "rejected"],
    );
    let mut rows = Vec::new();
    for w in workloads {
        let row = measure_serve_workload(backend.clone(), w, policy, queue_cap);
        table.row(vec![
            row.workload.workers.to_string(),
            row.workload.producers.to_string(),
            format!("{:.0}", row.req_per_sec()),
            format!("{:.1}", row.snapshot.mean_batch),
            format!("{:.1}ms", row.snapshot.p50.as_secs_f64() * 1e3),
            format!("{:.1}ms", row.snapshot.p95.as_secs_f64() * 1e3),
            row.rejected.to_string(),
        ]);
        rows.push(row);
    }
    table.print();
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_workers_and_producers() {
        let ws = serve_scaling_workloads(64);
        assert_eq!(ws.len(), 9);
        assert!(ws.iter().any(|w| w.workers == 4 && w.producers == 16));
        assert!(ws.iter().all(|w| w.requests == 64));
    }

    #[test]
    fn closed_loop_accounts_for_every_request() {
        let backend = Arc::new(SyntheticBackend { cost_per_image: Duration::from_micros(20) });
        let w = ServeWorkload { workers: 2, producers: 4, requests: 24 };
        let row = measure_serve_workload(
            backend,
            &w,
            BatchPolicy { max_batch: 8, window: Duration::from_millis(1) },
            1024,
        );
        assert_eq!(row.served + row.rejected, 24);
        assert_eq!(row.rejected, 0, "closed loop under queue_cap must not reject");
        assert_eq!(row.snapshot.requests, row.served as u64);
        let hist: u64 = row.snapshot.batch_hist.iter().map(|&(s, c)| s as u64 * c).sum();
        assert_eq!(hist, row.snapshot.requests);
        assert!(row.req_per_sec() > 0.0);
    }

    #[test]
    fn quick_grid_and_policy_points_cover_endpoints() {
        let q = quick_serve_workloads(32);
        assert_eq!(q.len(), 4);
        assert!(q.iter().all(|w| w.requests == 32));
        let pts = policy_points(false);
        assert_eq!(pts.len(), 6);
        assert_eq!(pts[0].label(), "b=1,w=0ms");
        let quick = policy_points(true);
        assert_eq!(quick.len(), 2);
        assert_eq!(quick[1].policy().max_batch, 32);
    }

    #[test]
    fn synthetic_backend_is_deterministic_argmax() {
        let b = SyntheticBackend { cost_per_image: Duration::ZERO };
        let mut imgs = vec![0.0f32; 2 * 784];
        imgs[5] = 1.0; // image 0 -> class 5
        imgs[784 + 13] = 1.0; // image 1 -> class 3 (13 % 10)
        let preds = b.classify_batch(&imgs, 2).unwrap();
        assert_eq!(preds[0].0, 5);
        assert_eq!(preds[1].0, 3);
    }
}
