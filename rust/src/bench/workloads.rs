//! The GEMM workloads of Figures 1–3 (paper §3.1).
//!
//! All three figures measure GEMM inside a convolution layer with
//! M = filters, N = batch × out_h × out_w, K = k_w × k_h × channels.
//! The paper fixes out spatial size via its input so that batch 200 gives
//! N = 12800 (i.e. 8×8 outputs per image).
//!
//! * Fig 1: filters 64, kernel 5×5, batch 200, channels ∈ {64..512} —
//!   absolute times per method.
//! * Fig 2: channels 256, kernel 5×5, batch 200, filters ∈ {16..512} —
//!   speedup over naive.
//! * Fig 3: channels 256, filters 64, batch 200, kernel ∈ {1..8} —
//!   speedup over naive.
//!
//! `reduced = true` (default everywhere) scales batch 200 → 20 so the
//! naive baseline stays in seconds on a single core; speedup *ratios* are
//! unaffected (verified by comparing a reduced vs full spot-check in
//! EXPERIMENTS.md).

use crate::data::Rng;

/// One GEMM measurement point.
#[derive(Debug, Clone)]
pub struct GemmWorkload {
    /// x-axis label (channel count, filter count, or kernel size).
    pub x: usize,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmWorkload {
    fn conv(filters: usize, channels: usize, kernel: usize, batch: usize) -> Self {
        GemmWorkload {
            x: 0,
            m: filters,
            n: batch * 64, // 8x8 outputs per image, as in the paper
            k: kernel * kernel * channels,
        }
    }

    /// Deterministic operand data for this shape.
    pub fn operands(&self, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let a: Vec<f32> = (0..self.m * self.k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..self.k * self.n).map(|_| rng.normal()).collect();
        (a, b)
    }

    /// Multiply-accumulate count (for GFLOP/s style reporting).
    pub fn macs(&self) -> usize {
        self.m * self.n * self.k
    }
}

fn batch(reduced: bool) -> usize {
    if reduced {
        20
    } else {
        200
    }
}

/// Figure 1: vary input channels; filters 64, kernel 5×5.
pub fn fig1_workloads(reduced: bool) -> Vec<GemmWorkload> {
    [64, 128, 256, 512]
        .iter()
        .map(|&c| {
            let mut w = GemmWorkload::conv(64, c, 5, batch(reduced));
            w.x = c;
            w
        })
        .collect()
}

/// Figure 2: vary filter count; channels 256, kernel 5×5.
pub fn fig2_workloads(reduced: bool) -> Vec<GemmWorkload> {
    [16, 32, 64, 128, 256, 512]
        .iter()
        .map(|&f| {
            let mut w = GemmWorkload::conv(f, 256, 5, batch(reduced));
            w.x = f;
            w
        })
        .collect()
}

/// Shrink a figure's workload grid for `bench-suite --quick` (the CI
/// perf-smoke size): keep only the first and last x points (the sweep's
/// endpoints still exercise the small- and large-K regimes) and cut the
/// batch-driven N dimension 4× more.  Quick numbers are only compared
/// against other quick numbers — `bench-compare` refuses records of
/// different families, and the provenance block says `quick: true`.
pub fn quick_gemm(mut ws: Vec<GemmWorkload>) -> Vec<GemmWorkload> {
    if ws.len() > 2 {
        let last = ws.pop().unwrap();
        ws.truncate(1);
        ws.push(last);
    }
    for w in &mut ws {
        w.n = (w.n / 4).max(64);
    }
    ws
}

/// Figure 3: vary kernel size; channels 256, filters 64.
pub fn fig3_workloads(reduced: bool) -> Vec<GemmWorkload> {
    (1..=8)
        .map(|ks| {
            let mut w = GemmWorkload::conv(64, 256, ks, batch(reduced));
            w.x = ks;
            w
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_matches_paper_parameters() {
        let ws = fig1_workloads(false);
        assert_eq!(ws.len(), 4);
        // paper: M=64, N=12800, K=5*5*C
        for w in &ws {
            assert_eq!(w.m, 64);
            assert_eq!(w.n, 12800);
            assert_eq!(w.k, 25 * w.x);
        }
        assert_eq!(ws[2].k, 6400); // C=256
    }

    #[test]
    fn fig2_sweeps_filters() {
        let ws = fig2_workloads(true);
        assert_eq!(ws[0].m, 16);
        assert_eq!(ws.last().unwrap().m, 512);
        assert!(ws.iter().all(|w| w.k == 6400));
    }

    #[test]
    fn fig3_sweeps_kernel() {
        let ws = fig3_workloads(true);
        assert_eq!(ws.len(), 8);
        assert_eq!(ws[0].k, 256);
        assert_eq!(ws[7].k, 64 * 256);
    }

    #[test]
    fn reduced_scales_n_only() {
        let full = fig1_workloads(false);
        let red = fig1_workloads(true);
        for (f, r) in full.iter().zip(&red) {
            assert_eq!(f.m, r.m);
            assert_eq!(f.k, r.k);
            assert_eq!(f.n, 10 * r.n);
        }
    }

    #[test]
    fn quick_keeps_endpoints_and_shrinks_n() {
        let full = fig2_workloads(true);
        let q = quick_gemm(full.clone());
        assert_eq!(q.len(), 2);
        assert_eq!(q[0].x, full[0].x);
        assert_eq!(q[1].x, full.last().unwrap().x);
        assert_eq!(q[0].n, (full[0].n / 4).max(64));
        assert_eq!(q[0].k, full[0].k, "quick must not change K (the kernel regime)");
    }

    #[test]
    fn operands_deterministic_and_sized() {
        let w = &fig1_workloads(true)[0];
        let (a1, b1) = w.operands(5);
        let (a2, _) = w.operands(5);
        assert_eq!(a1, a2);
        assert_eq!(a1.len(), w.m * w.k);
        assert_eq!(b1.len(), w.k * w.n);
    }
}
