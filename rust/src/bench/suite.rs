//! The unified bench suite behind `bmxnet bench-suite`: every benchmark
//! family measured through [`super::harness`], emitted as one
//! [`PerfRecord`] per family (`BENCH_<family>.json`), comparable across
//! commits with `bmxnet bench-compare`.
//!
//! Families ([`FAMILIES`]):
//! * `gemm` — the Figure 1–3 method sweep (absolute ms per cell);
//! * `tables` — Table 1–2 model-size accounting (exact bytes, zero
//!   noise floor: any delta is a real converter/inventory change);
//! * `engine` — end-to-end forward latency of the synthetic packed
//!   LeNets at several batch sizes, plus the binary-kernel ablation on
//!   the QConv2 GEMM shape;
//! * `serve` — gateway pool scaling (workers × offered load, req/s);
//! * `serve_policy` — dynamic-batcher (max_batch, window) sweep;
//! * `serve_conns` — reactor connection-count sweep over real loopback
//!   HTTP (keep-alive closed-loop clients, binary `x-bmx-f32` bodies);
//! * `profile` — the PR-7 per-layer profiler as a record.
//!
//! Every family runs on synthetic models/operands — no artifacts, no
//! network — so the suite runs identically in CI (`--quick`, pinned
//! scalar kernels via `BMXNET_FORCE_SCALAR=1`) and on a dev box.
//!
//! The nine `cargo bench` targets are thin drivers over this module
//! (env knobs `BENCH_QUICK` / `BENCH_FULL` / `BENCH_REPS` /
//! `BENCH_REQUESTS` / `BENCH_JSON`, mirrored by the CLI's `--quick` /
//! `--full` / `--reps` / `--requests` / `--json` flags).

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::figures::run_gemm_figure;
use super::harness::{time_stats, BenchTable, Stats};
use super::record::{gemm_perf_record, GemmFigureRecord, PerfRecord, Provenance, Unit};
use super::serve_scaling::{
    measure_serve_workload, policy_points, quick_serve_workloads, serve_scaling_workloads,
    ServeWorkload,
};
use super::workloads::{fig1_workloads, fig2_workloads, fig3_workloads, quick_gemm};
use crate::coordinator::{Backend, BatchPolicy};
use crate::gemm::{xnor_gemm_prepacked, Method, PackedMatrix, Side};
use crate::model::bmx::synth_lenet;
use crate::model::inventory::{self, Stem};
use crate::nn::Engine;
use crate::tensor::Tensor;

/// Every family `bench-suite` runs, in run order.
pub const FAMILIES: &[&str] =
    &["gemm", "tables", "engine", "serve", "serve_policy", "serve_conns", "profile"];

/// Knobs shared by the CLI and the bench-target env vars.
#[derive(Debug, Clone, Default)]
pub struct SuiteOpts {
    /// CI-sized run: endpoint workloads, fewer reps.
    pub quick: bool,
    /// Paper-exact GEMM shapes (batch 200); only the gemm family cares.
    pub full: bool,
    /// Reps per cell; 0 = per-family default.
    pub reps: usize,
    /// Total requests per serve workload; 0 = default.
    pub requests: usize,
    /// Substring filter over family names.
    pub filter: Option<String>,
}

impl SuiteOpts {
    /// Read the bench-target env knobs (`BENCH_QUICK`, `BENCH_FULL`,
    /// `BENCH_REPS`, `BENCH_REQUESTS`).
    pub fn from_env() -> SuiteOpts {
        let flag = |k: &str| std::env::var(k).is_ok_and(|v| v != "0" && !v.is_empty());
        let num = |k: &str| {
            std::env::var(k).ok().and_then(|v| v.parse::<usize>().ok()).unwrap_or(0)
        };
        SuiteOpts {
            quick: flag("BENCH_QUICK"),
            full: flag("BENCH_FULL"),
            reps: num("BENCH_REPS"),
            requests: num("BENCH_REQUESTS"),
            filter: None,
        }
    }

    pub(crate) fn reps_or(&self, default: usize, quick: usize) -> usize {
        if self.reps > 0 {
            self.reps
        } else if self.quick {
            quick
        } else {
            default
        }
    }

    pub(crate) fn requests_or(&self, default: usize, quick: usize) -> usize {
        if self.requests > 0 {
            self.requests
        } else if self.quick {
            quick
        } else {
            default
        }
    }

    fn matches(&self, family: &str) -> bool {
        match &self.filter {
            None => true,
            Some(f) => family.contains(f.as_str()),
        }
    }
}

/// Base provenance for a suite record: capture + the opts every family
/// shares.  Families append their own `note`.
pub(crate) fn suite_provenance(opts: &SuiteOpts, reps: usize, note: &str) -> Provenance {
    let mut p = Provenance::capture("bmxnet bench-suite");
    p.reps = reps;
    p.quick = opts.quick;
    p.note = note.to_string();
    p
}

/// Run every family passing the filter; write one `BENCH_<family>.json`
/// per record when `out_dir` is given.  Returns the records in run order.
pub fn run_suite(opts: &SuiteOpts, out_dir: Option<&Path>) -> Result<Vec<PerfRecord>> {
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir).with_context(|| format!("create {dir:?}"))?;
    }
    let mut records = Vec::new();
    for family in FAMILIES {
        if !opts.matches(family) {
            continue;
        }
        let t0 = Instant::now();
        let rec = run_family(family, opts)?;
        println!(
            "[bench-suite] {family}: {} cells in {:.1}s",
            rec.cells.len(),
            t0.elapsed().as_secs_f64()
        );
        if let Some(dir) = out_dir {
            let path = dir.join(format!("BENCH_{family}.json"));
            rec.write(&path).with_context(|| format!("write {path:?}"))?;
            println!("[bench-suite] wrote {}", path.display());
        }
        records.push(rec);
    }
    if records.is_empty() {
        bail!(
            "no family matches filter {:?} (families: {})",
            opts.filter.as_deref().unwrap_or(""),
            FAMILIES.join(" ")
        );
    }
    Ok(records)
}

/// Run one family by name.
pub fn run_family(family: &str, opts: &SuiteOpts) -> Result<PerfRecord> {
    match family {
        "gemm" => Ok(run_gemm_figures(&[1, 2, 3], opts)?.1),
        "tables" => Ok(run_tables(opts)),
        "engine" => run_engine(opts),
        "serve" => run_serve(opts),
        "serve_policy" => run_serve_policy(opts),
        "serve_conns" => super::serve_conns::run_serve_conns(opts),
        "profile" => run_profile(opts),
        other => bail!("unknown bench family {other:?} (families: {})", FAMILIES.join(" ")),
    }
}

// ------------------------------------------------------------------ gemm

/// Measure the requested figures (1–3) and build the `gemm` record.
/// Shared by the suite, `bmxnet bench-gemm` and the fig bench targets.
pub fn run_gemm_figures(
    figs: &[usize],
    opts: &SuiteOpts,
) -> Result<(Vec<GemmFigureRecord>, PerfRecord)> {
    let reps = opts.reps_or(3, 2);
    let reduced = !opts.full;
    let mut records = Vec::new();
    for fig in figs {
        let (title, xlabel, mut ws) = match fig {
            1 => (
                "Figure 1: GEMM time vs input channels (M=64, 5x5)",
                "C",
                fig1_workloads(reduced),
            ),
            2 => (
                "Figure 2: speedup vs naive, varying filter number (C=256, 5x5)",
                "filters",
                fig2_workloads(reduced),
            ),
            3 => (
                "Figure 3: speedup vs naive, varying kernel size (C=256, filters=64)",
                "kernel",
                fig3_workloads(reduced),
            ),
            other => bail!("unknown figure {other} (1-3)"),
        };
        if opts.quick {
            ws = quick_gemm(ws);
        }
        let absolute = *fig == 1;
        let rows = run_gemm_figure(title, xlabel, &ws, reps, absolute);
        records.push(GemmFigureRecord {
            figure: format!("fig{fig}"),
            xlabel: xlabel.to_string(),
            absolute_times: absolute,
            rows,
        });
    }
    let shape_note = if opts.quick {
        "quick (endpoint shapes, batch 20, N/4)"
    } else if reduced {
        "reduced shapes (batch 20)"
    } else {
        "paper-exact shapes (batch 200)"
    };
    let prov = suite_provenance(opts, reps, shape_note);
    let rec = gemm_perf_record(prov, &records);
    Ok((records, rec))
}

// ---------------------------------------------------------------- tables

/// Byte-exact Table 1–2 size accounting.  Deterministic — `Stats::exact`
/// cells with a zero noise floor, so the compare gate flags *any* change
/// in converter/inventory accounting.
pub fn run_tables(opts: &SuiteOpts) -> PerfRecord {
    let mut rec = PerfRecord::new("tables", suite_provenance(opts, 0, "byte-exact inventory"));

    let mut t1 = BenchTable::new(
        "Table 1: model sizes (binary / full precision)",
        &["dataset", "arch", "binary", "fp32", "ratio", "paper"],
    );
    const MB: f64 = 1024.0 * 1024.0;
    const KB: f64 = 1024.0;
    let lenet_bin = inventory::lenet(true);
    let lenet_fp = inventory::lenet(false);
    t1.row(vec![
        "MNIST".into(),
        "LeNet".into(),
        format!("{:.0} kB", lenet_bin.bmx_bytes() as f64 / KB),
        format!("{:.1} MB", lenet_fp.fp32_bytes() as f64 / MB),
        format!("{:.1}x", lenet_fp.fp32_bytes() as f64 / lenet_bin.bmx_bytes() as f64),
        "206kB / 4.6MB".into(),
    ]);
    rec.push("table1/lenet/bmx_bytes", Unit::Bytes, Stats::exact(lenet_bin.bmx_bytes() as f64));
    rec.push("table1/lenet/fp32_bytes", Unit::Bytes, Stats::exact(lenet_fp.fp32_bytes() as f64));

    let rn_bin = inventory::resnet18(64, 10, Stem::Cifar, &[]);
    let rn_fp = inventory::resnet18(64, 10, Stem::Cifar, &[1, 2, 3, 4]);
    t1.row(vec![
        "CIFAR-10".into(),
        "ResNet-18".into(),
        format!("{:.1} MB", rn_bin.bmx_bytes() as f64 / MB),
        format!("{:.1} MB", rn_fp.fp32_bytes() as f64 / MB),
        format!("{:.1}x", rn_fp.fp32_bytes() as f64 / rn_bin.bmx_bytes() as f64),
        "1.5MB / 44.7MB (29x)".into(),
    ]);
    rec.push("table1/resnet18/bmx_bytes", Unit::Bytes, Stats::exact(rn_bin.bmx_bytes() as f64));
    rec.push("table1/resnet18/fp32_bytes", Unit::Bytes, Stats::exact(rn_fp.fp32_bytes() as f64));
    t1.print();

    let mut t2 = BenchTable::new(
        "Table 2: ResNet-18 ImageNet sizes by full-precision stage",
        &["fp stage", "size (ours)", "size (paper)"],
    );
    let rows: [(&str, &[usize], &str); 7] = [
        ("none", &[], "3.6MB"),
        ("1st", &[1], "4.1MB"),
        ("2nd", &[2], "5.6MB"),
        ("3rd", &[3], "11.3MB"),
        ("4th", &[4], "36MB"),
        ("1st+2nd", &[1, 2], "6.2MB"),
        ("all", &[1, 2, 3, 4], "47MB"),
    ];
    for (label, fp_stages, paper) in rows {
        let inv = inventory::resnet18(64, 1000, Stem::Imagenet, fp_stages);
        t2.row(vec![
            label.into(),
            format!("{:.1} MB", inv.bmx_bytes() as f64 / MB),
            paper.into(),
        ]);
        rec.push(
            format!("table2/{label}/bmx_bytes"),
            Unit::Bytes,
            Stats::exact(inv.bmx_bytes() as f64),
        );
    }
    t2.print();
    rec
}

// ---------------------------------------------------------------- engine

/// Forward latency of the synthetic packed LeNets + the binary-kernel
/// ablation on the QConv2 GEMM shape.
fn run_engine(opts: &SuiteOpts) -> Result<PerfRecord> {
    let reps = opts.reps_or(5, 2);
    let batches: &[usize] = if opts.quick { &[1, 8] } else { &[1, 8, 32] };
    let mut rec = PerfRecord::new(
        "engine",
        suite_provenance(opts, reps, "synthetic packed LeNets (artifact-free)"),
    );

    let mut table = BenchTable::new(
        "Engine inference (rust xnor path, synthetic weights)",
        &["model", "batch", "ms/batch", "img/s"],
    );
    for (name, seed, act_bit) in [("lenet_bin", 1u64, 1u32), ("lenet_q4", 2, 4)] {
        let engine = Engine::from_bmx(&synth_lenet(seed, act_bit)?)?;
        // Cell ids carry the epilogue label so a BMXNET_NO_FOLD=1 run
        // ("…/forward/f32bn") never silently compares against a folded
        // one ("…/forward/thr").
        let epi = engine.epilogue();
        let [c, h, w] = engine.input_shape();
        for &batch in batches {
            let data: Vec<f32> = (0..batch * c * h * w)
                .map(|i| ((i % 17) as f32) / 8.5 - 1.0)
                .collect();
            let x = Tensor::new(vec![batch, c, h, w], data);
            let s = time_stats(reps, || engine.forward(&x).unwrap());
            table.row(vec![
                name.into(),
                batch.to_string(),
                format!("{:.2}", s.median),
                format!("{:.0}", batch as f64 / (s.median / 1e3).max(1e-9)),
            ]);
            rec.push(format!("{name}/batch={batch}/forward/{epi}"), Unit::Ms, s);
        }
    }
    table.print();

    // Ablation: binary kernel variant on the LeNet QConv2 GEMM
    // (rows = batch*8*8 im2col rows, K = 32*5*5 = 800, N = 64 filters).
    let rows = if opts.quick { 8 * 64 } else { 32 * 64 };
    let (m, n, k) = (rows, 64, 800);
    let mut rng = crate::data::Rng::new(5);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let pa = PackedMatrix::pack_rows(&a, m, k, Side::A);
    let pb = PackedMatrix::pack_cols(&b, k, n);
    let mut ab = BenchTable::new(
        "Ablation: binary kernel variant on the QConv2 GEMM",
        &["method", "ms/call", "speedup vs first"],
    );
    let mut base = None;
    for method in Method::available().into_iter().filter(|m| m.is_binary()) {
        let s = time_stats(reps, || xnor_gemm_prepacked(method, &pa, &pb));
        let b0 = *base.get_or_insert(s.median);
        ab.row(vec![
            method.label().into(),
            format!("{:.3}", s.median),
            format!("{:.2}x", b0 / s.median.max(1e-12)),
        ]);
        rec.push(format!("ablation/qconv2/{}", method.label()), Unit::Ms, s);
    }
    ab.print();
    Ok(rec)
}

// ----------------------------------------------------------------- serve

fn synth_backend() -> Result<Arc<dyn Backend>> {
    Ok(Arc::new(Engine::from_bmx(&synth_lenet(1, 1)?)?))
}

/// Pool scaling: workers × closed-loop offered load over the real xnor
/// engine (synthetic weights).  Each grid point runs `reps` times; req/s
/// and p95 latency are summarized as stats.
fn run_serve(opts: &SuiteOpts) -> Result<PerfRecord> {
    let reps = opts.reps_or(3, 2);
    let requests = opts.requests_or(192, 48);
    let workloads = if opts.quick {
        quick_serve_workloads(requests)
    } else {
        serve_scaling_workloads(requests)
    };
    let policy = BatchPolicy {
        max_batch: 32,
        window: std::time::Duration::from_millis(2),
    };
    let backend = synth_backend()?;
    let mut rec = PerfRecord::new(
        "serve",
        suite_provenance(opts, reps, &format!("closed loop, {requests} requests/point")),
    );
    let mut table = BenchTable::new(
        "Serve scaling: offered load vs worker count (median over reps)",
        &["workers", "producers", "req/s", "p95_ms", "rejected"],
    );
    for w in &workloads {
        let (req_s, p95, rejected) = measure_workload_stats(&backend, w, policy, reps);
        table.row(vec![
            w.workers.to_string(),
            w.producers.to_string(),
            format!("{:.0}", req_s.median),
            format!("{:.1}", p95.median),
            rejected.to_string(),
        ]);
        let point = format!("w={},p={}", w.workers, w.producers);
        rec.push(format!("{point}/req_s"), Unit::ReqPerSec, req_s);
        rec.push(format!("{point}/p95"), Unit::Ms, p95);
    }
    table.print();
    Ok(rec)
}

/// Run one serve workload `reps` times; returns (req/s, p95 ms, total
/// rejected across reps).
fn measure_workload_stats(
    backend: &Arc<dyn Backend>,
    w: &ServeWorkload,
    policy: BatchPolicy,
    reps: usize,
) -> (Stats, Stats, usize) {
    let mut req_s = Vec::with_capacity(reps);
    let mut p95 = Vec::with_capacity(reps);
    let mut rejected = 0usize;
    for _ in 0..reps.max(1) {
        let row = measure_serve_workload(backend.clone(), w, policy, 4096);
        req_s.push(row.req_per_sec());
        p95.push(row.snapshot.p95.as_secs_f64() * 1e3);
        rejected += row.rejected;
    }
    (Stats::from_samples(&req_s), Stats::from_samples(&p95), rejected)
}

/// Dynamic-batcher policy sweep at fixed load (1 worker, 4 producers).
fn run_serve_policy(opts: &SuiteOpts) -> Result<PerfRecord> {
    let reps = opts.reps_or(3, 2);
    let requests = opts.requests_or(192, 48);
    let backend = synth_backend()?;
    let mut rec = PerfRecord::new(
        "serve_policy",
        suite_provenance(
            opts,
            reps,
            &format!("1 worker, 4 producers, {requests} requests/point"),
        ),
    );
    let mut table = BenchTable::new(
        "Serving throughput: batching policy sweep (median over reps)",
        &["max_batch", "window", "req/s", "p95_ms"],
    );
    let w = ServeWorkload { workers: 1, producers: 4, requests };
    for point in policy_points(opts.quick) {
        let (req_s, p95, _) = measure_workload_stats(&backend, &w, point.policy(), reps);
        table.row(vec![
            point.max_batch.to_string(),
            format!("{}ms", point.window_ms),
            format!("{:.0}", req_s.median),
            format!("{:.1}", p95.median),
        ]);
        let id = format!("policy/{}", point.label());
        rec.push(format!("{id}/req_s"), Unit::ReqPerSec, req_s);
        rec.push(format!("{id}/p95"), Unit::Ms, p95);
    }
    table.print();
    println!(
        "(closed-loop: each producer waits for its reply before sending the next; \
         b=1/w=0ms is the no-batching baseline)"
    );
    Ok(rec)
}

// --------------------------------------------------------------- profile

/// The PR-7 per-layer profiler as a suite family: one cell per layer
/// plus the forward total, on the synthetic packed LeNet.
fn run_profile(opts: &SuiteOpts) -> Result<PerfRecord> {
    let reps = opts.reps_or(5, 2);
    let batch = if opts.quick { 4 } else { 8 };
    let engine = Engine::from_bmx(&synth_lenet(1, 1)?)?;
    let mut report = engine.profile(batch, reps)?;
    report.model = "lenet_bin".to_string();
    print!("{}", report.render_table());
    let mut rec = report.to_perf_record("bmxnet bench-suite");
    rec.provenance.quick = opts.quick;
    Ok(rec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_family_is_deterministic_and_complete() {
        let opts = SuiteOpts::default();
        let a = run_tables(&opts);
        let b = run_tables(&opts);
        assert_eq!(a.cells, b.cells, "byte accounting must be deterministic");
        assert_eq!(a.bench, "tables");
        // 4 table1 cells + 7 table2 rows
        assert_eq!(a.cells.len(), 11);
        assert!(a.cells.iter().all(|c| c.unit == Unit::Bytes && c.stats.mad == 0.0));
        let lenet = a.cell("table1/lenet/bmx_bytes").unwrap();
        assert!(lenet.stats.median > 0.0);
        // provenance populated
        assert_eq!(a.provenance.tool, "bmxnet bench-suite");
        assert!(!a.provenance.git.is_empty());
    }

    #[test]
    fn unknown_family_errors() {
        let err = run_family("nope", &SuiteOpts::default()).unwrap_err();
        assert!(err.to_string().contains("unknown bench family"), "{err}");
    }

    #[test]
    fn filter_matches_substrings() {
        let opts = SuiteOpts { filter: Some("serve".into()), ..Default::default() };
        let hits: Vec<&str> = FAMILIES.iter().copied().filter(|f| opts.matches(f)).collect();
        assert_eq!(hits, ["serve", "serve_policy", "serve_conns"]);
        let all = SuiteOpts::default();
        assert!(FAMILIES.iter().all(|f| all.matches(f)));
    }

    #[test]
    fn quick_gemm_family_produces_schema_valid_record() {
        // tiny but real end-to-end measurement: one figure, quick shapes
        let opts = SuiteOpts { quick: true, reps: 1, ..Default::default() };
        let (figs, rec) = run_gemm_figures(&[1], &opts).unwrap();
        assert_eq!(figs.len(), 1);
        assert_eq!(rec.bench, "gemm");
        assert!(rec.provenance.quick);
        assert_eq!(rec.provenance.reps, 1);
        // 2 quick x-points × (available methods + bin+xnor_omp)
        let per_row = crate::gemm::Method::available().len() + 1;
        assert_eq!(rec.cells.len(), 2 * per_row);
        let parsed = PerfRecord::parse(&rec.render_json()).unwrap();
        assert_eq!(parsed, rec);
        assert!(rec.cells.iter().any(|c| c.id.starts_with("fig1/C=64/")), "{:?}", rec.cells[0].id);
    }
}
