//! Connection-count scaling of the reactor gateway: C concurrent
//! keep-alive HTTP connections, each a closed-loop client (write one
//! request, wait for the reply, repeat) over real loopback TCP.
//!
//! Where the `serve` family measures the *pool* (in-process `Client`
//! handles, no HTTP), this family measures the *gateway*: non-blocking
//! connection handling, head parsing, the binary `x-bmx-f32` body path,
//! and response flushing all sit on the measured path. The signal is
//! req/s and p99 latency as connections grow past the old
//! thread-per-connection design's comfort zone.
//!
//! Cells: `c={n}/req_s` (higher is better) and `c={n}/p99` ms (lower is
//! better) per connection count — both direction-aware under
//! `bmxnet bench-compare`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::harness::{BenchTable, Stats};
use super::record::{PerfRecord, Unit};
use super::suite::{suite_provenance, SuiteOpts};
use crate::coordinator::BatchPolicy;
use crate::model::bmx::synth_lenet;
use crate::serve::{Gateway, GatewayConfig, ModelRegistry, PoolConfig, RegistryConfig};

/// Connection counts swept per run.
pub fn conn_counts(quick: bool) -> &'static [usize] {
    if quick {
        &[4, 16]
    } else {
        &[8, 64, 256]
    }
}

/// One closed-loop rep: `conns` keep-alive connections, `per_conn`
/// requests each, binary f32 bodies. Returns (req/s, p99 ms).
fn run_closed_loop(addr: &str, conns: usize, per_conn: usize, body: &[f32]) -> Result<(f64, f64)> {
    let mut raw = Vec::with_capacity(body.len() * 4);
    for v in body {
        raw.extend_from_slice(&v.to_le_bytes());
    }
    let head = format!(
        "POST /v1/models/lenet_bin:classify HTTP/1.1\r\nhost: bench\r\n\
         content-type: application/x-bmx-f32\r\ncontent-length: {}\r\n\r\n",
        raw.len()
    );
    let mut request = head.into_bytes();
    request.extend_from_slice(&raw);
    let request = Arc::new(request);

    let t0 = Instant::now();
    let lat_us: Vec<Vec<u64>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..conns)
            .map(|_| {
                let request = request.clone();
                s.spawn(move || -> Result<Vec<u64>> {
                    let mut stream =
                        TcpStream::connect(addr).context("connect to bench gateway")?;
                    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
                    let mut lats = Vec::with_capacity(per_conn);
                    let mut buf = vec![0u8; 4096];
                    for _ in 0..per_conn {
                        let r0 = Instant::now();
                        stream.write_all(&request)?;
                        // keep-alive responses are delimited by content-length
                        let mut acc: Vec<u8> = Vec::with_capacity(512);
                        loop {
                            let n = stream.read(&mut buf)?;
                            if n == 0 {
                                bail!("gateway closed a keep-alive bench connection");
                            }
                            acc.extend_from_slice(&buf[..n]);
                            if let Some(done) = response_complete(&acc)? {
                                if acc.len() > done {
                                    bail!("unexpected pipelined bytes in closed loop");
                                }
                                break;
                            }
                        }
                        lats.push(r0.elapsed().as_micros() as u64);
                    }
                    Ok(lats)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("bench client panicked"))
            .collect::<Result<Vec<_>>>()
    })?;
    let wall = t0.elapsed().as_secs_f64();
    let mut all: Vec<u64> = lat_us.into_iter().flatten().collect();
    all.sort_unstable();
    let total = all.len();
    let p99 = all[((total - 1) as f64 * 0.99).round() as usize] as f64 / 1e3;
    Ok((total as f64 / wall.max(1e-9), p99))
}

/// Parse enough of a buffered response to know when it is complete:
/// `Some(total_len)` once head + content-length bytes are buffered.
/// Errors on non-200 statuses so a mis-sized body or 429 fails loudly
/// instead of skewing the measurement.
fn response_complete(acc: &[u8]) -> Result<Option<usize>> {
    let Some(head_end) = acc.windows(4).position(|w| w == b"\r\n\r\n").map(|i| i + 4) else {
        return Ok(None);
    };
    let head = std::str::from_utf8(&acc[..head_end]).context("non-UTF-8 response head")?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line in {head:?}"))?;
    if status != 200 {
        bail!("bench request failed with status {status}: {head:?}");
    }
    let content_len: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.trim().eq_ignore_ascii_case("content-length").then(|| v.trim().parse().ok())?
        })
        .context("response without content-length")?;
    if acc.len() >= head_end + content_len {
        Ok(Some(head_end + content_len))
    } else {
        Ok(None)
    }
}

/// The `serve_conns` suite family: one real gateway over loopback, a
/// connection-count sweep of closed-loop keep-alive clients.
pub fn run_serve_conns(opts: &SuiteOpts) -> Result<PerfRecord> {
    let reps = opts.reps_or(3, 2);
    let counts = conn_counts(opts.quick);
    let max_c = *counts.iter().max().expect("non-empty sweep");

    // Synthetic packed LeNet in a temp models dir — no artifacts needed.
    let dir: PathBuf =
        std::env::temp_dir().join(format!("bench_serve_conns_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).with_context(|| format!("create {dir:?}"))?;
    synth_lenet(1, 1)?.save(dir.join("lenet_bin.bmx"))?;

    let registry = Arc::new(ModelRegistry::new(RegistryConfig {
        pool: PoolConfig {
            workers: 2,
            policy: BatchPolicy { max_batch: 32, window: Duration::from_millis(1) },
            // closed-loop: at most max_c requests in flight; headroom so
            // the sweep never measures the 429 path
            queue_cap: (max_c * 2).max(512),
            ..Default::default()
        },
        ..RegistryConfig::new(dir.clone())
    }));
    let gateway = Gateway::start_with(
        registry,
        "127.0.0.1:0",
        GatewayConfig {
            io_workers: 2,
            max_conns: max_c + 64,
            idle_timeout: Duration::from_secs(60),
            request_timeout: Duration::from_secs(30),
        },
    )?;
    let addr = gateway.addr().to_string();
    let image = vec![0.1f32; 784];

    let mut rec = PerfRecord::new(
        "serve_conns",
        suite_provenance(opts, reps, "closed-loop keep-alive conns, x-bmx-f32 bodies"),
    );
    let mut table = BenchTable::new(
        "Gateway connection scaling (median over reps)",
        &["conns", "req/conn", "req/s", "p99_ms"],
    );
    for &c in counts {
        // enough requests per point that the loop dominates setup, but
        // bounded so 256 conns stays CI-sized
        let per_conn = (opts.requests_or(512, 128) / c).clamp(2, 64);
        let mut req_s = Vec::with_capacity(reps);
        let mut p99 = Vec::with_capacity(reps);
        for _ in 0..reps {
            let (r, p) = run_closed_loop(&addr, c, per_conn, &image)?;
            req_s.push(r);
            p99.push(p);
        }
        let (req_s, p99) = (Stats::from_samples(&req_s), Stats::from_samples(&p99));
        table.row(vec![
            c.to_string(),
            per_conn.to_string(),
            format!("{:.0}", req_s.median),
            format!("{:.1}", p99.median),
        ]);
        rec.push(format!("c={c}/req_s"), Unit::ReqPerSec, req_s);
        rec.push(format!("c={c}/p99"), Unit::Ms, p99);
    }
    table.print();
    gateway.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
    Ok(rec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_complete_detects_full_and_partial() {
        let full = b"HTTP/1.1 200 OK\r\ncontent-length: 2\r\n\r\nok";
        assert_eq!(response_complete(full).unwrap(), Some(full.len()));
        assert_eq!(response_complete(&full[..full.len() - 1]).unwrap(), None);
        assert_eq!(response_complete(b"HTTP/1.1 200").unwrap(), None);
    }

    #[test]
    fn response_complete_rejects_non_200() {
        let resp = b"HTTP/1.1 429 Too Many Requests\r\ncontent-length: 0\r\n\r\n";
        let err = response_complete(resp).unwrap_err();
        assert!(err.to_string().contains("429"), "{err}");
    }

    #[test]
    fn conn_counts_quick_is_a_subrange() {
        assert!(conn_counts(true).len() < conn_counts(false).len());
        let max_quick = conn_counts(true).iter().max().unwrap();
        let max_full = conn_counts(false).iter().max().unwrap();
        assert!(max_quick <= max_full);
    }
}
