//! Benchmark result recording: render Figure 1–3 rows into the
//! `BENCH_gemm.json` schema EXPERIMENTS.md §Perf references.
//!
//! Schema (hand-rolled writer, validated against our own
//! [`crate::model::json::parse`] in tests — no serde available offline):
//!
//! ```json
//! {
//!   "bench": "gemm",
//!   "provenance": "host/toolchain note",
//!   "figures": [
//!     {"figure": "fig1", "xlabel": "filter number", "absolute_times": true,
//!      "rows": [{"x": 64, "ms": {"naive": 12.5, "xnor_64_blk": 0.8}}]}
//!   ]
//! }
//! ```
//!
//! Method labels key the `ms` maps — the [`crate::gemm::Method::label`]
//! API contract is what makes records comparable across commits.

use std::fmt::Write as _;
use std::path::Path;

use super::figures::FigureRow;

/// One figure's worth of measured rows, ready to serialize.
#[derive(Debug, Clone)]
pub struct GemmFigureRecord {
    /// Figure id, e.g. `fig1`.
    pub figure: String,
    /// The swept axis, e.g. `filter number`.
    pub xlabel: String,
    /// Whether the figure reports absolute ms (Fig 1) or speedups.
    pub absolute_times: bool,
    pub rows: Vec<FigureRow>,
}

/// Render the full `BENCH_gemm.json` document.
pub fn render_gemm_json(provenance: &str, figures: &[GemmFigureRecord]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"gemm\",\n");
    let _ = writeln!(s, "  \"provenance\": \"{}\",", escape(provenance));
    s.push_str("  \"figures\": [\n");
    for (fi, f) in figures.iter().enumerate() {
        let _ = writeln!(s, "    {{");
        let _ = writeln!(s, "      \"figure\": \"{}\",", escape(&f.figure));
        let _ = writeln!(s, "      \"xlabel\": \"{}\",", escape(&f.xlabel));
        let _ = writeln!(s, "      \"absolute_times\": {},", f.absolute_times);
        s.push_str("      \"rows\": [\n");
        for (ri, row) in f.rows.iter().enumerate() {
            let _ = write!(s, "        {{\"x\": {}, \"ms\": {{", row.x);
            for (ti, (label, d)) in row.timings.iter().enumerate() {
                if ti > 0 {
                    s.push_str(", ");
                }
                let _ = write!(s, "\"{}\": {:.4}", escape(label), d.as_secs_f64() * 1e3);
            }
            s.push_str("}}");
            if ri + 1 < f.rows.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("      ]\n");
        s.push_str("    }");
        if fi + 1 < figures.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    s
}

/// Write the document to disk (the CLI `--json` flag and the bench
/// targets' `BENCH_JSON` env path land here).
pub fn write_gemm_json(
    path: impl AsRef<Path>,
    provenance: &str,
    figures: &[GemmFigureRecord],
) -> std::io::Result<()> {
    std::fs::write(path, render_gemm_json(provenance, figures))
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::json;
    use std::time::Duration;

    fn sample() -> Vec<GemmFigureRecord> {
        vec![GemmFigureRecord {
            figure: "fig1".into(),
            xlabel: "filter number".into(),
            absolute_times: true,
            rows: vec![FigureRow {
                x: 64,
                timings: vec![
                    ("naive", Duration::from_micros(12500)),
                    ("xnor_64_blk", Duration::from_micros(800)),
                ],
            }],
        }]
    }

    #[test]
    fn rendered_json_parses_with_our_parser() {
        let text = render_gemm_json("unit test", &sample());
        let v = json::parse(&text).expect("self-rendered JSON must parse");
        assert_eq!(v.get("bench").and_then(|b| b.as_str()), Some("gemm"));
        let figs = v.get("figures").and_then(|f| f.as_array()).unwrap();
        assert_eq!(figs.len(), 1);
        let rows = figs[0].get("rows").and_then(|r| r.as_array()).unwrap();
        assert_eq!(rows[0].get("x").and_then(|x| x.as_usize()), Some(64));
        let ms = rows[0].get("ms").unwrap();
        let naive = ms.get("naive").and_then(|m| m.as_f64()).unwrap();
        assert!((naive - 12.5).abs() < 1e-6, "naive ms = {naive}");
    }

    #[test]
    fn provenance_is_escaped() {
        let text = render_gemm_json("quote \" and \\ slash", &sample());
        let v = json::parse(&text).unwrap();
        assert_eq!(
            v.get("provenance").and_then(|p| p.as_str()),
            Some("quote \" and \\ slash")
        );
    }

    #[test]
    fn write_roundtrips_to_disk() {
        let path = std::env::temp_dir()
            .join(format!("bench_record_{}.json", std::process::id()));
        write_gemm_json(&path, "disk test", &sample()).unwrap();
        let back = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, render_gemm_json("disk test", &sample()));
    }
}
