//! The versioned `PerfRecord` schema every benchmark family reports
//! through (schema 2), plus the Figure 1–3 conversion that feeds
//! `BENCH_gemm.json`.
//!
//! One record = one bench family run on one binary on one machine:
//!
//! ```json
//! {
//!   "schema": 2,
//!   "bench": "gemm",
//!   "provenance": {
//!     "tool": "bmxnet bench-suite", "version": "0.1.0",
//!     "git": "e3ac3e2-dirty", "rustc": "rustc 1.74.0",
//!     "features": "default", "arch": "x86_64", "os": "linux",
//!     "cores": 4, "dispatch": "method xnor_fused · kernel avx2",
//!     "force_scalar": false, "kernels": "scalar avx2",
//!     "reps": 3, "quick": false, "note": "reduced shapes (batch 20)"
//!   },
//!   "cells": [
//!     {"id": "fig1/C=64/naive", "unit": "ms",
//!      "median": 12.5012, "min": 12.4480, "mad": 0.0320, "reps": 3}
//!   ]
//! }
//! ```
//!
//! Design rules the compare gate relies on:
//! * **Cell ids are the alignment key.** `bench-compare` matches cells of
//!   two records by exact id string; ids therefore embed every axis of
//!   the measurement (`<group>/<point>/<metric-or-method>`).  Method
//!   labels inside ids follow the [`crate::gemm::Method::label`] API
//!   contract, which is what keeps records comparable across commits.
//! * **Units carry direction.** `ms`/`us`/`bytes` regress upward,
//!   `req_s` regresses downward ([`Unit::lower_is_better`]).
//! * **Stats, not best-of.** Every cell stores median/min/MAD over reps
//!   ([`super::harness::Stats`]); the MAD is the per-cell noise floor.
//!
//! Hand-rolled writer + reader (no serde offline); round-trip is
//! validated against [`crate::model::json::parse`] in tests and in
//! `rust/tests/bench_compare.rs`.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::figures::FigureRow;
use super::harness::Stats;
use crate::model::json::{self, Value};

/// The record format version; [`PerfRecord::parse`] rejects anything else.
pub const SCHEMA_VERSION: u64 = 2;

/// What a cell's numbers measure, and therefore which direction is worse.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Unit {
    /// Milliseconds of wall time — lower is better.
    Ms,
    /// Exact byte counts (model sizes) — lower is better, zero noise.
    Bytes,
    /// Requests per second — higher is better.
    ReqPerSec,
}

impl Unit {
    pub fn label(&self) -> &'static str {
        match self {
            Unit::Ms => "ms",
            Unit::Bytes => "bytes",
            Unit::ReqPerSec => "req_s",
        }
    }

    pub fn from_label(s: &str) -> Option<Unit> {
        match s {
            "ms" => Some(Unit::Ms),
            "bytes" => Some(Unit::Bytes),
            "req_s" => Some(Unit::ReqPerSec),
            _ => None,
        }
    }

    /// Direction: does a larger median mean a regression?
    pub fn lower_is_better(&self) -> bool {
        !matches!(self, Unit::ReqPerSec)
    }
}

/// Environment + binary identity block stamped into every record.
///
/// `version`/`git`/`rustc`/`features` identify the binary (git + rustc
/// come from `rust/build.rs` at compile time; absent toolchains degrade
/// to `"unknown"`).  `dispatch`/`force_scalar`/`kernels`/`cores` identify
/// the machine-dependent code path — the same binary produces different
/// numbers under `BMXNET_FORCE_SCALAR=1`, and the record must say so.
#[derive(Debug, Clone, PartialEq)]
pub struct Provenance {
    /// What produced the record, e.g. `bmxnet bench-suite`.
    pub tool: String,
    /// Crate version (`CARGO_PKG_VERSION`).
    pub version: String,
    /// `git describe --always --dirty --tags` at build time.
    pub git: String,
    /// `rustc --version` that built the binary.
    pub rustc: String,
    /// Enabled cargo features, space-joined, or `default`.
    pub features: String,
    /// Target architecture (`std::env::consts::ARCH`).
    pub arch: String,
    /// Operating system (`std::env::consts::OS`).
    pub os: String,
    /// `available_parallelism` at run time.
    pub cores: usize,
    /// GEMM dispatch summary, e.g. `method xnor_fused · kernel avx2`.
    pub dispatch: String,
    /// Whether `BMXNET_FORCE_SCALAR` pinned the scalar kernel.
    pub force_scalar: bool,
    /// Runtime-dispatchable row kernels, space-joined (CPU feature view).
    pub kernels: String,
    /// Repetitions per cell (0 when cells are deterministic counts).
    pub reps: usize,
    /// Whether this was a `--quick` (CI-sized) run.
    pub quick: bool,
    /// Free-text qualifier (e.g. `reduced shapes (batch 20)`).
    pub note: String,
}

impl Provenance {
    /// Capture the current build + machine + dispatch state.  Callers
    /// set `reps`/`quick`/`note` afterwards — only they know them.
    pub fn capture(tool: &str) -> Provenance {
        let mut features: Vec<&str> = Vec::new();
        if cfg!(feature = "pjrt") {
            features.push("pjrt");
        }
        if cfg!(feature = "simd-avx512") {
            features.push("simd-avx512");
        }
        let kernels: Vec<&str> =
            crate::gemm::simd::available_kernels().iter().map(|k| k.label()).collect();
        Provenance {
            tool: tool.to_string(),
            version: env!("CARGO_PKG_VERSION").to_string(),
            git: option_env!("BMXNET_GIT_DESCRIBE").unwrap_or("unknown").to_string(),
            rustc: option_env!("BMXNET_RUSTC_VERSION").unwrap_or("unknown").to_string(),
            features: if features.is_empty() { "default".to_string() } else { features.join(" ") },
            arch: std::env::consts::ARCH.to_string(),
            os: std::env::consts::OS.to_string(),
            cores: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            dispatch: format!(
                "method {} · kernel {}",
                crate::gemm::Method::auto().label(),
                crate::gemm::simd::best_kernel().label(),
            ),
            force_scalar: crate::gemm::simd::force_scalar(),
            kernels: kernels.join(" "),
            reps: 0,
            quick: false,
            note: String::new(),
        }
    }

    /// Render as an indented JSON object (shared by [`PerfRecord`] and
    /// `obs::ProfileReport`, which embeds the same block).
    pub fn render_json_object(&self, indent: usize) -> String {
        let pad = " ".repeat(indent);
        let mut s = String::new();
        s.push_str("{\n");
        let _ = writeln!(s, "{pad}  \"tool\": {},", json_str(&self.tool));
        let _ = writeln!(s, "{pad}  \"version\": {},", json_str(&self.version));
        let _ = writeln!(s, "{pad}  \"git\": {},", json_str(&self.git));
        let _ = writeln!(s, "{pad}  \"rustc\": {},", json_str(&self.rustc));
        let _ = writeln!(s, "{pad}  \"features\": {},", json_str(&self.features));
        let _ = writeln!(s, "{pad}  \"arch\": {},", json_str(&self.arch));
        let _ = writeln!(s, "{pad}  \"os\": {},", json_str(&self.os));
        let _ = writeln!(s, "{pad}  \"cores\": {},", self.cores);
        let _ = writeln!(s, "{pad}  \"dispatch\": {},", json_str(&self.dispatch));
        let _ = writeln!(s, "{pad}  \"force_scalar\": {},", self.force_scalar);
        let _ = writeln!(s, "{pad}  \"kernels\": {},", json_str(&self.kernels));
        let _ = writeln!(s, "{pad}  \"reps\": {},", self.reps);
        let _ = writeln!(s, "{pad}  \"quick\": {},", self.quick);
        let _ = writeln!(s, "{pad}  \"note\": {}", json_str(&self.note));
        let _ = write!(s, "{pad}}}");
        s
    }

    fn from_value(v: &Value) -> Result<Provenance> {
        if v.as_object().is_none() {
            bail!("provenance is not an object");
        }
        Ok(Provenance {
            tool: str_field(v, "tool"),
            version: str_field(v, "version"),
            git: str_field(v, "git"),
            rustc: str_field(v, "rustc"),
            features: str_field(v, "features"),
            arch: str_field(v, "arch"),
            os: str_field(v, "os"),
            cores: usize_field(v, "cores"),
            dispatch: str_field(v, "dispatch"),
            force_scalar: bool_field(v, "force_scalar"),
            kernels: str_field(v, "kernels"),
            reps: usize_field(v, "reps"),
            quick: bool_field(v, "quick"),
            note: str_field(v, "note"),
        })
    }
}

/// One measured quantity: the compare gate aligns cells of two records
/// by exact `id` and judges the median delta against the MAD floor.
#[derive(Debug, Clone, PartialEq)]
pub struct Cell {
    /// Alignment key: `<group>/<point>/<metric>`, e.g. `fig1/C=64/naive`.
    pub id: String,
    pub unit: Unit,
    pub stats: Stats,
    /// Free-text annotation (e.g. the profile's per-layer
    /// `kind=qconv method=xnor_fused kernel=avx2`); never compared.
    pub note: String,
}

impl Cell {
    pub fn new(id: impl Into<String>, unit: Unit, stats: Stats) -> Cell {
        Cell { id: id.into(), unit, stats, note: String::new() }
    }

    pub fn with_note(mut self, note: impl Into<String>) -> Cell {
        self.note = note.into();
        self
    }

    /// Render as a single JSON object line (no trailing comma/newline).
    pub fn render_json_line(&self) -> String {
        let mut s = format!(
            "{{\"id\": {}, \"unit\": \"{}\", \"median\": {}, \"min\": {}, \"mad\": {}, \
             \"reps\": {}",
            json_str(&self.id),
            self.unit.label(),
            fmt_num(self.stats.median),
            fmt_num(self.stats.min),
            fmt_num(self.stats.mad),
            self.stats.reps,
        );
        if !self.note.is_empty() {
            let _ = write!(s, ", \"note\": {}", json_str(&self.note));
        }
        s.push('}');
        s
    }

    fn from_value(v: &Value) -> Result<Cell> {
        let id = v
            .get("id")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow!("cell missing string \"id\""))?
            .to_string();
        let unit_label = v
            .get("unit")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow!("cell {id:?} missing \"unit\""))?;
        let unit = Unit::from_label(unit_label)
            .ok_or_else(|| anyhow!("cell {id:?} has unknown unit {unit_label:?}"))?;
        let num = |key: &str| -> Result<f64> {
            v.get(key)
                .and_then(Value::as_f64)
                .ok_or_else(|| anyhow!("cell {id:?} missing number {key:?}"))
        };
        let stats = Stats {
            median: num("median")?,
            min: num("min")?,
            mad: num("mad")?,
            reps: usize_field(v, "reps"),
        };
        Ok(Cell { id, unit, stats, note: str_field(v, "note") })
    }
}

/// One bench family's full result set + provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct PerfRecord {
    /// Family name: `gemm`, `tables`, `engine`, `serve`, `serve_policy`,
    /// `profile`.
    pub bench: String,
    pub provenance: Provenance,
    pub cells: Vec<Cell>,
}

impl PerfRecord {
    pub fn new(bench: impl Into<String>, provenance: Provenance) -> PerfRecord {
        PerfRecord { bench: bench.into(), provenance, cells: Vec::new() }
    }

    pub fn push(&mut self, id: impl Into<String>, unit: Unit, stats: Stats) {
        self.cells.push(Cell::new(id, unit, stats));
    }

    pub fn cell(&self, id: &str) -> Option<&Cell> {
        self.cells.iter().find(|c| c.id == id)
    }

    /// Render the full document.
    pub fn render_json(&self) -> String {
        self.render_json_extra(&[])
    }

    /// Render with additional pre-rendered top-level entries inserted
    /// after `"bench"` — the profile report adds `model`/`batch` etc.
    /// this way while staying parseable as a plain [`PerfRecord`]
    /// (unknown top-level keys are ignored on read).
    pub fn render_json_extra(&self, extra: &[(&str, String)]) -> String {
        let mut s = String::with_capacity(1024);
        s.push_str("{\n");
        let _ = writeln!(s, "  \"schema\": {SCHEMA_VERSION},");
        let _ = writeln!(s, "  \"bench\": {},", json_str(&self.bench));
        for (key, rendered) in extra {
            let _ = writeln!(s, "  \"{key}\": {rendered},");
        }
        let _ = writeln!(s, "  \"provenance\": {},", self.provenance.render_json_object(2));
        s.push_str("  \"cells\": [\n");
        for (i, c) in self.cells.iter().enumerate() {
            s.push_str("    ");
            s.push_str(&c.render_json_line());
            s.push_str(if i + 1 < self.cells.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Parse a record; rejects wrong/missing schema versions loudly so
    /// `bench-compare` never silently mis-aligns old-format files.
    pub fn parse(text: &str) -> Result<PerfRecord> {
        let v = json::parse(text).map_err(|e| anyhow!("invalid JSON: {e}"))?;
        let schema = v.get("schema").and_then(Value::as_f64).map(|n| n as u64);
        match schema {
            Some(SCHEMA_VERSION) => {}
            Some(other) => bail!(
                "unsupported perf record schema {other} (this tool reads schema \
                 {SCHEMA_VERSION}; re-run the producing bench)"
            ),
            None => bail!("not a perf record: missing \"schema\" field"),
        }
        let bench = v
            .get("bench")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow!("perf record missing \"bench\""))?
            .to_string();
        let provenance = Provenance::from_value(
            v.get("provenance").ok_or_else(|| anyhow!("perf record missing \"provenance\""))?,
        )?;
        let cells = v
            .get("cells")
            .and_then(Value::as_array)
            .ok_or_else(|| anyhow!("perf record missing \"cells\" array"))?
            .iter()
            .map(Cell::from_value)
            .collect::<Result<Vec<_>>>()?;
        Ok(PerfRecord { bench, provenance, cells })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<PerfRecord> {
        let path = path.as_ref();
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        PerfRecord::parse(&text).with_context(|| format!("parse perf record {path:?}"))
    }

    pub fn write(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        std::fs::write(path, self.render_json())
    }
}

fn str_field(v: &Value, key: &str) -> String {
    v.get(key).and_then(Value::as_str).unwrap_or("").to_string()
}

fn usize_field(v: &Value, key: &str) -> usize {
    v.get(key).and_then(Value::as_usize).unwrap_or(0)
}

fn bool_field(v: &Value, key: &str) -> bool {
    matches!(v.get(key), Some(Value::Bool(true)))
}

/// Numbers with enough digits to round-trip sub-microsecond deltas, but
/// no float-noise tails (records are diffed by humans too).
fn fmt_num(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{x:.1}")
    } else {
        format!("{x:.6}")
    }
}

/// Full JSON string escaper (same contract as `serve::http`'s).
pub(crate) fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

// ---------------------------------------------------------------------
// Figure 1–3 conversion (the `BENCH_gemm.json` family)

/// One figure's worth of measured rows, ready to convert into cells.
#[derive(Debug, Clone)]
pub struct GemmFigureRecord {
    /// Figure id, e.g. `fig1`.
    pub figure: String,
    /// The swept axis, e.g. `C` or `filters`.
    pub xlabel: String,
    /// Whether the figure's *table* reports absolute ms (Fig 1) or
    /// speedups (Figs 2–3).  Cells always store absolute ms — speedups
    /// are derivable and would hide absolute regressions.
    pub absolute_times: bool,
    pub rows: Vec<FigureRow>,
}

/// Flatten figures into cells: `fig1/C=64/naive` etc.
pub fn gemm_cells(figures: &[GemmFigureRecord]) -> Vec<Cell> {
    let mut cells = Vec::new();
    for f in figures {
        for row in &f.rows {
            for (label, stats) in &row.timings {
                cells.push(Cell::new(
                    format!("{}/{}={}/{}", f.figure, f.xlabel, row.x, label),
                    Unit::Ms,
                    *stats,
                ));
            }
        }
    }
    cells
}

/// Build the `gemm` family record from measured figures.
pub fn gemm_perf_record(provenance: Provenance, figures: &[GemmFigureRecord]) -> PerfRecord {
    let mut rec = PerfRecord::new("gemm", provenance);
    rec.cells = gemm_cells(figures);
    rec
}

/// Write the `BENCH_gemm.json` document (the CLI `--json` flag and the
/// bench targets' `BENCH_JSON` env path land here).
pub fn write_gemm_json(
    path: impl AsRef<Path>,
    provenance: Provenance,
    figures: &[GemmFigureRecord],
) -> std::io::Result<()> {
    gemm_perf_record(provenance, figures).write(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prov() -> Provenance {
        let mut p = Provenance::capture("unit test");
        p.reps = 3;
        p.note = "synthetic".into();
        p
    }

    fn sample_figures() -> Vec<GemmFigureRecord> {
        vec![GemmFigureRecord {
            figure: "fig1".into(),
            xlabel: "C".into(),
            absolute_times: true,
            rows: vec![FigureRow {
                x: 64,
                timings: vec![
                    ("naive", Stats { median: 12.5, min: 12.4, mad: 0.05, reps: 3 }),
                    ("xnor_64_blk", Stats { median: 0.8, min: 0.79, mad: 0.01, reps: 3 }),
                ],
            }],
        }]
    }

    #[test]
    fn capture_populates_every_field() {
        let p = prov();
        assert_eq!(p.tool, "unit test");
        assert!(!p.version.is_empty());
        assert!(!p.git.is_empty(), "git falls back to \"unknown\", never empty");
        assert!(!p.rustc.is_empty());
        assert!(p.dispatch.contains("method") && p.dispatch.contains("kernel"));
        assert!(p.kernels.contains("scalar"), "scalar kernel always dispatchable");
        assert!(p.cores >= 1);
    }

    #[test]
    fn record_round_trips_through_parse() {
        let rec = gemm_perf_record(prov(), &sample_figures());
        let back = PerfRecord::parse(&rec.render_json()).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn cells_flatten_with_ids_and_absolute_ms() {
        let rec = gemm_perf_record(prov(), &sample_figures());
        assert_eq!(rec.cells.len(), 2);
        let naive = rec.cell("fig1/C=64/naive").expect("naive cell");
        assert_eq!(naive.unit, Unit::Ms);
        assert!((naive.stats.median - 12.5).abs() < 1e-9);
        assert!((naive.stats.mad - 0.05).abs() < 1e-9);
    }

    #[test]
    fn parse_rejects_wrong_schema_and_garbage() {
        let err = PerfRecord::parse("{\"schema\": 1, \"bench\": \"gemm\"}").unwrap_err();
        assert!(err.to_string().contains("schema 1"), "{err}");
        let err = PerfRecord::parse("{\"bench\": \"gemm\"}").unwrap_err();
        assert!(err.to_string().contains("missing \"schema\""), "{err}");
        assert!(PerfRecord::parse("not json").is_err());
    }

    #[test]
    fn parse_rejects_unknown_unit() {
        let rec = gemm_perf_record(prov(), &sample_figures());
        let text = rec.render_json().replace("\"unit\": \"ms\"", "\"unit\": \"parsecs\"");
        let err = PerfRecord::parse(&text).unwrap_err();
        assert!(err.to_string().contains("parsecs"), "{err}");
    }

    #[test]
    fn escaping_survives_hostile_strings() {
        let mut p = prov();
        p.note = "quote \" slash \\ newline \n tab \t".into();
        let mut rec = PerfRecord::new("gemm", p);
        rec.cells
            .push(Cell::new("a/b/c", Unit::Ms, Stats::exact(1.0)).with_note("k=\"v\""));
        let back = PerfRecord::parse(&rec.render_json()).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn unit_labels_round_trip_and_carry_direction() {
        for u in [Unit::Ms, Unit::Bytes, Unit::ReqPerSec] {
            assert_eq!(Unit::from_label(u.label()), Some(u));
        }
        assert!(Unit::Ms.lower_is_better());
        assert!(Unit::Bytes.lower_is_better());
        assert!(!Unit::ReqPerSec.lower_is_better());
    }

    #[test]
    fn write_roundtrips_to_disk() {
        let path =
            std::env::temp_dir().join(format!("bench_record_{}.json", std::process::id()));
        let rec = gemm_perf_record(prov(), &sample_figures());
        rec.write(&path).unwrap();
        let back = PerfRecord::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, rec);
    }

    #[test]
    fn integers_render_compactly() {
        assert_eq!(fmt_num(4096.0), "4096.0");
        assert_eq!(fmt_num(1.25), "1.250000");
    }
}
