//! Timing + table-printing helpers for the bench targets.

use std::time::{Duration, Instant};

/// Best-of-`reps` wall time of `f` after one warmup call.
///
/// Best-of (not mean) is the standard for CPU microbenchmarks: it filters
/// scheduler noise, which on this single-core box is the dominant variance.
pub fn time_best_of<T>(reps: usize, mut f: impl FnMut() -> T) -> Duration {
    std::hint::black_box(f()); // warmup
    let mut best = Duration::MAX;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed());
    }
    best
}

/// Simple fixed-width table writer for paper-style rows.
pub struct BenchTable {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl BenchTable {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render to a string (also used by tests).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a duration as milliseconds with sensible precision.
pub fn fmt_ms(d: Duration) -> String {
    let ms = d.as_secs_f64() * 1e3;
    if ms >= 100.0 {
        format!("{ms:.0}")
    } else if ms >= 1.0 {
        format!("{ms:.1}")
    } else {
        format!("{ms:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_best_of_measures_something() {
        let d = time_best_of(3, || {
            let mut s = 0u64;
            for i in 0..10_000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(d > Duration::ZERO);
        assert!(d < Duration::from_secs(1));
    }

    #[test]
    fn best_of_le_single_run() {
        // best-of-5 of a sleep is roughly the sleep, never much more
        let d = time_best_of(2, || std::thread::sleep(Duration::from_millis(1)));
        assert!(d >= Duration::from_millis(1));
        assert!(d < Duration::from_millis(50));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = BenchTable::new("test", &["a", "method_name"]);
        t.row(vec!["1".into(), "x".into()]);
        t.row(vec!["100".into(), "yyyy".into()]);
        let s = t.render();
        assert!(s.contains("== test =="));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
        // right-aligned columns: all data lines equal length
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_checks_columns() {
        let mut t = BenchTable::new("t", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn fmt_ms_precision() {
        assert_eq!(fmt_ms(Duration::from_millis(250)), "250");
        assert_eq!(fmt_ms(Duration::from_micros(1500)), "1.5");
        assert_eq!(fmt_ms(Duration::from_micros(12)), "0.012");
    }
}
