//! Timing + table-printing helpers for the bench targets.
//!
//! The measurement primitive is [`time_stats`]: warmup, then `reps`
//! timed runs, summarized as **median / min / MAD** ([`Stats`]).  The
//! median is the headline number (robust to scheduler spikes), the min
//! bounds the noise-free cost, and the MAD (median absolute deviation)
//! is the noise floor `bench-compare` uses to suppress deltas that are
//! indistinguishable from run-to-run jitter.  Best-of-N — the previous
//! protocol — survives as [`time_best_of`] for quick interactive probes,
//! but records carry the full statistics: best-of-N systematically
//! under-reports and gives a regression gate no noise model to stand on.

use std::time::{Duration, Instant};

/// Noise-aware summary of repeated measurements of one quantity.
///
/// The unit is the cell's business (`bench/record.rs` tags it); for the
/// timing helpers here it is milliseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    /// Median of the samples — the headline value.
    pub median: f64,
    /// Smallest sample — lower bound on the noise-free cost.
    pub min: f64,
    /// Median absolute deviation from the median — the noise floor.
    pub mad: f64,
    /// Number of samples summarized.
    pub reps: usize,
}

impl Stats {
    /// Summarize raw samples (any unit). Empty input yields all-zero
    /// stats rather than NaN so records stay parseable.
    pub fn from_samples(samples: &[f64]) -> Stats {
        if samples.is_empty() {
            return Stats { median: 0.0, min: 0.0, mad: 0.0, reps: 0 };
        }
        let median = median_of(samples);
        let min = samples.iter().copied().fold(f64::INFINITY, f64::min);
        let devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
        Stats { median, min, mad: median_of(&devs), reps: samples.len() }
    }

    /// Summarize wall-clock samples as milliseconds.
    pub fn from_durations(samples: &[Duration]) -> Stats {
        let ms: Vec<f64> = samples.iter().map(|d| d.as_secs_f64() * 1e3).collect();
        Stats::from_samples(&ms)
    }

    /// A deterministic quantity (byte counts, exact sizes): one "sample",
    /// zero noise floor — any delta at all is a real change.
    pub fn exact(value: f64) -> Stats {
        Stats { median: value, min: value, mad: 0.0, reps: 1 }
    }
}

fn median_of(samples: &[f64]) -> f64 {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = sorted.len();
    if n % 2 == 1 {
        sorted[n / 2]
    } else {
        (sorted[n / 2 - 1] + sorted[n / 2]) / 2.0
    }
}

/// Run `f` once for warmup, then `reps` timed repetitions; summarize the
/// per-rep wall times (ms) as [`Stats`].
pub fn time_stats<T>(reps: usize, mut f: impl FnMut() -> T) -> Stats {
    std::hint::black_box(f()); // warmup
    let mut samples = Vec::with_capacity(reps.max(1));
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    Stats::from_durations(&samples)
}

/// Best-of-`reps` wall time of `f` after one warmup call.  Kept for
/// interactive spot checks; recorded benchmarks use [`time_stats`].
pub fn time_best_of<T>(reps: usize, mut f: impl FnMut() -> T) -> Duration {
    std::hint::black_box(f()); // warmup
    let mut best = Duration::MAX;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed());
    }
    best
}

/// Simple fixed-width table writer for paper-style rows.
pub struct BenchTable {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl BenchTable {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render to a string (also used by tests).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a millisecond value with sensible precision.
pub fn fmt_ms_val(ms: f64) -> String {
    if ms >= 100.0 {
        format!("{ms:.0}")
    } else if ms >= 1.0 {
        format!("{ms:.1}")
    } else {
        format!("{ms:.3}")
    }
}

/// Format a duration as milliseconds with sensible precision.
pub fn fmt_ms(d: Duration) -> String {
    fmt_ms_val(d.as_secs_f64() * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_odd_and_even_medians() {
        let s = Stats::from_samples(&[3.0, 1.0, 2.0]);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.reps, 3);
        // deviations from 2.0: [1, 1, 0] -> median 1
        assert_eq!(s.mad, 1.0);
        let e = Stats::from_samples(&[1.0, 2.0, 3.0, 10.0]);
        assert_eq!(e.median, 2.5);
        assert_eq!(e.min, 1.0);
        // deviations: [1.5, 0.5, 0.5, 7.5] -> median (0.5+1.5)/2 = 1.0
        assert_eq!(e.mad, 1.0);
    }

    #[test]
    fn stats_constant_samples_have_zero_mad() {
        let s = Stats::from_samples(&[5.0, 5.0, 5.0]);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.mad, 0.0);
    }

    #[test]
    fn stats_exact_and_empty() {
        let s = Stats::exact(4096.0);
        assert_eq!((s.median, s.min, s.mad, s.reps), (4096.0, 4096.0, 0.0, 1));
        let z = Stats::from_samples(&[]);
        assert_eq!(z.reps, 0);
        assert_eq!(z.median, 0.0);
    }

    #[test]
    fn time_stats_measures_something() {
        let s = time_stats(3, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(s.reps, 3);
        assert!(s.median > 0.0);
        assert!(s.min <= s.median);
        assert!(s.median < 1000.0, "10k mults should be far under a second");
    }

    #[test]
    fn time_best_of_measures_something() {
        let d = time_best_of(3, || {
            let mut s = 0u64;
            for i in 0..10_000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(d > Duration::ZERO);
        assert!(d < Duration::from_secs(1));
    }

    #[test]
    fn best_of_le_single_run() {
        // best-of-2 of a sleep is roughly the sleep, never much more
        let d = time_best_of(2, || std::thread::sleep(Duration::from_millis(1)));
        assert!(d >= Duration::from_millis(1));
        assert!(d < Duration::from_millis(50));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = BenchTable::new("test", &["a", "method_name"]);
        t.row(vec!["1".into(), "x".into()]);
        t.row(vec!["100".into(), "yyyy".into()]);
        let s = t.render();
        assert!(s.contains("== test =="));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
        // right-aligned columns: all data lines equal length
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_checks_columns() {
        let mut t = BenchTable::new("t", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn fmt_ms_precision() {
        assert_eq!(fmt_ms(Duration::from_millis(250)), "250");
        assert_eq!(fmt_ms(Duration::from_micros(1500)), "1.5");
        assert_eq!(fmt_ms(Duration::from_micros(12)), "0.012");
        assert_eq!(fmt_ms_val(2.5), "2.5");
    }
}
