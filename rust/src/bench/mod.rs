//! Benchmark harness (criterion is unavailable offline; this is our own).
//!
//! * [`harness`] — timing helpers: warmup + best-of-N wall-clock timing,
//!   table-formatted output shared by `cargo bench` targets and the
//!   `bmxnet bench-gemm` CLI.
//! * [`workloads`] — the exact GEMM shapes of Figures 1–3 (and a reduced
//!   variant: batch 20 instead of 200, so the naive baseline finishes in
//!   seconds on this 1-core box; `--full` restores paper-exact shapes).
//! * [`serve_scaling`] — the serving-gateway scaling sweep (offered load ×
//!   pool worker count) shared by `cargo bench --bench serve_scaling`.
//! * [`record`] — `BENCH_gemm.json` writer (the CLI `--json` flag and the
//!   bench targets' `BENCH_JSON` env var), keyed by `Method::label`.

pub mod figures;
pub mod harness;
pub mod record;
pub mod serve_scaling;
pub mod workloads;

pub use figures::{
    measure_workload, measure_workload_methods, run_gemm_figure, run_gemm_figure_methods,
    FigureRow,
};
pub use record::{render_gemm_json, write_gemm_json, GemmFigureRecord};
pub use harness::{time_best_of, BenchTable};
pub use serve_scaling::{
    measure_serve_workload, run_serve_scaling, serve_scaling_workloads, ServeScalingRow,
    ServeWorkload, SyntheticBackend,
};
pub use workloads::{fig1_workloads, fig2_workloads, fig3_workloads, GemmWorkload};
