//! Benchmark harness (criterion is unavailable offline; this is our own).
//!
//! * [`harness`] — timing helpers: noise-aware [`Stats`] (median/min/MAD
//!   over reps via [`time_stats`]), table-formatted output shared by the
//!   `cargo bench` targets and the CLI.
//! * [`workloads`] — the exact GEMM shapes of Figures 1–3 (and a reduced
//!   variant: batch 20 instead of 200, so the naive baseline finishes in
//!   seconds on this 1-core box; `--full` restores paper-exact shapes).
//! * [`serve_scaling`] — the serving-gateway scaling sweep (offered load ×
//!   pool worker count) and the batching-policy grid.
//! * [`record`] — the versioned [`PerfRecord`] schema (provenance block +
//!   per-cell stats) every family writes (`BENCH_<family>.json`).
//! * [`suite`] — `bmxnet bench-suite`: runs every family, one record per
//!   family; the `cargo bench` targets are thin drivers over it.
//! * [`compare`] — `bmxnet bench-compare`: aligns two records cell-by-cell,
//!   suppresses deltas within the MAD noise floor, fails on regressions.

pub mod compare;
pub mod figures;
pub mod harness;
pub mod record;
pub mod serve_conns;
pub mod serve_scaling;
pub mod suite;
pub mod workloads;

pub use compare::{compare, CellDelta, CompareOpts, CompareReport, Verdict};
pub use figures::{
    measure_workload, measure_workload_methods, run_gemm_figure, run_gemm_figure_methods,
    FigureRow,
};
pub use harness::{fmt_ms_val, time_best_of, time_stats, BenchTable, Stats};
pub use record::{
    gemm_cells, gemm_perf_record, write_gemm_json, Cell, GemmFigureRecord, PerfRecord,
    Provenance, Unit, SCHEMA_VERSION,
};
pub use serve_scaling::{
    measure_serve_workload, policy_points, quick_serve_workloads, run_serve_scaling,
    serve_scaling_workloads, PolicyPoint, ServeScalingRow, ServeWorkload, SyntheticBackend,
};
pub use serve_conns::{conn_counts, run_serve_conns};
pub use suite::{run_family, run_gemm_figures, run_suite, SuiteOpts, FAMILIES};
pub use workloads::{fig1_workloads, fig2_workloads, fig3_workloads, quick_gemm, GemmWorkload};
