//! im2col: unfold NCHW convolution inputs into GEMM rows.
//!
//! Matches `jax.lax.conv_general_dilated_patches` with NCHW/OIHW numbers:
//! output row layout is `(n, ho, wo)` by `(c, kh, kw)`, so a weight tensor
//! reshaped `(O, C*KH*KW)` multiplies it directly — the exact layout the
//! L2 Pallas path uses, keeping the two engines bit-comparable.

/// Output spatial size for a conv dimension.
pub fn conv_output_size(input: usize, kernel: usize, stride: usize, pad: usize) -> usize {
    (input + 2 * pad - kernel) / stride + 1
}

/// Unfold `x` (N, C, H, W) into a row-major matrix
/// (N*Ho*Wo, C*KH*KW); zero padding of `pad` on each spatial side.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    x: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> (Vec<f32>, usize, usize) {
    assert_eq!(x.len(), n * c * h * w, "input length mismatch");
    let ho = conv_output_size(h, kh, stride, pad);
    let wo = conv_output_size(w, kw, stride, pad);
    let k = c * kh * kw;
    let rows = n * ho * wo;
    let mut out = vec![0.0f32; rows * k];

    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = ((ni * ho) + oy) * wo + ox;
                let base = row * k;
                for ci in 0..c {
                    for ky in 0..kh {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue; // zero padding: leave zeros
                        }
                        let src = ((ni * c + ci) * h + iy as usize) * w;
                        let dst = base + (ci * kh + ky) * kw;
                        for kx in 0..kw {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            out[dst + kx] = x[src + ix as usize];
                        }
                    }
                }
            }
        }
    }
    (out, rows, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_size_formula() {
        assert_eq!(conv_output_size(28, 5, 1, 0), 24);
        assert_eq!(conv_output_size(32, 3, 1, 1), 32);
        assert_eq!(conv_output_size(32, 3, 2, 1), 16);
        assert_eq!(conv_output_size(8, 1, 1, 0), 8);
    }

    #[test]
    fn identity_kernel_1x1() {
        // 1x1 kernel, stride 1, no pad: im2col is a (N*H*W, C) reordering.
        let x: Vec<f32> = (0..2 * 3 * 2 * 2).map(|i| i as f32).collect();
        let (m, rows, k) = im2col(&x, 2, 3, 2, 2, 1, 1, 1, 0);
        assert_eq!((rows, k), (8, 3));
        // row for (n=0, y=0, x=0) = channels [0, 4, 8]
        assert_eq!(&m[0..3], &[0.0, 4.0, 8.0]);
        // row for (n=1, y=1, x=1) = last elements of each channel in img 1
        assert_eq!(&m[7 * 3..8 * 3], &[15.0, 19.0, 23.0]);
    }

    #[test]
    fn manual_3x3_valid() {
        // 1 channel 4x4 image, 3x3 kernel VALID -> 2x2 output, 9-wide rows.
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let (m, rows, k) = im2col(&x, 1, 1, 4, 4, 3, 3, 1, 0);
        assert_eq!((rows, k), (4, 9));
        assert_eq!(&m[0..9], &[0., 1., 2., 4., 5., 6., 8., 9., 10.]);
        assert_eq!(&m[3 * 9..4 * 9], &[5., 6., 7., 9., 10., 11., 13., 14., 15.]);
    }

    #[test]
    fn padding_zeroes_border() {
        let x = vec![1.0f32; 9]; // 1x1x3x3 of ones
        let (m, rows, k) = im2col(&x, 1, 1, 3, 3, 3, 3, 1, 1);
        assert_eq!((rows, k), (9, 9));
        // top-left output: 4 in-bounds ones, 5 padded zeros
        let first: f32 = m[0..9].iter().sum();
        assert_eq!(first, 4.0);
        // center output: fully in-bounds
        let center: f32 = m[4 * 9..5 * 9].iter().sum();
        assert_eq!(center, 9.0);
    }

    #[test]
    fn stride_two() {
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let (m, rows, k) = im2col(&x, 1, 1, 4, 4, 2, 2, 2, 0);
        assert_eq!((rows, k), (4, 4));
        assert_eq!(&m[0..4], &[0., 1., 4., 5.]);
        assert_eq!(&m[12..16], &[10., 11., 14., 15.]);
    }
}
