//! Minimal NCHW tensor substrate for the pure-Rust inference engine.
//!
//! Deliberately simple: contiguous `Vec<f32>` row-major storage plus the
//! few structural ops the BMXNet layers need (im2col, padding, pooling
//! windows).  All heavy math goes through [`crate::gemm`].

mod im2col;

pub use im2col::{conv_output_size, im2col};

/// Dense f32 tensor, row-major, shape-checked at construction.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from shape + data; panics if the element count mismatches.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(
            n,
            data.len(),
            "shape {shape:?} needs {n} elements, got {}",
            data.len()
        );
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![v; n] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reshape without copying; total element count must be preserved.
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {shape:?}", self.shape);
        self.shape = shape;
        self
    }

    /// Number of images in an NCHW batch (first dim).
    pub fn batch(&self) -> usize {
        self.shape[0]
    }

    /// Apply `f` elementwise in place.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// Row-major index helper for 4-D tensors.
    #[inline]
    pub fn at4(&self, n: usize, c: usize, h: usize, w: usize) -> f32 {
        let (_, cs, hs, ws) = (
            self.shape[0],
            self.shape[1],
            self.shape[2],
            self.shape[3],
        );
        self.data[((n * cs + c) * hs + h) * ws + w]
    }

    /// argmax over the last axis of a 2-D tensor -> one index per row.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.shape.len(), 2, "argmax_rows needs a 2-D tensor");
        let (rows, cols) = (self.shape[0], self.shape[1]);
        (0..rows)
            .map(|r| {
                let row = &self.data[r * cols..(r + 1) * cols];
                // first occurrence wins on ties (matches jnp.argmax)
                let mut best = 0usize;
                for (i, &v) in row.iter().enumerate().skip(1) {
                    if v > row[best] {
                        best = i;
                    }
                }
                best
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_element_count() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
    }

    #[test]
    #[should_panic(expected = "needs 6 elements")]
    fn new_panics_on_mismatch() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let r = t.reshape(vec![3, 2]);
        assert_eq!(r.shape(), &[3, 2]);
        assert_eq!(r.data()[5], 5.0);
    }

    #[test]
    fn at4_row_major() {
        let t = Tensor::new(vec![1, 2, 2, 2], (0..8).map(|i| i as f32).collect());
        assert_eq!(t.at4(0, 1, 0, 1), 5.0);
        assert_eq!(t.at4(0, 0, 1, 0), 2.0);
    }

    #[test]
    fn argmax_rows_ties_take_first() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 3.0, 3.0, -1.0, -2.0, -0.5]);
        assert_eq!(t.argmax_rows(), vec![1, 2]);
    }

    #[test]
    fn map_inplace_applies() {
        let mut t = Tensor::new(vec![2], vec![-1.0, 2.0]);
        t.map_inplace(|v| v * 2.0);
        assert_eq!(t.data(), &[-2.0, 4.0]);
    }
}
