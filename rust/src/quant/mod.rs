//! Quantization math: Eq. 1 (k-bit linear quantization), sign binarization
//! and the Eq. 2 range maps between the float-dot and xnor-dot domains.
//!
//! Semantics are byte-identical to `python/compile/kernels/ref.py`; the
//! cross-layer equality is enforced by `rust/tests/engine_vs_artifacts.rs`.

/// Sign binarization to {-1, +1}; 0 maps to +1 (paper: `x >= 0`).
#[inline]
pub fn sign_binarize(x: f32) -> f32 {
    if x >= 0.0 {
        1.0
    } else {
        -1.0
    }
}

/// Eq. 1: quantize a real in [0, 1] to k-bit resolution (k in [1, 31]).
#[inline]
pub fn quantize_k(x: f32, k: u32) -> f32 {
    assert!((1..=31).contains(&k), "act_bit k must be in [1, 31], got {k}");
    let levels = ((1u64 << k) - 1) as f32;
    (levels * x).round() / levels
}

/// QActivation forward for k > 1: clip to [0, 1] then Eq. 1.
#[inline]
pub fn clip_quantize(x: f32, k: u32) -> f32 {
    quantize_k(x.clamp(0.0, 1.0), k)
}

/// QActivation forward for k = 1: clip to [-1, 1] then sign.
#[inline]
pub fn qactivation_bin(x: f32) -> f32 {
    sign_binarize(x.clamp(-1.0, 1.0))
}

/// QActivation forward for arbitrary k (paper §2.1): k = 1 binarizes,
/// k > 1 clips to [0, 1] and applies Eq. 1.
#[inline]
pub fn qactivation_k(x: f32, k: u32) -> f32 {
    if k == 1 {
        qactivation_bin(x)
    } else {
        clip_quantize(x, k)
    }
}

/// DoReFa-style k-bit weight quantization (mirrors
/// `python/compile/layers.py::quantize_weights` for k > 1):
/// tanh-normalize to [0, 1] by the tensor's max |tanh|, Eq. 1-quantize,
/// rescale to [-1, 1].  Applied tensor-wide (the max is global).
pub fn quantize_weights_kbit(w: &[f32], k: u32) -> Vec<f32> {
    assert!(k > 1, "k = 1 weights are sign-binarized, not Eq.1-quantized");
    let max_t = w
        .iter()
        .map(|v| v.tanh().abs())
        .fold(0.0f32, f32::max)
        .max(1e-12);
    w.iter()
        .map(|v| {
            let t01 = v.tanh() / (2.0 * max_t) + 0.5;
            2.0 * quantize_k(t01, k) - 1.0
        })
        .collect()
}

/// Eq. 2: map a ±1 dot product in [-n, n] to the xnor range [0, n].
#[inline]
pub fn dot_to_xnor(dot: f32, n: usize) -> f32 {
    (dot + n as f32) / 2.0
}

/// Inverse of Eq. 2: map an xnor popcount in [0, n] to the dot range.
/// `n` is the true (unpadded) reduction length.
#[inline]
pub fn xnor_to_dot(pop: i32, n: usize) -> f32 {
    (2 * pop - n as i32) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_zero_is_positive() {
        assert_eq!(sign_binarize(0.0), 1.0);
        assert_eq!(sign_binarize(-0.0), 1.0); // -0.0 >= 0.0 in IEEE
        assert_eq!(sign_binarize(1e-30), 1.0);
        assert_eq!(sign_binarize(-1e-30), -1.0);
    }

    #[test]
    fn quantize_endpoints_fixed() {
        for k in 1..=31 {
            assert_eq!(quantize_k(0.0, k), 0.0);
            assert_eq!(quantize_k(1.0, k), 1.0);
        }
    }

    #[test]
    fn quantize_k1_is_threshold() {
        assert_eq!(quantize_k(0.49, 1), 0.0);
        assert_eq!(quantize_k(0.51, 1), 1.0);
    }

    #[test]
    fn quantize_level_count_k3() {
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..=10_000 {
            let q = quantize_k(i as f32 / 10_000.0, 3);
            seen.insert(q.to_bits());
        }
        assert_eq!(seen.len(), 8); // 2^3 levels
    }

    #[test]
    fn quantize_idempotent() {
        for k in [1, 2, 4, 8, 16] {
            for i in 0..100 {
                let x = i as f32 / 99.0;
                let q = quantize_k(x, k);
                assert_eq!(quantize_k(q, k), q, "k={k} x={x}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "act_bit")]
    fn quantize_rejects_k0() {
        quantize_k(0.5, 0);
    }

    #[test]
    #[should_panic(expected = "act_bit")]
    fn quantize_rejects_k32() {
        quantize_k(0.5, 32);
    }

    #[test]
    fn clip_quantize_clips() {
        assert_eq!(clip_quantize(-3.0, 4), 0.0);
        assert_eq!(clip_quantize(7.0, 4), 1.0);
    }

    #[test]
    fn eq2_roundtrip() {
        // dot in [-n, n] step 2  <->  pop in [0, n] step 1
        for n in [1usize, 5, 64, 12800] {
            for matches in [0usize, 1, n / 2, n] {
                let dot = (2 * matches) as f32 - n as f32;
                let pop = dot_to_xnor(dot, n);
                assert_eq!(pop, matches as f32);
                assert_eq!(xnor_to_dot(matches as i32, n), dot);
            }
        }
    }

    #[test]
    fn qactivation_bin_alphabet() {
        for x in [-5.0f32, -1.0, -0.3, 0.0, 0.7, 9.0] {
            let y = qactivation_bin(x);
            assert!(y == 1.0 || y == -1.0);
        }
    }

    #[test]
    fn qactivation_k_dispatch() {
        assert_eq!(qactivation_k(-0.7, 1), -1.0);
        assert_eq!(qactivation_k(-0.7, 4), 0.0);
        assert_eq!(qactivation_k(2.0, 4), 1.0);
        // k=2: levels {0, 1/3, 2/3, 1}
        assert!((qactivation_k(0.5, 2) - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn kbit_weights_in_range_and_level_count() {
        let w: Vec<f32> = (0..200).map(|i| (i as f32 - 100.0) * 0.03).collect();
        for k in [2u32, 4, 8] {
            let q = quantize_weights_kbit(&w, k);
            let mut levels = std::collections::BTreeSet::new();
            for v in &q {
                assert!((-1.0..=1.0).contains(v), "k={k} v={v}");
                levels.insert(v.to_bits());
            }
            assert!(levels.len() <= (1usize << k), "k={k}: {} levels", levels.len());
            assert!(levels.len() > 2, "k={k}: degenerate quantization");
        }
    }

    #[test]
    fn kbit_weights_preserve_sign_order() {
        let w = [-2.0f32, -0.5, 0.0, 0.5, 2.0];
        let q = quantize_weights_kbit(&w, 4);
        for pair in q.windows(2) {
            assert!(pair[0] <= pair[1], "not monotone: {q:?}");
        }
        assert!(q[0] < 0.0 && q[4] > 0.0);
    }

    #[test]
    #[should_panic(expected = "sign-binarized")]
    fn kbit_weights_reject_k1() {
        quantize_weights_kbit(&[0.5], 1);
    }
}
