//! # bmxnet-rs — BMXNet reproduced as a three-layer Rust + JAX/Pallas stack
//!
//! Reproduction of *"BMXNet: An Open-Source Binary Neural Network
//! Implementation Based on MXNet"* (Yang et al., 2017).  The paper's
//! contributions live here as first-class subsystems:
//!
//! * [`gemm`] — the xnor+popcount GEMM family (paper §2.2.1, Listing 3,
//!   Figures 1–3): naive f32, register-blocked f32 (the CBLAS stand-in),
//!   `xnor_32`, `xnor_64`, blocked/unrolled and multi-threaded variants.
//! * [`quant`] — Eq. 1 k-bit linear quantization, sign binarization and the
//!   Eq. 2 range maps between float-dot and xnor-dot outputs.
//! * [`tensor`] / [`nn`] — the pure-Rust binary inference engine: NCHW
//!   tensors, im2col, Q-layers, LeNet and (partially binarized) ResNet-18.
//! * [`model`] — BMXC f32 checkpoints, the `.bmx` packed binary model
//!   format and the model converter (paper §2.2.3, 29× compression).
//! * [`data`] — synthetic dataset substrates standing in for MNIST /
//!   CIFAR-10 / ImageNet (substitutions documented in DESIGN.md).
//! * [`runtime`] — PJRT bridge: loads the HLO-text artifacts that
//!   `python/compile/aot.py` emits and executes them on the XLA CPU client.
//! * [`train`] — the training orchestrator driving AOT `train_step`
//!   artifacts (L2 graphs) with checkpoints, LR schedule and metrics.
//! * [`coordinator`] — the in-process serving core: request router,
//!   dynamic batcher, worker, latency/throughput metrics.
//! * [`serve`] — the network-facing gateway: multi-model registry (lazy
//!   load, LRU eviction, hot-swap), sharded engine pools with admission
//!   control, and a std-only HTTP/1.1 server with Prometheus-style
//!   `/metrics`.
//! * [`obs`] — observability: request-scoped stage tracing into a
//!   lock-free ring journal, opt-in per-layer profiler, and process-wide
//!   GEMM kernel counters (DESIGN.md §Observability).
//!
//! Python never runs on the request path: `make artifacts` emits HLO text +
//! manifest once, and everything else is this crate.
//!
//! Repo-level documentation: README.md (quickstart, layout → paper-section
//! map), DESIGN.md (dataset substitutions, the bit convention, PJRT
//! gating), EXPERIMENTS.md (measurement protocol, perf findings, figure
//! and table templates).
//!
//! Feature flags: `pjrt` enables the real XLA/PJRT runtime in
//! [`runtime::client`]; the default build substitutes an API-compatible
//! stub so `cargo build && cargo test` are green with no XLA bindings
//! (artifact-driven tests skip — DESIGN.md §PJRT runtime gating).

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod gemm;
pub mod model;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;

/// Default artifacts directory (relative to the repo root / cwd).
pub const ARTIFACTS_DIR: &str = "artifacts";
