//! Inference-engine layers.
//!
//! Float layers (Conv2d, Dense) run im2col + the blocked f32 GEMM; binary
//! layers (QConv2d, QDense) run im2col + the fused binarize→pack→xnor GEMM
//! ([`Method::auto`], overridable per layer via the `method` field) and
//! map popcounts back to the ±1 dot range (`2*pop − K`).  QConv2d pads
//! with **+1** (matching `python/compile/layers.py::qconv2d`) because a
//! zero pad is unrepresentable in the xnor domain.

use crate::gemm::{self, ChannelRule, Method, PackedMatrix};
use crate::quant::{qactivation_bin, xnor_to_dot};
use crate::tensor::{conv_output_size, im2col, Tensor};

pub const BN_EPS: f32 = 1e-5;

/// Full-precision conv: weights (O, C, KH, KW) with optional bias.
#[derive(Debug, Clone)]
pub struct Conv2d {
    pub w: Vec<f32>,
    pub b: Option<Vec<f32>>,
    pub out_ch: usize,
    pub in_ch: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
    /// Transposed weight matrix (K, O) for the f32 GEMM, built once.
    wt: Vec<f32>,
}

impl Conv2d {
    pub fn new(
        w: Vec<f32>,
        b: Option<Vec<f32>>,
        shape: [usize; 4],
        stride: usize,
        pad: usize,
    ) -> Self {
        let [o, c, kh, kw] = shape;
        assert_eq!(w.len(), o * c * kh * kw);
        let k = c * kh * kw;
        let mut wt = vec![0.0f32; k * o];
        for oi in 0..o {
            for ki in 0..k {
                wt[ki * o + oi] = w[oi * k + ki];
            }
        }
        Self { w, b, out_ch: o, in_ch: c, kh, kw, stride, pad, wt }
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        let [n, c, h, w] = [x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]];
        assert_eq!(c, self.in_ch, "channel mismatch");
        let (cols, rows, k) =
            im2col(x.data(), n, c, h, w, self.kh, self.kw, self.stride, self.pad);
        let ho = conv_output_size(h, self.kh, self.stride, self.pad);
        let wo = conv_output_size(w, self.kw, self.stride, self.pad);
        // (rows, k) x (k, O) -> (rows, O), rows ordered (n, ho, wo)
        crate::obs::counters::record_gemm_f32(Method::BlockedF32);
        let out = gemm::blocked::gemm_f32(&cols, &self.wt, rows, self.out_ch, k);
        let mut y = rows_to_nchw(&out, n, self.out_ch, ho, wo);
        if let Some(b) = &self.b {
            add_channel_bias(&mut y, b, self.out_ch, ho * wo);
        }
        Tensor::new(vec![n, self.out_ch, ho, wo], y)
    }
}

/// Binary conv: weights bit-packed (O rows × C*KH*KW bits).
/// Input must already be ±1 (post-QActivation).
#[derive(Debug, Clone)]
pub struct QConv2d {
    pub packed: PackedMatrix,
    pub out_ch: usize,
    pub in_ch: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
    pub method: Method,
}

impl QConv2d {
    pub fn new(packed: PackedMatrix, shape: [usize; 4], stride: usize, pad: usize) -> Self {
        let [o, c, kh, kw] = shape;
        assert_eq!(packed.rows, o);
        assert_eq!(packed.k, c * kh * kw);
        Self { packed, out_ch: o, in_ch: c, kh, kw, stride, pad, method: Method::auto() }
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        let xp = pad_plus_one(x, self.pad);
        let [n, c, h, w] = [xp.shape()[0], xp.shape()[1], xp.shape()[2], xp.shape()[3]];
        assert_eq!(c, self.in_ch, "channel mismatch");
        let (cols, rows, k) = im2col(xp.data(), n, c, h, w, self.kh, self.kw, self.stride, 0);
        let ho = conv_output_size(h, self.kh, self.stride, 0);
        let wo = conv_output_size(w, self.kw, self.stride, 0);
        let pops = gemm::binary_gemm_packed_b(self.method, &cols, rows, k, &self.packed);
        let dots: Vec<f32> = pops.into_iter().map(|p| xnor_to_dot(p, k)).collect();
        let y = rows_to_nchw(&dots, n, self.out_ch, ho, wo);
        Tensor::new(vec![n, self.out_ch, ho, wo], y)
    }

    /// Folded forward: conv + BatchNorm + sign in one pass.  `rules` is
    /// the layer's folded BN+sign (one [`ChannelRule`] per output
    /// channel, from [`BatchNorm::fold_sign_rules`] with `k =
    /// self.packed.k`); the threshold epilogue writes packed sign bits
    /// directly, so the output never exists as f32.
    pub fn forward_folded(&self, x: &Tensor, rules: &[ChannelRule]) -> PackedActs {
        let xp = pad_plus_one(x, self.pad);
        let [n, c, h, w] = [xp.shape()[0], xp.shape()[1], xp.shape()[2], xp.shape()[3]];
        assert_eq!(c, self.in_ch, "channel mismatch");
        let (cols, rows, k) = im2col(xp.data(), n, c, h, w, self.kh, self.kw, self.stride, 0);
        let ho = conv_output_size(h, self.kh, self.stride, 0);
        let wo = conv_output_size(w, self.kw, self.stride, 0);
        let bits = gemm::binary_gemm_packed_b_threshold(&cols, rows, k, &self.packed, rules);
        PackedActs::new(bits, n, self.out_ch, ho, wo)
    }

    /// Binary conv over packed activations: bit-domain im2col (spatial
    /// pads become 1-bits — the same +1 pad value `pad_plus_one` uses in
    /// f32), prepacked xnor GEMM, f32 dots out.  This is the exit from
    /// the bit domain when this conv's own BatchNorm cannot fold (e.g. a
    /// residual add follows it).
    pub fn forward_packed(&self, x: &PackedActs) -> Tensor {
        assert_eq!(x.ch, self.in_ch, "channel mismatch");
        let (hp, wp) = (x.h + 2 * self.pad, x.w + 2 * self.pad);
        let ho = conv_output_size(hp, self.kh, self.stride, 0);
        let wo = conv_output_size(wp, self.kw, self.stride, 0);
        let rows = x.n * ho * wo;
        let k = self.in_ch * self.kh * self.kw;
        let mut cols = PackedMatrix::zeroed(rows, k, gemm::Side::A);
        for ni in 0..x.n {
            for oy in 0..ho {
                for ox in 0..wo {
                    let row = (ni * ho + oy) * wo + ox;
                    for ky in 0..self.kh {
                        let iy = (oy * self.stride + ky) as isize - self.pad as isize;
                        let y_in = iy >= 0 && iy < x.h as isize;
                        for kx in 0..self.kw {
                            let ix = (ox * self.stride + kx) as isize - self.pad as isize;
                            let inside = y_in && ix >= 0 && ix < x.w as isize;
                            // bit index order (c, ky, kx) matches im2col
                            let base = ky * self.kw + kx;
                            if inside {
                                let src = (ni * x.h + iy as usize) * x.w + ix as usize;
                                for ci in 0..self.in_ch {
                                    if x.rows.get_bit(src, ci) {
                                        cols.set_bit(row, ci * self.kh * self.kw + base);
                                    }
                                }
                            } else {
                                for ci in 0..self.in_ch {
                                    cols.set_bit(row, ci * self.kh * self.kw + base);
                                }
                            }
                        }
                    }
                }
            }
        }
        let pops = gemm::xnor_gemm_prepacked(self.method, &cols, &self.packed);
        let dots: Vec<f32> = pops.into_iter().map(|p| xnor_to_dot(p, k)).collect();
        let y = rows_to_nchw(&dots, x.n, self.out_ch, ho, wo);
        Tensor::new(vec![x.n, self.out_ch, ho, wo], y)
    }
}

/// Full-precision dense layer: w (N, K), optional bias.
#[derive(Debug, Clone)]
pub struct Dense {
    pub w: Vec<f32>,
    pub b: Option<Vec<f32>>,
    pub out_dim: usize,
    pub in_dim: usize,
    wt: Vec<f32>,
}

impl Dense {
    pub fn new(w: Vec<f32>, b: Option<Vec<f32>>, out_dim: usize, in_dim: usize) -> Self {
        assert_eq!(w.len(), out_dim * in_dim);
        let mut wt = vec![0.0f32; in_dim * out_dim];
        for o in 0..out_dim {
            for k in 0..in_dim {
                wt[k * out_dim + o] = w[o * in_dim + k];
            }
        }
        Self { w, b, out_dim, in_dim, wt }
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        let (bsz, k) = (x.shape()[0], x.shape()[1]);
        assert_eq!(k, self.in_dim, "dense input dim mismatch");
        crate::obs::counters::record_gemm_f32(Method::BlockedF32);
        let mut out = gemm::blocked::gemm_f32(x.data(), &self.wt, bsz, self.out_dim, k);
        if let Some(b) = &self.b {
            for r in 0..bsz {
                for (o, &bv) in b.iter().enumerate() {
                    out[r * self.out_dim + o] += bv;
                }
            }
        }
        Tensor::new(vec![bsz, self.out_dim], out)
    }
}

/// Binary dense: packed weights (N rows × K bits); ±1 input expected.
#[derive(Debug, Clone)]
pub struct QDense {
    pub packed: PackedMatrix,
    pub out_dim: usize,
    pub in_dim: usize,
    pub method: Method,
}

impl QDense {
    pub fn new(packed: PackedMatrix, out_dim: usize, in_dim: usize) -> Self {
        assert_eq!(packed.rows, out_dim);
        assert_eq!(packed.k, in_dim);
        Self { packed, out_dim, in_dim, method: Method::auto() }
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        let (bsz, k) = (x.shape()[0], x.shape()[1]);
        assert_eq!(k, self.in_dim, "qdense input dim mismatch");
        let pops = gemm::binary_gemm_packed_b(self.method, x.data(), bsz, k, &self.packed);
        let out: Vec<f32> = pops.into_iter().map(|p| xnor_to_dot(p, k)).collect();
        Tensor::new(vec![bsz, self.out_dim], out)
    }

    /// Forward from an already-packed A operand (one packed row per
    /// batch element, bits in the layer's input order) — the folded
    /// path's entry, fed by [`PackedActs::to_dense_rows`].
    pub fn forward_packed(&self, a: &PackedMatrix) -> Tensor {
        assert_eq!(a.k, self.in_dim, "qdense packed input dim mismatch");
        let pops = gemm::xnor_gemm_prepacked(self.method, a, &self.packed);
        let out: Vec<f32> =
            pops.into_iter().map(|p| xnor_to_dot(p, self.in_dim)).collect();
        Tensor::new(vec![a.rows, self.out_dim], out)
    }
}

/// BatchNorm (inference: running stats), channel axis 1 for 4-D, 1 for 2-D.
#[derive(Debug, Clone)]
pub struct BatchNorm {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
}

impl BatchNorm {
    /// The inference-time affine form: per-channel `(scale, shift)` with
    /// `y = scale·x + shift`.  Single source of truth shared by
    /// [`BatchNorm::forward`] and the threshold fold
    /// ([`gemm::fold_bn_sign`] consumes exactly these values, which is
    /// what makes the folded path bit-exact against this forward).
    pub fn scale_shift(&self) -> (Vec<f32>, Vec<f32>) {
        let ch = self.gamma.len();
        let scale: Vec<f32> = (0..ch)
            .map(|c| self.gamma[c] / (self.var[c] + BN_EPS).sqrt())
            .collect();
        let shift: Vec<f32> =
            (0..ch).map(|c| self.beta[c] - self.mean[c] * scale[c]).collect();
        (scale, shift)
    }

    /// Fold this BatchNorm followed by a sign activation into per-channel
    /// popcount rules for a preceding binary GEMM with reduction length
    /// `k` (the conv/dense layer's `packed.k`).
    pub fn fold_sign_rules(&self, k: usize) -> Vec<ChannelRule> {
        let (scale, shift) = self.scale_shift();
        gemm::fold_bn_sign_all(&scale, &shift, k)
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        let ch = self.gamma.len();
        let mut y = x.clone();
        let spatial: usize = if x.shape().len() == 4 {
            x.shape()[2] * x.shape()[3]
        } else {
            1
        };
        assert_eq!(x.shape()[1], ch, "batchnorm channel mismatch");
        let (scale, shift) = self.scale_shift();
        let data = y.data_mut();
        let n = x.shape()[0];
        for ni in 0..n {
            for c in 0..ch {
                let base = (ni * ch + c) * spatial;
                for s in 0..spatial {
                    data[base + s] = data[base + s] * scale[c] + shift[c];
                }
            }
        }
        y
    }
}

/// Bit-packed binary activations between folded layers: one packed row
/// per spatial position (row index `(ni*h + y)*w + x`, matching the
/// im2col output-row order, which is how the threshold epilogue emits
/// them), `ch` bits per row (bit 1 == +1), A-side pad bits preset.
///
/// This is the only form activations take between consecutive binary
/// layers on the folded path — 1 bit per value, never f32.
#[derive(Debug, Clone)]
pub struct PackedActs {
    pub n: usize,
    pub ch: usize,
    pub h: usize,
    pub w: usize,
    pub rows: PackedMatrix,
}

impl PackedActs {
    pub fn new(rows: PackedMatrix, n: usize, ch: usize, h: usize, w: usize) -> Self {
        assert_eq!(rows.rows, n * h * w, "packed activation row count mismatch");
        assert_eq!(rows.k, ch, "packed activation channel count mismatch");
        Self { n, ch, h, w, rows }
    }

    /// Repack into one packed-A row per image with bits in NCHW order
    /// (`(c*h + y)*w + x`) — the order `flatten` would produce in f32 —
    /// so a folded conv feeds a QDense without leaving the bit domain.
    /// Integer-only: a per-bit shuffle, no float materialization.
    pub fn to_dense_rows(&self) -> PackedMatrix {
        let k = self.ch * self.h * self.w;
        let mut out = PackedMatrix::zeroed(self.n, k, gemm::Side::A);
        for ni in 0..self.n {
            for y in 0..self.h {
                for x in 0..self.w {
                    let src = (ni * self.h + y) * self.w + x;
                    for c in 0..self.ch {
                        if self.rows.get_bit(src, c) {
                            out.set_bit(ni, (c * self.h + y) * self.w + x);
                        }
                    }
                }
            }
        }
        out
    }

    /// Unpack to a ±1 f32 NCHW tensor — the fallback exit from the bit
    /// domain (and a test helper for comparing against the f32 path).
    pub fn to_tensor(&self) -> Tensor {
        let mut out = vec![-1.0f32; self.n * self.ch * self.h * self.w];
        for ni in 0..self.n {
            for y in 0..self.h {
                for x in 0..self.w {
                    let src = (ni * self.h + y) * self.w + x;
                    for c in 0..self.ch {
                        if self.rows.get_bit(src, c) {
                            out[((ni * self.ch + c) * self.h + y) * self.w + x] = 1.0;
                        }
                    }
                }
            }
        }
        Tensor::new(vec![self.n, self.ch, self.h, self.w], out)
    }
}

/// 2×2 max pool (stride 2, VALID) in the bit domain: `sign(max(y)) ==
/// OR(sign(y))` — a window's max is ≥ 0 iff any element is — so
/// per-channel pooling is a word-wise OR of the four position rows
/// (channels are bit lanes).  A-side pad bits are 1 in every input row
/// and stay 1 under OR, so the output is a valid packed-A operand.
pub fn maxpool2_bits(x: &PackedActs) -> PackedActs {
    let (ho, wo) = (x.h / 2, x.w / 2);
    let mut out = PackedMatrix::zeroed(x.n * ho * wo, x.ch, gemm::Side::A);
    for ni in 0..x.n {
        for oy in 0..ho {
            for ox in 0..wo {
                let dst = (ni * ho + oy) * wo + ox;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let src = (ni * x.h + oy * 2 + dy) * x.w + ox * 2 + dx;
                        let srow = x.rows.row(src);
                        for (d, &s) in out.row_mut(dst).iter_mut().zip(srow) {
                            *d |= s;
                        }
                    }
                }
            }
        }
    }
    PackedActs::new(out, x.n, x.ch, ho, wo)
}

/// 2×2 max pooling, stride 2, VALID.
pub fn maxpool2(x: &Tensor) -> Tensor {
    let [n, c, h, w] = [x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]];
    let (ho, wo) = (h / 2, w / 2);
    let mut out = vec![f32::NEG_INFINITY; n * c * ho * wo];
    for ni in 0..n {
        for ci in 0..c {
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            m = m.max(x.at4(ni, ci, oy * 2 + dy, ox * 2 + dx));
                        }
                    }
                    out[((ni * c + ci) * ho + oy) * wo + ox] = m;
                }
            }
        }
    }
    Tensor::new(vec![n, c, ho, wo], out)
}

/// Global average pooling NCHW -> NC.
pub fn global_avgpool(x: &Tensor) -> Tensor {
    let [n, c, h, w] = [x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]];
    let mut out = vec![0.0f32; n * c];
    let inv = 1.0 / (h * w) as f32;
    for ni in 0..n {
        for ci in 0..c {
            let mut acc = 0.0;
            for hy in 0..h {
                for wx in 0..w {
                    acc += x.at4(ni, ci, hy, wx);
                }
            }
            out[ni * c + ci] = acc * inv;
        }
    }
    Tensor::new(vec![n, c], out)
}

/// Elementwise tanh.
pub fn tanh(x: &Tensor) -> Tensor {
    let mut y = x.clone();
    y.map_inplace(f32::tanh);
    y
}

/// Elementwise ReLU.
pub fn relu(x: &Tensor) -> Tensor {
    let mut y = x.clone();
    y.map_inplace(|v| v.max(0.0));
    y
}

/// QActivation, k = 1: clip to [-1, 1] then sign.
pub fn qactivation(x: &Tensor) -> Tensor {
    let mut y = x.clone();
    y.map_inplace(qactivation_bin);
    y
}

/// QActivation, arbitrary act_bit (paper §2.1).
pub fn qactivation_k(x: &Tensor, k: u32) -> Tensor {
    let mut y = x.clone();
    y.map_inplace(|v| crate::quant::qactivation_k(v, k));
    y
}

/// Flatten NCHW -> (N, C*H*W).
pub fn flatten(x: &Tensor) -> Tensor {
    let n = x.shape()[0];
    let rest: usize = x.shape()[1..].iter().product();
    x.clone().reshape(vec![n, rest])
}

/// Elementwise a + b.
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape(), "add shape mismatch");
    let mut y = a.clone();
    for (v, &bv) in y.data_mut().iter_mut().zip(b.data()) {
        *v += bv;
    }
    y
}

/// Pad spatial dims with +1.0 (the binary-domain pad value).
fn pad_plus_one(x: &Tensor, pad: usize) -> Tensor {
    if pad == 0 {
        return x.clone();
    }
    let [n, c, h, w] = [x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]];
    let (hp, wp) = (h + 2 * pad, w + 2 * pad);
    let mut out = Tensor::full(vec![n, c, hp, wp], 1.0);
    let data = out.data_mut();
    for ni in 0..n {
        for ci in 0..c {
            for hy in 0..h {
                let src = ((ni * c + ci) * h + hy) * w;
                let dst = ((ni * c + ci) * hp + hy + pad) * wp + pad;
                data[dst..dst + w].copy_from_slice(&x.data()[src..src + w]);
            }
        }
    }
    out
}

/// Reorder GEMM output rows (n*ho*wo, O) into NCHW (n, O, ho, wo).
fn rows_to_nchw(rows: &[f32], n: usize, o: usize, ho: usize, wo: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * o * ho * wo];
    for ni in 0..n {
        for y in 0..ho {
            for x in 0..wo {
                let row = ((ni * ho) + y) * wo + x;
                for oi in 0..o {
                    out[((ni * o + oi) * ho + y) * wo + x] = rows[row * o + oi];
                }
            }
        }
    }
    out
}

fn add_channel_bias(y: &mut [f32], b: &[f32], ch: usize, spatial: usize) {
    let n = y.len() / (ch * spatial);
    for ni in 0..n {
        for (c, &bv) in b.iter().enumerate() {
            let base = (ni * ch + c) * spatial;
            for s in 0..spatial {
                y[base + s] += bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::Side;
    use crate::quant::sign_binarize;

    fn lcg(seed: u64, n: usize) -> Vec<f32> {
        let mut s = seed;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((s >> 33) as f32 / (1u64 << 31) as f32) - 1.0
            })
            .collect()
    }

    #[test]
    fn conv2d_matches_naive_loop() {
        // 1x1x3x3 input, 1 filter 2x2, stride 1, no pad
        let x = Tensor::new(vec![1, 1, 3, 3], (1..=9).map(|i| i as f32).collect());
        let w = vec![1.0, 0.0, 0.0, -1.0]; // detects diagonal difference
        let conv = Conv2d::new(w, Some(vec![0.5]), [1, 1, 2, 2], 1, 0);
        let y = conv.forward(&x);
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        // y[0,0] = 1 - 5 + 0.5 = -3.5, etc.
        assert_eq!(y.data(), &[-3.5, -3.5, -3.5, -3.5]);
    }

    #[test]
    fn qconv_equals_float_conv_on_pm1() {
        let (o, c, kh, kw) = (6, 4, 3, 3);
        let wf: Vec<f32> = lcg(1, o * c * kh * kw).iter().map(|&v| sign_binarize(v)).collect();
        let x = Tensor::new(
            vec![2, c, 8, 8],
            lcg(2, 2 * c * 64).iter().map(|&v| sign_binarize(v)).collect(),
        );
        for (stride, pad) in [(1, 0), (1, 1), (2, 1)] {
            let fconv = Conv2d::new(wf.clone(), None, [o, c, kh, kw], stride, pad);
            // float path must also pad with +1 to match the binary domain
            let xp = pad_plus_one(&x, pad);
            let fconv_nopad = Conv2d::new(wf.clone(), None, [o, c, kh, kw], stride, 0);
            let expect = fconv_nopad.forward(&xp);
            let packed = PackedMatrix::pack_rows(&wf, o, c * kh * kw, Side::B);
            let qconv = QConv2d::new(packed, [o, c, kh, kw], stride, pad);
            let got = qconv.forward(&x);
            assert_eq!(got.shape(), expect.shape(), "stride={stride} pad={pad}");
            assert_eq!(got.data(), expect.data(), "stride={stride} pad={pad}");
            let _ = fconv;
        }
    }

    #[test]
    fn qdense_equals_dense_on_pm1() {
        let (n, k) = (5, 70);
        let wf: Vec<f32> = lcg(3, n * k).iter().map(|&v| sign_binarize(v)).collect();
        let x = Tensor::new(
            vec![3, k],
            lcg(4, 3 * k).iter().map(|&v| sign_binarize(v)).collect(),
        );
        let dense = Dense::new(wf.clone(), None, n, k);
        let expect = dense.forward(&x);
        let q = QDense::new(PackedMatrix::pack_rows(&wf, n, k, Side::B), n, k);
        assert_eq!(q.forward(&x).data(), expect.data());
    }

    #[test]
    fn batchnorm_applies_affine() {
        let bn = BatchNorm {
            gamma: vec![2.0],
            beta: vec![1.0],
            mean: vec![3.0],
            var: vec![4.0],
        };
        let x = Tensor::new(vec![1, 1, 1, 2], vec![3.0, 5.0]);
        let y = bn.forward(&x);
        // (3-3)/2*2+1 = 1 ; (5-3)/2*2+1 = 3
        assert!((y.data()[0] - 1.0).abs() < 1e-4);
        assert!((y.data()[1] - 3.0).abs() < 1e-4);
    }

    #[test]
    fn maxpool_picks_max() {
        let x = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(maxpool2(&x).data(), &[4.0]);
    }

    #[test]
    fn global_avgpool_means() {
        let x = Tensor::new(vec![1, 2, 2, 2], vec![1., 2., 3., 4., 10., 10., 10., 10.]);
        let y = global_avgpool(&x);
        assert_eq!(y.shape(), &[1, 2]);
        assert_eq!(y.data(), &[2.5, 10.0]);
    }

    #[test]
    fn qactivation_pm1() {
        let x = Tensor::new(vec![1, 4], vec![-2.0, -0.1, 0.0, 3.0]);
        assert_eq!(qactivation(&x).data(), &[-1.0, -1.0, 1.0, 1.0]);
    }

    #[test]
    fn pad_plus_one_fills_border() {
        let x = Tensor::new(vec![1, 1, 1, 1], vec![-5.0]);
        let y = pad_plus_one(&x, 1);
        assert_eq!(y.shape(), &[1, 1, 3, 3]);
        let sum: f32 = y.data().iter().sum();
        assert_eq!(sum, 8.0 - 5.0);
        assert_eq!(y.at4(0, 0, 1, 1), -5.0);
    }

    #[test]
    fn add_elementwise() {
        let a = Tensor::new(vec![2], vec![1.0, 2.0]);
        let b = Tensor::new(vec![2], vec![0.5, -2.0]);
        assert_eq!(add(&a, &b).data(), &[1.5, 0.0]);
    }

    /// Random BN with mixed-sign gammas (flipped comparisons) and one
    /// zero-variance channel, over `ch` channels.
    fn edge_bn(seed: u64, ch: usize) -> BatchNorm {
        let g = lcg(seed, ch);
        BatchNorm {
            gamma: g.iter().map(|&v| v * 3.0).collect(), // mixed signs
            beta: lcg(seed + 1, ch),
            mean: lcg(seed + 2, ch),
            var: lcg(seed + 3, ch)
                .iter()
                .enumerate()
                .map(|(i, &v)| if i == 0 { 0.0 } else { v.abs() })
                .collect(),
        }
    }

    #[test]
    fn folded_qconv_is_bit_exact_vs_conv_bn_sign() {
        // 6 output channels (odd-ish), negative gammas, zero variance.
        let (o, c, kh, kw) = (6, 4, 3, 3);
        let wf: Vec<f32> = lcg(10, o * c * kh * kw).iter().map(|&v| sign_binarize(v)).collect();
        let packed = PackedMatrix::pack_rows(&wf, o, c * kh * kw, Side::B);
        let qconv = QConv2d::new(packed, [o, c, kh, kw], 1, 1);
        let bn = edge_bn(20, o);
        let x = Tensor::new(
            vec![2, c, 8, 8],
            lcg(11, 2 * c * 64).iter().map(|&v| sign_binarize(v)).collect(),
        );
        let rules = bn.fold_sign_rules(qconv.packed.k);
        let folded = qconv.forward_folded(&x, &rules);
        let unfolded = qactivation(&bn.forward(&qconv.forward(&x)));
        assert_eq!(folded.to_tensor().data(), unfolded.data());
    }

    #[test]
    fn bit_maxpool_matches_f32_maxpool_then_sign() {
        // arbitrary f32 input -> BN -> the two pool orders must agree:
        // sign(maxpool(y)) == maxpool_bits(sign-per-element bits)
        let (n, ch, h, w) = (2, 5, 6, 6);
        let y = Tensor::new(vec![n, ch, h, w], lcg(30, n * ch * h * w));
        // pack sign bits per position row
        let mut rows = PackedMatrix::zeroed(n * h * w, ch, Side::A);
        for ni in 0..n {
            for yy in 0..h {
                for xx in 0..w {
                    for c in 0..ch {
                        if y.at4(ni, c, yy, xx) >= 0.0 {
                            rows.set_bit((ni * h + yy) * w + xx, c);
                        }
                    }
                }
            }
        }
        let pooled_bits = maxpool2_bits(&PackedActs::new(rows, n, ch, h, w));
        let pooled_f32 = qactivation(&maxpool2(&y));
        assert_eq!(pooled_bits.to_tensor().data(), pooled_f32.data());
    }

    #[test]
    fn dense_rows_match_flatten_order() {
        let (n, ch, h, w) = (2, 3, 2, 2);
        let t = Tensor::new(
            vec![n, ch, h, w],
            lcg(40, n * ch * h * w).iter().map(|&v| sign_binarize(v)).collect(),
        );
        // pack per-position rows from the tensor
        let mut rows = PackedMatrix::zeroed(n * h * w, ch, Side::A);
        for ni in 0..n {
            for yy in 0..h {
                for xx in 0..w {
                    for c in 0..ch {
                        if t.at4(ni, c, yy, xx) >= 0.0 {
                            rows.set_bit((ni * h + yy) * w + xx, c);
                        }
                    }
                }
            }
        }
        let acts = PackedActs::new(rows, n, ch, h, w);
        let dense = acts.to_dense_rows();
        let flat = flatten(&t);
        for ni in 0..n {
            for i in 0..ch * h * w {
                assert_eq!(
                    dense.get_bit(ni, i),
                    flat.data()[ni * ch * h * w + i] >= 0.0,
                    "row {ni} bit {i}"
                );
            }
        }
    }

    #[test]
    fn packed_qconv_matches_f32_qconv() {
        // conv over packed input (bit-domain im2col, +1 spatial pads)
        // must equal the f32 path on the same ±1 activations.
        let (o, c, kh, kw) = (5, 3, 3, 3);
        let wf: Vec<f32> = lcg(50, o * c * kh * kw).iter().map(|&v| sign_binarize(v)).collect();
        for (stride, pad) in [(1, 1), (2, 1), (1, 0)] {
            let packed = PackedMatrix::pack_rows(&wf, o, c * kh * kw, Side::B);
            let qconv = QConv2d::new(packed, [o, c, kh, kw], stride, pad);
            let (n, h, w) = (2, 6, 6);
            let xv: Vec<f32> =
                lcg(51, n * c * h * w).iter().map(|&v| sign_binarize(v)).collect();
            let x = Tensor::new(vec![n, c, h, w], xv);
            let mut rows = PackedMatrix::zeroed(n * h * w, c, Side::A);
            for ni in 0..n {
                for yy in 0..h {
                    for xx in 0..w {
                        for ci in 0..c {
                            if x.at4(ni, ci, yy, xx) >= 0.0 {
                                rows.set_bit((ni * h + yy) * w + xx, ci);
                            }
                        }
                    }
                }
            }
            let acts = PackedActs::new(rows, n, c, h, w);
            let got = qconv.forward_packed(&acts);
            let expect = qconv.forward(&x);
            assert_eq!(got.shape(), expect.shape(), "stride={stride} pad={pad}");
            assert_eq!(got.data(), expect.data(), "stride={stride} pad={pad}");
        }
    }

    #[test]
    fn packed_qdense_matches_f32_qdense() {
        let (n, k) = (4, 70);
        let wf: Vec<f32> = lcg(60, n * k).iter().map(|&v| sign_binarize(v)).collect();
        let q = QDense::new(PackedMatrix::pack_rows(&wf, n, k, Side::B), n, k);
        let xv: Vec<f32> = lcg(61, 3 * k).iter().map(|&v| sign_binarize(v)).collect();
        let x = Tensor::new(vec![3, k], xv.clone());
        let pa = PackedMatrix::pack_rows(&xv, 3, k, Side::A);
        assert_eq!(q.forward_packed(&pa).data(), q.forward(&x).data());
    }
}
