//! The pure-Rust binary inference engine.
//!
//! This is the deployment path of the paper (§2.2.2–2.2.3, §4.2): models
//! train on the AOT/XLA graphs (float dots on ±1 values), then run here
//! with packed 1-bit weights and the xnor+popcount GEMM family — producing
//! **the same logits** (Eq. 2 equivalence; verified against the PJRT
//! artifacts by `rust/tests/engine_vs_artifacts.rs`).
//!
//! * [`layers`] — Conv2d / Dense (f32), QConv2d / QDense (packed xnor),
//!   BatchNorm, pooling and activations.
//! * [`lenet`] — Listing 1 / Listing 2 graphs over those layers.
//! * [`resnet`] — CIFAR-style ResNet-18 with stage-wise binarization.
//! * [`engine`] — arch-dispatching facade: `.bmx` in, logits out.

pub mod engine;
pub mod layers;
pub mod lenet;
pub mod resnet;

pub use engine::Engine;
