//! LeNet inference graphs (paper Listings 1 and 2) over a `.bmx` model.

use anyhow::{bail, Context, Result};

use super::layers as L;
use crate::gemm::dispatch::Method;
use crate::gemm::ChannelRule;
use crate::model::bmx::BmxModel;
use crate::obs::Profiler;
use crate::tensor::Tensor;

/// Binary (Listing 2), k-bit quantized (§2.1) or full-precision
/// (Listing 1) LeNet.
#[derive(Debug)]
pub struct Lenet {
    pub binary: bool,
    /// act_bit: 1 = xnor path; >1 = Eq. 1 quantized activations with
    /// pre-quantized f32 weights (the paper's storage for k in [2, 31]).
    pub act_bit: u32,
    conv1: L::Conv2d,
    bn1: L::BatchNorm,
    conv2_fp: Option<L::Conv2d>,
    conv2_bin: Option<L::QConv2d>,
    /// Float BN after conv2; absent when the model file ships pre-folded
    /// thresholds (`thr.conv2`) instead of BN tensors.
    bn2: Option<L::BatchNorm>,
    /// Per-channel popcount thresholds replacing bn2 + sign (paper §2.2.1
    /// taken to its integer-only conclusion). `Some` ⇒ conv2 runs the
    /// fused threshold epilogue and pool2/fc1 stay in the bit domain.
    fold2: Option<Vec<ChannelRule>>,
    fc1_fp: Option<L::Dense>,
    fc1_bin: Option<L::QDense>,
    bn3: L::BatchNorm,
    fc2: L::Dense,
}

pub(super) fn get_f32(m: &BmxModel, name: &str) -> Result<(Vec<usize>, Vec<f32>)> {
    let (shape, data) = m
        .get_f32(name)
        .with_context(|| format!("missing f32 tensor {name}"))?;
    Ok((shape.to_vec(), data.to_vec()))
}

pub(super) fn get_bn(m: &BmxModel, name: &str) -> Result<L::BatchNorm> {
    Ok(L::BatchNorm {
        gamma: get_f32(m, &format!("params.{name}.gamma"))?.1,
        beta: get_f32(m, &format!("params.{name}.beta"))?.1,
        mean: get_f32(m, &format!("state.{name}.mean"))?.1,
        var: get_f32(m, &format!("state.{name}.var"))?.1,
    })
}

impl Lenet {
    /// Build from a converted model; `binary` per the model metadata.
    pub fn from_bmx(m: &BmxModel, binary: bool) -> Result<Self> {
        Self::from_bmx_act_bit(m, binary, 1)
    }

    /// Build with an explicit act_bit (k > 1: quantized f32 weights,
    /// k-bit QActivation, standard dots — paper §2.1). Folding follows
    /// the `BMXNET_NO_FOLD` escape hatch (see [`super::engine::fold_enabled`]).
    pub fn from_bmx_act_bit(m: &BmxModel, binary: bool, act_bit: u32) -> Result<Self> {
        Self::from_bmx_with_fold(m, binary, act_bit, super::engine::fold_enabled())
    }

    /// Build with an explicit fold decision (tests use this instead of
    /// mutating the environment). `fold` only matters on the xnor path
    /// (`binary && act_bit == 1`); a model file that already ships
    /// `thr.conv2` thresholds is always folded — there is no BN left to
    /// run the float epilogue with.
    pub fn from_bmx_with_fold(m: &BmxModel, binary: bool, act_bit: u32, fold: bool) -> Result<Self> {
        let (s, w) = get_f32(m, "params.conv1.w")?;
        let conv1 = L::Conv2d::new(
            w,
            Some(get_f32(m, "params.conv1.b")?.1),
            [s[0], s[1], s[2], s[3]],
            1,
            0,
        );
        let bn1 = get_bn(m, "bn1")?;
        let bn3 = get_bn(m, "bn3")?;
        let (fs, fw) = get_f32(m, "params.fc2.w")?;
        let fc2 = L::Dense::new(fw, Some(get_f32(m, "params.fc2.b")?.1), fs[0], fs[1]);

        let (conv2_fp, conv2_bin, fc1_fp, fc1_bin, bn2, fold2) = if binary && act_bit > 1 {
            // k-bit mode: weights were Eq.1-quantized by convert_kbit and
            // stored f32; compute uses the standard float GEMM (§2.1).
            let (cs, cw) = get_f32(m, "params.conv2.w")?;
            let c2 = L::Conv2d::new(cw, None, [cs[0], cs[1], cs[2], cs[3]], 1, 0);
            let (ds, dw) = get_f32(m, "params.fc1.w")?;
            let d1 = L::Dense::new(dw, None, ds[0], ds[1]);
            (Some(c2), None, Some(d1), None, Some(get_bn(m, "bn2")?), None)
        } else if binary {
            let (cs, packed) = m
                .get_packed("conv2.w")
                .context("binary lenet: missing packed conv2.w")?;
            let mut qc = L::QConv2d::new(packed.clone(), [cs[0], cs[1], cs[2], cs[3]], 1, 0);
            let (ds, dpacked) = m
                .get_packed("fc1.w")
                .context("binary lenet: missing packed fc1.w")?;
            let qd = L::QDense::new(dpacked.clone(), ds[0], ds[1]);
            let (bn2, fold2) = if let Some(rules) = m.get_thresholds("thr.conv2") {
                // Pre-folded file: BN tensors are gone, thresholds rule.
                (None, Some(rules.to_vec()))
            } else {
                let bn = get_bn(m, "bn2")?;
                let fold2 = fold.then(|| bn.fold_sign_rules(qc.packed.k));
                (Some(bn), fold2)
            };
            if fold2.is_some() {
                qc.method = Method::XnorFusedThresh;
            }
            (None, Some(qc), None, Some(qd), bn2, fold2)
        } else {
            let (cs, cw) = get_f32(m, "params.conv2.w")?;
            let c2 = L::Conv2d::new(
                cw,
                Some(get_f32(m, "params.conv2.b")?.1),
                [cs[0], cs[1], cs[2], cs[3]],
                1,
                0,
            );
            let (ds, dw) = get_f32(m, "params.fc1.w")?;
            let d1 = L::Dense::new(dw, Some(get_f32(m, "params.fc1.b")?.1), ds[0], ds[1]);
            (Some(c2), None, Some(d1), None, Some(get_bn(m, "bn2")?), None)
        };
        Ok(Self {
            binary,
            act_bit,
            conv1,
            bn1,
            conv2_fp,
            conv2_bin,
            bn2,
            fold2,
            fc1_fp,
            fc1_bin,
            bn3,
            fc2,
        })
    }

    /// Which conv2 epilogue this instance runs: `"thr"` (folded integer
    /// thresholds, bit-domain pool2/fc1) or `"f32bn"` (float BatchNorm
    /// then sign). Bench cell ids and `dispatch_summary` carry this label.
    pub fn epilogue(&self) -> &'static str {
        if self.fold2.is_some() {
            "thr"
        } else {
            "f32bn"
        }
    }

    /// Forward pass: x (B, 1, 28, 28) -> logits (B, 10).
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        self.forward_with(x, None)
    }

    /// Forward with optional per-layer profiling. With `prof: None` every
    /// hook collapses to a direct call (no timing, no allocation); with a
    /// profiler each op records wall time, bytes touched and — for GEMM
    /// layers — the dispatch Method/Kernel labels.
    pub fn forward_with(&self, x: &Tensor, prof: Option<&Profiler>) -> Result<Tensor> {
        use crate::obs::profiler::layer;
        if x.shape().len() != 4 || x.shape()[1] != 1 || x.shape()[2] != 28 {
            bail!("lenet expects (B, 1, 28, 28), got {:?}", x.shape());
        }
        let bytes = x.data().len() * 4 + self.conv1.w.len() * 4;
        let h = layer(prof, || "conv1".into(), "conv_f32", Some(Method::BlockedF32), bytes, || {
            self.conv1.forward(x) // (B,32,24,24)
        });
        let bytes = h.data().len() * 4;
        let h = layer(prof, || "act1".into(), "tanh", None, bytes, || L::tanh(&h));
        // (B,32,12,12)
        let h = layer(prof, || "pool1".into(), "maxpool2", None, bytes, || L::maxpool2(&h));
        let bytes = h.data().len() * 4;
        let h = layer(prof, || "bn1".into(), "batchnorm", None, bytes, || self.bn1.forward(&h));

        let bytes = h.data().len() * 4;
        if let (true, Some(rules)) = (self.binary && self.act_bit == 1, self.fold2.as_deref()) {
            // Integer-only tail: conv2's popcount accumulators compare
            // against the folded thresholds and emit the next layer's
            // packed bits directly — no f32 tensor until after fc1.
            let hb = layer(prof, || "qact2".into(), "sign", None, bytes, || L::qactivation(&h));
            let c = self.conv2_bin.as_ref().unwrap();
            let cb = bytes + c.packed.words.len() * 8;
            let hbits = layer(prof, || "conv2".into(), "qconv", Some(c.method), cb, || {
                c.forward_folded(&hb, rules) // (B,64,8,8) packed
            });
            let pb = hbits.rows.words.len() * 8;
            let hbits = layer(prof, || "pool2".into(), "maxpool2_bits", None, pb, || {
                L::maxpool2_bits(&hbits) // (B,64,4,4) packed
            });
            let rows = hbits.to_dense_rows();
            let d = self.fc1_bin.as_ref().unwrap();
            let db = rows.words.len() * 8 + d.packed.words.len() * 8;
            let h = layer(prof, || "fc1".into(), "qdense", Some(d.method), db, || {
                d.forward_packed(&rows)
            });
            let bytes = h.data().len() * 4;
            let h = layer(prof, || "bn3".into(), "batchnorm", None, bytes, || self.bn3.forward(&h));
            let h = layer(prof, || "act3".into(), "tanh", None, bytes, || L::tanh(&h));
            let fb = bytes + self.fc2.w.len() * 4;
            return Ok(layer(
                prof,
                || "fc2".into(),
                "dense_f32",
                Some(Method::BlockedF32),
                fb,
                || self.fc2.forward(&h),
            ));
        }
        let h = if self.binary && self.act_bit > 1 {
            let hq = layer(prof, || "qact2".into(), "qact_k", None, bytes, || {
                L::qactivation_k(&h, self.act_bit)
            });
            let c = self.conv2_fp.as_ref().unwrap();
            let cb = bytes + c.w.len() * 4;
            layer(prof, || "conv2".into(), "conv_f32", Some(Method::BlockedF32), cb, || {
                c.forward(&hq)
            })
        } else if self.binary {
            let hb = layer(prof, || "qact2".into(), "sign", None, bytes, || L::qactivation(&h));
            let c = self.conv2_bin.as_ref().unwrap();
            let cb = bytes + c.packed.words.len() * 8;
            layer(prof, || "conv2".into(), "qconv", Some(c.method), cb, || {
                c.forward(&hb) // (B,64,8,8)
            })
        } else {
            let c = self.conv2_fp.as_ref().unwrap();
            let cb = bytes + c.w.len() * 4;
            layer(prof, || "conv2".into(), "conv_f32", Some(Method::BlockedF32), cb, || {
                c.forward(&h)
            })
        };
        let bytes = h.data().len() * 4;
        let bn2 = self.bn2.as_ref().expect("unfolded lenet path requires bn2");
        let h = layer(prof, || "bn2".into(), "batchnorm", None, bytes, || bn2.forward(&h));
        let h = if self.binary {
            h
        } else {
            layer(prof, || "act2".into(), "tanh", None, bytes, || L::tanh(&h))
        };
        // (B,64,4,4)
        let h = layer(prof, || "pool2".into(), "maxpool2", None, bytes, || L::maxpool2(&h));

        let h = L::flatten(&h);
        let bytes = h.data().len() * 4;
        let h = if self.binary && self.act_bit > 1 {
            let hq = layer(prof, || "qact3".into(), "qact_k", None, bytes, || {
                L::qactivation_k(&h, self.act_bit)
            });
            let d = self.fc1_fp.as_ref().unwrap();
            let db = bytes + d.w.len() * 4;
            layer(prof, || "fc1".into(), "dense_f32", Some(Method::BlockedF32), db, || {
                d.forward(&hq)
            })
        } else if self.binary {
            let hb = layer(prof, || "qact3".into(), "sign", None, bytes, || L::qactivation(&h));
            let d = self.fc1_bin.as_ref().unwrap();
            let db = bytes + d.packed.words.len() * 8;
            layer(prof, || "fc1".into(), "qdense", Some(d.method), db, || d.forward(&hb))
        } else {
            let d = self.fc1_fp.as_ref().unwrap();
            let db = bytes + d.w.len() * 4;
            layer(prof, || "fc1".into(), "dense_f32", Some(Method::BlockedF32), db, || {
                d.forward(&h)
            })
        };
        let bytes = h.data().len() * 4;
        let h = layer(prof, || "bn3".into(), "batchnorm", None, bytes, || self.bn3.forward(&h));
        let h = layer(prof, || "act3".into(), "tanh", None, bytes, || L::tanh(&h));
        let fb = bytes + self.fc2.w.len() * 4;
        Ok(layer(prof, || "fc2".into(), "dense_f32", Some(Method::BlockedF32), fb, || {
            self.fc2.forward(&h)
        }))
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::model::bmx::convert;
    use crate::model::ckpt::Checkpoint;
    use crate::model::inventory;

    /// Build a deterministic fake checkpoint matching the LeNet inventory.
    /// (Thin wrapper over the public generator so artifact-free integration
    /// tests can build the same models — see `Inventory::synthetic_checkpoint`.)
    pub(crate) fn fake_ckpt(binary: bool) -> Checkpoint {
        inventory::lenet(binary).synthetic_checkpoint(1)
    }

    #[test]
    fn binary_lenet_forward_shape() {
        let ck = fake_ckpt(true);
        let names = inventory::lenet(true).binary_names();
        let m = convert(&ck, &names, "{}").unwrap();
        let net = Lenet::from_bmx(&m, true).unwrap();
        let x = Tensor::full(vec![2, 1, 28, 28], 0.3);
        let y = net.forward(&x).unwrap();
        assert_eq!(y.shape(), &[2, 10]);
        assert!(y.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fp_lenet_forward_shape() {
        let ck = fake_ckpt(false);
        let m = convert(&ck, &[], "{}").unwrap();
        let net = Lenet::from_bmx(&m, false).unwrap();
        let x = Tensor::full(vec![1, 1, 28, 28], -0.2);
        let y = net.forward(&x).unwrap();
        assert_eq!(y.shape(), &[1, 10]);
    }

    #[test]
    fn profiled_forward_records_gemm_layers() {
        let ck = fake_ckpt(true);
        let names = inventory::lenet(true).binary_names();
        let m = convert(&ck, &names, "{}").unwrap();
        let net = Lenet::from_bmx(&m, true).unwrap();
        let prof = Profiler::new();
        let x = Tensor::full(vec![1, 1, 28, 28], 0.3);
        net.forward_with(&x, Some(&prof)).unwrap();
        let recs = prof.take();
        let names: Vec<&str> = recs.iter().map(|r| r.name.as_str()).collect();
        for want in ["conv1", "qact2", "conv2", "fc1", "fc2"] {
            assert!(names.contains(&want), "missing layer {want} in {names:?}");
        }
        let conv2 = recs.iter().find(|r| r.name == "conv2").unwrap();
        assert_eq!(conv2.kind, "qconv");
        assert!(conv2.method.is_some() && conv2.kernel.is_some());
        assert!(conv2.bytes > 0);
        let act = recs.iter().find(|r| r.name == "act1").unwrap();
        assert!(act.method.is_none() && act.kernel.is_none());
    }

    #[test]
    fn folded_logits_match_unfolded_bit_exactly() {
        let ck = fake_ckpt(true);
        let names = inventory::lenet(true).binary_names();
        let m = convert(&ck, &names, "{}").unwrap();
        let folded = Lenet::from_bmx_with_fold(&m, true, 1, true).unwrap();
        let unfolded = Lenet::from_bmx_with_fold(&m, true, 1, false).unwrap();
        assert_eq!(folded.epilogue(), "thr");
        assert_eq!(unfolded.epilogue(), "f32bn");
        let data: Vec<f32> =
            (0..2 * 28 * 28).map(|i| ((i * 37 + 11) % 97) as f32 / 48.5 - 1.0).collect();
        let x = Tensor::new(vec![2, 1, 28, 28], data);
        let yf = folded.forward(&x).unwrap();
        let yu = unfolded.forward(&x).unwrap();
        assert_eq!(yf.shape(), yu.shape());
        // Bit-exact, not approximately equal: the fold is constructed to
        // reproduce the f32 BN+sign decision for every popcount.
        assert_eq!(yf.data(), yu.data());
    }

    #[test]
    fn prefolded_model_file_loads_without_bn_and_matches() {
        let ck = fake_ckpt(true);
        let names = inventory::lenet(true).binary_names();
        let m = convert(&ck, &names, r#"{"arch": "lenet"}"#).unwrap();
        let unfolded = Lenet::from_bmx_with_fold(&m, true, 1, false).unwrap();
        let mut mf = m.clone();
        crate::model::bmx::fold_thresholds(&mut mf).unwrap();
        // Even with folding "disabled", a pre-folded file runs thresholds:
        // there are no bn2 tensors left to do anything else with.
        let net = Lenet::from_bmx_with_fold(&mf, true, 1, false).unwrap();
        assert_eq!(net.epilogue(), "thr");
        let data: Vec<f32> =
            (0..28 * 28).map(|i| ((i * 13 + 5) % 89) as f32 / 44.5 - 1.0).collect();
        let x = Tensor::new(vec![1, 1, 28, 28], data);
        assert_eq!(net.forward(&x).unwrap().data(), unfolded.forward(&x).unwrap().data());
    }

    #[test]
    fn folded_forward_stays_in_bit_domain_between_binary_layers() {
        let ck = fake_ckpt(true);
        let names = inventory::lenet(true).binary_names();
        let m = convert(&ck, &names, "{}").unwrap();
        // Explicit fold=true: env-independent (CI runs a BMXNET_NO_FOLD leg).
        let net = Lenet::from_bmx_with_fold(&m, true, 1, true).unwrap();
        let prof = Profiler::new();
        let x = Tensor::full(vec![1, 1, 28, 28], 0.3);
        net.forward_with(&x, Some(&prof)).unwrap();
        let recs = prof.take();
        let pool2 = recs.iter().find(|r| r.name == "pool2").unwrap();
        assert_eq!(pool2.kind, "maxpool2_bits");
        let conv2 = recs.iter().find(|r| r.name == "conv2").unwrap();
        assert_eq!(conv2.method, Some("xnor_fused_thr"));
        // qact3 is absorbed into the conv2 threshold epilogue.
        assert!(!recs.iter().any(|r| r.name == "qact3"));
    }

    #[test]
    fn rejects_wrong_input_shape() {
        let ck = fake_ckpt(false);
        let m = convert(&ck, &[], "{}").unwrap();
        let net = Lenet::from_bmx(&m, false).unwrap();
        assert!(net.forward(&Tensor::zeros(vec![1, 3, 32, 32])).is_err());
    }

    #[test]
    fn binary_model_needs_packed_weights() {
        let ck = fake_ckpt(true);
        let m = convert(&ck, &[], "{}").unwrap(); // nothing packed
        assert!(Lenet::from_bmx(&m, true).is_err());
    }
}
