//! Arch-dispatching inference facade: `.bmx` model in, logits out.
//!
//! The `.bmx` metadata JSON names the architecture and its hyperparameters;
//! `Engine` parses it and routes to the right graph.  This is what the
//! serving coordinator and the CLI `predict` command use.
//!
//! The binary layers' forward path runs [`crate::gemm::Method::auto`] —
//! the fused
//! binarize→pack→xnor GEMM with runtime SIMD dispatch
//! ([`crate::gemm::simd::best_kernel`]); [`Engine::dispatch_summary`]
//! reports what that resolves to on the running machine.

use anyhow::{bail, Context, Result};
use std::path::Path;

use std::time::Instant;

use super::{lenet::Lenet, resnet::Resnet};
use crate::model::bmx::BmxModel;
use crate::model::json;
use crate::obs::{ProfileReport, Profiler};
use crate::tensor::Tensor;

/// A loaded, ready-to-run model.
pub enum Engine {
    Lenet(Lenet),
    Resnet(Resnet),
}

/// `BMXNET_NO_FOLD` escape hatch: when set to `1`/`true`/`yes`, engines
/// keep the float BatchNorm + sign epilogue instead of folding it into
/// per-channel popcount thresholds at load. Pre-folded `.bmx` files
/// (with `thr.*` tensors) ignore this — their BN tensors are gone.
///
/// Read per engine load (not cached) for the same reason as
/// [`crate::gemm::simd::force_scalar`].
pub fn fold_enabled() -> bool {
    !matches!(
        std::env::var("BMXNET_NO_FOLD").ok().as_deref(),
        Some("1") | Some("true") | Some("yes")
    )
}

impl Engine {
    /// Build from a parsed `.bmx` model using its embedded metadata.
    pub fn from_bmx(m: &BmxModel) -> Result<Self> {
        let meta = json::parse(&m.meta)
            .map_err(|e| anyhow::anyhow!("bad .bmx metadata: {e}"))?;
        let arch = meta
            .get("arch")
            .and_then(|v| v.as_str())
            .context(".bmx metadata missing \"arch\"")?;
        match arch {
            "lenet" => {
                let binary = matches!(meta.get("binary"), Some(json::Value::Bool(true)));
                let act_bit = meta
                    .get("act_bit")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(1) as u32;
                Ok(Engine::Lenet(Lenet::from_bmx_act_bit(m, binary, act_bit)?))
            }
            "resnet18" => {
                let fp_stages: Vec<usize> = meta
                    .get("fp_stages")
                    .and_then(|v| v.as_array())
                    .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
                    .unwrap_or_default();
                Ok(Engine::Resnet(Resnet::from_bmx(m, &fp_stages)?))
            }
            other => bail!("unknown architecture {other:?}"),
        }
    }

    /// Load a `.bmx` file and build the engine.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Self::from_bmx(&BmxModel::load(path)?)
    }

    /// Forward pass over an NCHW batch.
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        self.forward_with(x, None)
    }

    /// Forward with optional per-layer profiling. `prof: None` is the
    /// serving hot path and adds a single branch per layer — no timing,
    /// no allocation (see `tests/profiler_overhead.rs`).
    pub fn forward_with(&self, x: &Tensor, prof: Option<&Profiler>) -> Result<Tensor> {
        match self {
            Engine::Lenet(n) => n.forward_with(x, prof),
            Engine::Resnet(n) => n.forward_with(x, prof),
        }
    }

    /// Architecture label ("lenet" / "resnet18").
    pub fn arch(&self) -> &'static str {
        match self {
            Engine::Lenet(_) => "lenet",
            Engine::Resnet(_) => "resnet18",
        }
    }

    /// Run `reps` profiled forward passes over a deterministic synthetic
    /// batch and aggregate per-layer wall time / bytes / dispatch labels.
    /// Backs `bmxnet profile` and `GET /v1/models/{name}/profile`.
    pub fn profile(&self, batch: usize, reps: usize) -> Result<ProfileReport> {
        let [c, h, w] = self.input_shape();
        let n = batch.max(1);
        let reps = reps.max(1);
        let data: Vec<f32> = (0..n * c * h * w)
            .map(|i| ((i % 17) as f32) / 8.5 - 1.0)
            .collect();
        let x = Tensor::new(vec![n, c, h, w], data);
        let prof = Profiler::new();
        let mut totals = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = Instant::now();
            self.forward_with(&x, Some(&prof))?;
            totals.push(t0.elapsed());
        }
        Ok(ProfileReport::from_runs(
            self.arch(),
            n,
            reps,
            self.dispatch_summary(),
            crate::gemm::simd::force_scalar(),
            &totals,
            prof.take(),
        ))
    }

    /// Expected input shape [C, H, W].
    pub fn input_shape(&self) -> [usize; 3] {
        match self {
            Engine::Lenet(_) => [1, 28, 28],
            Engine::Resnet(_) => [3, 32, 32],
        }
    }

    pub fn classes(&self) -> usize {
        match self {
            Engine::Lenet(_) => 10,
            Engine::Resnet(n) => n.classes,
        }
    }

    /// Classify a batch: flat images -> (top-1 class, logit) per image.
    pub fn classify(&self, images: &[f32], batch: usize) -> Result<Vec<(usize, f32)>> {
        let [c, h, w] = self.input_shape();
        if images.len() != batch * c * h * w {
            bail!(
                "expected {batch}x{c}x{h}x{w} = {} floats, got {}",
                batch * c * h * w,
                images.len()
            );
        }
        let x = Tensor::new(vec![batch, c, h, w], images.to_vec());
        let logits = self.forward(&x)?;
        let classes = logits.shape()[1];
        Ok(logits
            .data()
            .chunks(classes)
            .map(|row| {
                // first occurrence wins on ties (matches jnp.argmax)
                let mut best = 0usize;
                for (i, &v) in row.iter().enumerate().skip(1) {
                    if v > row[best] {
                        best = i;
                    }
                }
                (best, row[best])
            })
            .collect())
    }

    /// Which binary-layer epilogue this engine runs: `"thr"` (folded
    /// integer thresholds) or `"f32bn"` (float BatchNorm + sign).
    pub fn epilogue(&self) -> &'static str {
        match self {
            Engine::Lenet(n) => n.epilogue(),
            Engine::Resnet(n) => n.epilogue(),
        }
    }

    /// One-line description of the GEMM dispatch this engine's binary
    /// layers will use, e.g. `x86_64 · method xnor_fused · kernel avx2 ·
    /// epilogue thr`. Logged by `bmxnet predict` / `serve` so perf
    /// reports can name the code path that produced them.
    pub fn dispatch_summary(&self) -> String {
        format!(
            "{arch} · method {method} · kernel {kernel} · epilogue {epi}",
            arch = std::env::consts::ARCH,
            method = crate::gemm::Method::auto().label(),
            kernel = crate::gemm::simd::best_kernel().label(),
            epi = self.epilogue(),
        )
    }

    /// Top-1 accuracy over a dataset slice.
    pub fn accuracy(&self, images: &[f32], labels: &[i32], batch: usize) -> Result<f64> {
        let [c, h, w] = self.input_shape();
        let per = c * h * w;
        let mut correct = 0usize;
        let mut total = 0usize;
        for (chunk, lchunk) in images.chunks(batch * per).zip(labels.chunks(batch)) {
            let b = lchunk.len();
            let preds = self.classify(&chunk[..b * per], b)?;
            correct += preds
                .iter()
                .zip(lchunk)
                .filter(|((p, _), &l)| *p == l as usize)
                .count();
            total += b;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::bmx::convert;
    use crate::model::inventory;

    fn lenet_model(binary: bool) -> BmxModel {
        let ck = super::super::lenet::tests::fake_ckpt(binary);
        let names = if binary {
            inventory::lenet(true).binary_names()
        } else {
            vec![]
        };
        let meta = format!(r#"{{"arch": "lenet", "binary": {binary}}}"#);
        convert(&ck, &names, &meta).unwrap()
    }

    #[test]
    fn dispatches_lenet_from_meta() {
        let m = lenet_model(true);
        let e = Engine::from_bmx(&m).unwrap();
        assert_eq!(e.input_shape(), [1, 28, 28]);
        assert_eq!(e.classes(), 10);
    }

    #[test]
    fn classify_returns_one_pred_per_image() {
        let m = lenet_model(false);
        let e = Engine::from_bmx(&m).unwrap();
        let imgs = vec![0.1f32; 3 * 784];
        let preds = e.classify(&imgs, 3).unwrap();
        assert_eq!(preds.len(), 3);
        assert!(preds.iter().all(|(c, _)| *c < 10));
    }

    #[test]
    fn classify_rejects_bad_length() {
        let m = lenet_model(false);
        let e = Engine::from_bmx(&m).unwrap();
        assert!(e.classify(&[0.0; 100], 1).is_err());
    }

    #[test]
    fn unknown_arch_rejected() {
        let mut m = lenet_model(false);
        m.meta = r#"{"arch": "vgg"}"#.to_string();
        assert!(Engine::from_bmx(&m).is_err());
    }

    #[test]
    fn dispatch_summary_names_method_and_kernel() {
        let m = lenet_model(true);
        let e = Engine::from_bmx(&m).unwrap();
        let s = e.dispatch_summary();
        assert!(s.contains("xnor_fused"), "summary missing method: {s}");
        assert!(
            s.contains(crate::gemm::simd::best_kernel().label()),
            "summary missing kernel: {s}"
        );
        assert!(
            s.contains("epilogue thr") || s.contains("epilogue f32bn"),
            "summary missing epilogue: {s}"
        );
    }

    #[test]
    fn fold_defaults_on_and_fp_models_report_f32bn() {
        // Don't set the env var here (tests share a process); just pin the
        // unset-default and the fp-model label.
        if std::env::var("BMXNET_NO_FOLD").is_err() {
            assert!(fold_enabled());
        }
        let e = Engine::from_bmx(&lenet_model(false)).unwrap();
        assert_eq!(e.epilogue(), "f32bn");
        let e = Engine::from_bmx(&lenet_model(true)).unwrap();
        if fold_enabled() {
            assert_eq!(e.epilogue(), "thr");
        }
    }

    #[test]
    fn profile_reports_layers_in_forward_order() {
        let m = lenet_model(true);
        let e = Engine::from_bmx(&m).unwrap();
        let r = e.profile(2, 2).unwrap();
        assert_eq!(r.arch, "lenet");
        assert_eq!(r.batch, 2);
        assert_eq!(r.reps, 2);
        // reps are aggregated: each layer appears once
        let names: Vec<&str> = r.layers.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names.iter().filter(|n| **n == "conv1").count(), 1);
        assert_eq!(names.first(), Some(&"conv1"));
        assert_eq!(names.last(), Some(&"fc2"));
        assert!(r.layers.iter().any(|l| l.kind == "qconv"));
        assert!(r.layers.iter().all(|l| l.stats.reps == 2 && l.stats.min <= l.stats.median));
        assert!(r.total.median > 0.0);
        let json = r.render_json();
        let v = crate::model::json::parse(&json).unwrap();
        assert_eq!(v.get("arch").and_then(|a| a.as_str()), Some("lenet"));
    }

    #[test]
    fn accuracy_on_constant_labels() {
        let m = lenet_model(false);
        let e = Engine::from_bmx(&m).unwrap();
        let imgs = vec![0.2f32; 4 * 784];
        let preds = e.classify(&imgs, 4).unwrap();
        let labels: Vec<i32> = preds.iter().map(|(c, _)| *c as i32).collect();
        let acc = e.accuracy(&imgs, &labels, 2).unwrap();
        assert_eq!(acc, 1.0);
    }
}
