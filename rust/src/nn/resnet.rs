//! CIFAR-style ResNet-18 inference with stage-wise binarization
//! (paper §3.2 / Table 2), mirroring `python/compile/resnet.py`.

use anyhow::{bail, Context, Result};

use super::layers as L;
use super::lenet::{get_bn, get_f32};
use crate::gemm::dispatch::Method;
use crate::gemm::ChannelRule;
use crate::model::bmx::BmxModel;
use crate::obs::Profiler;
use crate::tensor::Tensor;

const NUM_STAGES: usize = 4;
const BLOCKS_PER_STAGE: usize = 2;

enum BlockConv {
    Fp(L::Conv2d),
    Bin(L::QConv2d),
}

struct Block {
    /// Stage/block label ("s1b1", ...) for profiler layer names.
    name: String,
    binary: bool,
    conv1: BlockConv,
    /// Float BN after conv1; absent when the model ships pre-folded
    /// `thr.{name}.conv1` thresholds instead of BN tensors.
    bn1: Option<L::BatchNorm>,
    /// Folded bn1+sign thresholds: conv1 runs the fused threshold
    /// epilogue and conv2 consumes its packed bits directly. bn2 feeds
    /// the residual add and stays float (not foldable).
    fold1: Option<Vec<ChannelRule>>,
    conv2: BlockConv,
    bn2: L::BatchNorm,
    down: Option<(L::Conv2d, L::BatchNorm)>,
}

/// ResNet-18 engine built from a `.bmx` model.
pub struct Resnet {
    pub width: usize,
    pub classes: usize,
    pub fp_stages: Vec<usize>,
    stem: L::Conv2d,
    stem_bn: L::BatchNorm,
    blocks: Vec<Block>,
    fc: L::Dense,
}

fn load_conv(
    m: &BmxModel,
    name: &str,
    binary: bool,
    stride: usize,
    pad: usize,
) -> Result<BlockConv> {
    if binary {
        let (s, packed) = m
            .get_packed(name)
            .with_context(|| format!("missing packed conv {name}"))?;
        Ok(BlockConv::Bin(L::QConv2d::new(
            packed.clone(),
            [s[0], s[1], s[2], s[3]],
            stride,
            pad,
        )))
    } else {
        let (s, w) = get_f32(m, &format!("params.{name}"))?;
        Ok(BlockConv::Fp(L::Conv2d::new(w, None, [s[0], s[1], s[2], s[3]], stride, pad)))
    }
}

impl Resnet {
    /// Folding follows the `BMXNET_NO_FOLD` escape hatch (see
    /// [`super::engine::fold_enabled`]).
    pub fn from_bmx(m: &BmxModel, fp_stages: &[usize]) -> Result<Self> {
        Self::from_bmx_with_fold(m, fp_stages, super::engine::fold_enabled())
    }

    /// Build with an explicit fold decision (tests use this instead of
    /// mutating the environment). Pre-folded files (with `thr.*` tensors)
    /// always run thresholds regardless of `fold`.
    pub fn from_bmx_with_fold(m: &BmxModel, fp_stages: &[usize], fold: bool) -> Result<Self> {
        let (ss, sw) = get_f32(m, "params.stem.w")?;
        let width = ss[0];
        let stem = L::Conv2d::new(sw, None, [ss[0], ss[1], ss[2], ss[3]], 1, 1);
        let stem_bn = get_bn(m, "stem_bn")?;
        let mut blocks = Vec::new();
        let mut in_ch = width;
        for s in 1..=NUM_STAGES {
            let out_ch = width * (1 << (s - 1));
            let binary = !fp_stages.contains(&s);
            for b in 1..=BLOCKS_PER_STAGE {
                let stride = if s > 1 && b == 1 { 2 } else { 1 };
                let name = format!("s{s}b{b}");
                let conv1 = load_conv(m, &format!("{name}.conv1.w"), binary, stride, 1)?;
                let conv2 = load_conv(m, &format!("{name}.conv2.w"), binary, 1, 1)?;
                let (conv1, bn1, fold1) = if binary {
                    let (bn1, fold1) =
                        if let Some(rules) = m.get_thresholds(&format!("thr.{name}.conv1")) {
                            (get_bn(m, &format!("{name}.bn1")).ok(), Some(rules.to_vec()))
                        } else {
                            let bn = get_bn(m, &format!("{name}.bn1"))?;
                            let k = match &conv1 {
                                BlockConv::Bin(q) => q.packed.k,
                                BlockConv::Fp(_) => unreachable!("binary block loads packed"),
                            };
                            let fold1 = fold.then(|| bn.fold_sign_rules(k));
                            (Some(bn), fold1)
                        };
                    let conv1 = match (fold1.is_some(), conv1) {
                        (true, BlockConv::Bin(mut q)) => {
                            q.method = Method::XnorFusedThresh;
                            BlockConv::Bin(q)
                        }
                        (_, c) => c,
                    };
                    (conv1, bn1, fold1)
                } else {
                    (conv1, Some(get_bn(m, &format!("{name}.bn1"))?), None)
                };
                let bn2 = get_bn(m, &format!("{name}.bn2"))?;
                let down = if stride != 1 || in_ch != out_ch {
                    let (ds, dw) = get_f32(m, &format!("params.{name}.down.w"))?;
                    let dconv =
                        L::Conv2d::new(dw, None, [ds[0], ds[1], ds[2], ds[3]], stride, 0);
                    Some((dconv, get_bn(m, &format!("{name}.down_bn"))?))
                } else {
                    None
                };
                blocks.push(Block { name, binary, conv1, bn1, fold1, conv2, bn2, down });
                in_ch = out_ch;
            }
        }
        let (fs, fw) = get_f32(m, "params.fc.w")?;
        let fc = L::Dense::new(fw, Some(get_f32(m, "params.fc.b")?.1), fs[0], fs[1]);
        Ok(Self {
            width,
            classes: fs[0],
            fp_stages: fp_stages.to_vec(),
            stem,
            stem_bn,
            blocks,
            fc,
        })
    }

    /// Which conv1 epilogue the binary blocks run: `"thr"` (folded
    /// integer thresholds) or `"f32bn"` (float BatchNorm then sign).
    pub fn epilogue(&self) -> &'static str {
        if self.blocks.iter().any(|b| b.fold1.is_some()) {
            "thr"
        } else {
            "f32bn"
        }
    }

    /// Forward: x (B, 3, 32, 32) -> logits (B, classes).
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        self.forward_with(x, None)
    }

    /// Forward with optional per-layer profiling (see [`Lenet::forward_with`]
    /// for the hook semantics).
    ///
    /// [`Lenet::forward_with`]: super::lenet::Lenet::forward_with
    pub fn forward_with(&self, x: &Tensor, prof: Option<&Profiler>) -> Result<Tensor> {
        use crate::obs::profiler::layer;
        if x.shape().len() != 4 || x.shape()[1] != 3 {
            bail!("resnet expects (B, 3, H, W), got {:?}", x.shape());
        }
        let bytes = x.data().len() * 4 + self.stem.w.len() * 4;
        let mut h = layer(prof, || "stem".into(), "conv_f32", Some(Method::BlockedF32), bytes, || {
            self.stem.forward(x)
        });
        let bytes = h.data().len() * 4;
        h = layer(prof, || "stem_bn".into(), "batchnorm", None, bytes, || {
            self.stem_bn.forward(&h)
        });
        h = layer(prof, || "stem_act".into(), "relu", None, bytes, || L::relu(&h));
        for blk in &self.blocks {
            h = block_forward(blk, &h, prof);
        }
        let bytes = h.data().len() * 4;
        let pooled = layer(prof, || "gap".into(), "global_avgpool", None, bytes, || {
            L::global_avgpool(&h)
        });
        let fb = pooled.data().len() * 4 + self.fc.w.len() * 4;
        Ok(layer(prof, || "fc".into(), "dense_f32", Some(Method::BlockedF32), fb, || {
            self.fc.forward(&pooled)
        }))
    }
}

/// Dispatch method a block conv resolves to (for profiler labels).
fn conv_method(c: &BlockConv) -> Method {
    match c {
        BlockConv::Fp(_) => Method::BlockedF32,
        BlockConv::Bin(q) => q.method,
    }
}

fn conv_kind(c: &BlockConv) -> &'static str {
    match c {
        BlockConv::Fp(_) => "conv_f32",
        BlockConv::Bin(_) => "qconv",
    }
}

/// Weight bytes a block conv reads per forward.
fn conv_bytes(c: &BlockConv) -> usize {
    match c {
        BlockConv::Fp(conv) => conv.w.len() * 4,
        BlockConv::Bin(q) => q.packed.words.len() * 8,
    }
}

fn conv_forward(c: &BlockConv, x: &Tensor, binary_input: bool) -> Tensor {
    match c {
        BlockConv::Fp(conv) => conv.forward(x),
        BlockConv::Bin(qconv) => {
            debug_assert!(binary_input);
            qconv.forward(x)
        }
    }
}

fn block_forward(blk: &Block, x: &Tensor, prof: Option<&Profiler>) -> Tensor {
    use crate::obs::profiler::layer;
    let nm = &blk.name;
    let mut h;
    let bytes = x.data().len() * 4;
    if blk.binary && blk.fold1.is_some() {
        // Integer tail: conv1's threshold epilogue emits packed bits
        // (bn1 + sign folded in), conv2 consumes them via bit-domain
        // im2col. No f32 tensor between the two binary convs.
        let rules = blk.fold1.as_deref().unwrap();
        let q1 = match &blk.conv1 {
            BlockConv::Bin(q) => q,
            BlockConv::Fp(_) => unreachable!("folded block is binary"),
        };
        let q2 = match &blk.conv2 {
            BlockConv::Bin(q) => q,
            BlockConv::Fp(_) => unreachable!("folded block is binary"),
        };
        let hb = layer(prof, || format!("{nm}.qact1"), "sign", None, bytes, || L::qactivation(x));
        let cb = bytes + conv_bytes(&blk.conv1);
        let bits = layer(
            prof,
            || format!("{nm}.conv1"),
            "qconv",
            Some(q1.method),
            cb,
            || q1.forward_folded(&hb, rules),
        );
        let cb = bits.rows.words.len() * 8 + conv_bytes(&blk.conv2);
        h = layer(
            prof,
            || format!("{nm}.conv2"),
            "qconv",
            Some(q2.method),
            cb,
            || q2.forward_packed(&bits),
        );
        let hbytes = h.data().len() * 4;
        h = layer(prof, || format!("{nm}.bn2"), "batchnorm", None, hbytes, || {
            blk.bn2.forward(&h)
        });
    } else if blk.binary {
        let hb = layer(prof, || format!("{nm}.qact1"), "sign", None, bytes, || L::qactivation(x));
        let cb = bytes + conv_bytes(&blk.conv1);
        h = layer(
            prof,
            || format!("{nm}.conv1"),
            conv_kind(&blk.conv1),
            Some(conv_method(&blk.conv1)),
            cb,
            || conv_forward(&blk.conv1, &hb, true),
        );
        let hbytes = h.data().len() * 4;
        let bn1 = blk.bn1.as_ref().expect("unfolded binary block requires bn1");
        h = layer(prof, || format!("{nm}.bn1"), "batchnorm", None, hbytes, || bn1.forward(&h));
        let hb = layer(prof, || format!("{nm}.qact2"), "sign", None, hbytes, || {
            L::qactivation(&h)
        });
        let cb = hbytes + conv_bytes(&blk.conv2);
        h = layer(
            prof,
            || format!("{nm}.conv2"),
            conv_kind(&blk.conv2),
            Some(conv_method(&blk.conv2)),
            cb,
            || conv_forward(&blk.conv2, &hb, true),
        );
        let hbytes = h.data().len() * 4;
        h = layer(prof, || format!("{nm}.bn2"), "batchnorm", None, hbytes, || {
            blk.bn2.forward(&h)
        });
    } else {
        let cb = bytes + conv_bytes(&blk.conv1);
        h = layer(
            prof,
            || format!("{nm}.conv1"),
            conv_kind(&blk.conv1),
            Some(conv_method(&blk.conv1)),
            cb,
            || conv_forward(&blk.conv1, x, false),
        );
        let hbytes = h.data().len() * 4;
        let bn1 = blk.bn1.as_ref().expect("fp block always has bn1");
        h = layer(prof, || format!("{nm}.bn1"), "batchnorm", None, hbytes, || bn1.forward(&h));
        h = layer(prof, || format!("{nm}.act1"), "relu", None, hbytes, || L::relu(&h));
        let cb = hbytes + conv_bytes(&blk.conv2);
        h = layer(
            prof,
            || format!("{nm}.conv2"),
            conv_kind(&blk.conv2),
            Some(conv_method(&blk.conv2)),
            cb,
            || conv_forward(&blk.conv2, &h, false),
        );
        let hbytes = h.data().len() * 4;
        h = layer(prof, || format!("{nm}.bn2"), "batchnorm", None, hbytes, || {
            blk.bn2.forward(&h)
        });
    }
    let skip = match &blk.down {
        Some((dconv, dbn)) => {
            let db = bytes + dconv.w.len() * 4;
            let d = layer(
                prof,
                || format!("{nm}.down"),
                "conv_f32",
                Some(Method::BlockedF32),
                db,
                || dconv.forward(x),
            );
            let dbb = d.data().len() * 4;
            layer(prof, || format!("{nm}.down_bn"), "batchnorm", None, dbb, || dbn.forward(&d))
        }
        None => x.clone(),
    };
    let out = L::add(&h, &skip);
    if blk.binary {
        out
    } else {
        L::relu(&out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::bmx::convert;
    use crate::model::ckpt::Checkpoint;
    use crate::model::inventory::{self, Stem};

    fn fake_ckpt(width: usize, classes: usize, fp_stages: &[usize]) -> (Checkpoint, Vec<String>) {
        let inv = inventory::resnet18(width, classes, Stem::Cifar, fp_stages);
        let mut ck = Checkpoint::new();
        let mut s = 7u64;
        for p in &inv.params {
            let data: Vec<f32> = (0..p.numel())
                .map(|_| {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                    (((s >> 33) as f32 / (1u64 << 31) as f32) - 1.0) * 0.2
                })
                .collect();
            let name = if p.name.starts_with("state.") {
                p.name.clone()
            } else {
                format!("params.{}", p.name)
            };
            let data = if name.contains(".var") {
                data.iter().map(|v| v.abs() + 0.5).collect()
            } else {
                data
            };
            ck.push_f32(&name, p.shape.clone(), data);
        }
        (ck, inv.binary_names())
    }

    #[test]
    fn fully_binary_forward() {
        let (ck, names) = fake_ckpt(8, 10, &[]);
        let m = convert(&ck, &names, "{}").unwrap();
        let net = Resnet::from_bmx(&m, &[]).unwrap();
        let x = Tensor::full(vec![2, 3, 32, 32], 0.1);
        let y = net.forward(&x).unwrap();
        assert_eq!(y.shape(), &[2, 10]);
        assert!(y.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn partially_binarized_forward() {
        let (ck, names) = fake_ckpt(8, 100, &[1, 2]);
        let m = convert(&ck, &names, "{}").unwrap();
        let net = Resnet::from_bmx(&m, &[1, 2]).unwrap();
        let x = Tensor::full(vec![1, 3, 32, 32], -0.4);
        let y = net.forward(&x).unwrap();
        assert_eq!(y.shape(), &[1, 100]);
    }

    #[test]
    fn all_fp_forward() {
        let (ck, names) = fake_ckpt(8, 10, &[1, 2, 3, 4]);
        assert!(names.is_empty());
        let m = convert(&ck, &names, "{}").unwrap();
        let net = Resnet::from_bmx(&m, &[1, 2, 3, 4]).unwrap();
        let y = net.forward(&Tensor::full(vec![1, 3, 32, 32], 0.2)).unwrap();
        assert_eq!(y.shape(), &[1, 10]);
    }

    #[test]
    fn spatial_dims_halve_through_stages() {
        // width 8, input 32x32: stage outputs 32,16,8,4 -> gap over 4x4
        let (ck, names) = fake_ckpt(8, 10, &[]);
        let m = convert(&ck, &names, "{}").unwrap();
        let net = Resnet::from_bmx(&m, &[]).unwrap();
        // must not panic on shape mismatches anywhere in the graph
        net.forward(&Tensor::full(vec![1, 3, 32, 32], 0.0)).unwrap();
    }

    #[test]
    fn profiled_forward_names_blocks() {
        let (ck, names) = fake_ckpt(8, 10, &[]);
        let m = convert(&ck, &names, "{}").unwrap();
        let net = Resnet::from_bmx(&m, &[]).unwrap();
        let prof = Profiler::new();
        net.forward_with(&Tensor::full(vec![1, 3, 32, 32], 0.1), Some(&prof)).unwrap();
        let recs = prof.take();
        let names: Vec<&str> = recs.iter().map(|r| r.name.as_str()).collect();
        for want in ["stem", "s1b1.conv1", "s4b2.conv2", "s2b1.down", "gap", "fc"] {
            assert!(names.contains(&want), "missing layer {want}");
        }
        let c = recs.iter().find(|r| r.name == "s1b1.conv1").unwrap();
        assert_eq!(c.kind, "qconv");
        assert!(c.method.is_some());
    }

    #[test]
    fn folded_logits_match_unfolded_bit_exactly() {
        let (ck, names) = fake_ckpt(8, 10, &[]);
        let m = convert(&ck, &names, "{}").unwrap();
        let folded = Resnet::from_bmx_with_fold(&m, &[], true).unwrap();
        let unfolded = Resnet::from_bmx_with_fold(&m, &[], false).unwrap();
        assert_eq!(folded.epilogue(), "thr");
        assert_eq!(unfolded.epilogue(), "f32bn");
        let data: Vec<f32> =
            (0..2 * 3 * 32 * 32).map(|i| ((i * 29 + 3) % 101) as f32 / 50.5 - 1.0).collect();
        let x = Tensor::new(vec![2, 3, 32, 32], data);
        let yf = folded.forward(&x).unwrap();
        let yu = unfolded.forward(&x).unwrap();
        assert_eq!(yf.shape(), yu.shape());
        assert_eq!(yf.data(), yu.data());
    }

    #[test]
    fn prefolded_model_file_loads_without_bn1_and_matches() {
        let (ck, names) = fake_ckpt(8, 10, &[]);
        let m = convert(&ck, &names, r#"{"arch": "resnet18"}"#).unwrap();
        let unfolded = Resnet::from_bmx_with_fold(&m, &[], false).unwrap();
        let mut mf = m.clone();
        let n = crate::model::bmx::fold_thresholds(&mut mf).unwrap();
        assert_eq!(n, NUM_STAGES * BLOCKS_PER_STAGE);
        let net = Resnet::from_bmx_with_fold(&mf, &[], false).unwrap();
        assert_eq!(net.epilogue(), "thr");
        let x = Tensor::full(vec![1, 3, 32, 32], 0.15);
        assert_eq!(net.forward(&x).unwrap().data(), unfolded.forward(&x).unwrap().data());
    }

    #[test]
    fn folded_blocks_absorb_qact2_and_bn1() {
        let (ck, names) = fake_ckpt(8, 10, &[]);
        let m = convert(&ck, &names, "{}").unwrap();
        let net = Resnet::from_bmx_with_fold(&m, &[], true).unwrap();
        let prof = Profiler::new();
        net.forward_with(&Tensor::full(vec![1, 3, 32, 32], 0.1), Some(&prof)).unwrap();
        let recs = prof.take();
        let c = recs.iter().find(|r| r.name == "s1b1.conv1").unwrap();
        assert_eq!(c.kind, "qconv");
        assert_eq!(c.method, Some("xnor_fused_thr"));
        assert!(!recs.iter().any(|r| r.name == "s1b1.qact2" || r.name == "s1b1.bn1"));
    }

    #[test]
    fn wrong_channels_rejected() {
        let (ck, names) = fake_ckpt(8, 10, &[]);
        let m = convert(&ck, &names, "{}").unwrap();
        let net = Resnet::from_bmx(&m, &[]).unwrap();
        assert!(net.forward(&Tensor::zeros(vec![1, 1, 32, 32])).is_err());
    }
}
