//! Serving-gateway scaling: offered load (closed-loop producers) swept
//! against pool worker count over the packed binary LeNet.
//!
//!     cargo bench --bench serve_scaling
//!     BENCH_JSON=out.json cargo bench --bench serve_scaling
//!
//! Thin driver over the `serve` family of `bench::suite` (synthetic
//! packed LeNet — the real xnor engine, no artifacts needed; knobs:
//! BENCH_QUICK, BENCH_REPS, BENCH_REQUESTS).  Record results in
//! EXPERIMENTS.md §Serve scaling (`BENCH_serve.json`).

use repro::bench::{run_family, SuiteOpts};

fn main() {
    let opts = SuiteOpts::from_env();
    let record = run_family("serve", &opts).expect("serve family");
    println!(
        "(closed-loop: each producer waits for its reply before sending the next; \
         req/s at fixed producers is the scaling signal as workers grow)"
    );
    if let Ok(path) = std::env::var("BENCH_JSON") {
        record.write(&path).expect("write BENCH_JSON");
        println!("recorded serve family to {path}");
    }
}
