//! Serving-gateway scaling: offered load (closed-loop producers) swept
//! against pool worker count over the converted binary LeNet.
//!
//!     cargo bench --bench serve_scaling
//!
//! Falls back to a synthetic spin-loop backend when `make artifacts` has
//! not run, so the sweep is runnable anywhere.  Record results in
//! EXPERIMENTS.md §Serve scaling (`BENCH_serve_scaling.json`).

use std::sync::Arc;
use std::time::Duration;

use repro::bench::{run_serve_scaling, serve_scaling_workloads, SyntheticBackend};
use repro::coordinator::{Backend, BatchPolicy};
use repro::model::bmx::convert;
use repro::model::ckpt::Checkpoint;
use repro::model::inventory;
use repro::nn::Engine;
use repro::runtime::Manifest;

fn main() {
    let requests: usize = std::env::var("BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let backend: Arc<dyn Backend> = match Manifest::load(repro::ARTIFACTS_DIR) {
        Ok(man) => {
            let entry = man.model("lenet_bin").unwrap();
            let ck = Checkpoint::load(man.path(&entry.init_ckpt)).unwrap();
            let names = inventory::lenet(true).binary_names();
            let bmx = convert(&ck, &names, &entry.bmx_meta()).unwrap();
            Arc::new(Engine::from_bmx(&bmx).unwrap())
        }
        Err(_) => {
            println!("(artifacts not built: sweeping over the synthetic spin backend)");
            Arc::new(SyntheticBackend { cost_per_image: Duration::from_micros(200) })
        }
    };
    let policy = BatchPolicy { max_batch: 32, window: Duration::from_millis(2) };
    run_serve_scaling(backend, &serve_scaling_workloads(requests), policy, 4096);
    println!(
        "(closed-loop: each producer waits for its reply before sending the next; \
         req/s at fixed producers is the scaling signal as workers grow)"
    );
}
