//! Figure 1: processing time of each GEMM method across input channel
//! sizes (filters 64, kernel 5×5, batch 200 — reduced to 20 by default).
//!
//!     cargo bench --bench gemm_fig1            # reduced (batch 20)
//!     BENCH_FULL=1 cargo bench --bench gemm_fig1   # paper-exact batch 200
//!
//! Paper reference (4-core i5, batch 200): naive ≈ 19,000 ms at C=512;
//! xnor_64_omp ≈ 125× over naive and ≈ 50× over Cblas; binarization
//! included still ≈ 13× over Cblas.

use repro::bench::{fig1_workloads, run_gemm_figure, write_gemm_json, GemmFigureRecord};
use repro::gemm::simd;

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let reps: usize = std::env::var("BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let ws = fig1_workloads(!full);
    let rows = run_gemm_figure(
        "Figure 1: GEMM processing time vs input channels (M=64, 5x5)",
        "C",
        &ws,
        reps,
        true,
    );
    // paper-shape summary: who wins and by what factor at C=256
    let c256 = rows.iter().find(|r| r.x == 256).expect("C=256 row");
    let labels: Vec<&str> = c256.timings.iter().map(|(l, _)| *l).collect();
    let blocked = labels.iter().position(|&l| l == "cblas").unwrap();
    let omp = labels.iter().position(|&l| l == "xnor_64_omp").unwrap();
    println!(
        "\nC=256: xnor_64_omp {:.1}x vs naive, {:.1}x vs cblas (paper: ~125x, ~50x on 4 cores)",
        c256.speedup(omp),
        c256.speedup(omp) / c256.speedup(blocked),
    );
    if !full {
        println!("(reduced batch 20; set BENCH_FULL=1 for paper-exact shapes)");
    }
    if let Ok(path) = std::env::var("BENCH_JSON") {
        let provenance = format!(
            "cargo bench gemm_fig1 · {} · kernel {} · {} · best-of-{reps}",
            std::env::consts::ARCH,
            simd::best_kernel().label(),
            if full { "paper-exact" } else { "reduced" },
        );
        let rec = GemmFigureRecord {
            figure: "fig1".into(),
            xlabel: "C".into(),
            absolute_times: true,
            rows,
        };
        write_gemm_json(&path, &provenance, &[rec]).expect("write BENCH_JSON");
        println!("recorded fig1 to {path}");
    }
}
