//! Figure 1: processing time of each GEMM method across input channel
//! sizes (filters 64, kernel 5×5, batch 200 — reduced to 20 by default).
//!
//!     cargo bench --bench gemm_fig1            # reduced (batch 20)
//!     BENCH_FULL=1 cargo bench --bench gemm_fig1   # paper-exact batch 200
//!     BENCH_JSON=out.json cargo bench --bench gemm_fig1  # perf record
//!
//! Thin driver over `bench::suite::run_gemm_figures` (also behind
//! `bmxnet bench-suite`); knobs: BENCH_FULL, BENCH_QUICK, BENCH_REPS,
//! BENCH_JSON.
//!
//! Paper reference (4-core i5, batch 200): naive ≈ 19,000 ms at C=512;
//! xnor_64_omp ≈ 125× over naive and ≈ 50× over Cblas; binarization
//! included still ≈ 13× over Cblas.

use repro::bench::{run_gemm_figures, SuiteOpts};

fn main() {
    let opts = SuiteOpts::from_env();
    let (figs, record) = run_gemm_figures(&[1], &opts).expect("figure 1");
    let rows = &figs[0].rows;
    // paper-shape summary: who wins and by what factor at C=256
    if let Some(c256) = rows.iter().find(|r| r.x == 256) {
        let labels: Vec<&str> = c256.timings.iter().map(|(l, _)| *l).collect();
        let blocked = labels.iter().position(|&l| l == "cblas").unwrap();
        let omp = labels.iter().position(|&l| l == "xnor_64_omp").unwrap();
        println!(
            "\nC=256: xnor_64_omp {:.1}x vs naive, {:.1}x vs cblas (paper: ~125x, ~50x on 4 cores)",
            c256.speedup(omp),
            c256.speedup(omp) / c256.speedup(blocked),
        );
    }
    if !opts.full {
        println!("(reduced batch 20; set BENCH_FULL=1 for paper-exact shapes)");
    }
    if let Ok(path) = std::env::var("BENCH_JSON") {
        record.write(&path).expect("write BENCH_JSON");
        println!("recorded fig1 to {path}");
    }
}
