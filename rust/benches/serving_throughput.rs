//! Serving coordinator throughput/latency + batching-policy ablation.
//!
//!     cargo bench --bench serving_throughput
//!
//! Sweeps the dynamic batcher's (max_batch, window) knobs under a closed-
//! loop multi-producer load over the converted binary LeNet — the knobs a
//! serving system tunes (DESIGN.md §Perf: batcher overhead target).

use std::sync::Arc;
use std::time::{Duration, Instant};

use repro::bench::harness::BenchTable;
use repro::coordinator::{BatchPolicy, Server, ServerConfig};
use repro::data::Kind;
use repro::model::bmx::convert;
use repro::model::ckpt::Checkpoint;
use repro::model::inventory;
use repro::nn::Engine;
use repro::runtime::Manifest;

fn main() {
    let Ok(man) = Manifest::load(repro::ARTIFACTS_DIR) else {
        println!("artifacts not built; run `make artifacts` first");
        return;
    };
    let entry = man.model("lenet_bin").unwrap();
    let ck = Checkpoint::load(man.path(&entry.init_ckpt)).unwrap();
    let names = inventory::lenet(true).binary_names();
    let engine =
        Arc::new(Engine::from_bmx(&convert(&ck, &names, &entry.bmx_meta()).unwrap()).unwrap());

    let requests: usize = std::env::var("BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let producers = 4;
    let ds = Kind::Digits.generate(requests, 19);

    let mut table = BenchTable::new(
        "Serving throughput: batching policy sweep",
        &["max_batch", "window", "req/s", "mean_batch", "p50", "p95", "p99"],
    );
    for (max_batch, window_ms) in
        [(1usize, 0u64), (8, 1), (8, 4), (32, 1), (32, 4), (32, 16)]
    {
        let server = Server::start(
            engine.clone(),
            ServerConfig {
                policy: BatchPolicy {
                    max_batch,
                    window: Duration::from_millis(window_ms),
                },
                queue_cap: 4096,
            },
        );
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for p in 0..producers {
                let client = server.client();
                let ds = &ds;
                s.spawn(move || {
                    for i in (p..requests).step_by(producers) {
                        let _ = client.classify(ds.image(i).to_vec()).unwrap();
                    }
                });
            }
        });
        let wall = t0.elapsed();
        let snap = server.shutdown();
        table.row(vec![
            max_batch.to_string(),
            format!("{window_ms}ms"),
            format!("{:.0}", requests as f64 / wall.as_secs_f64()),
            format!("{:.1}", snap.mean_batch),
            format!("{:.1}ms", snap.p50.as_secs_f64() * 1e3),
            format!("{:.1}ms", snap.p95.as_secs_f64() * 1e3),
            format!("{:.1}ms", snap.p99.as_secs_f64() * 1e3),
        ]);
    }
    table.print();
    println!(
        "(closed-loop, {producers} producers, {requests} requests; \
         batch=1/window=0 row is the no-batching baseline)"
    );
}
