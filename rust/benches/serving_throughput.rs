//! Serving coordinator throughput/latency + batching-policy ablation.
//!
//!     cargo bench --bench serving_throughput
//!     BENCH_JSON=out.json cargo bench --bench serving_throughput
//!
//! Thin driver over the `serve_policy` family of `bench::suite`: sweeps
//! the dynamic batcher's (max_batch, window) knobs under a closed-loop
//! multi-producer load over the packed binary LeNet (DESIGN.md §Perf:
//! batcher overhead target).  Knobs: BENCH_QUICK, BENCH_REPS,
//! BENCH_REQUESTS.

use repro::bench::{run_family, SuiteOpts};

fn main() {
    let opts = SuiteOpts::from_env();
    let record = run_family("serve_policy", &opts).expect("serve_policy family");
    if let Ok(path) = std::env::var("BENCH_JSON") {
        record.write(&path).expect("write BENCH_JSON");
        println!("recorded serve_policy family to {path}");
    }
}
