//! Binary-engine inference latency/throughput + GEMM-method ablation.
//!
//!     cargo bench --bench engine_inference
//!
//! Measures the deployed path (the role of the paper's mobile apps):
//! converted `.bmx` LeNet and mini-ResNet classified by the Rust xnor
//! engine at several batch sizes, plus an ablation over the xnor kernel
//! variant used inside QConv/QFC (DESIGN.md calls this design choice out).

use repro::bench::harness::{time_best_of, BenchTable};
use repro::data::Kind;
use repro::gemm::{xnor_gemm_prepacked, Method, PackedMatrix, Side};
use repro::model::bmx::convert;
use repro::model::ckpt::Checkpoint;
use repro::model::inventory::{self, Stem};
use repro::nn::Engine;
use repro::runtime::Manifest;
use repro::tensor::Tensor;

fn main() {
    let Ok(man) = Manifest::load(repro::ARTIFACTS_DIR) else {
        println!("artifacts not built; run `make artifacts` first");
        return;
    };
    let reps: usize = std::env::var("BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);

    let mut table = BenchTable::new(
        "Engine inference (rust xnor path)",
        &["model", "batch", "ms/batch", "img/s"],
    );
    for (model, kind) in [
        ("lenet_bin", Kind::Digits),
        ("lenet_fp", Kind::Digits),
        ("resnet_mini_bin", Kind::Cifar),
    ] {
        let entry = man.model(model).unwrap();
        let ck = Checkpoint::load(man.path(&entry.init_ckpt)).unwrap();
        let names = match entry.arch.as_str() {
            "lenet" if model == "lenet_bin" => inventory::lenet(true).binary_names(),
            "resnet18" => {
                let width = entry.raw.get("width").and_then(|v| v.as_usize()).unwrap();
                inventory::resnet18(width, entry.classes, Stem::Cifar, &entry.fp_stages())
                    .binary_names()
            }
            _ => vec![],
        };
        let engine = Engine::from_bmx(&convert(&ck, &names, &entry.bmx_meta()).unwrap()).unwrap();
        for batch in [1usize, 8, 32] {
            let ds = kind.generate(batch, 3);
            let [c, h, w] = engine.input_shape();
            let x = Tensor::new(vec![batch, c, h, w], ds.images.clone());
            let d = time_best_of(reps, || engine.forward(&x).unwrap());
            table.row(vec![
                model.into(),
                batch.to_string(),
                format!("{:.2}", d.as_secs_f64() * 1e3),
                format!("{:.0}", batch as f64 / d.as_secs_f64()),
            ]);
        }
    }
    table.print();

    // Ablation: xnor kernel variant on the LeNet QConv2 workload
    // (rows = batch*8*8 im2col rows, K = 32*5*5 = 800, N = 64 filters).
    let mut ab = BenchTable::new(
        "Ablation: xnor kernel variant on the QConv2 GEMM (b=32)",
        &["method", "us/call", "speedup vs xnor_32"],
    );
    let (m, n, k) = (32 * 64, 64, 800);
    let mut rng = repro::data::Rng::new(5);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let pa = PackedMatrix::pack_rows(&a, m, k, Side::A);
    let pb = PackedMatrix::pack_cols(&b, k, n);
    let mut base = None;
    for method in Method::available().into_iter().filter(|m| m.is_binary()) {
        let d = time_best_of(reps, || xnor_gemm_prepacked(method, &pa, &pb));
        let us = d.as_secs_f64() * 1e6;
        let b0 = *base.get_or_insert(us);
        ab.row(vec![
            method.label().into(),
            format!("{us:.0}"),
            format!("{:.2}x", b0 / us),
        ]);
    }
    ab.print();
}
