//! Binary-engine inference latency/throughput + GEMM-method ablation.
//!
//!     cargo bench --bench engine_inference
//!     BENCH_JSON=out.json cargo bench --bench engine_inference
//!
//! Thin driver over the `engine` family of `bench::suite` (synthetic
//! packed LeNets — runs without artifacts; knobs: BENCH_QUICK,
//! BENCH_REPS).  When `make artifacts` has been run, the converted real
//! models are additionally timed as a cross-check.

use repro::bench::{run_family, time_stats, BenchTable, SuiteOpts};
use repro::data::Kind;
use repro::model::bmx::convert;
use repro::model::ckpt::Checkpoint;
use repro::model::inventory::{self, Stem};
use repro::nn::Engine;
use repro::runtime::Manifest;
use repro::tensor::Tensor;

fn main() {
    let opts = SuiteOpts::from_env();
    let record = run_family("engine", &opts).expect("engine family");
    if let Ok(path) = std::env::var("BENCH_JSON") {
        record.write(&path).expect("write BENCH_JSON");
        println!("recorded engine family to {path}");
    }

    // Artifact cross-check: the converted real models (trained-shape
    // checkpoints), same protocol, not part of the comparable record.
    let Ok(man) = Manifest::load(repro::ARTIFACTS_DIR) else {
        println!("(artifacts not built; converted-model cross-check skipped)");
        return;
    };
    let reps = if opts.reps > 0 { opts.reps } else { 3 };
    let mut table = BenchTable::new(
        "Cross-check: converted artifact models (rust xnor path)",
        &["model", "batch", "ms/batch", "img/s"],
    );
    for (model, kind) in [
        ("lenet_bin", Kind::Digits),
        ("lenet_fp", Kind::Digits),
        ("resnet_mini_bin", Kind::Cifar),
    ] {
        let entry = man.model(model).unwrap();
        let ck = Checkpoint::load(man.path(&entry.init_ckpt)).unwrap();
        let names = match entry.arch.as_str() {
            "lenet" if model == "lenet_bin" => inventory::lenet(true).binary_names(),
            "resnet18" => {
                let width = entry.raw.get("width").and_then(|v| v.as_usize()).unwrap();
                inventory::resnet18(width, entry.classes, Stem::Cifar, &entry.fp_stages())
                    .binary_names()
            }
            _ => vec![],
        };
        let engine = Engine::from_bmx(&convert(&ck, &names, &entry.bmx_meta()).unwrap()).unwrap();
        for batch in [1usize, 8, 32] {
            let ds = kind.generate(batch, 3);
            let [c, h, w] = engine.input_shape();
            let x = Tensor::new(vec![batch, c, h, w], ds.images.clone());
            let s = time_stats(reps, || engine.forward(&x).unwrap());
            table.row(vec![
                model.into(),
                batch.to_string(),
                format!("{:.2}", s.median),
                format!("{:.0}", batch as f64 / (s.median / 1e3).max(1e-9)),
            ]);
        }
    }
    table.print();
}
