//! Figure 3: speedup over the naive GEMM while varying the kernel size
//! (input channels 256, filters 64, batch 200 → reduced 20).
//!
//!     cargo bench --bench gemm_fig3
//!     BENCH_FULL=1 cargo bench --bench gemm_fig3

use repro::bench::{fig3_workloads, run_gemm_figure, write_gemm_json, GemmFigureRecord};
use repro::gemm::simd;

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let reps: usize = std::env::var("BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let ws = fig3_workloads(!full);
    let rows = run_gemm_figure(
        "Figure 3: speedup vs naive, varying kernel size (C=256, filters=64)",
        "kernel",
        &ws,
        reps,
        false,
    );
    let omp = rows[0].timings.iter().position(|(l, _)| *l == "xnor_64_omp").unwrap();
    println!(
        "\nxnor_64_omp speedup: {:.1}x @ 1x1 -> {:.1}x @ 8x8 \
         (paper: grows with K = k^2 * C)",
        rows.first().unwrap().speedup(omp),
        rows.last().unwrap().speedup(omp)
    );
    if let Ok(path) = std::env::var("BENCH_JSON") {
        let provenance = format!(
            "cargo bench gemm_fig3 · {} · kernel {} · {} · best-of-{reps}",
            std::env::consts::ARCH,
            simd::best_kernel().label(),
            if full { "paper-exact" } else { "reduced" },
        );
        let rec = GemmFigureRecord {
            figure: "fig3".into(),
            xlabel: "kernel".into(),
            absolute_times: false,
            rows,
        };
        write_gemm_json(&path, &provenance, &[rec]).expect("write BENCH_JSON");
        println!("recorded fig3 to {path}");
    }
}
