//! Figure 3: speedup over the naive GEMM while varying the kernel size
//! (input channels 256, filters 64, batch 200 → reduced 20).
//!
//!     cargo bench --bench gemm_fig3
//!     BENCH_FULL=1 cargo bench --bench gemm_fig3
//!
//! Thin driver over `bench::suite::run_gemm_figures`; knobs: BENCH_FULL,
//! BENCH_QUICK, BENCH_REPS, BENCH_JSON.

use repro::bench::{run_gemm_figures, SuiteOpts};

fn main() {
    let opts = SuiteOpts::from_env();
    let (figs, record) = run_gemm_figures(&[3], &opts).expect("figure 3");
    let rows = &figs[0].rows;
    let omp = rows[0].timings.iter().position(|(l, _)| *l == "xnor_64_omp").unwrap();
    println!(
        "\nxnor_64_omp speedup: {:.1}x @ {}x{} -> {:.1}x @ {}x{} \
         (paper: grows with K = k^2 * C)",
        rows.first().unwrap().speedup(omp),
        rows.first().unwrap().x,
        rows.first().unwrap().x,
        rows.last().unwrap().speedup(omp),
        rows.last().unwrap().x,
        rows.last().unwrap().x
    );
    if let Ok(path) = std::env::var("BENCH_JSON") {
        record.write(&path).expect("write BENCH_JSON");
        println!("recorded fig3 to {path}");
    }
}
