//! Table 2 (model-size ladder): ResNet-18 on ImageNet with stage-wise
//! partial binarization — byte-exact size accounting for every row of the
//! paper's table, plus the converter cross-check on the mini artifacts.
//!
//!     cargo bench --bench table2_partial
//!     BENCH_JSON=out.json cargo bench --bench table2_partial
//!
//! Thin driver over the `tables` family of `bench::suite` (Tables 1 and 2
//! are one family: byte-exact cells, zero noise floor).  Paper reference
//! sizes: none 3.6 MB · 1st 4.1 · 2nd 5.6 · 3rd 11.3 · 4th 36 ·
//! 1st+2nd 6.2 · all 47 MB.  The accuracy trend columns come from
//! training the mini variants (`--example table_accuracy`).

use repro::bench::{run_family, BenchTable, SuiteOpts};
use repro::model::bmx::convert;
use repro::model::ckpt::Checkpoint;
use repro::model::inventory::{self, Stem};
use repro::runtime::Manifest;

fn main() {
    let record = run_family("tables", &SuiteOpts::from_env()).expect("tables family");
    if let Ok(path) = std::env::var("BENCH_JSON") {
        record.write(&path).expect("write BENCH_JSON");
        println!("recorded tables family to {path}");
    }

    // Converter cross-check on the trainable mini variants.
    if let Ok(man) = Manifest::load(repro::ARTIFACTS_DIR) {
        let mut t2 = BenchTable::new(
            "Mini (width 16, 100-class) converted sizes — same ordering",
            &["config", ".bmx bytes"],
        );
        for cfg in ["none", "fp1", "fp2", "fp3", "fp4", "fp12", "all"] {
            let name = format!("resnet_mini_img_{cfg}");
            let entry = man.model(&name).unwrap();
            let ck = Checkpoint::load(man.path(&entry.init_ckpt)).unwrap();
            let width = entry.raw.get("width").and_then(|v| v.as_usize()).unwrap();
            let names =
                inventory::resnet18(width, entry.classes, Stem::Cifar, &entry.fp_stages())
                    .binary_names();
            let bmx = convert(&ck, &names, &entry.bmx_meta()).unwrap();
            t2.row(vec![cfg.into(), bmx.payload_bytes().to_string()]);
        }
        t2.print();
    } else {
        println!("(artifacts not built; mini cross-check skipped)");
    }
}
