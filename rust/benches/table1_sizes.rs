//! Table 1 (model-size columns): LeNet on MNIST and ResNet-18 on CIFAR-10,
//! binary vs full precision, with byte-exact converter measurements.
//!
//!     cargo bench --bench table1_sizes
//!     BENCH_JSON=out.json cargo bench --bench table1_sizes
//!
//! Thin driver over the `tables` family of `bench::suite` (prints the
//! Table 1 and Table 2 accounting; cells are exact byte counts with a
//! zero noise floor, so `bench-compare` flags any converter/inventory
//! change).  Paper reference: LeNet 206 kB / 4.6 MB; ResNet-18 1.5 MB /
//! 44.7 MB (29×).  The accuracy columns are produced by the training
//! examples (`cargo run --release --example table_accuracy`) — see
//! EXPERIMENTS.md.

use repro::bench::{run_family, BenchTable, SuiteOpts};
use repro::model::bmx::convert;
use repro::model::ckpt::Checkpoint;
use repro::model::inventory;
use repro::runtime::Manifest;

fn main() {
    let record = run_family("tables", &SuiteOpts::from_env()).expect("tables family");
    if let Ok(path) = std::env::var("BENCH_JSON") {
        record.write(&path).expect("write BENCH_JSON");
        println!("recorded tables family to {path}");
    }

    // Converter cross-check on the real artifacts (trained-shape ckpts).
    if let Ok(man) = Manifest::load(repro::ARTIFACTS_DIR) {
        let mut t2 = BenchTable::new(
            "Converter cross-check (measured .bmx payload bytes)",
            &["model", "predicted", "measured", "match"],
        );
        for (name, inv) in [
            ("lenet_bin", inventory::lenet(true)),
            ("lenet_fp", inventory::lenet(false)),
        ] {
            let entry = man.model(name).unwrap();
            let ck = Checkpoint::load(man.path(&entry.init_ckpt)).unwrap();
            let names = if name == "lenet_bin" { inv.binary_names() } else { vec![] };
            let bmx = convert(&ck, &names, &entry.bmx_meta()).unwrap();
            let predicted = if name == "lenet_bin" { inv.bmx_bytes() } else { inv.fp32_bytes() };
            t2.row(vec![
                name.into(),
                predicted.to_string(),
                bmx.payload_bytes().to_string(),
                (predicted == bmx.payload_bytes()).to_string(),
            ]);
        }
        t2.print();
    } else {
        println!("(artifacts not built; converter cross-check skipped)");
    }
}
