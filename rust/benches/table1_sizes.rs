//! Table 1 (model-size columns): LeNet on MNIST and ResNet-18 on CIFAR-10,
//! binary vs full precision, with byte-exact converter measurements.
//!
//!     cargo bench --bench table1_sizes
//!
//! Paper reference: LeNet 206 kB / 4.6 MB; ResNet-18 1.5 MB / 44.7 MB (29×).
//! The accuracy columns are produced by the training examples
//! (`cargo run --release --example table_accuracy`) — see EXPERIMENTS.md.

use repro::bench::harness::BenchTable;
use repro::model::bmx::convert;
use repro::model::ckpt::Checkpoint;
use repro::model::inventory::{self, Stem};
use repro::runtime::Manifest;

const MB: f64 = 1024.0 * 1024.0;
const KB: f64 = 1024.0;

fn main() {
    let mut table = BenchTable::new(
        "Table 1: model sizes (binary / full precision)",
        &["dataset", "arch", "binary", "fp32", "ratio", "paper"],
    );

    // LeNet — exact inventory accounting.
    let lenet_bin = inventory::lenet(true);
    let lenet_fp = inventory::lenet(false);
    table.row(vec![
        "MNIST".into(),
        "LeNet".into(),
        format!("{:.0} kB", lenet_bin.bmx_bytes() as f64 / KB),
        format!("{:.1} MB", lenet_fp.fp32_bytes() as f64 / MB),
        format!("{:.1}x", lenet_fp.fp32_bytes() as f64 / lenet_bin.bmx_bytes() as f64),
        "206kB / 4.6MB".into(),
    ]);

    // ResNet-18 (real width 64) — exact inventory accounting.
    let rn_bin = inventory::resnet18(64, 10, Stem::Cifar, &[]);
    let rn_fp = inventory::resnet18(64, 10, Stem::Cifar, &[1, 2, 3, 4]);
    table.row(vec![
        "CIFAR-10".into(),
        "ResNet-18".into(),
        format!("{:.1} MB", rn_bin.bmx_bytes() as f64 / MB),
        format!("{:.1} MB", rn_fp.fp32_bytes() as f64 / MB),
        format!("{:.1}x", rn_fp.fp32_bytes() as f64 / rn_bin.bmx_bytes() as f64),
        "1.5MB / 44.7MB (29x)".into(),
    ]);
    table.print();

    // Converter cross-check on the real artifacts (trained-shape ckpts).
    if let Ok(man) = Manifest::load(repro::ARTIFACTS_DIR) {
        let mut t2 = BenchTable::new(
            "Converter cross-check (measured .bmx payload bytes)",
            &["model", "predicted", "measured", "match"],
        );
        for (name, inv) in [
            ("lenet_bin", inventory::lenet(true)),
            ("lenet_fp", inventory::lenet(false)),
        ] {
            let entry = man.model(name).unwrap();
            let ck = Checkpoint::load(man.path(&entry.init_ckpt)).unwrap();
            let names = if name == "lenet_bin" { inv.binary_names() } else { vec![] };
            let bmx = convert(&ck, &names, &entry.bmx_meta()).unwrap();
            let predicted = if name == "lenet_bin" { inv.bmx_bytes() } else { inv.fp32_bytes() };
            t2.row(vec![
                name.into(),
                predicted.to_string(),
                bmx.payload_bytes().to_string(),
                (predicted == bmx.payload_bytes()).to_string(),
            ]);
        }
        t2.print();
    } else {
        println!("(artifacts not built; converter cross-check skipped)");
    }
}
