//! Figure 2: speedup over the naive GEMM while varying the convolution's
//! filter number (input channels 256, kernel 5×5, batch 200 → reduced 20).
//!
//!     cargo bench --bench gemm_fig2
//!     BENCH_FULL=1 cargo bench --bench gemm_fig2
//!
//! Thin driver over `bench::suite::run_gemm_figures`; knobs: BENCH_FULL,
//! BENCH_QUICK, BENCH_REPS, BENCH_JSON.

use repro::bench::{run_gemm_figures, SuiteOpts};

fn main() {
    let opts = SuiteOpts::from_env();
    let (figs, record) = run_gemm_figures(&[2], &opts).expect("figure 2");
    let rows = &figs[0].rows;
    // paper shape: speedup grows with filter count (better A-row reuse)
    let omp = rows[0].timings.iter().position(|(l, _)| *l == "xnor_64_omp").unwrap();
    println!(
        "\nxnor_64_omp speedup: {:.1}x @ {} filters -> {:.1}x @ {} filters \
         (paper: rises with filter number)",
        rows.first().unwrap().speedup(omp),
        rows.first().unwrap().x,
        rows.last().unwrap().speedup(omp),
        rows.last().unwrap().x
    );
    if let Ok(path) = std::env::var("BENCH_JSON") {
        record.write(&path).expect("write BENCH_JSON");
        println!("recorded fig2 to {path}");
    }
}
