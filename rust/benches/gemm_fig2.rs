//! Figure 2: speedup over the naive GEMM while varying the convolution's
//! filter number (input channels 256, kernel 5×5, batch 200 → reduced 20).
//!
//!     cargo bench --bench gemm_fig2
//!     BENCH_FULL=1 cargo bench --bench gemm_fig2

use repro::bench::{fig2_workloads, run_gemm_figure, write_gemm_json, GemmFigureRecord};
use repro::gemm::simd;

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let reps: usize = std::env::var("BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let ws = fig2_workloads(!full);
    let rows = run_gemm_figure(
        "Figure 2: speedup vs naive, varying filter number (C=256, 5x5)",
        "filters",
        &ws,
        reps,
        false,
    );
    // paper shape: speedup grows with filter count (better A-row reuse)
    let omp = rows[0].timings.iter().position(|(l, _)| *l == "xnor_64_omp").unwrap();
    let first = rows.first().unwrap().speedup(omp);
    let last = rows.last().unwrap().speedup(omp);
    println!(
        "\nxnor_64_omp speedup: {first:.1}x @ {} filters -> {last:.1}x @ {} filters \
         (paper: rises with filter number)",
        rows.first().unwrap().x,
        rows.last().unwrap().x
    );
    if let Ok(path) = std::env::var("BENCH_JSON") {
        let provenance = format!(
            "cargo bench gemm_fig2 · {} · kernel {} · {} · best-of-{reps}",
            std::env::consts::ARCH,
            simd::best_kernel().label(),
            if full { "paper-exact" } else { "reduced" },
        );
        let rec = GemmFigureRecord {
            figure: "fig2".into(),
            xlabel: "filters".into(),
            absolute_times: false,
            rows,
        };
        write_gemm_json(&path, &provenance, &[rec]).expect("write BENCH_JSON");
        println!("recorded fig2 to {path}");
    }
}
