//! Gateway connection scaling: closed-loop keep-alive HTTP connections
//! (binary `x-bmx-f32` bodies) swept against the reactor gateway over
//! real loopback TCP.
//!
//!     cargo bench --bench serve_conns
//!     BENCH_JSON=out.json cargo bench --bench serve_conns
//!
//! Thin driver over the `serve_conns` family of `bench::suite` (knobs:
//! BENCH_QUICK, BENCH_REPS, BENCH_REQUESTS).  Record results in
//! EXPERIMENTS.md §Gateway connection scaling (`BENCH_serve_conns.json`).

use repro::bench::{run_family, SuiteOpts};

fn main() {
    let opts = SuiteOpts::from_env();
    let record = run_family("serve_conns", &opts).expect("serve_conns family");
    println!(
        "(closed-loop: each connection waits for its reply before sending the next; \
         req/s and p99 as connections grow is the reactor-scaling signal)"
    );
    if let Ok(path) = std::env::var("BENCH_JSON") {
        record.write(&path).expect("write BENCH_JSON");
        println!("recorded serve_conns family to {path}");
    }
}
