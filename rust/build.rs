//! Build-time provenance capture for perf records (bench/record.rs).
//!
//! Emits two env vars compiled into the binary via `option_env!`:
//! `BMXNET_RUSTC_VERSION` (the exact compiler that produced this build)
//! and `BMXNET_GIT_DESCRIBE` (commit id + dirty marker of the source
//! tree).  Perf numbers are meaningless without the binary's identity —
//! `Provenance::capture` stamps both into every `PerfRecord`.
//!
//! Both probes degrade to absence (not failure) when the tool is missing
//! or the checkout has no `.git`: `option_env!` then yields `None` and
//! the record says `unknown`.  A build script must never be the reason
//! tier-1 fails.

use std::process::Command;

fn probe(cmd: &str, args: &[&str]) -> Option<String> {
    let out = Command::new(cmd).args(args).output().ok()?;
    if !out.status.success() {
        return None;
    }
    let text = String::from_utf8(out.stdout).ok()?;
    let line = text.lines().next()?.trim().to_string();
    if line.is_empty() {
        None
    } else {
        Some(line)
    }
}

fn main() {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    if let Some(v) = probe(&rustc, &["--version"]) {
        println!("cargo:rustc-env=BMXNET_RUSTC_VERSION={v}");
    }
    // --always falls back to the bare commit id when no tag exists;
    // --dirty marks uncommitted changes so a record can't masquerade as
    // a clean build of some commit.
    if let Some(v) = probe("git", &["describe", "--always", "--dirty", "--tags"]) {
        println!("cargo:rustc-env=BMXNET_GIT_DESCRIBE={v}");
    }
    // Re-run when HEAD moves so the stamp tracks the checkout, without
    // forcing a rebuild on every unrelated file change.
    println!("cargo:rerun-if-changed=../.git/HEAD");
    println!("cargo:rerun-if-changed=build.rs");
}
