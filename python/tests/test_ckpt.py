"""BMXC checkpoint format roundtrip + manifest sanity."""

import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import ckpt

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 5),
    seed=st.integers(0, 1000),
)
def test_ckpt_roundtrip(tmp_path_factory, n, seed):
    rng = np.random.default_rng(seed)
    tensors = []
    for i in range(n):
        ndim = int(rng.integers(0, 4))
        shape = tuple(int(d) for d in rng.integers(1, 5, ndim))
        if rng.random() < 0.5:
            arr = rng.standard_normal(shape).astype(np.float32)
        else:
            arr = rng.integers(0, 2**32, shape, dtype=np.uint32)
        tensors.append((f"t{i}.x", arr))
    path = str(tmp_path_factory.mktemp("ck") / "t.bmxc")
    ckpt.save(path, tensors)
    back = ckpt.load(path)
    assert [n for n, _ in back] == [n for n, _ in tensors]
    for (_, a), (_, b) in zip(tensors, back):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)


def test_ckpt_rejects_bad_magic(tmp_path):
    p = tmp_path / "bad.bmxc"
    p.write_bytes(b"NOPE" + b"\x00" * 16)
    with pytest.raises(ValueError, match="bad magic"):
        ckpt.load(str(p))


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_consistent_with_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    for name, entry in man["models"].items():
        assert os.path.exists(os.path.join(ART, entry["init_ckpt"])), name
        assert os.path.exists(os.path.join(ART, entry["train"]["file"]))
        for inf in entry["infer"]:
            assert os.path.exists(os.path.join(ART, inf["file"]))
        # init ckpt matches declared param/state inventory
        tensors = dict(ckpt.load(os.path.join(ART, entry["init_ckpt"])))
        for pname, shape in entry["params"]:
            assert tuple(shape) == tensors[f"params.{pname}"].shape, pname
        for sname, shape in entry["state"]:
            assert tuple(shape) == tensors[f"state.{sname}"].shape, sname
    for kname, kentry in man["kernels"].items():
        assert os.path.exists(os.path.join(ART, kentry["file"])), kname
