"""L2 model tests: shapes, STE gradients, Eq.2 layer equivalence,
Pallas-forward equality, and loss-decreases training smoke tests."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L
from compile import lenet, model, resnet
from compile import train as T

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# STE + Q-layers
# ---------------------------------------------------------------------------

def test_ste_sign_forward_and_grad():
    x = jnp.asarray([-2.0, -0.5, 0.0, 0.5, 2.0])
    y, vjp = jax.vjp(L.ste_sign, x)
    np.testing.assert_array_equal(np.asarray(y), [-1, -1, 1, 1, 1])
    (g,) = vjp(jnp.ones_like(x))
    # gradient passes where |x| <= 1, clipped outside
    np.testing.assert_array_equal(np.asarray(g), [0, 1, 1, 1, 0])


def test_ste_round_identity_grad():
    x = jnp.asarray([0.2, 0.7, 1.4])
    y, vjp = jax.vjp(L.ste_round, x)
    np.testing.assert_array_equal(np.asarray(y), [0, 1, 1])
    (g,) = vjp(jnp.asarray([3.0, 4.0, 5.0]))
    np.testing.assert_array_equal(np.asarray(g), [3, 4, 5])


@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_qactivation_output_alphabet(k):
    x = jax.random.normal(jax.random.PRNGKey(1), (64,)) * 2
    y = np.asarray(L.qactivation(x, k))
    if k == 1:
        assert set(np.unique(y)) <= {-1.0, 1.0}
    else:
        levels = (1 << k) - 1
        np.testing.assert_allclose(y * levels, np.round(y * levels),
                                   atol=1e-5)
        assert y.min() >= 0.0 and y.max() <= 1.0


def test_qdense_equals_binarized_dense():
    """QFC == plain dot on sign-binarized weights/inputs (§2.2.2)."""
    p = L.init_dense(KEY, 37, 11, bias=False)
    x = jax.random.normal(jax.random.PRNGKey(2), (5, 37))
    xb = L.ste_sign(x)
    got = L.qdense(p, xb)
    expect = xb @ jnp.where(p["w"] >= 0, 1.0, -1.0).T
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


def test_qconv_equals_binarized_conv():
    p = L.init_conv(KEY, 8, 4, 3, bias=False)
    x = L.ste_sign(jax.random.normal(jax.random.PRNGKey(3), (2, 8, 9, 9)))
    got = L.qconv2d(p, x, padding="VALID")
    pb = {"w": jnp.where(p["w"] >= 0, 1.0, -1.0),
          "b": jnp.zeros(4)}
    expect = L.conv2d(pb, x, padding="VALID")
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=0, atol=1e-4)


def test_xnor_conv2d_pallas_matches_qconv():
    """The L1-composed conv equals the L2 float-path conv exactly."""
    p = L.init_conv(KEY, 8, 6, 5, bias=False)
    x = L.ste_sign(jax.random.normal(jax.random.PRNGKey(4), (2, 8, 12, 12)))
    got = model.xnor_conv2d_pallas(p, x, padding="VALID")
    pb = {"w": jnp.where(p["w"] >= 0, 1.0, -1.0), "b": jnp.zeros(6)}
    expect = L.conv2d(pb, x, padding="VALID")
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=0, atol=1e-3)


# ---------------------------------------------------------------------------
# batchnorm
# ---------------------------------------------------------------------------

def test_batchnorm_train_normalizes():
    p, s = L.init_bn(4)
    x = jax.random.normal(jax.random.PRNGKey(5), (64, 4, 6, 6)) * 3 + 2
    y, ns = L.batchnorm(p, x, s, train=True)
    yn = np.asarray(y)
    np.testing.assert_allclose(yn.mean(axis=(0, 2, 3)), 0, atol=1e-4)
    np.testing.assert_allclose(yn.std(axis=(0, 2, 3)), 1, atol=1e-2)
    # EMA moved toward batch stats
    assert np.all(np.asarray(ns["mean"]) != np.asarray(s["mean"]))


def test_batchnorm_eval_uses_running_stats():
    p, s = L.init_bn(3)
    s = {"mean": jnp.asarray([1.0, 2.0, 3.0]), "var": jnp.ones(3) * 4}
    x = jnp.ones((2, 3, 2, 2))
    y, ns = L.batchnorm(p, x, s, train=False)
    expect = (1.0 - np.asarray([1, 2, 3])) / np.sqrt(4 + L.BN_EPS)
    np.testing.assert_allclose(np.asarray(y)[0, :, 0, 0], expect, rtol=1e-5)
    assert ns is s


# ---------------------------------------------------------------------------
# LeNet / ResNet shapes + training smoke
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("binary", [True, False])
def test_lenet_shapes(binary):
    params, state, _ = lenet.init(KEY, binary=binary)
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 1, 28, 28))
    logits, ns = lenet.forward(params, state, x, binary=binary, train=True)
    assert logits.shape == (4, 10)
    assert set(ns) == set(state)


@pytest.mark.parametrize("fp_stages", [frozenset(), frozenset({1, 2, 3, 4}),
                                       frozenset({1, 2})])
def test_resnet_shapes(fp_stages):
    params, state, _ = resnet.init(KEY, fp_stages=fp_stages, width=8,
                                   classes=10)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 3, 32, 32))
    logits, _ = resnet.forward(params, state, x, fp_stages=fp_stages)
    assert logits.shape == (2, 10)


def test_flatten_unflatten_roundtrip():
    params, state, _ = lenet.init(KEY, binary=True)
    flat = T.flatten_tree(params)
    names = [n for n, _ in flat]
    assert names == sorted(names)
    rebuilt = T.unflatten_like(params, [a for _, a in flat])
    for (n1, a1), (n2, a2) in zip(T.flatten_tree(rebuilt), flat):
        assert n1 == n2
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def _run_steps(fwd, params, state, n_steps, batch, in_shape, classes, lr):
    step = jax.jit(T.make_train_step(fwd, params, state))
    p_flat = [a for _, a in T.flatten_tree(params)]
    s_flat = [a for _, a in T.flatten_tree(state)]
    m_flat = [jnp.zeros_like(a) for a in p_flat]
    rng = np.random.default_rng(0)
    losses = []
    for i in range(n_steps):
        # Learnable synthetic task: class = argmax of per-class mean mask.
        y = rng.integers(0, classes, batch).astype(np.int32)
        x = rng.standard_normal((batch, *in_shape)).astype(np.float32) * 0.1
        x[np.arange(batch), 0, y % in_shape[1], :] += 2.0
        out = step(*p_flat, *s_flat, *m_flat,
                   jnp.asarray(x), jnp.asarray(y), jnp.float32(lr))
        n_p, n_s = len(p_flat), len(s_flat)
        p_flat = list(out[:n_p])
        s_flat = list(out[n_p:n_p + n_s])
        m_flat = list(out[n_p + n_s:2 * n_p + n_s])
        losses.append(float(out[-2]))
    return losses


def test_binary_lenet_loss_decreases():
    params, state, _ = lenet.init(KEY, binary=True)
    fwd = lambda p, s, x, train=False: lenet.forward(
        p, s, x, binary=True, train=train)
    losses = _run_steps(fwd, params, state, 30, 16, (1, 28, 28), 10, 0.05)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.9, losses


def test_fp_lenet_loss_decreases():
    params, state, _ = lenet.init(KEY, binary=False)
    fwd = lambda p, s, x, train=False: lenet.forward(
        p, s, x, binary=False, train=train)
    losses = _run_steps(fwd, params, state, 30, 16, (1, 28, 28), 10, 0.05)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.9, losses


def test_pallas_forward_matches_plain_forward():
    """L1-composed LeNet inference == plain L2 inference, bit-for-bit on
    the binary layers (tiny float tolerance from BN arithmetic order)."""
    params, state, _ = lenet.init(KEY, binary=True)
    x = jax.random.normal(jax.random.PRNGKey(8), (4, 1, 28, 28))
    plain, _ = lenet.forward(params, state, x, binary=True, train=False)
    pallas, _ = model.lenet_forward_pallas(params, state, x)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(pallas),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("act_bit", [2, 4])
def test_kbit_lenet_forward_and_weight_alphabet(act_bit):
    """paper §2.1: act_bit > 1 uses Eq.1-quantized weights/activations."""
    params, state, _ = lenet.init(KEY, binary=True, act_bit=act_bit)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 1, 28, 28))
    logits, _ = lenet.forward(params, state, x, binary=True,
                              act_bit=act_bit, train=False)
    assert logits.shape == (2, 10)
    wq = np.asarray(L.quantize_weights(params["conv2"]["w"], act_bit))
    levels = np.unique(wq)
    assert len(levels) <= (1 << act_bit)
    assert wq.min() >= -1.0 and wq.max() <= 1.0


def test_kbit_lenet_loss_decreases():
    params, state, _ = lenet.init(KEY, binary=True, act_bit=2)
    fwd = lambda p, s, x, train=False: lenet.forward(
        p, s, x, binary=True, act_bit=2, train=train)
    losses = _run_steps(fwd, params, state, 30, 16, (1, 28, 28), 10, 0.05)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.9, losses


def test_resnet_partial_binarization_param_counts():
    """More fp stages never decreases binarizable parameter fraction —
    the Table 2 size ordering none < 1st < 2nd < 3rd < 4th < all."""
    def binary_params(fp_stages):
        params, _, _ = resnet.init(KEY, fp_stages=fp_stages, width=16)
        n = 0
        for s in range(1, 5):
            if s in fp_stages:
                continue
            for b in (1, 2):
                blk = params[f"s{s}b{b}"]
                n += blk["conv1"]["w"].size + blk["conv2"]["w"].size
        return n

    sizes = [binary_params(fs) for fs in
             [frozenset(), {1}, {2}, {3}, {4}, {1, 2}, {1, 2, 3, 4}]]
    assert sizes[0] > 0 and sizes[-1] == 0
    # stage s cost grows with s (channel widths double): fp1 keeps most bits
    assert sizes[1] > sizes[2] > sizes[3] > sizes[4]
    assert sizes[5] < sizes[2]
