"""Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

Hypothesis sweeps shapes/values; equality is exact (integer/bit semantics),
not allclose, except where float rounding is inherent (Eq. 1 quantizer).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import binarize as K_bin
from compile.kernels import quantize as K_quant
from compile.kernels import ref
from compile.kernels import xnor_gemm as K_gemm

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


# ---------------------------------------------------------------------------
# binarize / pack
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(m=st.integers(1, 65), k=st.integers(1, 200), seed=st.integers(0, 99))
def test_binarize_matches_ref(m, k, seed):
    x = _rand(np.random.default_rng(seed), m, k)
    np.testing.assert_array_equal(
        np.asarray(K_bin.binarize(x)), np.asarray(ref.sign_binarize(x)))


@settings(**SETTINGS)
@given(m=st.integers(1, 65), words=st.integers(1, 8), seed=st.integers(0, 99))
def test_pack_matches_ref(m, words, seed):
    x = _rand(np.random.default_rng(seed), m, 32 * words)
    np.testing.assert_array_equal(
        np.asarray(K_bin.pack(x)), np.asarray(ref.pack_bits(x)))


def test_pack_rejects_unaligned_k():
    with pytest.raises(ValueError):
        K_bin.pack(jnp.zeros((4, 33)))


@settings(**SETTINGS)
@given(m=st.integers(1, 16), k=st.integers(1, 100), seed=st.integers(0, 99))
def test_pack_unpack_roundtrip(m, k, seed):
    x = _rand(np.random.default_rng(seed), m, k)
    xp = ref.pad_to_words(x, 1.0)
    back = ref.unpack_bits(ref.pack_bits(xp), k)
    np.testing.assert_array_equal(np.asarray(back),
                                  np.asarray(ref.sign_binarize(x)))


def test_binarize_zero_maps_to_plus_one():
    x = jnp.zeros((2, 32))
    assert np.all(np.asarray(K_bin.binarize(x)) == 1.0)
    assert np.asarray(ref.pack_bits(x)).tolist() == [[0xFFFFFFFF]] * 2


def test_pack_lsb_first_bit_order():
    # Only element 0 positive -> word == 1 (LSB-first).
    x = -np.ones((1, 32), np.float32)
    x[0, 0] = 1.0
    assert np.asarray(ref.pack_bits(jnp.asarray(x)))[0, 0] == 1
    x[0, 0], x[0, 31] = -1.0, 1.0
    assert np.asarray(ref.pack_bits(jnp.asarray(x)))[0, 0] == 1 << 31


# ---------------------------------------------------------------------------
# xnor GEMM
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    m=st.integers(1, 70), n=st.integers(1, 70), words=st.integers(1, 6),
    seed=st.integers(0, 99),
)
def test_xnor_gemm_packed_matches_ref(m, n, words, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, 2**32, (m, words), dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, (n, words), dtype=np.uint32))
    got = K_gemm.xnor_gemm_packed(a, b, block_m=32, block_n=32)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.xnor_popcount_gemm(a, b)))


@settings(**SETTINGS)
@given(
    m=st.integers(1, 40), n=st.integers(1, 40), k=st.integers(1, 150),
    seed=st.integers(0, 99),
)
def test_xnor_linear_equals_float_binary_gemm(m, n, k, seed):
    """The paper's core claim (§2.2.2): xnor path == float dot on +/-1."""
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, n, k)
    got = K_gemm.xnor_linear(x, w)
    expect = ref.binary_gemm_reference(x, w.T)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


@settings(**SETTINGS)
@given(m=st.integers(1, 30), k=st.integers(1, 120), seed=st.integers(0, 99))
def test_eq2_range_map_roundtrip(m, k, seed):
    """Eq. 2: dot -> xnor range -> dot is the identity on +/-1 dots."""
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, m, k)
    dot = ref.binary_gemm_reference(x, w.T)
    pop = ref.dot_to_xnor(dot, k)
    back = ref.xnor_to_dot(pop.astype(np.int32), k)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(dot))
    # xnor output range [0, n], step 1 (paper §2.2.2)
    p = np.asarray(pop)
    assert p.min() >= 0 and p.max() <= k
    np.testing.assert_array_equal(p, np.round(p))


def test_xnor_gemm_all_match_and_all_mismatch():
    ones = jnp.asarray(np.full((3, 2), 0xFFFFFFFF, np.uint32))
    zeros = jnp.asarray(np.zeros((3, 2), np.uint32))
    assert np.all(np.asarray(ref.xnor_popcount_gemm(ones, ones)) == 64)
    assert np.all(np.asarray(ref.xnor_popcount_gemm(ones, zeros)) == 0)


@pytest.mark.parametrize("block", [8, 32, 128, 256])
def test_xnor_gemm_block_shape_invariance(block):
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.integers(0, 2**32, (50, 9), dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, (70, 9), dtype=np.uint32))
    got = K_gemm.xnor_gemm_packed(a, b, block_m=block, block_n=block)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.xnor_popcount_gemm(a, b)))


# ---------------------------------------------------------------------------
# quantize (Eq. 1)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(k=st.integers(1, 20), m=st.integers(1, 40), seed=st.integers(0, 99))
def test_quantize_matches_ref_1ulp(k, m, seed):
    """Kernel (interpret-mode numpy) vs ref (XLA eager) may differ by one
    ulp in the final division (`round(x*L)/L`); everything stronger —
    level alphabet, idempotence, monotonicity — is tested exactly below."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random((m, 16)).astype(np.float32))
    a = np.asarray(K_quant.quantize(x, k))
    b = np.asarray(ref.quantize_k(x, k))
    ulp = np.spacing(np.maximum(np.abs(a), np.abs(b)).astype(np.float32))
    np.testing.assert_array_less(np.abs(a - b), 1.5 * ulp + 1e-12)


@settings(**SETTINGS)
@given(k=st.integers(21, 31), seed=st.integers(0, 99))
def test_quantize_matches_ref_high_bits(k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random((8, 16)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(K_quant.quantize(x, k)),
        np.asarray(ref.quantize_k(x, k)), rtol=1e-6, atol=1e-7)


@settings(**SETTINGS)
@given(k=st.integers(1, 8), seed=st.integers(0, 99))
def test_quantize_idempotent(k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random((8, 16)).astype(np.float32))
    q1 = ref.quantize_k(x, k)
    np.testing.assert_allclose(np.asarray(ref.quantize_k(q1, k)),
                               np.asarray(q1), atol=1e-7)


@settings(**SETTINGS)
@given(k=st.integers(1, 8))
def test_quantize_level_count(k):
    """Eq. 1 produces exactly 2^k distinct values on [0, 1]."""
    x = jnp.linspace(0.0, 1.0, 4096, dtype=jnp.float32)[None, :]
    q = np.unique(np.asarray(ref.quantize_k(x, k)))
    assert len(q) == (1 << k)
    assert q[0] == 0.0 and q[-1] == 1.0


def test_quantize_monotone():
    x = jnp.linspace(0.0, 1.0, 1000, dtype=jnp.float32)[None, :]
    q = np.asarray(ref.quantize_k(x, 3))[0]
    assert np.all(np.diff(q) >= 0)


def test_quantize_rejects_bad_k():
    x = jnp.zeros((2, 2))
    for bad in (0, 32, -1):
        with pytest.raises(ValueError):
            ref.quantize_k(x, bad)
        with pytest.raises(ValueError):
            K_quant.quantize(x, bad)


def test_clip_quantize_clips():
    x = jnp.asarray([[-3.0, 0.5, 7.0]])
    q = np.asarray(K_quant.clip_quantize(x, 2))
    assert q[0, 0] == 0.0 and q[0, 2] == 1.0
