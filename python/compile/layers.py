"""L2 building blocks: BMXNet's Q-layers re-expressed in JAX.

The paper's drop-in layers (``QActivation``, ``QConvolution``,
``QFullyConnected``) are reproduced as functional layers over explicit
parameter pytrees.  Training-path semantics follow §2.2.2: compute with
{-1, +1} values through standard dots (XLA fuses these on any backend) with
straight-through estimators (STE) for the sign/round non-differentiabilities;
the Rust inference engine computes the same numbers with xnor+popcount
(Eq. 2 equivalence, tested at every layer).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import ref

Params = dict[str, Any]

BN_EPS = 1e-5
BN_MOMENTUM = 0.9


# ---------------------------------------------------------------------------
# Straight-through estimators
# ---------------------------------------------------------------------------

@jax.custom_vjp
def ste_sign(x: jax.Array) -> jax.Array:
    """sign(x) in {-1, +1} with the clipped straight-through gradient.

    Backward passes the gradient where |x| <= 1 and zeroes it elsewhere
    (Hubara et al. / XNOR-Net; BMXNet inherits this rule from MXNet's
    det_sign).
    """
    return ref.sign_binarize(x)


def _ste_sign_fwd(x):
    return ref.sign_binarize(x), x


def _ste_sign_bwd(x, g):
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


ste_sign.defvjp(_ste_sign_fwd, _ste_sign_bwd)


@jax.custom_vjp
def ste_round(x: jax.Array) -> jax.Array:
    """round(x) with identity gradient (DoReFa quantizer STE)."""
    return jnp.round(x)


ste_round.defvjp(lambda x: (jnp.round(x), None), lambda _, g: (g,))


def qactivation(x: jax.Array, act_bit: int = 1) -> jax.Array:
    """The paper's QActivation: binarize (k=1) or k-bit quantize inputs.

    k = 1: clip to [-1, 1] then STE sign -> {-1, +1}.
    k > 1: clip to [0, 1] then Eq. 1 with an STE round -> 2^k - 1 levels.
    """
    if act_bit == 1:
        return ste_sign(jnp.clip(x, -1.0, 1.0))
    levels = float((1 << act_bit) - 1)
    return ste_round(jnp.clip(x, 0.0, 1.0) * levels) / levels


def quantize_weights(w: jax.Array, act_bit: int) -> jax.Array:
    """Weight binarization/quantization used inside QConv/QFC.

    k = 1: STE sign.  k > 1: DoReFa-style: tanh-normalize to [0, 1],
    Eq. 1-quantize, then rescale to [-1, 1].
    """
    if act_bit == 1:
        return ste_sign(w)
    t = jnp.tanh(w)
    t01 = t / (2.0 * jnp.max(jnp.abs(t))) + 0.5
    levels = float((1 << act_bit) - 1)
    q = ste_round(t01 * levels) / levels
    return 2.0 * q - 1.0


# ---------------------------------------------------------------------------
# Dense / conv layers
# ---------------------------------------------------------------------------

def dense(p: Params, x: jax.Array) -> jax.Array:
    """Full-precision fully connected: x (B, K) @ w (N, K)^T + b."""
    return x @ p["w"].T + p["b"]


def qdense(p: Params, x: jax.Array, act_bit: int = 1) -> jax.Array:
    """QFullyConnected: quantized weights, standard dot, no bias.

    The input is expected to already be quantized by a preceding
    QActivation (the paper's block order QActivation-QFC-BatchNorm).
    """
    wq = quantize_weights(p["w"], act_bit)
    return x @ wq.T


def conv2d(
    p: Params,
    x: jax.Array,
    stride: int = 1,
    padding: str | int = "SAME",
) -> jax.Array:
    """Full-precision NCHW convolution with bias."""
    out = _conv(x, p["w"], stride, padding)
    return out + p["b"][None, :, None, None]


def qconv2d(
    p: Params,
    x: jax.Array,
    stride: int = 1,
    padding: str | int = "SAME",
    act_bit: int = 1,
) -> jax.Array:
    """QConvolution: quantized weights, standard conv, no bias.

    Integer padding pads the (already binarized) input with **+1**, not 0:
    a zero pad is unrepresentable in the xnor domain (sign(0) = +1), and
    padding pre-binarization keeps the float training path and the Rust
    xnor inference path bit-identical (the Eq. 2 contract).
    """
    wq = quantize_weights(p["w"], act_bit)
    if isinstance(padding, int) and padding > 0:
        x = jnp.pad(
            x,
            ((0, 0), (0, 0), (padding, padding), (padding, padding)),
            constant_values=1.0,
        )
        padding = "VALID"
    return _conv(x, wq, stride, padding)


def _conv(x: jax.Array, w: jax.Array, stride: int, padding: str | int):
    if isinstance(padding, int):
        padding = [(padding, padding), (padding, padding)]
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


# ---------------------------------------------------------------------------
# BatchNorm / pooling / misc
# ---------------------------------------------------------------------------

def batchnorm(
    p: Params,
    x: jax.Array,
    state: Params,
    train: bool,
) -> tuple[jax.Array, Params]:
    """BatchNorm over NCHW (axis 1) or NK (axis 1) with EMA running stats.

    Returns (y, new_state); in eval mode state passes through unchanged.
    """
    axes = (0, 2, 3) if x.ndim == 4 else (0,)
    if train:
        mean = jnp.mean(x, axis=axes)
        var = jnp.var(x, axis=axes)
        new_state = {
            "mean": BN_MOMENTUM * state["mean"] + (1 - BN_MOMENTUM) * mean,
            "var": BN_MOMENTUM * state["var"] + (1 - BN_MOMENTUM) * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    shape = (1, -1, 1, 1) if x.ndim == 4 else (1, -1)
    inv = jax.lax.rsqrt(var + BN_EPS).reshape(shape)
    y = (x - mean.reshape(shape)) * inv * p["gamma"].reshape(shape)
    return y + p["beta"].reshape(shape), new_state


def maxpool2d(x: jax.Array, size: int = 2, stride: int | None = None):
    """Max pooling over NCHW spatial dims, VALID padding."""
    stride = stride or size
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 1, size, size),
        window_strides=(1, 1, stride, stride),
        padding="VALID",
    )


def global_avgpool(x: jax.Array) -> jax.Array:
    """NCHW -> NC mean over spatial dims."""
    return jnp.mean(x, axis=(2, 3))


def flatten(x: jax.Array) -> jax.Array:
    return x.reshape(x.shape[0], -1)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def init_dense(key, in_dim: int, out_dim: int, bias: bool = True) -> Params:
    scale = (2.0 / in_dim) ** 0.5
    p = {"w": scale * jax.random.normal(key, (out_dim, in_dim), jnp.float32)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), jnp.float32)
    return p


def init_conv(
    key, in_ch: int, out_ch: int, ksize: int, bias: bool = True
) -> Params:
    fan_in = in_ch * ksize * ksize
    scale = (2.0 / fan_in) ** 0.5
    p = {
        "w": scale
        * jax.random.normal(key, (out_ch, in_ch, ksize, ksize), jnp.float32)
    }
    if bias:
        p["b"] = jnp.zeros((out_ch,), jnp.float32)
    return p


def init_bn(ch: int) -> tuple[Params, Params]:
    params = {"gamma": jnp.ones((ch,), jnp.float32),
              "beta": jnp.zeros((ch,), jnp.float32)}
    state = {"mean": jnp.zeros((ch,), jnp.float32),
             "var": jnp.ones((ch,), jnp.float32)}
    return params, state
