"""ResNet-18 with stage-wise (partial) binarization — paper §3.2 / Table 2.

The MXNet ResNet-18 the paper uses has 4 ResUnit stages of 2 basic blocks.
``fp_stages`` selects which stages stay full precision: Table 2 sweeps
none / {1} / {2} / {3} / {4} / {1,2} / all.  Binary blocks use the paper's
block order (QActivation before each QConv); the stem conv, downsample
1x1 convs and the final FC stay full precision always (paper §3.2 strategy,
downsample convs are <2% of weights and binarizing them breaks the skip
path's scale).

``width`` scales channel counts: 64 is the real ResNet-18 (Table 1/2 model
sizes are computed from this inventory in Rust), 16 is the "mini" variant we
can actually *train* on this 1-core CPU box for the accuracy-trend columns
(substitution documented in DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L

NUM_STAGES = 4
BLOCKS_PER_STAGE = 2


def stage_widths(width: int) -> list[int]:
    return [width * (1 << s) for s in range(NUM_STAGES)]


def init(
    key: jax.Array,
    *,
    fp_stages: frozenset[int] | set[int],
    width: int = 64,
    classes: int = 10,
    in_ch: int = 3,
    act_bit: int = 1,
):
    """Initialize (params, state, meta) for a CIFAR-style ResNet-18."""
    fp_stages = frozenset(fp_stages)
    widths = stage_widths(width)
    keys = iter(jax.random.split(key, 64))
    bn_s, st_s = L.init_bn(widths[0])
    params = {"stem": L.init_conv(next(keys), in_ch, widths[0], 3, bias=False),
              "stem_bn": bn_s}
    state = {"stem_bn": st_s}
    ch = widths[0]
    for s in range(NUM_STAGES):
        out_ch = widths[s]
        binary = (s + 1) not in fp_stages
        for b in range(BLOCKS_PER_STAGE):
            stride = 2 if (s > 0 and b == 0) else 1
            name = f"s{s + 1}b{b + 1}"
            blk, blk_state = _init_block(
                next(keys), ch, out_ch, stride, binary=binary
            )
            params[name] = blk
            state[name] = blk_state
            ch = out_ch
    params["fc"] = L.init_dense(next(keys), ch, classes)
    meta = {
        "arch": "resnet18",
        "width": width,
        "fp_stages": sorted(fp_stages),
        "act_bit": act_bit,
        "classes": classes,
        "in_ch": in_ch,
    }
    return params, state, meta


def _init_block(key, in_ch: int, out_ch: int, stride: int, *, binary: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    bn1, s1 = L.init_bn(out_ch)
    bn2, s2 = L.init_bn(out_ch)
    p = {
        "conv1": L.init_conv(k1, in_ch, out_ch, 3, bias=False),
        "bn1": bn1,
        "conv2": L.init_conv(k2, out_ch, out_ch, 3, bias=False),
        "bn2": bn2,
    }
    s = {"bn1": s1, "bn2": s2}
    if stride != 1 or in_ch != out_ch:
        bnd, sd = L.init_bn(out_ch)
        p["down"] = L.init_conv(k3, in_ch, out_ch, 1, bias=False)
        p["down_bn"] = bnd
        s["down_bn"] = sd
    return p, s


def _block(p, s, x, stride: int, *, binary: bool, act_bit: int, train: bool):
    ns = dict(s)
    if binary:
        h = L.qactivation(x, act_bit)
        h = L.qconv2d(p["conv1"], h, stride=stride, padding=1,
                      act_bit=act_bit)
    else:
        h = L.conv2d({"w": p["conv1"]["w"],
                      "b": jnp.zeros(p["conv1"]["w"].shape[0])},
                     x, stride=stride, padding=1)
    h, ns["bn1"] = L.batchnorm(p["bn1"], h, s["bn1"], train)
    if not binary:
        h = jax.nn.relu(h)

    if binary:
        h = L.qactivation(h, act_bit)
        h = L.qconv2d(p["conv2"], h, padding=1, act_bit=act_bit)
    else:
        h = L.conv2d({"w": p["conv2"]["w"],
                      "b": jnp.zeros(p["conv2"]["w"].shape[0])},
                     h, padding=1)
    h, ns["bn2"] = L.batchnorm(p["bn2"], h, s["bn2"], train)

    if "down" in p:
        skip = L.conv2d({"w": p["down"]["w"],
                         "b": jnp.zeros(p["down"]["w"].shape[0])},
                        x, stride=stride, padding=0)
        skip, ns["down_bn"] = L.batchnorm(p["down_bn"], skip,
                                          s["down_bn"], train)
    else:
        skip = x
    out = h + skip
    if not binary:
        out = jax.nn.relu(out)
    return out, ns


def forward(
    params, state, x: jax.Array, *,
    fp_stages: frozenset[int] | set[int],
    act_bit: int = 1,
    train: bool = False,
):
    """Forward -> (logits, new_state).  x: (B, in_ch, 32, 32)."""
    fp_stages = frozenset(fp_stages)
    ns = dict(state)
    h = L.conv2d({"w": params["stem"]["w"],
                  "b": jnp.zeros(params["stem"]["w"].shape[0])},
                 x, padding=1)
    h, ns["stem_bn"] = L.batchnorm(params["stem_bn"], h,
                                   state["stem_bn"], train)
    h = jax.nn.relu(h)
    for s in range(NUM_STAGES):
        binary = (s + 1) not in fp_stages
        for b in range(BLOCKS_PER_STAGE):
            stride = 2 if (s > 0 and b == 0) else 1
            name = f"s{s + 1}b{b + 1}"
            h, ns[name] = _block(params[name], state[name], h, stride,
                                 binary=binary, act_bit=act_bit, train=train)
    h = L.global_avgpool(h)
    return L.dense(params["fc"], h), ns
