"""Pallas kernel for Eq. 1 k-bit linear quantization (paper §2.1).

Quantizes a real input in [0, 1] to the nearest of 2^k - 1 levels.  The
paper stores quantized values back in f32 and uses standard dot products;
the kernel is elementwise, so the tile schedule is row-blocked like
``binarize``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(x_ref, o_ref, *, levels: float):
    x = x_ref[...]
    o_ref[...] = jnp.round(x * levels) / levels


@functools.partial(jax.jit, static_argnames=("k", "block_rows"))
def quantize(x: jax.Array, k: int, block_rows: int = 128) -> jax.Array:
    """Eq. 1 over a 2D array (M, N); k is the act_bit width in [1, 31]."""
    if not 1 <= k <= 31:
        raise ValueError(f"act_bit k must be in [1, 31], got {k}")
    m, n = x.shape
    block_rows = min(block_rows, m)
    grid = (pl.cdiv(m, block_rows),)
    levels = float((1 << k) - 1)
    return pl.pallas_call(
        functools.partial(_quantize_kernel, levels=levels),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x)


@functools.partial(jax.jit, static_argnames=("k", "block_rows"))
def clip_quantize(x: jax.Array, k: int, block_rows: int = 128) -> jax.Array:
    """Clip to [0, 1] then Eq. 1 — the QActivation forward for k > 1."""
    return quantize(jnp.clip(x, 0.0, 1.0), k, block_rows)
