"""Pallas kernels for sign-binarization and BINARY_WORD bit-packing.

TPU adaptation of BMXNet's input-binarization stage (paper §2.2): instead of
a scalar CPU loop setting bits, each grid step loads a (block_rows, K) tile
into VMEM, computes the sign bits with the VPU, and reduces 32 lanes into a
single uint32 word per output element.  ``interpret=True`` everywhere — the
CPU PJRT plugin cannot execute Mosaic custom-calls (see DESIGN.md
§Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

WORD_BITS = 32


def _binarize_kernel(x_ref, o_ref):
    """o = sign(x) in {-1, +1}, 0 mapping to +1."""
    x = x_ref[...]
    o_ref[...] = jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def binarize(x: jax.Array, block_rows: int = 128) -> jax.Array:
    """Sign-binarize a 2D array (M, K) tile-by-tile.

    Grid over row blocks only: K is kept whole per tile because binarization
    is elementwise (no reduction) and LeNet/ResNet K values (<= 12800 f32 =
    50 KiB/row-block-lane) fit comfortably in VMEM.
    """
    m, k = x.shape
    block_rows = min(block_rows, m)
    grid = (pl.cdiv(m, block_rows),)
    return pl.pallas_call(
        _binarize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), x.dtype),
        interpret=True,
    )(x)


def _pack_kernel(x_ref, o_ref):
    """Pack sign bits of a (bm, K) tile into (bm, K/32) uint32 words."""
    x = x_ref[...]
    bm, k = x.shape
    bits = (x >= 0).astype(jnp.uint32).reshape(bm, k // WORD_BITS, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    o_ref[...] = jnp.sum(bits << shifts, axis=-1).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def pack(x: jax.Array, block_rows: int = 128) -> jax.Array:
    """Binarize + pack a 2D array (M, K), K % 32 == 0, to (M, K/32) uint32.

    One fused VMEM pass: the float tile never round-trips to HBM between
    binarization and packing (the paper binarizes then packs in one loop for
    the same reason).
    """
    m, k = x.shape
    if k % WORD_BITS != 0:
        raise ValueError(f"K={k} not a multiple of {WORD_BITS}; pad first")
    w = k // WORD_BITS
    block_rows = min(block_rows, m)
    grid = (pl.cdiv(m, block_rows),)
    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, w), jnp.uint32),
        interpret=True,
    )(x)
