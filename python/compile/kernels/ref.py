"""Pure-jnp reference oracles for the BMXNet L1 kernels.

These are the CORE correctness signal: every Pallas kernel in this package
is checked against the functions here by ``python/tests``.  The semantics
follow the paper exactly:

* ``sign_binarize`` — the sign function used by BMXNet to binarize weights
  and inputs to {-1, +1} (0 maps to +1, matching ``x >= 0``).
* ``quantize_k`` — Eq. 1: linear quantization of a real in [0, 1] to a k-bit
  representable value in [0, 1].
* ``pack_bits`` / ``unpack_bits`` — BINARY_WORD packing: 32 sign bits per
  uint32 lane (bit 1 == +1, bit 0 == -1), LSB-first within a word.
* ``xnor_popcount_gemm`` — the paper's xnor GEMM: per output element the
  popcount of xnor over packed words; value in [0, K] (step 1).
* ``xnor_to_dot`` / ``dot_to_xnor`` — Eq. 2 range maps between the xnor
  output range [0, n] and the +/-1 dot-product range [-n, n].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

WORD_BITS = 32


def sign_binarize(x: jax.Array) -> jax.Array:
    """Binarize to {-1, +1} with sign(x), mapping 0 -> +1 (paper: x >= 0)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def quantize_k(x: jax.Array, k: int) -> jax.Array:
    """Eq. 1: quantize input in [0, 1] to k-bit resolution, k in [1, 31]."""
    if not 1 <= k <= 31:
        raise ValueError(f"act_bit k must be in [1, 31], got {k}")
    levels = jnp.asarray((1 << k) - 1, x.dtype)
    return jnp.round(levels * x) / levels


def clip_quantize(x: jax.Array, k: int) -> jax.Array:
    """DoReFa-style activation quantization: clip to [0, 1] then Eq. 1."""
    return quantize_k(jnp.clip(x, 0.0, 1.0), k)


def pack_bits(x: jax.Array) -> jax.Array:
    """Pack sign bits of x (..., K) into uint32 words (..., K/32).

    Bit b of word w is 1 iff x[..., 32*w + b] >= 0 (LSB-first). K must be a
    multiple of 32; callers pad (A rows with +1, B rows with -1) so padding
    contributes 0 to xnor popcounts — see ``pad_pair``.
    """
    if x.shape[-1] % WORD_BITS != 0:
        raise ValueError(f"K={x.shape[-1]} not a multiple of {WORD_BITS}")
    bits = (x >= 0).astype(jnp.uint32)
    bits = bits.reshape(*x.shape[:-1], x.shape[-1] // WORD_BITS, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1).astype(jnp.uint32)


def unpack_bits(words: jax.Array, k: int) -> jax.Array:
    """Inverse of pack_bits: (..., K/32) uint32 -> (..., k) float in {-1,+1}."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(*words.shape[:-1], words.shape[-1] * WORD_BITS)
    return jnp.where(flat[..., :k] == 1, 1.0, -1.0).astype(jnp.float32)


def pad_to_words(x: jax.Array, pad_value: float) -> jax.Array:
    """Pad the last axis up to a multiple of 32 with ``pad_value``."""
    k = x.shape[-1]
    rem = (-k) % WORD_BITS
    if rem == 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
    return jnp.pad(x, widths, constant_values=pad_value)


def pad_pair(a: jax.Array, b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Pad A with +1 and B with -1 so padded lanes xnor to 0 (no popcount)."""
    return pad_to_words(a, 1.0), pad_to_words(b, -1.0)


def xnor_popcount_gemm(a_packed: jax.Array, b_packed: jax.Array) -> jax.Array:
    """Paper's xnor GEMM on packed operands.

    a_packed: (M, W) uint32, b_packed: (N, W) uint32 (B stored row-major by
    output column, i.e. already transposed).  Returns (M, N) int32 popcount
    accumulations — the xnor dot in [0, K].
    """
    x = jnp.bitwise_xor(a_packed[:, None, :], b_packed[None, :, :])
    xnor = jnp.bitwise_not(x)
    return jnp.sum(
        jax.lax.population_count(xnor).astype(jnp.int32), axis=-1
    )


def xnor_to_dot(pop: jax.Array, k: int) -> jax.Array:
    """Map xnor popcount in [0, n] back to the +/-1 dot range [-n, n].

    With A padded +1 / B padded -1, padded lanes contribute 0 matches, so
    dot = 2*pop - k exactly (k = the true, unpadded reduction length).
    """
    return (2 * pop - k).astype(jnp.float32)


def dot_to_xnor(dot: jax.Array, n: int) -> jax.Array:
    """Eq. 2: map a +/-1 dot product in [-n, n] to the xnor range [0, n]."""
    return (dot + n) / 2


def binary_gemm_reference(a: jax.Array, b: jax.Array) -> jax.Array:
    """Float reference: sign-binarize both operands, then ordinary matmul.

    a: (M, K), b: (K, N).  This is what BMXNet's GPU training path computes;
    the xnor path must match it exactly (Eq. 2 equivalence).
    """
    return sign_binarize(a) @ sign_binarize(b)


def xnor_linear(x: jax.Array, w: jax.Array) -> jax.Array:
    """End-to-end packed path: binarize+pack x (M,K) and w (N,K), xnor GEMM,
    map back to the dot range.  Must equal ``binary_gemm_reference(x, w.T)``.
    """
    k = x.shape[-1]
    xp, wp = pad_pair(x, w)
    pop = xnor_popcount_gemm(pack_bits(xp), pack_bits(wp))
    return xnor_to_dot(pop, k)
