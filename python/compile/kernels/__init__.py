"""L1: Pallas kernels for BMXNet's compute hot-spots.

* :mod:`.binarize` — sign binarization + BINARY_WORD bit packing
* :mod:`.xnor_gemm` — packed xnor+popcount GEMM (the paper's Listing 3)
* :mod:`.quantize` — Eq. 1 k-bit linear quantization
* :mod:`.ref` — pure-jnp oracles every kernel is tested against
"""

from . import binarize, quantize, ref, xnor_gemm  # noqa: F401
