"""Pallas xnor+popcount GEMM — the paper's compute hot-spot on TPU terms.

BMXNet's CPU kernel (Listing 3) runs xnor+popcnt over 64-bit words with
cache blocking.  The TPU rethink (DESIGN.md §Hardware-Adaptation): the
operands are *packed uint32* matrices, so this is an integer bit-op
workload for the VPU, not an MXU matmul.  We tile the output (bm, bn) and
stream W = K/32 packed words per tile pair through VMEM, accumulating
``popcount(xnor(a, b))`` in int32.  BlockSpec expresses the HBM->VMEM
schedule the paper expressed with cache blocking.

``interpret=True`` is mandatory on this box (CPU PJRT cannot run Mosaic
custom-calls); TPU performance is estimated structurally in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

WORD_BITS = 32


def _xnor_gemm_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) output tile: popcount(xnor) accumulated over all words.

    a_ref: (bm, W) uint32, b_ref: (bn, W) uint32 (B pre-transposed so both
    operands stream row-major, the same trick the paper's packed B uses).
    """
    a = a_ref[...]
    b = b_ref[...]
    xnor = jnp.bitwise_not(jnp.bitwise_xor(a[:, None, :], b[None, :, :]))
    pop = jax.lax.population_count(xnor).astype(jnp.int32)
    o_ref[...] = jnp.sum(pop, axis=-1)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def xnor_gemm_packed(
    a_packed: jax.Array,
    b_packed: jax.Array,
    block_m: int = 128,
    block_n: int = 128,
) -> jax.Array:
    """Packed xnor GEMM: (M, W) x (N, W) uint32 -> (M, N) int32 popcounts.

    Output range [0, 32*W] step 1, exactly the paper's xnor dot.  VMEM per
    grid step = (bm + bn) * W * 4 bytes + bm * bn * 4 bytes; defaults keep
    this < 4 MiB for every shape in the paper's sweeps (W <= 200 at
    C=256, 5x5 kernels).
    """
    m, w = a_packed.shape
    n, wb = b_packed.shape
    if w != wb:
        raise ValueError(f"word-width mismatch: {w} vs {wb}")
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    grid = (pl.cdiv(m, block_m), pl.cdiv(n, block_n))
    return pl.pallas_call(
        _xnor_gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, w), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a_packed, b_packed)


def xnor_linear(x: jax.Array, w: jax.Array) -> jax.Array:
    """Full binary-linear hot path on the Pallas kernels.

    x: (M, K) float activations, w: (N, K) float weights.  Binarize+pack
    both (with the +1/-1 padding trick so K need not divide 32), run the
    packed kernel, and map popcounts back to the +/-1 dot range.  Must
    equal ``ref.binary_gemm_reference(x, w.T)`` exactly — pytest enforces.
    """
    from . import binarize as bz

    k = x.shape[-1]
    xp, wp = ref.pad_pair(x, w)
    pop = xnor_gemm_packed(bz.pack(xp), bz.pack(wp))
    return (2 * pop - k).astype(jnp.float32)
