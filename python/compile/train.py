"""Training graph: loss, SGD-with-momentum, and the AOT train_step factory.

The train_step is a *pure flat-array function* so the Rust coordinator can
drive it through PJRT without any pytree machinery: inputs are the flattened
params, BN state, momentum buffers, a batch (x, y) and a scalar lr; outputs
are the updated flats plus (loss, accuracy).  Flattening order is the
deterministic sorted-key order of :func:`flatten_tree` and is recorded in
the artifact manifest.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

MOMENTUM = 0.9
WEIGHT_DECAY = 0.0  # binary weights are regularized by the clipped STE


def flatten_tree(tree: Any, prefix: str = "") -> list[tuple[str, jax.Array]]:
    """Deterministic (path, leaf) flattening: sorted dict keys, '.'-joined."""
    if isinstance(tree, dict):
        out: list[tuple[str, jax.Array]] = []
        for k in sorted(tree):
            out.extend(flatten_tree(tree[k], f"{prefix}{k}."))
        return out
    return [(prefix[:-1], tree)]


def unflatten_like(tree: Any, flat: list[jax.Array], _i: list[int] | None = None):
    """Inverse of flatten_tree given the original tree structure."""
    _i = _i if _i is not None else [0]
    if isinstance(tree, dict):
        return {k: unflatten_like(tree[k], flat, _i) for k in sorted(tree)}
    v = flat[_i[0]]
    _i[0] += 1
    return v


def cross_entropy(logits: jax.Array, y: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy with integer labels."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


def accuracy(logits: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))


def make_train_step(
    forward: Callable,
    params_tpl: Any,
    state_tpl: Any,
) -> Callable:
    """Build the flat train_step for a model ``forward(params, state, x,
    train=True) -> (logits, new_state)``.

    Returns ``step(p_flat, s_flat, m_flat, x, y, lr) ->
    (new_p_flat, new_s_flat, new_m_flat, loss, acc)`` over flat lists.
    """
    n_p = len(flatten_tree(params_tpl))
    n_s = len(flatten_tree(state_tpl))

    def step(*args):
        p_flat = list(args[:n_p])
        s_flat = list(args[n_p:n_p + n_s])
        m_flat = list(args[n_p + n_s:2 * n_p + n_s])
        x, y, lr = args[2 * n_p + n_s:]
        params = unflatten_like(params_tpl, p_flat)
        state = unflatten_like(state_tpl, s_flat)

        def loss_fn(params):
            logits, new_state = forward(params, state, x, train=True)
            return cross_entropy(logits, y), (logits, new_state)

        (loss, (logits, new_state)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params)
        acc = accuracy(logits, y)

        g_flat = [g for _, g in flatten_tree(grads)]
        new_m = [MOMENTUM * m + g for m, g in zip(m_flat, g_flat)]
        new_p = [p - lr * m for p, m in zip(p_flat, new_m)]
        new_s = [s for _, s in flatten_tree(new_state)]
        return (*new_p, *new_s, *new_m, loss, acc)

    return step


def make_infer(forward: Callable, params_tpl: Any, state_tpl: Any) -> Callable:
    """Flat inference fn: (p_flat..., s_flat..., x) -> (logits,)."""
    n_p = len(flatten_tree(params_tpl))
    n_s = len(flatten_tree(state_tpl))

    def infer(*args):
        p_flat = list(args[:n_p])
        s_flat = list(args[n_p:n_p + n_s])
        (x,) = args[n_p + n_s:]
        params = unflatten_like(params_tpl, p_flat)
        state = unflatten_like(state_tpl, s_flat)
        logits, _ = forward(params, state, x, train=False)
        return (logits,)

    return infer
