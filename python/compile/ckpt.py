"""BMXC checkpoint format — the f32 interchange between python and rust.

Layout (little-endian):

    magic   b"BMXC"
    u32     version (1)
    u32     tensor count
    per tensor:
        u16     name length, then UTF-8 name bytes
        u8      dtype code (0 = f32, 1 = u32)
        u8      ndim
        u32*n   dims
        bytes   raw data, row-major LE

The Rust side (rust/src/model/ckpt.rs) reads and writes the same layout;
``tests/test_ckpt.py`` and the cargo integration tests round-trip files in
both directions.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"BMXC"
VERSION = 1
_DTYPES = {0: np.float32, 1: np.uint32}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.uint32): 1}


def save(path: str, tensors: list[tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            code = _CODES[arr.dtype]
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def load(path: str) -> list[tuple[str, np.ndarray]]:
    with open(path, "rb") as f:
        data = f.read()
    if data[:4] != MAGIC:
        raise ValueError(f"{path}: bad magic {data[:4]!r}")
    version, count = struct.unpack_from("<II", data, 4)
    if version != VERSION:
        raise ValueError(f"{path}: unsupported version {version}")
    off = 12
    out = []
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", data, off)
        off += 2
        name = data[off:off + nlen].decode("utf-8")
        off += nlen
        code, ndim = struct.unpack_from("<BB", data, off)
        off += 2
        dims = struct.unpack_from(f"<{ndim}I", data, off)
        off += 4 * ndim
        dtype = np.dtype(_DTYPES[code])
        n = int(np.prod(dims)) if ndim else 1
        arr = np.frombuffer(
            data, dtype=dtype, count=n, offset=off
        ).reshape(dims)
        off += n * dtype.itemsize
        out.append((name, arr.copy()))
    return out
