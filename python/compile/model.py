"""L2 facade: model zoo + the Pallas-backed inference variants.

``aot.py`` builds every artifact through the functions here.  The training
graphs use the STE formulation from :mod:`.layers`; ``lenet_forward_pallas``
is the composition proof — the binary layers of LeNet run through the L1
Pallas kernels (im2col + packed xnor GEMM) inside one lowered HLO module,
and must produce bit-identical logits to the plain forward (pytest
``test_model.py::test_pallas_forward_matches``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import lenet, resnet, train
from .kernels import xnor_gemm

__all__ = [
    "lenet", "resnet", "train", "L",
    "lenet_forward_pallas", "xnor_conv2d_pallas",
]


def xnor_conv2d_pallas(
    p, x: jax.Array, stride: int = 1, padding: str | int = "VALID"
) -> jax.Array:
    """Binary convolution on the L1 packed-xnor path.

    im2col (lax patches, feature order C*fh*fw matching an OIHW reshape)
    followed by the Pallas xnor GEMM.  Inputs are expected binarized
    (post-QActivation); weights are sign-binarized inside xnor_linear's
    packing, so this equals qconv2d(...) exactly on {-1,+1} inputs.
    """
    w = p["w"]
    o, _, fh, fw = w.shape
    if isinstance(padding, int):
        if padding > 0:
            # +1 padding, matching layers.qconv2d (xnor-representable)
            x = jnp.pad(
                x,
                ((0, 0), (0, 0), (padding, padding), (padding, padding)),
                constant_values=1.0,
            )
        padding = "VALID"
    patches = jax.lax.conv_general_dilated_patches(
        x, (fh, fw), (stride, stride), padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    b, f, ho, wo = patches.shape
    cols = patches.transpose(0, 2, 3, 1).reshape(-1, f)
    out = xnor_gemm.xnor_linear(cols, w.reshape(o, -1))
    return out.reshape(b, ho, wo, o).transpose(0, 3, 1, 2)


def lenet_forward_pallas(params, state, x, *, act_bit: int = 1,
                         train: bool = False):
    """Binary LeNet forward with QConv2/QFC1 on the Pallas xnor kernels."""
    del train  # inference only: Pallas path has no STE; BN uses run stats
    ns = dict(state)
    h = L.conv2d(params["conv1"], x, padding="VALID")
    h = jnp.tanh(h)
    h = L.maxpool2d(h)
    h, _ = L.batchnorm(params["bn1"], h, state["bn1"], False)

    h = L.qactivation(h, act_bit)
    h = xnor_conv2d_pallas(params["conv2"], h, padding="VALID")
    h, _ = L.batchnorm(params["bn2"], h, state["bn2"], False)
    h = L.maxpool2d(h)

    h = L.flatten(h)
    h = L.qactivation(h, act_bit)
    h = xnor_gemm.xnor_linear(h, params["fc1"]["w"])
    h, _ = L.batchnorm(params["bn3"], h, state["bn3"], False)
    h = jnp.tanh(h)
    return L.dense(params["fc2"], h), ns
