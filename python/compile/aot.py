"""AOT artifact emitter: lower every L2 graph to HLO *text* + manifest.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which the xla crate's xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example/README).

Outputs per model:
  * ``<name>_train_b<B>.hlo.txt``   flat train_step (params/state/momentum
                                    flats + x + y + lr -> updated flats +
                                    loss + acc)
  * ``<name>_infer_b<B>.hlo.txt``   flat inference (flats + x -> logits)
  * ``<name>_init.bmxc``            initial params+state checkpoint
plus standalone L1 kernel artifacts and ``manifest.json`` describing every
input/output so the Rust coordinator is fully self-describing.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import ckpt, lenet, model, resnet
from . import train as T
from .kernels import binarize as K_bin
from .kernels import quantize as K_quant
from .kernels import xnor_gemm as K_gemm

SEED = 42

# Table 2 partial-binarization configs: fp stage sets, in paper row order.
TABLE2_CONFIGS: list[tuple[str, frozenset[int]]] = [
    ("none", frozenset()),
    ("fp1", frozenset({1})),
    ("fp2", frozenset({2})),
    ("fp3", frozenset({3})),
    ("fp4", frozenset({4})),
    ("fp12", frozenset({1, 2})),
    ("all", frozenset({1, 2, 3, 4})),
]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _specs(flats):
    return [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in flats]


def _shape_entry(pairs):
    return [[name, [int(d) for d in arr.shape]] for name, arr in pairs]


class Emitter:
    def __init__(self, out_dir: str):
        self.out = out_dir
        os.makedirs(out_dir, exist_ok=True)
        self.manifest = {"version": 1, "models": {}, "kernels": {}}

    def _write(self, name: str, text: str) -> str:
        path = os.path.join(self.out, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {name} ({len(text) / 1e6:.2f} MB)")
        return name

    def emit_model(
        self,
        name: str,
        forward,
        params,
        state,
        meta: dict,
        *,
        input_shape: tuple[int, ...],
        train_batch: int,
        infer_batches: list[int],
    ) -> None:
        print(f"[model {name}]")
        p_pairs = T.flatten_tree(params)
        s_pairs = T.flatten_tree(state)
        p_flat = [a for _, a in p_pairs]
        s_flat = [a for _, a in s_pairs]
        m_flat = [jnp.zeros_like(a) for a in p_flat]

        entry = dict(meta)
        entry["params"] = _shape_entry(p_pairs)
        entry["state"] = _shape_entry(s_pairs)
        entry["input_shape"] = list(input_shape)

        # Initial checkpoint (params then state, prefixed).
        ckpt_name = f"{name}_init.bmxc"
        ckpt.save(
            os.path.join(self.out, ckpt_name),
            [(f"params.{n}", np.asarray(a)) for n, a in p_pairs]
            + [(f"state.{n}", np.asarray(a)) for n, a in s_pairs],
        )
        entry["init_ckpt"] = ckpt_name

        # Train step.
        step = T.make_train_step(forward, params, state)
        x_spec = jax.ShapeDtypeStruct((train_batch, *input_shape), jnp.float32)
        y_spec = jax.ShapeDtypeStruct((train_batch,), jnp.int32)
        lr_spec = jax.ShapeDtypeStruct((), jnp.float32)
        lowered = jax.jit(step).lower(
            *_specs(p_flat), *_specs(s_flat), *_specs(m_flat),
            x_spec, y_spec, lr_spec,
        )
        entry["train"] = {
            "file": self._write(f"{name}_train_b{train_batch}.hlo.txt",
                                to_hlo_text(lowered)),
            "batch": train_batch,
        }

        # Inference graphs.
        infer = T.make_infer(forward, params, state)
        entry["infer"] = []
        for b in infer_batches:
            xb = jax.ShapeDtypeStruct((b, *input_shape), jnp.float32)
            lowered = jax.jit(infer).lower(
                *_specs(p_flat), *_specs(s_flat), xb
            )
            entry["infer"].append({
                "file": self._write(f"{name}_infer_b{b}.hlo.txt",
                                    to_hlo_text(lowered)),
                "batch": b,
            })
        self.manifest["models"][name] = entry

    def emit_pallas_infer(self, name: str, base_model: str, params, state,
                          input_shape, batch: int) -> None:
        """Binary-LeNet inference with the L1 Pallas kernels inlined."""
        print(f"[pallas-infer {name}]")
        infer = T.make_infer(
            lambda p, s, x, train=False: model.lenet_forward_pallas(
                p, s, x, train=train
            ),
            params, state,
        )
        p_flat = [a for _, a in T.flatten_tree(params)]
        s_flat = [a for _, a in T.flatten_tree(state)]
        xb = jax.ShapeDtypeStruct((batch, *input_shape), jnp.float32)
        lowered = jax.jit(infer).lower(*_specs(p_flat), *_specs(s_flat), xb)
        self.manifest["models"][base_model]["infer_pallas"] = {
            "file": self._write(f"{name}_b{batch}.hlo.txt",
                                to_hlo_text(lowered)),
            "batch": batch,
        }

    def emit_kernels(self) -> None:
        """Standalone L1 kernel artifacts for the Rust integration tests."""
        print("[kernels]")
        m, n, k = 64, 128, 800  # K = 5*5*32 words -> W = 25
        w = k // 32
        gem = jax.jit(functools.partial(
            K_gemm.xnor_gemm_packed, block_m=64, block_n=64))
        lowered = gem.lower(
            jax.ShapeDtypeStruct((m, w), jnp.uint32),
            jax.ShapeDtypeStruct((n, w), jnp.uint32),
        )
        self.manifest["kernels"]["xnor_gemm"] = {
            "file": self._write("kernel_xnor_gemm.hlo.txt",
                                to_hlo_text(lowered)),
            "m": m, "n": n, "words": w,
        }
        lowered = jax.jit(K_bin.pack).lower(
            jax.ShapeDtypeStruct((m, k), jnp.float32))
        self.manifest["kernels"]["pack"] = {
            "file": self._write("kernel_pack.hlo.txt", to_hlo_text(lowered)),
            "m": m, "k": k,
        }
        lowered = jax.jit(K_bin.binarize).lower(
            jax.ShapeDtypeStruct((m, k), jnp.float32))
        self.manifest["kernels"]["binarize"] = {
            "file": self._write("kernel_binarize.hlo.txt",
                                to_hlo_text(lowered)),
            "m": m, "k": k,
        }
        lowered = jax.jit(
            functools.partial(K_quant.clip_quantize, k=4)
        ).lower(jax.ShapeDtypeStruct((m, 64), jnp.float32))
        self.manifest["kernels"]["quantize_k4"] = {
            "file": self._write("kernel_quantize_k4.hlo.txt",
                                to_hlo_text(lowered)),
            "m": m, "n": 64, "bits": 4,
        }

    def finish(self) -> None:
        with open(os.path.join(self.out, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"manifest.json: {len(self.manifest['models'])} models, "
              f"{len(self.manifest['kernels'])} kernels")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-resnet", action="store_true",
                    help="emit only LeNet + kernels (fast debug)")
    args = ap.parse_args()
    em = Emitter(args.out)
    key = jax.random.PRNGKey(SEED)

    # --- LeNet, binary and full precision (Table 1 row 1) ---------------
    for binary, mname in [(True, "lenet_bin"), (False, "lenet_fp")]:
        params, state, meta = lenet.init(key, binary=binary)
        fwd = functools.partial(lenet.forward, binary=binary, act_bit=1)
        em.emit_model(
            mname,
            lambda p, s, x, train=False, _f=fwd: _f(p, s, x, train=train),
            params, state, meta,
            input_shape=(1, 28, 28),
            train_batch=64,
            infer_batches=[1, 8, 32] if binary else [32],
        )
        if binary:
            em.emit_pallas_infer("lenet_bin_infer_pallas", mname,
                                 params, state, (1, 28, 28), batch=8)

    # --- k-bit quantized LeNets (paper §2.1: act_bit in [2, 31]) --------
    for act_bit in (2, 4):
        params, state, meta = lenet.init(key, binary=True, act_bit=act_bit)
        fwd = functools.partial(lenet.forward, binary=True, act_bit=act_bit)
        em.emit_model(
            f"lenet_q{act_bit}",
            lambda p, s, x, train=False, _f=fwd: _f(p, s, x, train=train),
            params, state, meta,
            input_shape=(1, 28, 28),
            train_batch=64,
            infer_batches=[32],
        )

    if not args.skip_resnet:
        # --- ResNet mini on synth-CIFAR (Table 1 row 2 accuracy trend) --
        for fp_stages, mname in [
            (frozenset(), "resnet_mini_bin"),
            (frozenset({1, 2, 3, 4}), "resnet_mini_fp"),
        ]:
            params, state, meta = resnet.init(
                key, fp_stages=fp_stages, width=16, classes=10)
            fwd = functools.partial(
                resnet.forward, fp_stages=fp_stages, act_bit=1)
            em.emit_model(
                mname,
                lambda p, s, x, train=False, _f=fwd: _f(p, s, x, train=train),
                params, state, meta,
                input_shape=(3, 32, 32),
                train_batch=32,
                infer_batches=[64],
            )

        # --- ResNet mini, 100-class synth-ImageNet, Table 2 sweep -------
        for cfg_name, fp_stages in TABLE2_CONFIGS:
            params, state, meta = resnet.init(
                key, fp_stages=fp_stages, width=16, classes=100)
            fwd = functools.partial(
                resnet.forward, fp_stages=fp_stages, act_bit=1)
            em.emit_model(
                f"resnet_mini_img_{cfg_name}",
                lambda p, s, x, train=False, _f=fwd: _f(p, s, x, train=train),
                params, state, meta,
                input_shape=(3, 32, 32),
                train_batch=32,
                infer_batches=[64],
            )

    em.emit_kernels()
    em.finish()


if __name__ == "__main__":
    main()
