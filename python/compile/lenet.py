"""LeNet and Binary LeNet — the paper's Listing 1 / Listing 2 pair.

Binary block order follows §2: *QActivation - QConv/QFC - BatchNorm - Pool*,
with the first conv and last FC kept full precision (binarizing them
"greatly decreases accuracy", confirmed from [14]).

Architectures (28x28x1 input, 10 classes):

  fp     : conv1(32,5x5) tanh pool bn | conv2(64,5x5) bn tanh pool |
           flatten fc1(512) bn tanh | fc2(10)
  binary : conv1(32,5x5) tanh pool bn | QAct QConv2(64,5x5) bn pool |
           flatten QAct QFC1(512) bn tanh | fc2(10)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L


def init(key: jax.Array, binary: bool, act_bit: int = 1):
    """Initialize (params, state) pytrees; identical layout for fp/binary."""
    ks = jax.random.split(key, 4)
    bn1, s1 = L.init_bn(32)
    bn2, s2 = L.init_bn(64)
    bn3, s3 = L.init_bn(512)
    params = {
        "conv1": L.init_conv(ks[0], 1, 32, 5),
        "bn1": bn1,
        "conv2": L.init_conv(ks[1], 32, 64, 5, bias=not binary),
        "bn2": bn2,
        "fc1": L.init_dense(ks[2], 64 * 4 * 4, 512, bias=not binary),
        "bn3": bn3,
        "fc2": L.init_dense(ks[3], 512, 10),
    }
    state = {"bn1": s1, "bn2": s2, "bn3": s3}
    meta = {"arch": "lenet", "binary": binary, "act_bit": act_bit,
            "input": [1, 28, 28], "classes": 10}
    return params, state, meta


def forward(
    params, state, x: jax.Array, *, binary: bool, act_bit: int = 1,
    train: bool = False,
):
    """Forward pass -> (logits, new_state).  x: (B, 1, 28, 28)."""
    ns = dict(state)
    # First conv stays full precision (paper §2).
    h = L.conv2d(params["conv1"], x, padding="VALID")      # (B,32,24,24)
    h = jnp.tanh(h)
    h = L.maxpool2d(h)                                     # (B,32,12,12)
    h, ns["bn1"] = L.batchnorm(params["bn1"], h, state["bn1"], train)

    if binary:
        h = L.qactivation(h, act_bit)
        h = L.qconv2d(params["conv2"], h, padding="VALID", act_bit=act_bit)
    else:
        h = L.conv2d(params["conv2"], h, padding="VALID")  # (B,64,8,8)
    h, ns["bn2"] = L.batchnorm(params["bn2"], h, state["bn2"], train)
    if not binary:
        h = jnp.tanh(h)
    h = L.maxpool2d(h)                                     # (B,64,4,4)

    h = L.flatten(h)
    if binary:
        h = L.qactivation(h, act_bit)
        h = L.qdense(params["fc1"], h, act_bit)
    else:
        h = L.dense(params["fc1"], h)
    h, ns["bn3"] = L.batchnorm(params["bn3"], h, state["bn3"], train)
    h = jnp.tanh(h)

    logits = L.dense(params["fc2"], h)  # last FC full precision
    return logits, ns
